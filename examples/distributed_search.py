"""Distributed memory pool: the d-HNSW store sharded across devices.

    PYTHONPATH=src python examples/distributed_search.py

Uses 8 fake host devices (set BEFORE jax import) to stand in for the
pod: the serialized block region shards over the `model` axis (each
device = one memory instance), the meta-HNSW + metadata replicate into
every "compute instance", and a doorbell batch becomes ONE collective
launch.  Also demos straggler rebalancing and elastic rescale planning.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import build_meta, build_store  # noqa: E402
from repro.core.distributed import ShardedStore  # noqa: E402
from repro.data.synthetic import sift_like  # noqa: E402
from repro.pool.placement import (plan_store_migration,  # noqa: E402
                                  rebalance_partitions)


def main():
    print(f"devices: {len(jax.devices())}")
    ds = sift_like(n=8000, n_queries=16, seed=0)
    meta = build_meta(ds.data, 32, seed=0)
    store = build_store(ds.data, meta)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ss = ShardedStore(store, mesh)
    print(f"store: {store.spec.n_blocks} blocks sharded over "
          f"{ss.tp} memory instances ({ss.per_shard} blocks each)")

    # one doorbell batch: fetch partitions 3, 10, 17 in ONE collective
    pids = [3, 10, 17]
    ids = np.concatenate([store.span_block_ids(p) for p in pids])
    g, v = ss.fetch(ids)
    ok = np.array_equal(np.asarray(g), store.graph_buf[ids])
    print(f"doorbell fetch of partitions {pids}: one collective launch, "
          f"{ids.size} blocks, correct={ok}")

    owners = ss.partition_owners(store)
    print(f"partition->owner map (first 12): {owners[:12].tolist()}")

    # memory instance 2 goes slow: migrate its partitions
    new_owners, moves = rebalance_partitions(owners, sick={2}, n_owners=4)
    print(f"straggler rebalance off owner 2: {len(moves)} group moves "
          f"(each a contiguous span copy)")

    # elastic rescale 4 -> 6 owners
    plan = plan_store_migration(store.spec.n_blocks, old_tp=4, new_tp=6)
    moved = sum(n for _, _, _, n in plan)
    print(f"elastic 4->6 owners: {len(plan)} contiguous moves, "
          f"{moved}/{store.spec.n_blocks} blocks relocate "
          f"({moved * store.spec.block_bytes() / 1e6:.1f} MB)")


if __name__ == "__main__":
    main()
