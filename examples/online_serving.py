"""Online serving demo: concurrent clients through the micro-batcher.

    PYTHONPATH=src python examples/online_serving.py [--clients 8]

Builds a d-HNSW engine over synthetic SIFT-like vectors, stands up a
``SearchServer`` (micro-batching front-end), and fires closed-loop
client threads at it.  Concurrent requests coalesce into fused engine
batches — the paper's §3.3 batched query-aware loading assembled across
requesters — and the demo prints the resulting throughput, latency
percentiles, and stage breakdown, next to the same offered load served
one request at a time.

``--pool remote`` serves through REAL memory-node processes: pass
``--endpoints host:port,host:port`` to use running ``repro.net.server``
instances, or pass nothing and the demo forks ``--shards`` loopback
servers itself.  The summary then includes a per-endpoint verb/byte
table with the *measured* wire traffic next to the modeled ledger.

``--replication 2`` (sharded/remote pools) keeps every group on two
distinct memory nodes: reads are served from the best live replica and
the fleet survives a node death mid-traffic (see docs/operations.md
for the failure semantics and the snapshot fields this demo prints).

``--trace FILE`` records the whole demo through ``repro.obs`` (serve /
compute / pool / net spans; with ``--pool remote`` also the harvested
server-side service times), writes Chrome-trace JSON to FILE, and
prints the per-stage breakdown report at the end — see
docs/observability.md.

``--slo "p99<5ms"`` attaches a latency SLO to the serving tier
(``repro.obs.slo``): the batched run then scores every request against
it and the summary ends with the SLO attainment / burn-rate table and
the straggler detector's verdicts over the pool's per-(verb, shard)
latency histograms — see docs/observability.md.
"""
import argparse
import contextlib
import threading
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.data.synthetic import sift_like
from repro.serve.batcher import BatchPolicy
from repro.serve.server import SearchServer


def closed_loop(n_clients, per_client, queries, call):
    lat = []
    lock = threading.Lock()

    def client(cid):
        rng = np.random.default_rng(cid)
        mine = []
        for _ in range(per_client):
            q = queries[rng.integers(0, len(queries))]
            t0 = time.perf_counter()
            call(q)
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    arr = np.asarray(lat) * 1e3
    return (len(lat) / wall, float(np.percentile(arr, 50)),
            float(np.percentile(arr, 95)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=20,
                    help="requests per client")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--quant", action="store_true",
                    help="serve through the int8 quantized tier "
                         "(staged search; watch net.bytes_saved)")
    ap.add_argument("--pool", default="local",
                    choices=("local", "sim_rdma", "sharded", "remote"),
                    help="memory-pool transport; 'sharded' splits the "
                         "region across --shards memory nodes; 'remote' "
                         "serves through TCP pool-server processes")
    ap.add_argument("--shards", type=int, default=2,
                    help="memory nodes under --pool sharded / remote")
    ap.add_argument("--placement", default="round_robin",
                    choices=("round_robin", "size_balanced", "freq"),
                    help="group placement policy under --pool sharded")
    ap.add_argument("--replication", type=int, default=1,
                    help="replicas of every group across distinct "
                         "memory nodes (sharded/remote pools; >= 2 "
                         "survives a node death with transparent "
                         "failover, see docs/operations.md)")
    ap.add_argument("--endpoints", default="",
                    help="comma-separated host:port pool servers for "
                         "--pool remote (empty = fork --shards loopback "
                         "servers)")
    ap.add_argument("--trace", default="", metavar="FILE",
                    help="record spans with repro.obs, write "
                         "Chrome-trace JSON to FILE, and print the "
                         "stage breakdown report")
    ap.add_argument("--slo", default="", metavar="SPEC",
                    help='latency SLO like "p99<5ms" (units us/ms/s) '
                         "scored per request by the micro-batcher; the "
                         "summary ends with the attainment/burn-rate "
                         "table and straggler verdicts")
    args = ap.parse_args()

    if args.trace:
        from repro.obs.trace import TRACER
        TRACER.configure()

    endpoints = tuple(e for e in args.endpoints.split(",") if e) or None
    with contextlib.ExitStack() as stack:
        if args.pool == "remote" and endpoints is None:
            from repro.net import spawn_pool_servers
            print(f"forking {args.shards} loopback pool servers...")
            endpoints = tuple(stack.enter_context(
                spawn_pool_servers(args.shards)))
            print("  endpoints:", ", ".join(endpoints))

        print(f"indexing {args.n} vectors...")
        ds = sift_like(n=args.n, n_queries=64, seed=0)
        eng = DHNSWEngine(EngineConfig(mode="full", search_mode="scan", b=3,
                                       ef=32, n_rep=64, cache_frac=0.15,
                                       doorbell=16,
                                       quant="int8" if args.quant else "none",
                                       pool=args.pool, n_shards=args.shards,
                                       placement=args.placement,
                                       endpoints=endpoints,
                                       replication=args.replication)
                          ).build(ds.data)
        run_demo(args, ds, eng)


def print_slo_table(slo_report, straggler_report, straggler_stats):
    """SLO attainment / burn-rate table + straggler verdicts at exit."""
    print("\n  SLO attainment (burn = violation rate / error budget; "
          "short+long window min):")
    print(f"    {'tier':>6s} {'key':>6s} {'objective':>12s} {'n':>6s} "
          f"{'attain':>8s} {'burn':>6s} {'met':>4s}")
    for tier in sorted(slo_report):
        for key, r in sorted(slo_report[tier].items()):
            print(f"    {tier:>6s} {key:>6s} {r['slo']:>12s} {r['n']:>6d} "
                  f"{100 * r['attainment']:7.2f}% {r['burn']:6.2f} "
                  f"{'yes' if r['met'] else 'NO':>4s}")
    if straggler_report is None:
        return
    flagged = straggler_report.get("flagged", {})
    if not flagged:
        print(f"    stragglers: none flagged "
              f"({straggler_stats.get('checks', 0)} detector checks)")
        return
    for shard, info in sorted(flagged.items()):
        print(f"    STRAGGLER shard {shard}: {info['verb']} tail "
              f"{info['shard_q_s'] * 1e6:.1f} us vs fleet "
              f"{info['fleet_q_s'] * 1e6:.1f} us (x{info['ratio']:.1f}, "
              f"+{info['excess_s'] * 1e6:.1f} us penalty on reads)")


def print_endpoint_table(pool_snap):
    """Per-endpoint verb/byte table for remote transports: the measured
    wire traffic of each pool-server process."""
    shards = (pool_snap.get("shards", [])
              if pool_snap.get("kind") == "sharded" else [pool_snap])
    remote = [s for s in shards if s.get("kind") == "remote"]
    if not remote:
        return
    print(f"\n  remote endpoints (measured wire traffic):")
    print(f"    {'endpoint':>21s} {'frames':>7s} {'MB->srv':>8s} "
          f"{'MB<-srv':>8s} {'span rds':>8s} {'row rds':>8s} "
          f"{'appends':>7s} {'wire==model':>11s}")
    for s in remote:
        w, verbs = s["wire"], s["verbs"]
        spans = sum(v for k, v in verbs.items()
                    if k.startswith("read_spans"))
        rows = verbs.get("read_rows", 0) + verbs.get("read_quant_rows", 0)
        wvm = s.get("wire_vs_model", {})
        span_ok = all(
            v["measured"] == v["modeled"]
            for k, v in wvm.items() if k.startswith("read_spans")) \
            if wvm else True
        print(f"    {s['endpoint']:>21s} {w['frames_tx']:7d} "
              f"{w['bytes_tx'] / 1e6:8.2f} {w['bytes_rx'] / 1e6:8.2f} "
              f"{spans:8d} {rows:8d} {verbs.get('append', 0):7d} "
              f"{'yes' if span_ok else 'NO':>11s}")


def run_demo(args, ds, eng):
    # warm the pow2 batch shapes the batcher will produce
    b = 1
    while b <= 2 * args.clients:
        eng.search(ds.queries[:min(b, len(ds.queries))], k=10)
        b *= 2

    lock = threading.Lock()

    def serial_call(q):
        with lock:
            eng.search(q[None], k=10)

    warm = max(4, args.requests // 2)
    print(f"\n{args.clients} clients x {args.requests} requests, "
          f"one request per engine call (no batching):")
    closed_loop(args.clients, warm, ds.queries, serial_call)
    qps, p50, p95 = closed_loop(args.clients, args.requests, ds.queries,
                                serial_call)
    print(f"  {qps:8.1f} qps   p50 {p50:7.1f} ms   p95 {p95:7.1f} ms")

    print(f"\nsame load through the micro-batcher:")
    with SearchServer(eng, BatchPolicy(max_batch=64, max_wait_s=4e-3,
                                       slo=args.slo or None)) as srv:
        # warm the fused-shape jit caches like a long-running server
        closed_loop(args.clients, 2 * warm, ds.queries,
                    lambda q: srv.search(q, k=10))
        qps_b, p50_b, p95_b = closed_loop(args.clients, args.requests,
                                          ds.queries,
                                          lambda q: srv.search(q, k=10))
        snap = srv.stats()
        if args.trace:
            n_spans = srv.dump_trace(args.trace)
    print(f"  {qps_b:8.1f} qps   p50 {p50_b:7.1f} ms   p95 {p95_b:7.1f} ms")
    print(f"\n  speedup x{qps_b / qps:.2f}   mean fused batch "
          f"{snap['mean_fused_batch']:.1f}  over {snap['n_fused_calls']} "
          f"engine calls")
    bd = snap["breakdown_s"]
    total = sum(bd.values()) or 1.0
    print("  stage breakdown (share of request-seconds): " + "  ".join(
        f"{key[:-2]} {100 * v / total:.0f}%" for key, v in bd.items()))
    net = snap["net"]
    print(f"  network: {net['bytes_fetched'] / 1e6:.2f} MB fetched over "
          f"{net['round_trips']:.0f} round trips"
          + (f", {net['bytes_saved'] / 1e6:.2f} MB saved by the int8 tier"
             if net["bytes_saved"] else ""))
    if "wire_frames" in net:
        print(f"  wire (measured): {net['wire_bytes_rx'] / 1e6:.2f} MB "
              f"from servers / {net['wire_bytes_tx'] / 1e6:.2f} MB to "
              f"servers over {net['wire_frames']} frames")
    pool = snap.get("pool")
    if pool:
        print_endpoint_table(pool)
    if pool and pool.get("kind") == "sharded":
        print(f"\n  sharded pool: {pool['n_shards']} memory nodes, "
              f"placement={pool['placement']}, "
              f"replication={pool.get('replication', 1)}, "
              f"{pool['migration']['n']} migrations")
        fo = pool.get("failover", {})
        if fo.get("deaths") or fo.get("lost_groups"):
            print(f"    failover: {fo['deaths']} deaths, "
                  f"{fo['read_retries']} read retries, "
                  f"{fo['rereplicated_groups']} groups re-replicated, "
                  f"{fo['lost_groups']} lost")
        for i, sh in enumerate(pool["shards"]):
            tot = sh["totals"]
            verbs = sum(v for k, v in sh["verbs"].items()
                        if k.startswith(("read_spans", "append")))
            print(f"    shard {i}: {pool['groups_by_shard'][i]:3d} groups"
                  f"  {tot['bytes'] / 1e6:8.2f} MB"
                  f"  {tot['round_trips']:6.0f} trips"
                  f"  {verbs:5.0f} span/append verbs")

    if args.slo and snap.get("slo"):
        strag = strag_stats = None
        if hasattr(eng.pool, "check_stragglers"):
            strag = eng.pool.check_stragglers()
            strag_stats = eng.pool.straggler_stats
        print_slo_table(snap["slo"], strag, strag_stats)

    if args.trace:
        from repro.obs import report
        from repro.obs.trace import TRACER
        print(f"\n  wrote {args.trace} ({n_spans} spans) — open in "
              f"https://ui.perfetto.dev or chrome://tracing")
        print()
        print(report.render(TRACER.snapshot(), top=12))
        TRACER.disable()


if __name__ == "__main__":
    main()
