"""End-to-end training driver: ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ...]

Builds a ~100M-parameter variant of an assigned architecture, streams
synthetic token batches, runs the full train loop (AdamW + cosine +
clipping, remat, atomic checkpoints, restart-safe), and prints losses.
"""
import argparse

from repro.configs.base import InputShape
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.synthetic import token_stream
from repro.train.trainer import fit


def hundred_m_config(arch: str):
    """Scale the assigned config down to ~100M params (CPU-trainable)."""
    cfg = get_config(arch)
    kw = dict(n_layers=8, d_model=512, vocab_size=32_000)
    if cfg.n_heads:
        kw.update(n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
                  head_dim=64)
    if cfg.d_ff:
        kw.update(d_ff=2048)
    if cfg.family == "moe":
        kw.update(n_experts=8, moe_top_k=min(cfg.moe_top_k, 2),
                  expert_d_ff=512)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=64, ssm_head_dim=32)
    if cfg.family == "hybrid":
        kw.update(attn_every=4)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=4, enc_seq=64)
    if cfg.family == "vlm":
        kw.update(n_patches=16)
    return cfg.replace(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    from repro.models.model import param_defs
    from repro.models.params import count_params
    n = count_params(param_defs(cfg))
    print(f"arch {args.arch}: ~{n/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    shape = InputShape("example", args.seq, args.batch, "train")
    report = fit(cfg, shape,
                 token_stream(cfg.vocab_size, args.batch, args.seq, seed=0),
                 args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 log_every=10)
    print(f"loss: first10={sum(report.losses[:10])/10:.3f} "
          f"last10={sum(report.losses[-10:])/10:.3f}")
    print(f"mean step time: "
          f"{sum(report.step_times[5:]) / max(len(report.step_times) - 5, 1) * 1e3:.0f} ms")
    print(f"checkpoints in {args.ckpt_dir} (restart-safe: rerun resumes)")


if __name__ == "__main__":
    main()
