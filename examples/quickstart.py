"""Quickstart: build a d-HNSW index, run batched queries, insert vectors.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on a laptop-sized dataset: meta-HNSW
routing (§3.1), RDMA-friendly layout + doorbell fetches (§3.2),
query-aware batched loading with an LRU cache (§3.3), and dynamic
insertion into the shared overflow regions.
"""
import numpy as np

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G
from repro.data.synthetic import sift_like


def main():
    print("generating SIFT-like dataset (20k x 128d)...")
    ds = sift_like(n=20_000, n_queries=256, seed=0)

    print("building d-HNSW (meta-HNSW + sub-HNSWs + serialized layout)...")
    eng = DHNSWEngine(EngineConfig(
        mode="full",            # the paper's scheme (vs naive/no_doorbell)
        search_mode="graph",    # faithful sub-HNSW walk ("scan" = MXU mode)
        n_rep=128,              # partitions (paper: 500 on 1M vectors)
        b=4,                    # partitions probed per query
        ef=48,                  # efSearch
        cache_frac=0.10,        # compute-pool cache: 10% of partitions
        doorbell=16,            # span reads per doorbell batch
        fabric=RDMA_100G))      # price network events like the testbed
    eng.build(ds.data)
    print(f"  store: {eng.store.total_bytes()/1e6:.1f} MB in "
          f"{eng.store.spec.n_blocks} blocks; meta-HNSW cached in the "
          f"compute pool: {eng.meta.size_bytes()/1e6:.3f} MB")

    print("searching (batched, top-10)...")
    d, g, st = eng.search(ds.queries, k=10)
    print(f"  recall@10: {recall_at_k(g, ds.gt_ids[:, :10]):.3f}")
    print(f"  round trips/query: {st['round_trips_per_query']:.4f} "
          f"(naive would be ~{eng.cfg.b:.1f})")
    print(f"  modeled network latency: "
          f"{st['net']['latency_s']*1e6/len(ds.queries):.1f} us/query")

    print("inserting 100 new vectors (shared overflow regions)...")
    new = ds.data[:100] + 0.01
    gids = eng.insert(new)
    _, gi, _ = eng.search(new[:20], k=1)
    hits = np.mean([gids[i] in gi[i] for i in range(20)])
    print(f"  inserted ids immediately searchable: {hits*100:.0f}%")

    print("second batch (warm cache)...")
    _, _, st2 = eng.search(ds.queries, k=10)
    print(f"  cache hits: {st2['cache_hits']}, fetches: {st2['n_fetches']} "
          f"(first batch fetched {st['n_fetches']})")


if __name__ == "__main__":
    main()
