"""Live-ingest demo: concurrent appends and queries on one engine.

    PYTHONPATH=src python examples/live_ingest.py [--pool sharded]

Builds a d-HNSW engine over the first part of a synthetic SIFT-like
dataset, then runs three measured phases:

* **before** — queries only, against the initial index;
* **during** — a writer thread streams the held-out tail through
  ``engine.insert`` (the pool's one-sided WRITE verb: overflow appends,
  repacks when a group fills) while query threads keep serving;
* **after**  — queries only, with every insert folded in.

Each phase reports recall@k (before/during against the initial rows'
ground truth — the index legitimately grows mid-phase — after against
the full dataset's) and the query latency p50/p99, so the printout
shows what live ingestion costs the read path and that the inserted
vectors are actually found afterwards.

``--pool`` picks the transport exactly like ``online_serving.py``
(``sharded`` shows appends fanning to the owning shard's replicas;
``remote`` serves through forked pool-server processes).  The engine is
guarded by one lock — requests interleave rather than race — matching
the serial-call discipline of the other demos.
"""
import argparse
import contextlib
import threading
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.hnsw import brute_force_knn
from repro.data.synthetic import sift_like


def recall_at_k(got_gids: np.ndarray, true_gids: np.ndarray) -> float:
    hits = sum(len(set(g.tolist()) & set(t.tolist()))
               for g, t in zip(got_gids, true_gids))
    return hits / float(true_gids.size)


def query_phase(eng, lock, queries, true_gids, *, k: int, seconds: float,
                stop: threading.Event = None):
    """Closed-loop single-query reads for ``seconds`` (or until ``stop``);
    returns (recall@k, p50 ms, p99 ms, queries served)."""
    lat, got = [], {}
    rng = np.random.default_rng(0)
    t_end = time.perf_counter() + seconds
    while time.perf_counter() < t_end and not (stop and stop.is_set()):
        qi = int(rng.integers(0, len(queries)))
        t0 = time.perf_counter()
        with lock:
            _, gids, _ = eng.search(queries[qi][None], k=k)
        lat.append(time.perf_counter() - t0)
        got[qi] = np.asarray(gids)[0]
    qis = sorted(got)
    rec = recall_at_k(np.stack([got[q] for q in qis]),
                      np.stack([true_gids[q] for q in qis]))
    arr = np.asarray(lat) * 1e3
    return (rec, float(np.percentile(arr, 50)),
            float(np.percentile(arr, 99)), len(lat))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12_000,
                    help="initially indexed rows")
    ap.add_argument("--ingest", type=int, default=1_500,
                    help="rows appended live during the middle phase")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seconds", type=float, default=3.0,
                    help="measured duration of each query phase")
    ap.add_argument("--pool", default="local",
                    choices=("local", "sim_rdma", "sharded", "remote"))
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--quant", action="store_true",
                    help="serve through the int8 quantized tier")
    args = ap.parse_args()

    total = args.n + args.ingest
    ds = sift_like(n=total, n_queries=64, seed=0)
    base, tail = ds.data[:args.n], ds.data[args.n:]
    print(f"indexing {args.n} rows ({args.ingest} held out for live "
          f"ingest)...")

    with contextlib.ExitStack() as stack:
        endpoints = None
        if args.pool == "remote":
            from repro.net import spawn_pool_servers
            print(f"forking {args.shards} loopback pool servers...")
            endpoints = tuple(stack.enter_context(
                spawn_pool_servers(args.shards)))
        eng = DHNSWEngine(EngineConfig(
            mode="full", search_mode="scan", b=3, ef=32, n_rep=48,
            cache_frac=0.15, doorbell=16,
            quant="int8" if args.quant else "none",
            pool=args.pool, n_shards=args.shards,
            endpoints=endpoints)).build(base)

        k, lock = args.k, threading.Lock()
        # ground truth: initial rows for before/during, everything after
        _, gt_base = brute_force_knn(base, ds.queries, k)
        _, gt_full = brute_force_knn(ds.data, ds.queries, k)
        eng.search(ds.queries[:1], k=k)      # warm the jit caches

        rec, p50, p99, nq = query_phase(eng, lock, ds.queries, gt_base,
                                        k=k, seconds=args.seconds)
        print(f"\nbefore ingest: recall@{k} {rec:.3f}   p50 {p50:6.1f} ms"
              f"   p99 {p99:6.1f} ms   ({nq} queries)")

        done = threading.Event()
        appended = [0]

        def writer():
            for s in range(0, len(tail), 32):
                with lock:
                    eng.insert(tail[s:s + 32])
                appended[0] += len(tail[s:s + 32])
            done.set()

        wt = threading.Thread(target=writer)
        t0 = time.perf_counter()
        wt.start()
        # keep querying as long as the writer runs (at least one pass)
        rec, p50, p99, nq = query_phase(eng, lock, ds.queries, gt_base,
                                        k=k, seconds=args.seconds,
                                        stop=done)
        wt.join()
        ingest_s = time.perf_counter() - t0
        print(f"during ingest: recall@{k} {rec:.3f}   p50 {p50:6.1f} ms"
              f"   p99 {p99:6.1f} ms   ({nq} queries, {appended[0]} "
              f"appends in {ingest_s:.1f}s)")

        rec, p50, p99, nq = query_phase(eng, lock, ds.queries, gt_full,
                                        k=k, seconds=args.seconds)
        print(f"after ingest:  recall@{k} {rec:.3f}   p50 {p50:6.1f} ms"
              f"   p99 {p99:6.1f} ms   ({nq} queries, ground truth now "
              f"includes the {args.ingest} inserted rows)")

        net = eng._last_insert_net
        if net:
            print(f"\ninsert wire: {net['bytes'] / 1e3:.1f} kB over "
                  f"{net['round_trips']:.0f} one-sided WRITEs "
                  f"(last batch)")
        snap = eng.pool.snapshot()
        if snap.get("kind") == "sharded":
            stg = snap.get("staging")
            print(f"sharded pool: {snap['n_shards']} nodes, "
                  f"{snap['migration']['n']} migrations, "
                  f"replication fan-out "
                  f"{snap['replication_io']['fanout_writes']} writes")
            if stg:
                mb = [b / 1e6 for b in stg["device_bytes_by_shard"]]
                print("  staged device MB by shard: "
                      + ", ".join(f"{x:.2f}" for x in mb)
                      + f"  (restaged blocks: {stg['restaged_blocks']})")


if __name__ == "__main__":
    main()
