"""RAG serving: d-HNSW as the retrieval tier for an LM (paper §1).

    PYTHONPATH=src python examples/rag_serve.py [--arch qwen3-8b]

A batch of prompts is embedded, d-HNSW retrieves the closest document
vectors (meta-route -> doorbell fetch -> sub-search), the docs' tokens
are prepended, and the LM (any of the 10 assigned architectures, reduced
to a CPU-sized config) prefills + greedy-decodes.
"""
import argparse

import numpy as np

from repro.configs.registry import ARCH_IDS, smoke_config
from repro.core import DHNSWEngine, EngineConfig
from repro.serve.engine import RagServeEngine, synthetic_doc_store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_IDS)
    ap.add_argument("--n-docs", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    print(f"arch: {args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model})")

    print(f"indexing {args.n_docs} docs in d-HNSW...")
    docs = synthetic_doc_store(args.n_docs, 64, doc_len=8,
                               vocab=cfg.vocab_size)
    retriever = DHNSWEngine(EngineConfig(
        mode="full", search_mode="scan", n_rep=64, b=2, ef=32,
        cache_frac=0.15)).build(docs.embeddings)

    engine = RagServeEngine(cfg, retriever, docs, max_new_tokens=8,
                            docs_per_query=2)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, 12)).astype(np.int32)
    print(f"serving batch of {args.batch} prompts...")
    out, st = engine.serve(prompts)
    print(f"  retrieval: {st.retrieve_s*1e3:.1f} ms "
          f"({st.retrieval['n_fetches']} partition fetches, "
          f"{st.retrieval['round_trips_per_query']:.3f} trips/query)")
    print(f"  prefill:   {st.prefill_s*1e3:.1f} ms")
    print(f"  decode:    {st.decode_s*1e3:.1f} ms "
          f"({out.shape[1]} tokens/seq)")
    print(f"  generated token ids, first sequence: {out[0].tolist()}")


if __name__ == "__main__":
    main()
