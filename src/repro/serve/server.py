"""Serving front-end: one ``DHNSWEngine`` behind a ``MicroBatcher``.

``SearchServer`` is the process-level object a deployment embeds: it owns
the engine and the batching policy, exposes blocking and async
search/insert, and reports rolling service metrics (throughput,
p50/p95/p99, stage breakdown).  Many client threads may call it
concurrently; all engine access is serialized through the batcher's
dispatcher thread, which is also what makes concurrent requests fuse
into the paper's batched query-aware loads.
"""
from __future__ import annotations

from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.core.engine import DHNSWEngine
from repro.serve.batcher import BatchPolicy, MicroBatcher


class SearchServer:
    """build-or-adopt an engine -> ``with SearchServer(eng) as srv: ...``."""

    def __init__(self, engine: DHNSWEngine,
                 policy: Optional[BatchPolicy] = None, *,
                 autostart: bool = True):
        self.engine = engine
        self.batcher = MicroBatcher(engine, policy, autostart=autostart)

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "SearchServer":
        self.batcher.start()
        return self

    def stop(self):
        self.batcher.stop()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ------------------------------------------------------------ requests

    def search(self, vecs: np.ndarray, k: int = 10, *, tenant: str = "-"):
        """Blocking: (dists (m, k), gids (m, k), per-request stats)."""
        return self.batcher.search(vecs, k, tenant=tenant)

    def search_async(self, vecs: np.ndarray, k: int = 10, *,
                     tenant: str = "-") -> Future:
        return self.batcher.submit_search(vecs, k, tenant=tenant)

    def insert(self, vecs: np.ndarray, *, tenant: str = "-") -> np.ndarray:
        return self.batcher.insert(vecs, tenant=tenant)

    def insert_async(self, vecs: np.ndarray, *,
                     tenant: str = "-") -> Future:
        return self.batcher.submit_insert(vecs, tenant=tenant)

    # ------------------------------------------------------------ metrics

    def stats(self) -> dict:
        """Rolling service metrics (the /stats endpoint payload):
        request/latency percentiles, stage breakdown, the NetLedger
        roll-up under ``net`` — bytes_fetched / bytes_saved (nonzero
        when the engine serves through the quantized tier), round trips
        and doorbell descriptors across all fused calls — the
        per-tenant view under ``tenants`` (admit/reject counts, live
        queue depth, served rows + fair-queue ``share`` per tenant
        key), and under ``pool`` the latest memory-pool snapshot (verb
        totals; per-shard breakdown + migration counters when serving
        through a ``ShardedPool``)."""
        return self.batcher.metrics.snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of :meth:`stats` — SLO burn rates,
        per-(verb, shard) pool latency histograms, straggler verdicts,
        and tracer-health gauges included — plus per-span duration
        histograms when the tracer is enabled."""
        from repro.obs.metrics import render_prometheus
        from repro.obs.trace import TRACER
        spans = TRACER.snapshot() if TRACER.enabled else None
        return render_prometheus(self.stats(), spans, tracer=TRACER)

    def dump_trace(self, path) -> int:
        """Harvest server-side spans (remote pools) and write the whole
        trace as Chrome-trace JSON.  Returns the span count written."""
        from repro.obs.trace import TRACER
        pool = self.engine.pool
        if TRACER.enabled and hasattr(pool, "harvest_trace"):
            from repro.pool.protocol import PoolUnavailableError
            try:
                pool.harvest_trace()
            except PoolUnavailableError:
                pass
        return TRACER.save(path)
