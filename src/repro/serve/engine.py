"""Serving engine: batched LM inference with d-HNSW retrieval (RAG).

The paper positions d-HNSW as the retrieval tier for LLM/RAG serving
(§1).  This engine is that integration: a request batch is embedded,
the d-HNSW engine retrieves top-k document vectors (meta-HNSW routing in
the compute pool, doorbell fetches from the memory pool), and the
retrieved documents' tokens are prepended to each prompt before a
prefill + greedy decode on any of the 10 assigned architectures.

Embedding is the LM's own token-embedding mean (standard cheap query
encoder for tests/examples; any encoder slots in via ``embed_fn``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import DHNSWEngine
from repro.models import model as M
from repro.models.params import init_params
from repro.serve.server import SearchServer


@dataclass
class DocStore:
    """Document corpus: embedding per doc (indexed by d-HNSW) + tokens."""

    embeddings: np.ndarray          # (n_docs, D)
    tokens: np.ndarray              # (n_docs, doc_len) i32


@dataclass
class ServeStats:
    retrieve_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    retrieval: dict = field(default_factory=dict)


class RagServeEngine:
    """build -> serve(prompts) -> generated tokens.

    Retrieval goes through a ``SearchServer`` (micro-batching tier), so
    concurrent ``serve`` callers — or any other client of the same server
    — coalesce into fused d-HNSW batches.  Passing a bare ``DHNSWEngine``
    wraps it in a private server.
    """

    def __init__(self, cfg: ModelConfig,
                 retriever: "DHNSWEngine | SearchServer",
                 docs: DocStore, *, max_new_tokens: int = 16,
                 docs_per_query: int = 2,
                 embed_fn: Optional[Callable] = None, seed: int = 0):
        self.cfg = cfg
        self._own_server = not isinstance(retriever, SearchServer)
        self.server = (SearchServer(retriever) if self._own_server
                       else retriever)
        self.retriever = self.server.engine
        self.docs = docs
        self.max_new_tokens = max_new_tokens
        self.docs_per_query = docs_per_query
        defs = M.param_defs(cfg)
        self.params = init_params(defs, jax.random.key(seed))
        self._embed = embed_fn or self._default_embed
        self._prefill = jax.jit(
            lambda p, toks, L: M.prefill(cfg, p, {"tokens": toks}, L),
            static_argnums=(2,))
        self._decode = jax.jit(
            lambda p, cache, toks, pos: M.decode_step(cfg, p, cache, toks, pos))

    def close(self):
        """Stop the private batcher thread (no-op for an adopted server)."""
        if self._own_server:
            self.server.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _default_embed(self, tokens: np.ndarray) -> np.ndarray:
        emb = np.asarray(self.params["embed"])
        e = emb[np.clip(tokens, 0, emb.shape[0] - 1)].mean(axis=1)
        d = self.docs.embeddings.shape[1]
        if e.shape[1] >= d:
            return e[:, :d].astype(np.float32)
        return np.pad(e, ((0, 0), (0, d - e.shape[1]))).astype(np.float32)

    def serve(self, prompts: np.ndarray) -> tuple[np.ndarray, ServeStats]:
        """prompts (B, S_p) i32 -> (generated (B, max_new_tokens), stats)."""
        stats = ServeStats()
        B, Sp = prompts.shape

        # 1. retrieve through the micro-batching tier (the paper's tier:
        # batched, deduped, doorbell'd — fused across concurrent callers)
        t0 = time.perf_counter()
        q = self._embed(prompts)
        _, doc_ids, rstats = self.server.search(q, k=self.docs_per_query)
        stats.retrieval = rstats
        stats.retrieve_s = time.perf_counter() - t0

        # 2. prepend retrieved doc tokens (pad docs that returned -1)
        doc_len = self.docs.tokens.shape[1]
        ctx = np.zeros((B, self.docs_per_query * doc_len), np.int32)
        for i in range(B):
            for j in range(self.docs_per_query):
                d = int(doc_ids[i, j])
                if 0 <= d < len(self.docs.tokens):
                    ctx[i, j * doc_len:(j + 1) * doc_len] = self.docs.tokens[d]
        tokens = np.concatenate([ctx, prompts], axis=1)
        S = tokens.shape[1]
        cache_len = S + self.max_new_tokens

        # 3. prefill + greedy decode
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(tokens),
                                      cache_len)
        logits = jax.block_until_ready(logits)
        stats.prefill_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        out = np.zeros((B, self.max_new_tokens), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        for t in range(self.max_new_tokens):
            out[:, t] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(B)
            pos = pos + 1
        stats.decode_s = time.perf_counter() - t0
        return out, stats


def synthetic_doc_store(n_docs: int, dim: int, doc_len: int,
                        vocab: int, seed: int = 0) -> DocStore:
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n_docs, dim)).astype(np.float32)
    toks = rng.integers(0, vocab, (n_docs, doc_len)).astype(np.int32)
    return DocStore(emb, toks)
