"""Dynamic micro-batching front-end for the d-HNSW engine.

The paper's throughput wins (§3.3 batched query-aware loading, §3.2
doorbell batching) all trigger on the *batch* handed to the engine: one
load per needed partition per batch, many span reads per round trip, and
LRU reuse across the batch.  A serving tier that forwards each user
request as its own ``engine.search`` call forfeits every one of those —
two concurrent users needing the same partition pay two fetches, and
each call eats the fixed meta-route/plan/dispatch overhead alone.

``MicroBatcher`` restores the paper's invariant under live traffic: it
queues concurrent single-query (or small-batch) requests, coalesces them
under a policy (max batch size, max wait, token-bucket admission), and
dispatches ONE fused ``DHNSWEngine.search`` per window.  Cross-request
coalescing is therefore exactly the paper's batched query-aware loading
with the "batch" assembled from independent requesters instead of one
caller: partition dedup, doorbell grouping, and cache reuse all amortize
across users.  Results are scattered back per request together with a
queue/route/plan/fetch/serve latency breakdown, and the batcher keeps
rolling p50/p95/p99 service metrics.

Requests preserve arrival order: a window is drained as consecutive
same-kind runs (search / insert), so a search submitted after an insert
observes the inserted vectors.  With weighted fair queueing enabled
(``BatchPolicy.wfq`` / ``tenant_weight``) windows drain by deficit
round-robin across tenants instead of strict FIFO — per-tenant order is
still preserved (a tenant's search still observes its own earlier
inserts), but one tenant's backlog can no longer monopolize windows:
served rows converge to the configured weight ratio, reported as the
per-tenant ``share`` in ``stats()["tenants"]``.
"""
from __future__ import annotations

import copy
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.engine import pow2_pad
from repro.obs.slo import SLOTracker
from repro.obs.trace import TRACER


class AdmissionError(RuntimeError):
    """Token-bucket admission rejected a request (over offered-load cap)."""


@dataclass
class BatchPolicy:
    """Coalescing policy for one batcher.

    A window opens when the queue goes non-empty and closes when either
    ``max_batch`` query rows are pending or the oldest request has waited
    the window's wait budget.  ``rate``/``burst`` bound admission
    (0 = unlimited).

    With ``adaptive_wait`` the budget scales with the OBSERVED arrival
    rate instead of sitting at ``max_wait_s``: under load the queue
    fills a batch quickly so holding the window only adds latency (the
    budget shrinks toward ``min_wait_s``); when traffic is sparse a
    longer window is the only way requests ever coalesce (the budget
    grows toward ``max_wait_s``).  ``max_wait_s`` is always the cap.
    A window whose opening request found the queue EMPTY at enqueue
    time collapses straight to ``min_wait_s``: nothing was waiting to
    coalesce with it, so holding the window open is pure added latency.
    """

    max_batch: int = 64         # query rows fused into one engine call
    max_wait_s: float = 2e-3    # wait cap (fixed budget when not adaptive)
    rate: float = 0.0           # admission tokens/s (0 disables the bucket)
    burst: int = 64             # bucket depth
    admission_block: bool = True  # block when out of tokens (else raise)
    adaptive_wait: bool = False   # scale the window from arrival EWMA
    min_wait_s: float = 1e-4      # adaptive floor
    ewma_alpha: float = 0.2       # inter-arrival smoothing
    # per-tenant admission: every search request carries a ``tenant``
    # key (default "-"); each tenant gets its OWN token bucket on top of
    # the global one, so one tenant flooding the queue cannot starve the
    # rest of their admission budget (0 disables per-tenant buckets)
    tenant_rate: float = 0.0    # admission tokens/s per tenant
    tenant_burst: int = 32      # per-tenant bucket depth
    # weighted fair queueing: with ``wfq`` (or any explicit
    # ``tenant_weight``) the window drains queued requests by deficit
    # round-robin across tenants instead of FIFO — each tenant earns
    # ``wfq_quantum * weight`` query rows of credit per sweep, so a
    # backlogged tenant cannot monopolize a window and served capacity
    # converges to the weight ratio.  Arrival order is preserved
    # WITHIN a tenant (a tenant's search still observes its own earlier
    # inserts); cross-tenant order is intentionally not preserved.
    wfq: bool = False
    tenant_weight: dict = field(default_factory=dict)   # tenant -> weight
    wfq_quantum: int = 8        # rows of credit per weight unit per sweep
    # latency SLOs (repro.obs.slo): a single spec ("p99<5ms" or an SLO)
    # watches end-to-end request latency per tenant; a {tier: spec} dict
    # attaches objectives per stage ("serve" end-to-end, "fetch" pool
    # wire time, "queue" wait).  None disables SLO tracking entirely.
    slo: Optional[object] = None
    slo_short_window: int = 64   # burn-rate fast window (requests)
    slo_long_window: int = 512   # burn-rate slow window (requests)

    @property
    def fair_queue(self) -> bool:
        return self.wfq or bool(self.tenant_weight)

    def weight_of(self, tenant: str) -> float:
        return max(float(self.tenant_weight.get(tenant, 1.0)), 1e-6)


class ArrivalRateEWMA:
    """EWMA of request inter-arrival time -> adaptive window budget.

    The budget is the time it takes (at the observed rate) for half a
    ``max_batch`` to queue up: enough to coalesce, never so long that a
    full batch sits waiting on a timer.  Thread-safe; all methods take
    an explicit ``now`` so tests can drive synthetic clocks.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self._ewma: Optional[float] = None    # smoothed inter-arrival (s)
        self._last: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, now: float) -> None:
        with self._lock:
            if self._last is not None:
                dt = max(now - self._last, 0.0)
                self._ewma = (dt if self._ewma is None else
                              self.alpha * dt + (1 - self.alpha) * self._ewma)
            self._last = now

    def interarrival_s(self) -> Optional[float]:
        with self._lock:
            return self._ewma

    def wait_budget_s(self, policy: "BatchPolicy",
                      queue_empty: bool = False) -> float:
        if not policy.adaptive_wait:
            return policy.max_wait_s
        if queue_empty:
            # the opener found nothing queued behind it: holding the
            # window cannot coalesce what isn't there — dispatch fast
            return policy.min_wait_s
        with self._lock:
            ewma = self._ewma
        if ewma is None:                      # no signal yet: cap
            return policy.max_wait_s
        target = 0.5 * policy.max_batch * ewma
        return float(min(max(target, policy.min_wait_s), policy.max_wait_s))


class TokenBucket:
    """Classic token bucket; thread-safe; ``rate<=0`` admits everything."""

    def __init__(self, rate: float, burst: int):
        self.rate = float(rate)
        self.burst = max(int(burst), 1)
        self._tokens = float(self.burst)
        self._t = time.monotonic()
        self._lock = threading.Lock()

    def acquire(self, n: int = 1, *, block: bool = True) -> bool:
        if self.rate <= 0:
            return True
        # a request larger than the bucket depth drains the whole bucket
        # (n > burst could otherwise never be satisfied and would spin)
        n = min(n, self.burst)
        while True:
            with self._lock:
                now = time.monotonic()
                self._tokens = min(self.burst,
                                   self._tokens + (now - self._t) * self.rate)
                self._t = now
                if self._tokens >= n:
                    self._tokens -= n
                    return True
                need = (n - self._tokens) / self.rate
            if not block:
                return False
            time.sleep(min(need, 0.05))


@dataclass
class _Request:
    kind: str                   # "search" | "insert"
    vecs: np.ndarray            # (m, D)
    k: int
    t_submit: float
    tenant: str = "-"
    future: Future = field(default_factory=Future)
    # whether the queue was empty the instant this request was enqueued
    # (adaptive_wait collapses the window to min_wait_s on a lone opener)
    empty_at_enqueue: bool = False


class ServeMetrics:
    """Rolling per-request latency + stage breakdown (thread-safe)."""

    WINDOW = 8192               # per-request latencies kept for percentiles

    def __init__(self):
        self._lock = threading.Lock()
        self._lat = deque(maxlen=self.WINDOW)
        self.n_requests = 0
        self.n_queries = 0
        self.n_fused_calls = 0
        self.n_rejected = 0
        self.fused_sizes = deque(maxlen=self.WINDOW)
        self.breakdown = {"queue_s": 0.0, "route_s": 0.0, "plan_s": 0.0,
                          "fetch_s": 0.0, "serve_s": 0.0}
        # NetLedger roll-up, recorded once per fused CALL (every request
        # in a window shares one engine call's network events)
        self.net = {"bytes_fetched": 0.0, "bytes_saved": 0.0,
                    "round_trips": 0.0, "descriptors": 0.0}
        # per-tenant admission accounting: admitted/rejected counters,
        # the live queue depth (enqueued minus dispatched), and served
        # query rows (-> served share under weighted fair queueing)
        self.tenants: dict[str, dict] = {}
        # latest memory-pool snapshot (verb totals; per-shard breakdown
        # when the engine serves through a ShardedPool)
        self.pool_snap: Optional[dict] = None
        # engine-side counters folded across fused calls (cache hit
        # ratio, fetches, rounds) for the Prometheus exporter
        self.engine_agg = {"cache_hits": 0.0, "n_fetches": 0.0,
                           "n_rounds": 0.0}
        # per-tenant/per-tier SLO evaluation; attached by MicroBatcher
        # when BatchPolicy.slo is configured, else stays None
        self.slo: Optional[SLOTracker] = None

    def _tenant(self, tenant: str) -> dict:
        """Caller must hold the lock."""
        return self.tenants.setdefault(
            tenant, {"admitted": 0, "rejected": 0, "queued": 0,
                     "served": 0})

    def note_enqueued(self, tenant: str):
        with self._lock:
            t = self._tenant(tenant)
            t["admitted"] += 1
            t["queued"] += 1

    def note_dequeued(self, tenant: str):
        with self._lock:
            self._tenant(tenant)["queued"] -= 1

    def note_served(self, tenant: str, rows: int):
        """Rows that actually completed (not merely dispatched): a
        window whose engine call raises must not inflate the fair-queue
        served share."""
        with self._lock:
            self._tenant(tenant)["served"] += rows

    def record_call(self, batch: int, n_queries: int = 0,
                    net: Optional[dict] = None,
                    pool: Optional[dict] = None,
                    engine: Optional[dict] = None):
        with self._lock:
            self.n_fused_calls += 1
            self.fused_sizes.append(batch)
            self.n_queries += n_queries
            if net:
                self.net["bytes_fetched"] += net.get("bytes", 0.0)
                self.net["bytes_saved"] += net.get("bytes_saved", 0.0)
                self.net["round_trips"] += net.get("round_trips", 0.0)
                self.net["descriptors"] += net.get("descriptors", 0.0)
            if pool is not None:
                self.pool_snap = pool
            if engine:
                for key in self.engine_agg:
                    self.engine_agg[key] += float(engine.get(key, 0.0))

    def record_rejected(self, tenant: str = "-"):
        with self._lock:
            self.n_rejected += 1
            self._tenant(tenant)["rejected"] += 1

    def record_request(self, total_s: float, breakdown: dict,
                       tenant: str = "-"):
        with self._lock:
            self.n_requests += 1
            self._lat.append(total_s)
            for key in self.breakdown:
                self.breakdown[key] += breakdown.get(key, 0.0)
            if self.slo is not None:
                # feed every configured tier; record() ignores the rest
                self.slo.record("serve", tenant, total_s)
                for tier, key in (("fetch", "fetch_s"),
                                  ("queue", "queue_s")):
                    if key in breakdown:
                        self.slo.record(tier, tenant, breakdown[key])

    def snapshot(self) -> dict:
        with self._lock:
            lat = np.asarray(self._lat, np.float64)
            sizes = np.asarray(self.fused_sizes, np.float64)
            out = {
                "n_requests": self.n_requests,
                "n_queries": self.n_queries,
                "n_fused_calls": self.n_fused_calls,
                "n_rejected": self.n_rejected,
                "mean_fused_batch": float(sizes.mean()) if len(sizes) else 0.0,
                "breakdown_s": dict(self.breakdown),
                "net": dict(self.net),
                "engine": dict(self.engine_agg),
                "tenants": {t: dict(v) for t, v in self.tenants.items()},
            }
            total_served = sum(v["served"] for v in self.tenants.values())
            for v in out["tenants"].values():
                v["share"] = (v["served"] / total_served
                              if total_served else 0.0)
            if self.pool_snap is not None:
                out["pool"] = copy.deepcopy(self.pool_snap)
                # remote transports: roll the MEASURED wire traffic up
                # next to the modeled ledger totals under ``net``
                wt = (self.pool_snap.get("wire_total")
                      or self.pool_snap.get("wire"))
                if wt:
                    out["net"]["wire_frames"] = (wt["frames_tx"]
                                                 + wt["frames_rx"])
                    out["net"]["wire_bytes_tx"] = wt["bytes_tx"]
                    out["net"]["wire_bytes_rx"] = wt["bytes_rx"]
                # replicated pools: surface liveness + failover next to
                # the latency numbers so an operator sees a mid-run node
                # death (deaths > 0, alive count down) without digging
                # through the full per-shard snapshot
                if "failover" in self.pool_snap:
                    out["failover"] = dict(self.pool_snap["failover"])
                    out["failover"]["replication"] = self.pool_snap.get(
                        "replication", 1)
                    alive = self.pool_snap.get("alive")
                    if alive is not None:
                        out["failover"]["alive_shards"] = int(sum(alive))
                    out["failover"]["trace_harvest_failures"] = (
                        self.pool_snap.get("trace_harvest_failures", 0))
                # straggler verdicts ride next to the latency numbers:
                # "p99 moved AND shard 1 is flagged" is one glance
                if "stragglers" in self.pool_snap:
                    out["stragglers"] = copy.deepcopy(
                        self.pool_snap["stragglers"])
            if self.slo is not None:
                out["slo"] = self.slo.report()
            for p in (50, 95, 99):
                out[f"p{p}_ms"] = (float(np.percentile(lat, p)) * 1e3
                                   if len(lat) else 0.0)
            return out


class MicroBatcher:
    """Queue + dispatcher thread around one ``DHNSWEngine``.

    ``submit_search``/``submit_insert`` enqueue and return a ``Future``;
    the dispatcher coalesces pending requests into fused engine calls.
    The engine is only ever touched from the dispatcher thread, so the
    (not thread-safe) engine needs no internal locking.
    """

    def __init__(self, engine, policy: Optional[BatchPolicy] = None, *,
                 autostart: bool = True):
        self.engine = engine
        self.policy = policy or BatchPolicy()
        self.metrics = ServeMetrics()
        if self.policy.slo is not None:
            self.metrics.slo = SLOTracker(
                self.policy.slo,
                short_window=self.policy.slo_short_window,
                long_window=self.policy.slo_long_window)
        self.arrivals = ArrivalRateEWMA(self.policy.ewma_alpha)
        self._bucket = TokenBucket(self.policy.rate, self.policy.burst)
        self._tenant_buckets: dict[str, TokenBucket] = {}
        self._tenant_lock = threading.Lock()
        # weighted-fair-queueing state (deficit round-robin): per-tenant
        # row credit and the tenant service order, persisted across
        # windows so short-term bursts even out.  The sweep start
        # rotates every window so a window that fills before reaching
        # the last tenants cannot starve them forever; tenants with no
        # backlog are pruned (their credit is zero by construction).
        self._deficit: dict[str, float] = {}
        self._rr: list[str] = []
        self._rr_pos = 0
        self._queue: deque[_Request] = deque()
        self._cv = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        if autostart:
            self.start()

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "MicroBatcher":
        if self._thread is None or not self._thread.is_alive():
            # one live dispatcher per engine: the engine is not
            # thread-safe, and two batchers racing it would corrupt the
            # LRU/cache state the serialization exists to protect
            owner = getattr(self.engine, "_dispatcher", None)
            if (owner is not None and owner is not self
                    and owner._thread is not None
                    and owner._thread.is_alive()):
                raise RuntimeError(
                    "engine already has a live MicroBatcher; stop it first")
            if self.engine is not None:
                self.engine._dispatcher = self
            self._stop = False
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="dhnsw-batcher")
            self._thread.start()
        return self

    def stop(self, *, flush: bool = True):
        """Stop the dispatcher; by default drain queued requests first."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            # unbounded join: an in-flight fused call (e.g. a cold XLA
            # compile) can exceed any timeout, and draining or handing
            # the engine to a new batcher while the dispatcher is still
            # inside it would break the single-thread engine invariant
            self._thread.join()
        if flush:
            self._drain_all()
        if getattr(self.engine, "_dispatcher", None) is self:
            self.engine._dispatcher = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ------------------------------------------------------------ submit

    def _tenant_bucket(self, tenant: str) -> Optional[TokenBucket]:
        if self.policy.tenant_rate <= 0:
            return None
        with self._tenant_lock:
            bucket = self._tenant_buckets.get(tenant)
            if bucket is None:
                bucket = TokenBucket(self.policy.tenant_rate,
                                     self.policy.tenant_burst)
                self._tenant_buckets[tenant] = bucket
            return bucket

    def submit_search(self, vecs: np.ndarray, k: int = 10, *,
                      tenant: str = "-") -> Future:
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        # tenant bucket FIRST: a tenant-rejected request must not have
        # consumed shared global tokens, or a flooding tenant would
        # still drain everyone else's admission budget
        with TRACER.span("serve.admit", tier="serve", tenant=tenant,
                         rows=int(vecs.shape[0])):
            tb = self._tenant_bucket(tenant)
            if tb is not None and not tb.acquire(
                    vecs.shape[0], block=self.policy.admission_block):
                self.metrics.record_rejected(tenant)
                raise AdmissionError(
                    f"tenant {tenant!r} over its admission rate")
            if not self._bucket.acquire(vecs.shape[0],
                                        block=self.policy.admission_block):
                self.metrics.record_rejected(tenant)
                raise AdmissionError(
                    "token bucket empty (offered load over cap)")
        return self._enqueue(_Request("search", vecs, int(k),
                                      time.perf_counter(), tenant))

    def submit_insert(self, vecs: np.ndarray, *,
                      tenant: str = "-") -> Future:
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        return self._enqueue(_Request("insert", vecs, 0,
                                      time.perf_counter(), tenant))

    def search(self, vecs: np.ndarray, k: int = 10, *, tenant: str = "-"):
        """Blocking convenience: returns (dists, gids, stats)."""
        return self.submit_search(vecs, k, tenant=tenant).result()

    def insert(self, vecs: np.ndarray, *, tenant: str = "-") -> np.ndarray:
        return self.submit_insert(vecs, tenant=tenant).result()

    def _enqueue(self, req: _Request) -> Future:
        self.arrivals.observe(req.t_submit)
        with self._cv:
            if self._stop and self._thread is not None:
                raise RuntimeError("batcher is stopped")
            req.empty_at_enqueue = not self._queue
            self._queue.append(req)
            self.metrics.note_enqueued(req.tenant)
            self._cv.notify_all()
        return req.future

    # ------------------------------------------------------------ dispatcher

    def _run(self):
        pol = self.policy
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait(timeout=0.1)
                if self._stop:
                    return
                # window: open at the oldest pending request; close on
                # max_batch rows queued or the oldest exhausting the wait
                # budget (arrival-rate-adaptive when the policy says so)
                deadline = (self._queue[0].t_submit
                            + self.arrivals.wait_budget_s(
                                pol,
                                queue_empty=self._queue[0].empty_at_enqueue))
                while (sum(r.vecs.shape[0] for r in self._queue)
                       < pol.max_batch):
                    left = deadline - time.perf_counter()
                    if left <= 0 or self._stop:
                        break
                    self._cv.wait(timeout=left)
                window = self._take_window()
            self._dispatch_window(window)

    def _take_window(self) -> list[_Request]:
        """Pop up to max_batch query rows.  FIFO by default; deficit
        round-robin across tenants when the policy enables weighted
        fair queueing (per-tenant arrival order always preserved)."""
        if self.policy.fair_queue:
            return self._take_window_drr()
        out, rows = [], 0
        while self._queue and rows < self.policy.max_batch:
            rows += self._queue[0].vecs.shape[0]
            out.append(self._queue.popleft())
        return out

    def _take_window_drr(self) -> list[_Request]:
        """Deficit round-robin: sweep tenants in first-seen order, top
        each deficit up by ``wfq_quantum * weight`` rows per sweep, and
        pop that tenant's queue head while the deficit affords it — so
        over time every backlogged tenant's served rows converge to the
        weight ratio no matter how deep anyone's backlog is."""
        pol = self.policy
        pending: dict[str, deque] = {}
        for r in self._queue:
            pending.setdefault(r.tenant, deque()).append(r)
        for t in pending:
            if t not in self._deficit:
                self._deficit[t] = 0.0
                self._rr.append(t)
        # rotate the sweep start each window: a window that fills at
        # max_batch before reaching the tail tenants must not restart
        # at the same head next time (that would starve the tail)
        self._rr_pos %= max(len(self._rr), 1)
        order = self._rr[self._rr_pos:] + self._rr[:self._rr_pos]
        self._rr_pos += 1
        out: list[_Request] = []
        rows = 0
        while rows < pol.max_batch and any(pending.values()):
            progressed = False
            for t in order:
                q = pending.get(t)
                if not q:
                    continue
                self._deficit[t] += pol.wfq_quantum * pol.weight_of(t)
                while q and rows < pol.max_batch:
                    need = q[0].vecs.shape[0]
                    if self._deficit[t] < need:
                        break
                    self._deficit[t] -= need
                    out.append(q.popleft())
                    rows += need
                    progressed = True
                if rows >= pol.max_batch:
                    break
            if not progressed and rows < pol.max_batch:
                # no tenant could afford its queue head this pass (a
                # pathological near-zero weight would otherwise spin
                # this loop for ~need/quantum*weight passes while
                # HOLDING the batcher lock): force the first backlogged
                # head through at zero carried credit and move on
                for t in order:
                    q = pending.get(t)
                    if q:
                        self._deficit[t] = 0.0
                        r = q.popleft()
                        out.append(r)
                        rows += r.vecs.shape[0]
                        break
        # a tenant whose backlog drained carries no credit forward
        # (classic DRR: deficit only accumulates while backlogged), and
        # keeping it listed would grow the sweep without bound on
        # long-lived servers with many tenant keys — prune it
        drained = [t for t, q in pending.items() if not q]
        if drained:
            gone = set(drained)
            self._rr = [t for t in self._rr if t not in gone]
            for t in drained:
                self._deficit.pop(t, None)
        taken = {id(r) for r in out}
        self._queue = deque(r for r in self._queue if id(r) not in taken)
        return out

    def _drain_all(self):
        while True:
            with self._cv:
                window = self._take_window()
            if not window:
                return
            self._dispatch_window(window)

    def _dispatch_window(self, window: list[_Request]):
        """Split the window into consecutive same-kind runs (preserving
        submission order for insert/search interleave) and fuse each."""
        i = 0
        while i < len(window):
            j = i
            while j < len(window) and window[j].kind == window[i].kind:
                j += 1
            group = window[i:j]
            for r in group:
                self.metrics.note_dequeued(r.tenant)
            with TRACER.span("serve.window", tier="serve",
                             kind=group[0].kind, requests=len(group),
                             rows=int(sum(r.vecs.shape[0] for r in group))):
                try:
                    if group[0].kind == "search":
                        self._dispatch_search(group)
                    else:
                        self._dispatch_insert(group)
                except BaseException as e:  # deliver, don't kill the thread
                    for r in group:
                        if not r.future.done():
                            r.future.set_exception(e)
            i = j

    def _dispatch_search(self, group: list[_Request]):
        t_disp = time.perf_counter()
        if TRACER.enabled:
            for r in group:
                TRACER.add("serve.queue", "serve", r.t_submit,
                           t_disp - r.t_submit, tenant=r.tenant,
                           rows=int(r.vecs.shape[0]))
        with TRACER.span("serve.fuse", tier="serve", requests=len(group)):
            fused = np.concatenate([r.vecs for r in group])
            # one engine call at the max requested k: top-k lists are
            # prefix-consistent, so each request slices its own k back out
            k = max(r.k for r in group)
            B = fused.shape[0]
            # bucket the fused batch to a power of two so jitted engine
            # stages see a bounded set of shapes (each distinct B is its
            # own XLA compile); pad rows duplicate query 0, which §3.3
            # dedup makes free on the fetch path
            Bpad = pow2_pad(B, lo=1)
            if Bpad > B:
                fused = np.concatenate(
                    [fused, np.repeat(fused[:1], Bpad - B, axis=0)])
        with TRACER.span("serve.dispatch", tier="serve", batch=int(Bpad),
                         rows=int(B), k=int(k)):
            d, g, est = self.engine.search(fused, k=k)
        d, g = d[:B], g[:B]
        t_done = time.perf_counter()
        self.metrics.record_call(
            B, n_queries=B, net=est["net"], pool=est.get("pool"),
            engine={k2: est.get(k2, 0) for k2 in
                    ("cache_hits", "n_fetches", "n_rounds")})
        with TRACER.span("serve.merge", tier="serve", requests=len(group)):
            off = 0
            for r in group:
                m = r.vecs.shape[0]
                stats = copy.deepcopy(est)   # each request owns its stats
                                             # (est nests the net dict)
                stats["queue_s"] = t_disp - r.t_submit
                stats["route_s"] = est["meta_s"]
                stats["fetch_s"] = est["net"]["latency_s"]
                stats["serve_s"] = est["sub_s"]
                stats["fused_batch"] = B
                stats["total_s"] = t_done - r.t_submit
                self.metrics.record_request(stats["total_s"], {
                    "queue_s": stats["queue_s"], "route_s": est["meta_s"],
                    "plan_s": est["plan_s"], "fetch_s": stats["fetch_s"],
                    "serve_s": est["sub_s"]}, tenant=r.tenant)
                r.future.set_result((d[off:off + m, :r.k],
                                     g[off:off + m, :r.k], stats))
                self.metrics.note_served(r.tenant, m)
                off += m

    def _dispatch_insert(self, group: list[_Request]):
        t_disp = time.perf_counter()
        if TRACER.enabled:
            for r in group:
                TRACER.add("serve.queue", "serve", r.t_submit,
                           t_disp - r.t_submit, tenant=r.tenant,
                           rows=int(r.vecs.shape[0]))
        fused = np.concatenate([r.vecs for r in group])
        with TRACER.span("serve.dispatch", tier="serve",
                         rows=int(fused.shape[0]), kind="insert"):
            gids = self.engine.insert(fused)
        t_done = time.perf_counter()
        self.metrics.record_call(fused.shape[0],
                                 net=getattr(self.engine,
                                             "_last_insert_net", None))
        off = 0
        for r in group:
            m = r.vecs.shape[0]
            self.metrics.record_request(t_done - r.t_submit,
                                        {"queue_s": t_disp - r.t_submit},
                                        tenant=r.tenant)
            r.future.set_result(np.asarray(gids[off:off + m]))
            self.metrics.note_served(r.tenant, m)
            off += m
