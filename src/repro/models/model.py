"""Family dispatcher + abstract inputs for dry-runs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a given (arch x input-shape) cell — weak-type-correct,
shardable, zero allocation — exactly what ``jax.jit(...).lower(**specs)``
needs.  Modality frontends are stubs per the brief: whisper gets frame
embeddings, pixtral gets patch embeddings.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec, hybrid, mamba2
from repro.models import transformer as tfm
from repro.models.params import (ParamDef, abstract_params, count_params,
                                 init_params, param_pspecs, param_shardings)

_FAMS = {
    "dense": tfm, "moe": tfm, "vlm": tfm,
    "ssm": mamba2, "hybrid": hybrid, "encdec": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMS[cfg.family]


def param_defs(cfg: ModelConfig):
    return family_module(cfg).param_defs(cfg)


def serve_param_defs(cfg: ModelConfig):
    """Serving stores bf16 weights, TP-sharded only (no per-token FSDP
    gathers at decode)."""
    def conv(d: ParamDef) -> ParamDef:
        logical = tuple(None if ax == "fsdp" else ax for ax in d.logical)
        return ParamDef(d.shape, logical, d.init, d.scale, jnp.bfloat16)
    return jax.tree.map(conv, param_defs(cfg),
                        is_leaf=lambda x: isinstance(x, ParamDef))


def forward(cfg, params, batch: dict, *, mesh=None, remat=True,
            return_hidden=False):
    mod = family_module(cfg)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patches"] = batch.get("patches")
    return mod.forward(cfg, params, batch["tokens"], mesh=mesh, remat=remat,
                       return_hidden=return_hidden, **kw)


def prefill(cfg, params, batch: dict, cache_len: int, *, mesh=None):
    mod = family_module(cfg)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patches"] = batch.get("patches")
    return mod.prefill(cfg, params, batch["tokens"], cache_len, mesh=mesh,
                       **kw)


def decode_step(cfg, params, cache, tokens, pos, *, mesh=None):
    return family_module(cfg).decode_step(cfg, params, cache, tokens, pos,
                                          mesh=mesh)


def init_cache_abstract(cfg, batch: int, cache_len: int):
    return family_module(cfg).init_cache_abstract(cfg, batch, cache_len)


def cache_logical_spec(cfg, tp_size: int):
    return family_module(cfg).cache_logical_spec(cfg, tp_size)


# --------------------------------------------------------------- inputs

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract model inputs for one cell.  Keys depend on shape.kind."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    out: dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), i32)
        out["labels"] = sds((B, S), i32)
        if cfg.family == "encdec":
            out["frames"] = sds((B, cfg.enc_seq, cfg.d_model), f32)
        if cfg.family == "vlm":
            out["patches"] = sds((B, cfg.n_patches, cfg.d_model), f32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), i32)
        if cfg.family == "encdec":
            out["frames"] = sds((B, cfg.enc_seq, cfg.d_model), f32)
        if cfg.family == "vlm":
            out["patches"] = sds((B, cfg.n_patches, cfg.d_model), f32)
    else:  # decode: one new token against a cache of length S
        out["tokens"] = sds((B,), i32)
        out["pos"] = sds((B,), i32)
    return out


def input_logical_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Logical partition specs matching input_specs."""
    if shape.kind in ("train", "prefill"):
        out = {"tokens": ("batch", None)}
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        if cfg.family == "encdec":
            out["frames"] = ("batch", None, None)
        if cfg.family == "vlm":
            out["patches"] = ("batch", None, None)
        return out
    return {"tokens": ("batch",), "pos": ("batch",)}


# --------------------------------------------------------------- flops

def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D = batch
    tokens (one step).  Training counts fwd+bwd (x3 of 2ND)."""
    n = cfg.param_count(active_only=(cfg.family == "moe"))
    # exclude unembed? standard 6ND includes all matmul params; keep all.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
