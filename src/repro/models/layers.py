"""Shared neural building blocks (pure JAX, scan/remat friendly).

Attention comes in three flavours:
  * ``attend_blockwise`` — flash-style online-softmax over KV blocks
    (training / prefill; O(block) memory, causal + sliding-window masks,
    gemma2 score softcap).
  * ``attend_full`` — plain einsum path for short sequences / smoke tests.
  * ``attend_decode`` — single-token query against a (possibly
    sequence-sharded) KV cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ----------------------------------------------------------------- norms

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def l2_head_norm(x, scale, eps=1e-6):
    """qk-norm (qwen3): RMS-norm over head_dim with learned scale."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- masks

def _softcap(scores, cap: float):
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


# ----------------------------------------------------------------- attention

def attend_full(q, k, v, *, causal=True, window=0, softcap=0.0,
                q_offset=0, kv_positions=None):
    """q: (B, Sq, H, hd), k/v: (B, Skv, K, hd).  GQA via head grouping."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    w = jnp.asarray(window)
    mask &= (w <= 0) | (qpos[:, None] - kpos[None, :] < w)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, Sq, H, hd)


def attend_blockwise(q, k, v, *, causal=True, window=0, softcap=0.0,
                     block_q: int = 512, block_kv: int = 1024):
    """Double-blocked flash attention: ``lax.map`` over Q blocks, scan
    over KV blocks with online softmax.  Peak memory is O(bq x bkv) per
    head instead of O(S^2); future blocks are masked (static shapes)."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    if Skv % block_kv != 0 or Sq % block_q != 0:
        return attend_full(q, k, v, causal=causal, window=window, softcap=softcap)
    nq, nkv = Sq // block_q, Skv // block_kv
    scale = hd ** -0.5
    qb = q.reshape(B, nq, block_q, K, G, hd)
    kb = k.reshape(B, nkv, block_kv, K, hd)
    vb = v.reshape(B, nkv, block_kv, K, hd)
    w = jnp.asarray(window)

    def one_q_block(inp):
        qblk, iq = inp  # (B, bq, K, G, hd), scalar
        qg = qblk.astype(jnp.float32)
        qpos = iq * block_q + jnp.arange(block_q)

        def body(carry, inp2):
            m, l, acc = carry
            kblk, vblk, jk = inp2
            kpos = jk * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg,
                           kblk.astype(jnp.float32)) * scale
            s = _softcap(s, softcap)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            mask &= (w <= 0) | (qpos[:, None] - kpos[None, :] < w)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nkv)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, (1, 2), (2, 3))  # (B, bq, K, G, hd)

    out = lax.map(one_q_block, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1)  # (B, nq, bq, K, G, hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attend(q, k, v, *, causal=True, window=0, softcap=0.0,
           blockwise_threshold: int = 1024):
    import os
    from repro.models import flash
    if os.environ.get("REPRO_FORCE_FULL_ATTENTION"):
        # costing hook (benchmarks/hlo_cost.py): einsum path has the
        # exact same matmul flops but no inner scans to undercount
        return attend_full(q, k, v, causal=causal, window=window,
                           softcap=softcap)
    if q.shape[1] >= blockwise_threshold and flash.flash_ok(q.shape[1],
                                                            k.shape[1]):
        return flash.flash_attention(q, k, v, window=window, causal=causal,
                                     softcap=softcap)
    return attend_full(q, k, v, causal=causal, window=window, softcap=softcap)


def attend_decode(q, k_cache, v_cache, pos, *, window=0, softcap=0.0):
    """One-token decode.  q: (B, H, hd); caches: (B, S, K, hd);
    pos: (B,) current positions (token being written is at cache[pos])."""
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    kpos = jnp.arange(S)
    mask = kpos[None] <= pos[:, None]  # (B, S)
    w = jnp.asarray(window)
    mask &= (w <= 0) | (pos[:, None] - kpos[None] < w)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return out.reshape(B, H, hd)


def scatter_kv(cache, new, pos):
    """Write one token into the cache.  cache: (B, S, K, hd),
    new: (B, K, hd), pos: (B,)."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new.astype(cache.dtype))


# ----------------------------------------------------------------- mlp

def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def gelu_mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


# ----------------------------------------------------------------- loss

def softcap_logits(logits, cap: float):
    return _softcap(logits, cap)


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """logits: (B, S, V) possibly V-sharded; labels: (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - true_logit
    valid = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def _vocab_shard(logits, mesh):
    """§Perf cell C iter-3: pin per-chunk logits to vocab(TP)-sharded —
    lse/true-logit reductions then cross shards as (B, chunk) scalars
    instead of the partitioner resharding (B, chunk, V) with permutes."""
    import os
    if mesh is None or not os.environ.get("REPRO_SHARDED_CE"):
        return logits
    if "model" not in mesh.axis_names or logits.shape[-1] % mesh.shape["model"]:
        return logits
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    return lax.with_sharding_constraint(
        logits, NamedSharding(mesh, P(batch_axes, None, "model")))


def chunked_cross_entropy(x, unembed, labels, *, softcap=0.0,
                          ignore_id: int = -1, chunk: int = 512,
                          mesh=None):
    """CE without materializing full (B, S, V) fp32 logits: scan over S
    chunks, rematerializing each chunk's logits in the backward pass.
    x: (B, S, d) final normed hidden; unembed: (d, V)."""
    B, S, d = x.shape
    if S % chunk != 0 or S <= chunk:
        logits = x @ unembed.astype(x.dtype)
        return cross_entropy(softcap_logits(logits.astype(jnp.float32),
                                            softcap), labels,
                             ignore_id=ignore_id)
    nc = S // chunk
    xs = jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(carry, inp):
        nll_sum, n_valid = carry
        xc, lc = inp
        logits = (xc @ unembed.astype(xc.dtype)).astype(jnp.float32)
        logits = _vocab_shard(logits, mesh)
        logits = _softcap(logits, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(lc, logits.shape[-1], dtype=jnp.float32)
        true_logit = jnp.sum(logits * oh, axis=-1)
        valid = (lc != ignore_id).astype(jnp.float32)
        nll = (lse - true_logit) * valid
        return (nll_sum + nll.sum(), n_valid + valid.sum()), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (nll_sum, n_valid), _ = lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return nll_sum / jnp.maximum(n_valid, 1.0)
