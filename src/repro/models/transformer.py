"""Dense decoder-only transformer (also the backbone for moe / vlm).

Layers are stacked on a leading L axis and driven by ``lax.scan`` so the
HLO is depth-independent; the scan body is ``jax.checkpoint``-ed for
training (remat).  Per-layer heterogeneity (gemma2 local/global windows)
rides along as scan xs.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.params import ParamDef

# ------------------------------------------------------------------ defs

def block_param_defs(cfg: ModelConfig, n_layers: int, stacked: bool = True):
    d, hd = cfg.d_model, cfg.the_head_dim()
    H, K = cfg.n_heads, cfg.n_kv_heads
    Lx = (n_layers,) if stacked else ()
    st = (None,) if stacked else ()
    defs = {
        "attn_norm": ParamDef(Lx + (d,), st + (None,), init="zeros"),
        "wq": ParamDef(Lx + (d, H * hd), st + ("fsdp", "tp")),
        "wk": ParamDef(Lx + (d, K * hd), st + ("fsdp", "tp")),
        "wv": ParamDef(Lx + (d, K * hd), st + ("fsdp", "tp")),
        "wo": ParamDef(Lx + (H * hd, d), st + ("tp", "fsdp")),
        "mlp_norm": ParamDef(Lx + (d,), st + (None,), init="zeros"),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef(Lx + (hd,), st + (None,), init="zeros")
        defs["k_norm"] = ParamDef(Lx + (hd,), st + (None,), init="zeros")
    if cfg.family == "moe":
        defs.update(moe_lib.moe_param_defs(cfg, Lx, st))
        if cfg.shared_expert:
            defs.update({
                "se_wg": ParamDef(Lx + (d, cfg.d_ff), st + ("fsdp", "tp")),
                "se_wu": ParamDef(Lx + (d, cfg.d_ff), st + ("fsdp", "tp")),
                "se_wd": ParamDef(Lx + (cfg.d_ff, d), st + ("tp", "fsdp")),
            })
    else:
        defs.update({
            "wg": ParamDef(Lx + (d, cfg.d_ff), st + ("fsdp", "tp")),
            "wu": ParamDef(Lx + (d, cfg.d_ff), st + ("fsdp", "tp")),
            "wd": ParamDef(Lx + (cfg.d_ff, d), st + ("tp", "fsdp")),
        })
    return defs


def param_defs(cfg: ModelConfig):
    d = cfg.d_model
    defs = {
        "embed": ParamDef((cfg.vocab_size, d), ("tp", "fsdp"), scale=1.0),
        "blocks": block_param_defs(cfg, cfg.n_layers),
        "final_norm": ParamDef((d,), (None,), init="zeros"),
        "unembed": ParamDef((d, cfg.vocab_size), ("fsdp", "tp")),
    }
    if cfg.family == "vlm":
        defs["patch_proj"] = ParamDef((d, d), ("fsdp", "tp"))
    return defs


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding-window sizes (0 = global)."""
    if cfg.local_global_pattern:
        w = np.zeros(cfg.n_layers, np.int32)
        w[::2] = cfg.local_window  # even layers local, odd global (gemma2)
        return w
    return np.full(cfg.n_layers, cfg.local_window, np.int32)


# ------------------------------------------------------------------ blocks

def _attn_block(cfg: ModelConfig, p, x, window, *, mode, cache=None,
                pos=None, mesh=None):
    """x: (B, S, d) for train/prefill; (B, 1, d) for decode."""
    from repro.models.params import shard_heads
    dt = x.dtype
    hd = cfg.the_head_dim()
    H, K = cfg.n_heads, cfg.n_kv_heads
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    B, S, _ = h.shape
    q = (h @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (h @ p["wk"].astype(dt)).reshape(B, S, K, hd)
    v = (h @ p["wv"].astype(dt)).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = L.l2_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.l2_head_norm(k, p["k_norm"], cfg.norm_eps)
    if mode == "decode":
        positions = pos[:, None]  # (B, 1)
    else:
        positions = jnp.arange(S)[None, :]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if mode != "decode":
        q, k, v = (shard_heads(t, mesh) for t in (q, k, v))

    def _attend_tp(q, k, v):
        """Attention with TP-friendly head padding: when H doesn't divide
        the model axis (llama4: 40 heads on tp=16), pad the GQA group dim
        so K*G' divides tp, shard the padded heads, slice back after."""
        tp = (mesh.shape["model"]
              if mesh is not None and "model" in mesh.axis_names else 1)
        if tp <= 1 or H % tp == 0 or H <= tp:
            out = L.attend(q, k, v, causal=True, window=window,
                           softcap=cfg.attn_softcap)
            return shard_heads(out, mesh)
        G = H // K
        Gp = G
        while (K * Gp) % tp:
            Gp += 1
        qg = q.reshape(B, S, K, G, hd)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, Gp - G), (0, 0)))
        qp = shard_heads(qg.reshape(B, S, K * Gp, hd), mesh)
        out = L.attend(qp, k, v, causal=True, window=window,
                       softcap=cfg.attn_softcap)
        out = shard_heads(out, mesh)
        out = out.reshape(B, S, K, Gp, hd)[:, :, :, :G]
        return out.reshape(B, S, H, hd)

    if mode == "decode":
        kc, vc = cache  # (B, Smax, K, hd)
        kc = L.scatter_kv(kc, k[:, 0], pos)
        vc = L.scatter_kv(vc, v[:, 0], pos)
        out = L.attend_decode(q[:, 0], kc, vc, pos, window=window,
                              softcap=cfg.attn_softcap)[:, None]
        new_cache = (kc, vc)
    else:
        out = _attend_tp(q, k, v)
        new_cache = (k, v) if mode == "prefill" else None
    y = out.reshape(B, S, H * hd) @ p["wo"].astype(dt)
    return x + y, new_cache


def _mlp_block(cfg: ModelConfig, p, x, mesh=None):
    dt = x.dtype
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = moe_lib.moe_ffn(cfg, p, h, mesh=mesh)
        if cfg.shared_expert:
            y = y + L.swiglu(h, p["se_wg"].astype(dt), p["se_wu"].astype(dt),
                             p["se_wd"].astype(dt))
    else:
        y = L.swiglu(h, p["wg"].astype(dt), p["wu"].astype(dt),
                     p["wd"].astype(dt))
    return x + y, aux


def block(cfg: ModelConfig, p, x, window, *, mode, cache=None, pos=None,
          mesh=None):
    x, new_cache = _attn_block(cfg, p, x, window, mode=mode, cache=cache,
                               pos=pos, mesh=mesh)
    x, aux = _mlp_block(cfg, p, x, mesh=mesh)
    return x, new_cache, aux


# ------------------------------------------------------------------ model

def embed_tokens(cfg, params, tokens, patches=None):
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.family == "vlm" and patches is not None:
        pe = (patches.astype(dt) @ params["patch_proj"].astype(dt))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward(cfg: ModelConfig, params, tokens, *, patches=None, mesh=None,
            remat=True, return_hidden=False):
    """Full-sequence forward -> (logits (B, S_total, V), moe aux loss).
    With return_hidden=True, returns the final normed hidden instead of
    logits (training path: the loss does chunked CE)."""
    from repro.models.params import seq_shard
    x = embed_tokens(cfg, params, tokens, patches)
    x = seq_shard(x, mesh)
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, inp):
        x, aux_sum = carry
        p, w = inp
        y, _, aux = block(cfg, p, x, w, mode="train", mesh=mesh)
        return (seq_shard(y, mesh), aux_sum + aux), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           (params["blocks"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = aux / max(cfg.n_layers, 1)
    if return_hidden:
        return x, aux
    logits = x @ params["unembed"].astype(x.dtype)
    logits = L.softcap_logits(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits, aux


def prefill(cfg: ModelConfig, params, tokens, cache_len: int, *,
            patches=None, mesh=None):
    """Prefill: returns (last-token logits, populated KV cache)."""
    x = embed_tokens(cfg, params, tokens, patches)
    S = x.shape[1]
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, inp):
        p, w = inp
        y, kv, _ = block(cfg, p, x, w, mode="prefill", mesh=mesh)
        k, v = kv
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        return y, (jnp.pad(k, pad), jnp.pad(v, pad))

    x, caches = lax.scan(body, x, (params["blocks"], windows))
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(x.dtype)
    return L.softcap_logits(logits.astype(jnp.float32), cfg.logit_softcap), caches


def decode_step(cfg: ModelConfig, params, cache, tokens, pos, *, mesh=None):
    """One decode step.  tokens: (B,), pos: (B,) write positions.
    cache: (k, v) each (L, B, Smax, K, hd).  Returns (logits, new_cache)."""
    x = embed_tokens(cfg, params, tokens[:, None])
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, inp):
        p, w, kc, vc = inp
        y, (kc, vc), _ = block(cfg, p, x, w, mode="decode", cache=(kc, vc),
                               pos=pos, mesh=mesh)
        return y, (kc, vc)

    x, new_cache = lax.scan(body, x, (params["blocks"], windows,
                                      cache[0], cache[1]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["unembed"].astype(x.dtype)
    return L.softcap_logits(logits.astype(jnp.float32), cfg.logit_softcap), new_cache


def init_cache_abstract(cfg: ModelConfig, batch: int, cache_len: int):
    hd = cfg.the_head_dim()
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd)
    dt = jnp.dtype(cfg.dtype)
    return (jax.ShapeDtypeStruct(shape, dt), jax.ShapeDtypeStruct(shape, dt))


def cache_logical_spec(cfg: ModelConfig, tp_size: int):
    """(L, B, S, K, hd): shard K over tp when divisible, else shard S."""
    if cfg.n_kv_heads and tp_size and cfg.n_kv_heads % tp_size == 0:
        spec = (None, "batch", None, "tp", None)
    else:
        spec = (None, "batch", "seq", None, None)
    return (spec, spec)  # (k, v)
