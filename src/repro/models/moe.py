"""Mixture-of-Experts FFN with two execution paths.

* ``_moe_shardmap`` — production path (mesh present, many tokens):
  activations are replicated across the ``model`` axis (Megatron-style),
  experts are sharded over ``model`` (expert parallel) and their ff dim is
  FSDP-sharded over ``data`` (gathered just-in-time).  Each expert owner
  selects its tokens *locally* (tokens are replicated across the EP axis,
  so no dispatch all-to-all is needed), runs the expert matmuls at full
  MXU efficiency, and the combined output is ``psum``-reduced over
  ``model`` — the same collective the TP FFN already pays.

* ``_moe_dense`` — small-token path (decode, smoke tests, meshless):
  classic capacity-based one-hot dispatch einsum.

Both paths use top-k routing with softmax-renormalised gates and
capacity-factor token dropping; both return ``(y, aux_loss)`` where aux
is the standard load-balance loss (Switch/GShard form).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.params import ParamDef


def moe_param_defs(cfg, Lx, st):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    return {
        "router": ParamDef(Lx + (d, E), st + (None, None)),
        "we_g": ParamDef(Lx + (E, d, f), st + ("tp", None, "fsdp")),
        "we_u": ParamDef(Lx + (E, d, f), st + ("tp", None, "fsdp")),
        "we_d": ParamDef(Lx + (E, f, d), st + ("tp", "fsdp", None)),
    }


def _route(cfg, xf, router):
    """xf: (T, d) -> (top_p, top_i) each (T, k) and aux load-balance loss."""
    logits = (xf.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e mean(frac_e) * mean(prob_e)
    E = cfg.n_experts
    counts = jnp.zeros(E).at[top_i.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    mean_p = probs.mean(0)
    aux = E * jnp.sum(frac * mean_p)
    return top_p, top_i, aux


def _capacity(cfg, n_tokens: int, ep: int = 1) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(c, 4)


def _expert_mm(buf, wg, wu, wd, dt):
    """buf: (E?, C, d); weights (E?, d, f)/(E?, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd).astype(dt)


# ------------------------------------------------------------- dense path

def _moe_dense(cfg, p, x):
    dt = x.dtype
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    top_p, top_i, aux = _route(cfg, xf, p["router"])
    k, E = cfg.moe_top_k, cfg.n_experts
    C = _capacity(cfg, T)
    fe = top_i.reshape(-1)  # (T*k,)
    fp = top_p.reshape(-1)
    ft = jnp.repeat(jnp.arange(T), k)
    # rank of each assignment within its expert (stable, order-of-arrival)
    oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)  # (T*k, E)
    rank = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(T * k), fe]
    keep = rank < C
    slot = jnp.where(keep, fe * C + rank, E * C)  # E*C = dump row
    buf = jnp.zeros((E * C + 1, d), dt).at[slot].add(
        xf[ft] * keep[:, None].astype(dt))
    buf = buf[:-1].reshape(E, C, d)
    out = _expert_mm(buf, p["we_g"].astype(dt), p["we_u"].astype(dt),
                     p["we_d"].astype(dt), dt)
    flat = jnp.concatenate([out.reshape(E * C, d),
                            jnp.zeros((1, d), dt)], axis=0)
    contrib = flat[slot] * (fp[:, None] * keep[:, None]).astype(dt)
    y = jnp.zeros((T, d), dt).at[ft].add(contrib)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------- shard_map path

def _moe_shardmap(cfg, p, x, mesh):
    dt = x.dtype
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    ep_ax = "model"
    fsdp_ax = "data" if "data" in names else None
    ep = mesh.shape[ep_ax]
    E = cfg.n_experts
    assert E % ep == 0, f"experts {E} not divisible by EP size {ep}"
    E_loc = E // ep
    f = cfg.expert_d_ff
    fsdp = mesh.shape[fsdp_ax] if fsdp_ax else 1
    shard_f = fsdp_ax is not None and f % fsdp == 0

    def inner(x_loc, router, wg, wu, wd):
        B_loc, S, d = x_loc.shape
        xf = x_loc.reshape(-1, d)
        T = xf.shape[0]
        top_p, top_i, aux = _route(cfg, xf, router)
        k = cfg.moe_top_k
        C = _capacity(cfg, T, ep)
        my = lax.axis_index(ep_ax)
        fe = top_i.reshape(-1)
        fp = top_p.reshape(-1)
        ft = jnp.repeat(jnp.arange(T), k)
        order = jnp.argsort(fe, stable=True)
        se, sp, stk = fe[order], fp[order], ft[order]
        first = jnp.searchsorted(se, se, side="left")
        rank = jnp.arange(T * k) - first
        keep = rank < C
        rel = se - my * E_loc
        mine = (rel >= 0) & (rel < E_loc) & keep
        slot = jnp.where(mine, rel * C + rank, E_loc * C)
        buf = jnp.zeros((E_loc * C + 1, d), dt).at[slot].add(
            xf[stk] * mine[:, None].astype(dt))
        buf = buf[:-1].reshape(E_loc, C, d)
        if shard_f:  # FSDP: gather expert weights just-in-time (bf16 wire)
            wg_g = lax.all_gather(wg.astype(dt), fsdp_ax, axis=2, tiled=True)
            wu_g = lax.all_gather(wu.astype(dt), fsdp_ax, axis=2, tiled=True)
            wd_g = lax.all_gather(wd.astype(dt), fsdp_ax, axis=1, tiled=True)
        else:
            wg_g, wu_g, wd_g = wg.astype(dt), wu.astype(dt), wd.astype(dt)
        out = _expert_mm(buf, wg_g, wu_g, wd_g, dt)
        flat = jnp.concatenate([out.reshape(E_loc * C, d),
                                jnp.zeros((1, d), dt)], axis=0)
        contrib = flat[slot] * (sp[:, None] * mine[:, None]).astype(dt)
        y = jnp.zeros((T, d), dt).at[stk].add(contrib)
        y = lax.psum(y, ep_ax)
        return y.reshape(B_loc, S, d), aux

    wspec_gu = P(ep_ax, None, fsdp_ax if shard_f else None)
    wspec_d = P(ep_ax, fsdp_ax if shard_f else None, None)
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(batch_axes or None, None, None), P(None, None),
                  wspec_gu, wspec_gu, wspec_d),
        out_specs=(P(batch_axes or None, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["we_g"], p["we_u"], p["we_d"])
    return y, aux


def moe_ffn(cfg, p, x, mesh=None):
    """x: (B, S, d) -> (y, aux_loss)."""
    use_shardmap = (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and cfg.n_experts % mesh.shape["model"] == 0
    )
    if use_shardmap:
        return _moe_shardmap(cfg, p, x, mesh)
    return _moe_dense(cfg, p, x)
