"""Flash attention with a custom VJP (pure JAX, TPU-shaped blocks).

The forward is double-blocked online softmax; the backward *recomputes*
block scores instead of saving them (saved residuals: q, k, v, out, m, l
— O(S) memory, never O(S^2)).  Without this, the backward of the nested
scans would stash every block's probabilities and reconstruct the full
attention matrix in fp32.

Supports GQA (q heads grouped over kv heads), causal masking, sliding
windows (traced per-layer scalar — gemma2 local/global), and gemma2-style
score softcap (tanh), whose derivative is handled analytically in bwd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def _mask(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    w = jnp.asarray(window)
    m &= (w <= 0) | (qpos[:, None] - kpos[None, :] < w)
    return m


def _fwd_impl(q, k, v, window, causal, softcap, block_q, block_kv):
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    nq, nkv = Sq // block_q, Skv // block_kv
    scale = hd ** -0.5
    qb = jnp.moveaxis(q.reshape(B, nq, block_q, K, G, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nkv, block_kv, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nkv, block_kv, K, hd), 1, 0)

    def one_q(inp):
        qblk, iq = inp
        qg = qblk.astype(F32)
        qpos = iq * block_q + jnp.arange(block_q)

        def body(carry, inp2):
            m, l, acc = carry
            kblk, vblk, jk = inp2
            kpos = jk * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kblk.astype(F32)) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            s = jnp.where(_mask(qpos, kpos, causal, window)[None, None, None],
                          s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vblk.astype(F32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, block_q), -1e30, F32)
        l0 = jnp.zeros((B, K, G, block_q), F32)
        a0 = jnp.zeros((B, K, G, block_q, hd), F32)
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nkv)))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(o, (1, 2), (2, 3)), m, l  # (B,bq,K,G,hd), ...

    out, m, l = lax.map(one_q, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    m = jnp.moveaxis(m, 0, 3).reshape(B, K, G, Sq)  # (nq,B,K,G,bq)->(B,K,G,nq*bq)
    l = jnp.moveaxis(l, 0, 3).reshape(B, K, G, Sq)
    return out, m, l


def _bwd_impl(q, k, v, out, m, l, dout, window, causal, softcap,
              block_q, block_kv):
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    nq, nkv = Sq // block_q, Skv // block_kv
    scale = hd ** -0.5
    do = dout.astype(F32).reshape(B, Sq, K, G, hd)
    of = out.astype(F32).reshape(B, Sq, K, G, hd)
    D = jnp.einsum("bskgh,bskgh->bkgs", do, of)  # (B,K,G,Sq)

    qb = jnp.moveaxis(q.reshape(B, nq, block_q, K, G, hd), 1, 0)
    dob = jnp.moveaxis(do.reshape(B, nq, block_q, K, G, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nkv, block_kv, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nkv, block_kv, K, hd), 1, 0)
    mb = jnp.moveaxis(m.reshape(B, K, G, nq, block_q), 3, 0)
    lb = jnp.moveaxis(l.reshape(B, K, G, nq, block_q), 3, 0)
    Db = jnp.moveaxis(D.reshape(B, K, G, nq, block_q), 3, 0)

    def q_loop(carry, inp):
        dk_all, dv_all = carry  # (B, Skv, K, hd) f32 each
        qi, doi, mi, li, Di, iq = inp
        qg = qi.astype(F32)
        qpos = iq * block_q + jnp.arange(block_q)
        li_safe = jnp.maximum(li, 1e-30)

        def kv_loop(c2, inp2):
            dq_i, dk_all, dv_all = c2
            kj, vj, jk = inp2
            kpos = jk * block_kv + jnp.arange(block_kv)
            kjf, vjf = kj.astype(F32), vj.astype(F32)
            s_raw = jnp.einsum("bqkgh,bskh->bkgqs", qg, kjf) * scale
            if softcap:
                t = jnp.tanh(s_raw / softcap)
                s = t * softcap
            else:
                s = s_raw
            msk = _mask(qpos, kpos, causal, window)[None, None, None]
            s = jnp.where(msk, s, -1e30)
            p = jnp.exp(s - mi[..., None]) / li_safe[..., None]
            dp = jnp.einsum("bqkgh,bskh->bkgqs", doi, vjf)
            dc = p * (dp - Di[..., None])
            if softcap:
                ds = dc * (1.0 - t * t)
            else:
                ds = dc
            ds = jnp.where(msk, ds, 0.0)
            dq_i = dq_i + jnp.einsum("bkgqs,bskh->bqkgh", ds, kjf) * scale
            dk_j = jnp.einsum("bkgqs,bqkgh->bskh", ds, qg) * scale
            dv_j = jnp.einsum("bkgqs,bqkgh->bskh", p, doi)
            sl = (0, jk * block_kv, 0, 0)
            dk_all = lax.dynamic_update_slice(
                dk_all, lax.dynamic_slice(dk_all, sl, dk_j.shape) + dk_j, sl)
            dv_all = lax.dynamic_update_slice(
                dv_all, lax.dynamic_slice(dv_all, sl, dv_j.shape) + dv_j, sl)
            return (dq_i, dk_all, dv_all), None

        dq0 = jnp.zeros((B, block_q, K, G, hd), F32)
        (dq_i, dk_all, dv_all), _ = lax.scan(
            kv_loop, (dq0, dk_all, dv_all), (kb, vb, jnp.arange(nkv)))
        return (dk_all, dv_all), dq_i

    dk0 = jnp.zeros((B, Skv, K, hd), F32)
    dv0 = jnp.zeros((B, Skv, K, hd), F32)
    (dk, dv), dqs = lax.scan(q_loop, (dk0, dv0),
                             (qb, dob, mb, lb, Db, jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, H, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, window, causal, softcap, block_q, block_kv):
    out, _, _ = _fwd_impl(q, k, v, window, causal, softcap, block_q, block_kv)
    return out


def _fa_fwd(q, k, v, window, causal, softcap, block_q, block_kv):
    out, m, l = _fwd_impl(q, k, v, window, causal, softcap, block_q, block_kv)
    return out, (q, k, v, out, m, l, window)


def _fa_bwd(causal, softcap, block_q, block_kv, res, dout):
    q, k, v, out, m, l, window = res
    dq, dk, dv = _bwd_impl(q, k, v, out, m, l, dout, window, causal,
                           softcap, block_q, block_kv)
    return dq, dk, dv, jnp.zeros_like(window)


_flash.defvjp(_fa_fwd, _fa_bwd)


def _pick_block(s: int, target: int) -> int:
    if s <= target:
        return s
    for b in range(target, 127, -1):
        if s % b == 0:
            return b
    return 0  # no usable block size


def flash_attention(q, k, v, *, window=0, causal=True, softcap=0.0,
                    block_q=512, block_kv=1024):
    """q: (B, Sq, H, hd); k/v: (B, Skv, K, hd); window: traced scalar or
    int (<=0 disables).  Returns (B, Sq, H, hd)."""
    bq = _pick_block(q.shape[1], block_q)
    bkv = _pick_block(k.shape[1], block_kv)
    if not bq or not bkv:
        raise ValueError(f"no block size for Sq={q.shape[1]} Skv={k.shape[1]}")
    w = jnp.asarray(window, F32)
    return _flash(q, k, v, w, causal, softcap, bq, bkv)


def flash_ok(q_len: int, kv_len: int) -> bool:
    return bool(_pick_block(q_len, 512)) and bool(_pick_block(kv_len, 1024))
