"""whisper-style encoder-decoder backbone.

The conv/log-mel audio frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d_model).  Positions
are sinusoidal (adaptation: whisper-tiny's learned decoder positions cap
at 448; the assigned synthetic stress shapes need arbitrary lengths).

Decoder layers: causal self-attention (KV cache) + cross-attention to the
encoder output (cross-KV computed once at prefill and cached) + MLP.
Whisper predates SwiGLU; we keep GELU MLPs for the family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef


def _sinusoid(positions, d):
    """positions: (...,) -> (..., d) sinusoidal embeddings."""
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_defs(cfg, Lx, st, prefix=""):
    d, hd = cfg.d_model, cfg.the_head_dim()
    H, K = cfg.n_heads, cfg.n_kv_heads
    return {
        prefix + "norm": ParamDef(Lx + (d,), st + (None,), init="zeros"),
        prefix + "wq": ParamDef(Lx + (d, H * hd), st + ("fsdp", "tp")),
        prefix + "wk": ParamDef(Lx + (d, K * hd), st + ("fsdp", "tp")),
        prefix + "wv": ParamDef(Lx + (d, K * hd), st + ("fsdp", "tp")),
        prefix + "wo": ParamDef(Lx + (H * hd, d), st + ("tp", "fsdp")),
    }


def _mlp_defs(cfg, Lx, st):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mlp_norm": ParamDef(Lx + (d,), st + (None,), init="zeros"),
        "w1": ParamDef(Lx + (d, f), st + ("fsdp", "tp")),
        "w2": ParamDef(Lx + (f, d), st + ("tp", "fsdp")),
    }


def param_defs(cfg: ModelConfig):
    d = cfg.d_model
    Le, Ld = (cfg.n_enc_layers,), (cfg.n_layers,)
    st = (None,)
    enc_blocks = {**_attn_defs(cfg, Le, st), **_mlp_defs(cfg, Le, st)}
    dec_blocks = {**_attn_defs(cfg, Ld, st),
                  **_attn_defs(cfg, Ld, st, prefix="x_"),
                  **_mlp_defs(cfg, Ld, st)}
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("tp", "fsdp")),
        "enc_blocks": enc_blocks,
        "enc_norm": ParamDef((d,), (None,), init="zeros"),
        "dec_blocks": dec_blocks,
        "final_norm": ParamDef((d,), (None,), init="zeros"),
        "unembed": ParamDef((d, cfg.vocab_size), ("fsdp", "tp")),
    }


def _mha(cfg, p, x, kv_src, *, prefix="", causal, cache=None, pos=None,
         q_positions=None):
    """Generic attention sub-block.  kv_src: tensor to project K/V from."""
    dt0 = x.dtype
    d, hd = cfg.d_model, cfg.the_head_dim()
    H, K = cfg.n_heads, cfg.n_kv_heads
    h = L.rms_norm(x, p[prefix + "norm"], cfg.norm_eps)
    B, S, _ = h.shape
    q = (h @ p[prefix + "wq"].astype(dt0)).reshape(B, S, H, hd)
    if cache is not None and prefix == "x_":
        k, v = cache  # precomputed cross-KV
        out = L.attend_full(q[:, :1] if S == 1 else q, k, v, causal=False) \
            if S == 1 else L.attend(q, k, v, causal=False)
        y = out.reshape(B, S, H * hd) @ p[prefix + "wo"].astype(dt0)
        return x + y, cache
    kv = L.rms_norm(kv_src, p[prefix + "norm"], cfg.norm_eps) \
        if kv_src is not x else h
    Skv = kv.shape[1]
    k = (kv @ p[prefix + "wk"].astype(dt0)).reshape(B, Skv, K, hd)
    v = (kv @ p[prefix + "wv"].astype(dt0)).reshape(B, Skv, K, hd)
    if cache is not None:  # decode self-attention
        kc, vc = cache
        kc = L.scatter_kv(kc, k[:, 0], pos)
        vc = L.scatter_kv(vc, v[:, 0], pos)
        out = L.attend_decode(q[:, 0], kc, vc, pos)[:, None]
        new_cache = (kc, vc)
    else:
        out = L.attend(q, k, v, causal=causal)
        new_cache = (k, v)
    y = out.reshape(B, S, H * hd) @ p[prefix + "wo"].astype(dt0)
    return x + y, new_cache


def _mlp(cfg, p, x):
    dt0 = x.dtype
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + L.gelu_mlp(h, p["w1"].astype(dt0), p["w2"].astype(dt0))


def encode(cfg, params, frames):
    """frames: (B, enc_seq, d) stub embeddings -> encoder output."""
    dt0 = jnp.dtype(cfg.dtype)
    x = frames.astype(dt0) + _sinusoid(jnp.arange(frames.shape[1]),
                                       cfg.d_model).astype(dt0)[None]

    def body(x, p):
        y, _ = _mha(cfg, p, x, x, causal=False)
        y = _mlp(cfg, p, y)
        return y, None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg, params, tokens, *, frames=None, mesh=None, remat=True,
            patches=None, return_hidden=False):
    """Training forward: frames + teacher-forced tokens -> logits."""
    dt0 = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, frames)
    S = tokens.shape[1]
    x = params["embed"].astype(dt0)[tokens]
    x = x + _sinusoid(jnp.arange(S), cfg.d_model).astype(dt0)[None]

    def body(x, p):
        y, _ = _mha(cfg, p, x, x, causal=True)
        y, _ = _mha(cfg, p, y, enc, prefix="x_", causal=False)
        y = _mlp(cfg, p, y)
        return y, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def init_cache_abstract(cfg, batch: int, cache_len: int):
    hd = cfg.the_head_dim()
    dt0 = jnp.dtype(cfg.dtype)
    Lr = cfg.n_layers
    kv = jax.ShapeDtypeStruct((Lr, batch, cache_len, cfg.n_kv_heads, hd), dt0)
    xkv = jax.ShapeDtypeStruct((Lr, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dt0)
    return (kv, kv, xkv, xkv)


def cache_logical_spec(cfg, tp_size: int):
    if cfg.n_kv_heads and tp_size and cfg.n_kv_heads % tp_size == 0:
        kv = (None, "batch", None, "tp", None)
        xkv = (None, "batch", None, "tp", None)
    else:
        kv = (None, "batch", "seq", None, None)
        xkv = (None, "batch", None, None, None)
    return (kv, kv, xkv, xkv)


def prefill(cfg, params, tokens, cache_len: int, *, frames=None, mesh=None,
            patches=None):
    dt0 = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, frames)
    S = tokens.shape[1]
    x = params["embed"].astype(dt0)[tokens]
    x = x + _sinusoid(jnp.arange(S), cfg.d_model).astype(dt0)[None]
    hd = cfg.the_head_dim()
    K = cfg.n_kv_heads
    B = tokens.shape[0]

    def body(x, p):
        y, (k, v) = _mha(cfg, p, x, x, causal=True)
        # cross-KV computed once here, cached for decode
        kvn = L.rms_norm(enc, p["x_norm"], cfg.norm_eps)
        xk = (kvn @ p["x_wk"].astype(dt0)).reshape(B, -1, K, hd)
        xv = (kvn @ p["x_wv"].astype(dt0)).reshape(B, -1, K, hd)
        y, _ = _mha(cfg, p, y, enc, prefix="x_", causal=False)
        y = _mlp(cfg, p, y)
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        return y, (jnp.pad(k, pad), jnp.pad(v, pad), xk, xv)

    x, (kc, vc, xk, xv) = lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), (kc, vc, xk, xv)


def decode_step(cfg, params, cache, tokens, pos, *, mesh=None):
    dt0 = jnp.dtype(cfg.dtype)
    kc, vc, xk, xv = cache
    x = params["embed"].astype(dt0)[tokens[:, None]]
    x = x + _sinusoid(pos, cfg.d_model).astype(dt0)[:, None]

    def body(x, inp):
        p, kci, vci, xki, xvi = inp
        y, (kci, vci) = _mha(cfg, p, x, x, causal=True, cache=(kci, vci),
                             pos=pos)
        y, _ = _mha(cfg, p, y, None, prefix="x_", causal=False,
                    cache=(xki, xvi))
        y = _mlp(cfg, p, y)
        return y, (kci, vci)

    x, (kc, vc) = lax.scan(body, x, (params["dec_blocks"], kc, vc, xk, xv))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), (kc, vc, xk, xv)
