"""Parameter definition + logical-axis sharding machinery.

Params are nested dicts of arrays.  Each leaf is declared once as a
``ParamDef`` carrying shape, dtype, init scale and a *logical* partition
spec (axis names like "fsdp"/"tp"); ``resolve_specs`` maps logical names
onto whatever mesh axes actually exist ("data", "model", optionally
"pod"), dropping axes that don't divide the dimension (GSPMD could pad,
but exact shards keep memory analysis honest).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis name -> function(mesh axis names) -> physical axis (or None)
_LOGICAL = {
    "batch": lambda names: tuple(a for a in ("pod", "data") if a in names) or None,
    "fsdp": lambda names: "data" if "data" in names else None,
    "tp": lambda names: "model" if "model" in names else None,
    "seq": lambda names: "model" if "model" in names else None,
    "pod": lambda names: "pod" if "pod" in names else None,
    None: lambda names: None,
}


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        return int(np.prod([mesh.shape[a] for a in phys]))
    return mesh.shape[phys]


def resolve_spec(logical: tuple, shape: tuple, mesh: Optional[Mesh]) -> P:
    """Logical spec -> PartitionSpec valid on `mesh` (divisibility-checked)."""
    if mesh is None:
        return P()
    names = mesh.axis_names
    out = []
    for dim, log in zip(shape, logical):
        phys = _LOGICAL[log](names) if log in _LOGICAL else None
        if phys is not None and dim % _axis_size(mesh, phys) == 0 and dim > 0:
            out.append(phys)
        else:
            out.append(None)
    # trailing Nones are implicit
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(mesh: Optional[Mesh], logical: tuple, shape: tuple):
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh))


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple  # logical partition spec, one entry per dim (None ok)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0
    dtype: Any = jnp.float32

    def abstract(self):
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, d.shape)).astype(d.dtype)


def init_params(defs, key) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [init_leaf(d, k) for d, k in zip(leaves, keys)])


def abstract_params(defs) -> Any:
    return jax.tree.map(lambda d: d.abstract(), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def param_shardings(defs, mesh: Optional[Mesh]) -> Any:
    return jax.tree.map(
        lambda d: named_sharding(mesh, d.logical, d.shape),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def param_pspecs(defs, mesh: Optional[Mesh]) -> Any:
    return jax.tree.map(
        lambda d: resolve_spec(d.logical, d.shape, mesh),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def seq_shard(x, mesh):
    """Megatron-style sequence-parallel constraint on (B, S, d) residual
    activations: shard S over the `model` axis so per-layer saved
    activations are 1/tp the size.  XLA inserts the all-gather before
    attention/MoE and the reduce-scatter after (SP collectives)."""
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if mesh.shape["model"] <= 1 or x.ndim < 3 or x.shape[1] % mesh.shape["model"]:
        return x
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names) or None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(batch_axes, "model", None)))


def shard_heads(t, mesh):
    """Constraint for (B, S, H, hd) attention tensors: batch over
    data(/pod), heads over model when divisible (TP attention)."""
    if mesh is None or t.ndim != 4:
        return t
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names) or None
    tp = mesh.shape["model"] if "model" in names else 1
    head_ax = "model" if (tp > 1 and t.shape[2] % tp == 0) else None
    if batch_axes is None and head_ax is None:
        return t
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(batch_axes, None, head_ax, None)))


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(d.shape)) for d in leaves)
