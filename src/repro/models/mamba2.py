"""Mamba-2 (SSD — state-space duality) mixer + full LM.

Training/prefill run the chunked SSD algorithm (quadratic within Q-token
chunks on the MXU, linear recurrence across chunks via ``lax.scan``);
decode is the O(1) recurrent update.  Projections follow the mamba2
reference: in_proj -> (z, x, B, C, dt), depthwise causal conv over
(x, B, C), gated RMSNorm before out_proj.

TP sharding: the z/x/dt projections and conv channels are head-sharded
over ``model``; the (small, group-shared) B/C projections are replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef

CHUNK = 256


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim


def mixer_param_defs(cfg: ModelConfig, Lx, st):
    d = cfg.d_model
    d_in, nh, g, N, hp = dims(cfg)
    w = cfg.ssm_conv
    return {
        "norm": ParamDef(Lx + (d,), st + (None,), init="zeros"),
        "in_zx": ParamDef(Lx + (d, 2 * d_in), st + ("fsdp", "tp")),
        "in_bc": ParamDef(Lx + (d, 2 * g * N), st + ("fsdp", None)),
        "in_dt": ParamDef(Lx + (d, nh), st + ("fsdp", "tp")),
        "conv_x": ParamDef(Lx + (d_in, w), st + ("tp", None), scale=0.5),
        "conv_bc": ParamDef(Lx + (2 * g * N, w), st + (None, None), scale=0.5),
        "dt_bias": ParamDef(Lx + (nh,), st + (None,), init="zeros"),
        "A_log": ParamDef(Lx + (nh,), st + (None,), init="zeros"),
        "Dskip": ParamDef(Lx + (nh,), st + (None,), init="ones"),
        "gnorm": ParamDef(Lx + (d_in,), st + ("tp",), init="zeros"),
        "out_proj": ParamDef(Lx + (d_in, d), st + ("tp", "fsdp")),
    }


def param_defs(cfg: ModelConfig):
    d = cfg.d_model
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("tp", "fsdp")),
        "blocks": mixer_param_defs(cfg, (cfg.n_layers,), (None,)),
        "final_norm": ParamDef((d,), (None,), init="zeros"),
        "unembed": ParamDef((d, cfg.vocab_size), ("fsdp", "tp")),
    }


# ------------------------------------------------------------- conv

def causal_depthwise_conv(x, w, state=None):
    """x: (B, S, C), w: (C, W).  Returns (y, new_state (B, C, W-1))."""
    B, S, C = x.shape
    W = w.shape[1]
    xt = x.swapaxes(1, 2)  # (B, C, S)
    if state is None:
        pad = jnp.zeros((B, C, W - 1), x.dtype)
    else:
        pad = state.astype(x.dtype)
    full = jnp.concatenate([pad, xt], axis=-1)  # (B, C, S+W-1)
    idx = jnp.arange(S)[:, None] + jnp.arange(W)[None, :]  # (S, W)
    windows = full[:, :, idx]  # (B, C, S, W)
    y = jnp.einsum("bcsw,cw->bsc", windows, w.astype(x.dtype))
    new_state = full[:, :, -(W - 1):] if W > 1 else jnp.zeros((B, C, 0), x.dtype)
    return y, new_state


# ------------------------------------------------------------- SSD core

def _segsum(cs):
    """cs: (..., Q) cumulative sums -> (..., Q, Q) with [i,j]=cs[i]-cs[j],
    -inf above the diagonal."""
    Q = cs.shape[-1]
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, init_state=None, chunk=CHUNK):
    """Chunked SSD scan.

    x: (B, S, nh, hp); dt: (B, S, nh); A: (nh,) (negative);
    Bm/Cm: (B, S, nh, N) (already group-expanded).
    Returns (y (B, S, nh, hp), final_state (B, nh, hp, N)).
    """
    Bb, S, nh, hp = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    f32 = jnp.float32
    xr = x.reshape(Bb, nc, Q, nh, hp).astype(f32)
    dtr = dt.reshape(Bb, nc, Q, nh).astype(f32)
    Br = Bm.reshape(Bb, nc, Q, nh, N).astype(f32)
    Cr = Cm.reshape(Bb, nc, Q, nh, N).astype(f32)
    dA = dtr * A.astype(f32)  # (B, nc, Q, nh)
    cs = jnp.cumsum(dA, axis=2)
    Lmat = jnp.exp(_segsum(cs.swapaxes(2, 3)))  # (B, nc, nh, Q, Q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cr, Br)
    xdt = xr * dtr[..., None]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", CB * Lmat, xdt)
    # per-chunk new state contribution
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (B, nc, Q, nh)
    S_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Br, decay_to_end * dtr, xr)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B, nc, nh)

    def scan_body(state, inp):
        s_c, cd = inp  # (B, nh, hp, N), (B, nh)
        state_in = state
        state = state * cd[:, :, None, None] + s_c
        return state, state_in

    if init_state is None:
        init_state = jnp.zeros((Bb, nh, hp, N), f32)
    final_state, states_in = lax.scan(
        scan_body, init_state.astype(f32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)  # (B, nc, nh, hp, N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cr * jnp.exp(cs)[..., None],
                         states_in)
    y = (y_intra + y_inter).reshape(Bb, S, nh, hp)
    return y.astype(x.dtype), final_state


def ssd_decode(x, dt, A, Bm, Cm, state):
    """Single-token recurrence.  x: (B, nh, hp); dt: (B, nh);
    Bm/Cm: (B, nh, N); state: (B, nh, hp, N)."""
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))  # (B, nh)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(f32), x.astype(f32),
                     Bm.astype(f32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm.astype(f32))
    return y.astype(x.dtype), state


# ------------------------------------------------------------- mixer

def _project(cfg, p, h):
    d_in, nh, g, N, hp = dims(cfg)
    dt0 = h.dtype
    zx = h @ p["in_zx"].astype(dt0)
    z, xs = jnp.split(zx, 2, axis=-1)
    bc = h @ p["in_bc"].astype(dt0)
    dtv = h @ p["in_dt"].astype(dt0)
    return z, xs, bc, dtv


def _expand_groups(bc, cfg):
    d_in, nh, g, N, hp = dims(cfg)
    B, S = bc.shape[:2]
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    Bm = Bm.reshape(B, S, g, N)
    Cm = Cm.reshape(B, S, g, N)
    rep = nh // g
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    return Bm, Cm


def mixer(cfg, p, x, *, mode, cache=None):
    """x: (B, S, d).  cache = (conv_x_state, conv_bc_state, ssm_state)."""
    d_in, nh, g, N, hp = dims(cfg)
    dt0 = x.dtype
    B, S, _ = x.shape
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    z, xs, bc, dtv = _project(cfg, p, h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dtv.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if mode == "decode":
        conv_x_st, conv_bc_st, ssm_st = cache
        xs_c, conv_x_st = causal_depthwise_conv(xs, p["conv_x"], conv_x_st)
        bc_c, conv_bc_st = causal_depthwise_conv(bc, p["conv_bc"], conv_bc_st)
        xs_c, bc_c = jax.nn.silu(xs_c), jax.nn.silu(bc_c)
        Bm, Cm = _expand_groups(bc_c, cfg)
        y, ssm_st = ssd_decode(
            xs_c[:, 0].reshape(B, nh, hp), dt[:, 0],
            A, Bm[:, 0], Cm[:, 0], ssm_st)
        y = y.reshape(B, 1, nh, hp)
        xs_res = xs_c.reshape(B, 1, nh, hp)
        new_cache = (conv_x_st, conv_bc_st, ssm_st)
    else:
        xs_c, conv_x_st = causal_depthwise_conv(xs, p["conv_x"])
        bc_c, conv_bc_st = causal_depthwise_conv(bc, p["conv_bc"])
        xs_c, bc_c = jax.nn.silu(xs_c), jax.nn.silu(bc_c)
        Bm, Cm = _expand_groups(bc_c, cfg)
        y, ssm_st = ssd_chunked(xs_c.reshape(B, S, nh, hp), dt, A, Bm, Cm)
        xs_res = xs_c.reshape(B, S, nh, hp)
        new_cache = (conv_x_st, conv_bc_st, ssm_st) if mode == "prefill" else None

    y = y + xs_res * p["Dskip"].astype(dt0)[None, None, :, None]
    y = y.reshape(B, -1, d_in)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt0),
                   p["gnorm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt0)
    return x + out, new_cache


# ------------------------------------------------------------- full LM

def forward(cfg, params, tokens, *, mesh=None, remat=True, patches=None,
            return_hidden=False):
    dt0 = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt0)[tokens]

    def body(x, p):
        y, _ = mixer(cfg, p, x, mode="train")
        return y, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def init_cache_abstract(cfg, batch: int, cache_len: int):
    """SSM 'cache' is O(1): conv tails + state (cache_len-independent)."""
    d_in, nh, g, N, hp = dims(cfg)
    w = cfg.ssm_conv
    dt0 = jnp.dtype(cfg.dtype)
    Lr = cfg.n_layers
    return (
        jax.ShapeDtypeStruct((Lr, batch, d_in, w - 1), dt0),
        jax.ShapeDtypeStruct((Lr, batch, 2 * g * N, w - 1), dt0),
        jax.ShapeDtypeStruct((Lr, batch, nh, hp, N), jnp.float32),
    )


def cache_logical_spec(cfg, tp_size: int):
    return (
        (None, "batch", "tp", None),
        (None, "batch", None, None),
        (None, "batch", "tp", None, None),
    )


def prefill(cfg, params, tokens, cache_len: int, *, mesh=None, patches=None):
    dt0 = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt0)[tokens]

    def body(x, p):
        y, cache = mixer(cfg, p, x, mode="prefill")
        return y, cache

    x, caches = lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), caches


def decode_step(cfg, params, cache, tokens, pos, *, mesh=None):
    dt0 = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt0)[tokens[:, None]]

    def body(x, inp):
        p, cx, cbc, cs = inp
        y, new_cache = mixer(cfg, p, x, mode="decode", cache=(cx, cbc, cs))
        return y, new_cache

    x, new_cache = lax.scan(body, x, (params["blocks"],) + tuple(cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), new_cache
