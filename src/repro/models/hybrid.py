"""zamba2-style hybrid: Mamba-2 backbone + ONE shared attention block
applied every ``attn_every`` mamba layers (weights tied across all uses,
as in Zamba2 — the memory win of the architecture).

Layer stack = n_uses groups of [attn_every x mamba2, shared-attn+MLP].
The mamba layers scan (stacked params reshaped (n_uses, attn_every, ...));
the shared block is a single unstacked param set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.models import transformer as tfm
from repro.models.params import ParamDef


def n_uses(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def param_defs(cfg: ModelConfig):
    d = cfg.d_model
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("tp", "fsdp")),
        "blocks": mamba2.mixer_param_defs(cfg, (cfg.n_layers,), (None,)),
        "shared_attn": tfm.block_param_defs(
            cfg.replace(family="dense"), 0, stacked=False),
        "final_norm": ParamDef((d,), (None,), init="zeros"),
        "unembed": ParamDef((d, cfg.vocab_size), ("fsdp", "tp")),
    }


def _group_params(params, cfg):
    """Reshape stacked (L, ...) mamba params to (n_uses, attn_every, ...)."""
    u, k = n_uses(cfg), cfg.attn_every
    return jax.tree.map(lambda a: a.reshape((u, k) + a.shape[1:]),
                        params["blocks"])


def forward(cfg, params, tokens, *, mesh=None, remat=True, patches=None,
            return_hidden=False):
    dt0 = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt0)[tokens]
    grouped = _group_params(params, cfg)
    dense_cfg = cfg.replace(family="dense")

    def mamba_body(x, p):
        y, _ = mamba2.mixer(cfg, p, x, mode="train")
        return y, None

    def attn_body(x):
        y, _, _ = tfm.block(dense_cfg, params["shared_attn"], x,
                            jnp.int32(0), mode="train", mesh=mesh)
        return y

    if remat:
        mamba_body = jax.checkpoint(
            mamba_body, policy=jax.checkpoint_policies.nothing_saveable)
        attn_body = jax.checkpoint(
            attn_body, policy=jax.checkpoint_policies.nothing_saveable)

    for u in range(n_uses(cfg)):
        p_u = jax.tree.map(lambda a: a[u], grouped)
        x, _ = lax.scan(mamba_body, x, p_u)
        x = attn_body(x)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def init_cache_abstract(cfg, batch: int, cache_len: int):
    mcache = mamba2.init_cache_abstract(cfg, batch, cache_len)
    hd = cfg.the_head_dim()
    u = n_uses(cfg)
    kv = jax.ShapeDtypeStruct((u, batch, cache_len, cfg.n_kv_heads, hd),
                              jnp.dtype(cfg.dtype))
    return mcache + (kv, kv)


def cache_logical_spec(cfg, tp_size: int):
    mspec = mamba2.cache_logical_spec(cfg, tp_size)
    if cfg.n_kv_heads and tp_size and cfg.n_kv_heads % tp_size == 0:
        kv = (None, "batch", None, "tp", None)
    else:
        kv = (None, "batch", "seq", None, None)
    return mspec + (kv, kv)


def prefill(cfg, params, tokens, cache_len: int, *, mesh=None, patches=None):
    dt0 = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt0)[tokens]
    S = x.shape[1]
    grouped = _group_params(params, cfg)
    dense_cfg = cfg.replace(family="dense")

    def mamba_body(x, p):
        y, c = mamba2.mixer(cfg, p, x, mode="prefill")
        return y, c

    mcaches, kcaches, vcaches = [], [], []
    for u in range(n_uses(cfg)):
        p_u = jax.tree.map(lambda a: a[u], grouped)
        x, c = lax.scan(mamba_body, x, p_u)
        mcaches.append(c)
        x, (k, v), _ = tfm.block(dense_cfg, params["shared_attn"], x,
                                 jnp.int32(0), mode="prefill", mesh=mesh)
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        kcaches.append(jnp.pad(k, pad))
        vcaches.append(jnp.pad(v, pad))
    # mcaches are (attn_every, ...) per group -> concat to (L, ...)
    mcache = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *mcaches)
    kc = jnp.stack(kcaches)
    vc = jnp.stack(vcaches)
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), tuple(mcache) + (kc, vc)


def decode_step(cfg, params, cache, tokens, pos, *, mesh=None):
    dt0 = jnp.dtype(cfg.dtype)
    cx, cbc, cs, kc, vc = cache
    x = params["embed"].astype(dt0)[tokens[:, None]]
    u, k = n_uses(cfg), cfg.attn_every
    grouped = _group_params(params, cfg)
    g_cx = cx.reshape((u, k) + cx.shape[1:])
    g_cbc = cbc.reshape((u, k) + cbc.shape[1:])
    g_cs = cs.reshape((u, k) + cs.shape[1:])
    dense_cfg = cfg.replace(family="dense")

    def mamba_body(x, inp):
        p, c0, c1, c2 = inp
        y, c = mamba2.mixer(cfg, p, x, mode="decode", cache=(c0, c1, c2))
        return y, c

    new_m, new_k, new_v = [], [], []
    for ui in range(u):
        p_u = jax.tree.map(lambda a: a[ui], grouped)
        x, c = lax.scan(mamba_body, x, (p_u, g_cx[ui], g_cbc[ui], g_cs[ui]))
        new_m.append(c)
        x, (kci, vci), _ = tfm.block(dense_cfg, params["shared_attn"], x,
                                     jnp.int32(0), mode="decode",
                                     cache=(kc[ui], vc[ui]), pos=pos, mesh=mesh)
        new_k.append(kci)
        new_v.append(vci)
    mcache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_m)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["unembed"].astype(dt0)
    return logits.astype(jnp.float32), tuple(mcache) + (jnp.stack(new_k),
                                                        jnp.stack(new_v))
