"""Synthetic datasets: SIFT/GIST-like clustered vectors + LM token streams.

The paper evaluates on SIFT1M (128d) and GIST1M (960d).  We generate
clustered Gaussians with matching dimensionality and realistic cluster
structure (ANN benchmarks are only interesting when data is clustered —
uniform data makes every method look the same).  Sizes are CLI-tunable;
defaults fit this container.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class VectorDataset:
    name: str
    data: np.ndarray       # (N, D) f32
    queries: np.ndarray    # (Q, D) f32
    gt_ids: np.ndarray     # (Q, k_gt) exact nearest ids
    gt_dists: np.ndarray


def clustered(n: int, dim: int, n_queries: int, *, n_clusters: int = 0,
              spread: float = 0.15, seed: int = 0, k_gt: int = 100,
              name: str = "synthetic") -> VectorDataset:
    """Gaussian mixture: cluster centers ~ U[0,1]^D, points ~ N(c, spread)."""
    from repro.core.hnsw import brute_force_knn
    rng = np.random.default_rng(seed)
    n_clusters = n_clusters or max(8, n // 1000)
    centers = rng.random((n_clusters, dim), dtype=np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    data = (centers[assign]
            + spread * rng.standard_normal((n, dim)).astype(np.float32))
    # queries: perturbed data points (realistic ANN workload)
    qsrc = rng.integers(0, n, size=n_queries)
    queries = (data[qsrc]
               + 0.5 * spread * rng.standard_normal((n_queries, dim))
               .astype(np.float32))
    k_gt = min(k_gt, n)
    gt_d, gt_i = brute_force_knn(data, queries, k_gt)
    return VectorDataset(name, data, queries, gt_i, gt_d)


def sift_like(n: int = 50_000, n_queries: int = 500, seed: int = 0,
              **kw) -> VectorDataset:
    """128-d (SIFT1M's dimensionality)."""
    return clustered(n, 128, n_queries, seed=seed, name="sift-like", **kw)


def gist_like(n: int = 10_000, n_queries: int = 200, seed: int = 0,
              **kw) -> VectorDataset:
    """960-d (GIST1M's dimensionality) — higher-D, fewer rows (paper:
    GIST latency is dominated by per-vector distance cost)."""
    return clustered(n, 960, n_queries, seed=seed, name="gist-like", **kw)


def token_stream(vocab_size: int, batch: int, seq: int, *, seed: int = 0,
                 n_batches: int = 0):
    """Zipf-ish synthetic LM batches {tokens, labels} for train loops."""
    rng = np.random.default_rng(seed)
    i = 0
    while n_batches <= 0 or i < n_batches:
        # zipf over a capped vocab, shifted into range
        raw = rng.zipf(1.3, size=(batch, seq + 1)) % vocab_size
        yield {"tokens": raw[:, :-1].astype(np.int32),
               "labels": raw[:, 1:].astype(np.int32)}
        i += 1
