"""Step-scoped checkpointing with atomic commit + integrity manifest.

Layout:   <dir>/step_000123/
            manifest.json   — step, leaf paths, shapes, dtypes, checksums
            arr_00000.npy … — one file per pytree leaf (host numpy)
          <dir>/LATEST      — name of the newest COMMITTED step dir

Write protocol: stage into ``step_X.tmp``, fsync files, atomic
``rename`` to ``step_X``, then rewrite LATEST (itself via tmp+rename) —
a crash at any point leaves either the old or the new checkpoint fully
intact, never a torn one.  Restore verifies checksums and, given target
shardings, ``device_put``s leaves straight to a (possibly *different*)
mesh — that is the whole elastic-rescale path (``reshard_tree`` /
``rescale_train_state`` below).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_checksum(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Checkpoint a pytree of arrays.  Returns the committed directory."""
    leaves, treedef = jax.tree.flatten(tree)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256_16": _leaf_checksum(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit

    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip().split("_")[1])


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> tuple[Any, int]:
    """Load the latest (or given) step into the structure of
    ``tree_like``.  ``shardings``: matching pytree of (Named)Shardings —
    pass the NEW mesh's shardings to elastically reshard on restore."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    _, treedef = jax.tree.flatten(tree_like)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_meta))
    out = []
    for meta, sh in zip(leaves_meta, shard_leaves):
        arr = np.load(os.path.join(d, meta["file"]))
        if verify and _leaf_checksum(arr) != meta["sha256_16"]:
            raise IOError(f"checksum mismatch in {d}/{meta['file']}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]


# ------------------------------------------------------- elastic rescale
# (folded in from the retired repro.distributed.elastic stub: down-scale
# and up-scale are the same operation — build the new mesh, resolve the
# same *logical* specs against it, device_put every leaf)

def reshard_tree(tree: Any, new_shardings: Any) -> Any:
    """Move every leaf to the new mesh/sharding (cross-mesh device_put)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, new_shardings)


def rescale_train_state(params, opt_state, defs, new_mesh):
    """Re-resolve the params' logical specs on ``new_mesh`` and move."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.params import param_shardings
    from repro.train.adamw import AdamWState
    p_sh = param_shardings(defs, new_mesh)
    opt_sh = AdamWState(NamedSharding(new_mesh, P()), p_sh, p_sh)
    return reshard_tree(params, p_sh), reshard_tree(opt_state, opt_sh)
