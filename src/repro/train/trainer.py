"""Training driver: data -> step -> metrics -> checkpoint, restartable.

Thin composition of the pieces built elsewhere: step factory
(train_step.py), AdamW (adamw.py), atomic checkpoints (checkpoint.py).
The supervision layer lives here too (folded in from the retired
``repro.distributed.fault_tolerance`` stub): ``HeartbeatMonitor`` tracks
per-worker beat times and flags stragglers by an EWMA z-score on step
time, and ``run_with_restarts`` is the checkpoint-restart loop — step,
commit every ``ckpt_every`` steps, restore the last commit on failure.
Used by examples/train_lm.py and the smoke/integration tests.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.models.params import init_params
from repro.train import adamw
from repro.train import checkpoint as CKPT
from repro.train.train_step import make_train_step


@dataclass
class TrainReport:
    losses: list
    step_times: list
    final_step: int


def fit(cfg: ModelConfig, shape: InputShape, batches: Iterable[dict],
        n_steps: int, *, mesh=None, seed: int = 0,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, micro_steps: int = 1) -> TrainReport:
    step_fn, in_sh, out_sh, _ = make_train_step(cfg, shape, mesh,
                                                micro_steps=micro_steps)
    jit_step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1))
    defs = M.param_defs(cfg)
    params = init_params(defs, jax.random.key(seed))
    opt = adamw.init(params)

    start = 0
    if ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        (params, opt), start = CKPT.restore(ckpt_dir, (params, opt))

    losses, times = [], []
    it = iter(batches)
    for step in range(start, n_steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt, metrics = jit_step(params, opt, batch)
        loss = float(jax.block_until_ready(metrics["loss"]))
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"{times[-1]*1e3:.0f} ms", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            CKPT.save(ckpt_dir, step + 1, (params, opt))
    if ckpt_dir:
        CKPT.save(ckpt_dir, n_steps, (params, opt))
    return TrainReport(losses, times, n_steps)


# ------------------------------------------------------------ supervision

@dataclass
class WorkerStats:
    """Per-worker heartbeat bookkeeping (EWMA step time + variance)."""

    last_beat: float = 0.0
    ewma: float = 0.0       # step-time EWMA
    ewvar: float = 0.0      # EWMA of squared deviation
    n: int = 0


class HeartbeatMonitor:
    """Detects dead workers (beat timeout) and stragglers (z-score)."""

    def __init__(self, n_workers: int, *, timeout_s: float = 10.0,
                 alpha: float = 0.2, z_thresh: float = 3.0):
        self.workers = {i: WorkerStats() for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.alpha = alpha
        self.z_thresh = z_thresh

    def beat(self, worker: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        """Record one worker heartbeat carrying its last step time."""
        w = self.workers[worker]
        w.last_beat = time.monotonic() if now is None else now
        if w.n == 0:
            w.ewma = step_time_s
        else:
            d = step_time_s - w.ewma
            w.ewma += self.alpha * d
            w.ewvar = (1 - self.alpha) * (w.ewvar + self.alpha * d * d)
        w.n += 1

    def dead(self, now: Optional[float] = None) -> list:
        """Workers whose last beat is older than the timeout."""
        now = time.monotonic() if now is None else now
        return [i for i, w in self.workers.items()
                if w.n > 0 and now - w.last_beat > self.timeout_s]

    def stragglers(self) -> list:
        """Workers whose EWMA step time is a z_thresh outlier vs the fleet."""
        live = [w.ewma for w in self.workers.values() if w.n >= 3]
        if len(live) < 3:
            return []
        mean = sum(live) / len(live)
        var = sum((x - mean) ** 2 for x in live) / len(live)
        sd = math.sqrt(var) + 1e-9
        return [i for i, w in self.workers.items()
                if w.n >= 3 and (w.ewma - mean) / sd > self.z_thresh]


@dataclass
class RestartReport:
    """What a supervised run did: progress, failures, restores."""

    steps_done: int
    n_failures: int
    n_restores: int
    history: list = field(default_factory=list)


def run_with_restarts(step_fn: Callable[[Any, int], Any], state: Any,
                      n_steps: int, *, ckpt_dir: str, ckpt_every: int = 10,
                      shardings: Any = None,
                      max_failures: int = 10) -> tuple:
    """Supervised training loop: step, checkpoint, restore-on-failure.

    ``step_fn(state, step) -> state`` may raise (fault injection or real
    device loss).  On failure we restore the last committed checkpoint
    and resume from its step.  This is the single-controller analogue of
    a multi-controller restart: in a real pod deployment each host runs
    this loop and the failed host's work is recovered from the shared
    checkpoint directory.
    """
    report = RestartReport(0, 0, 0)
    step = 0
    CKPT.save(ckpt_dir, step, state)
    failures = 0
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            report.steps_done = step
            if step % ckpt_every == 0 or step == n_steps:
                CKPT.save(ckpt_dir, step, state)
                report.history.append(("ckpt", step))
        except Exception as e:  # noqa: BLE001 — supervision boundary
            failures += 1
            report.n_failures = failures
            if failures > max_failures:
                raise
            state, step = CKPT.restore(ckpt_dir, state, shardings=shardings)
            report.n_restores += 1
            report.history.append(("restore", step, repr(e)[:60]))
    return state, report
