"""Training driver: data -> step -> metrics -> checkpoint, restartable.

Thin composition of the pieces built elsewhere: step factory
(train_step.py), AdamW (adamw.py), atomic checkpoints (checkpoint.py),
and the supervised restart loop (distributed/fault_tolerance.py).  Used
by examples/train_lm.py and the smoke/integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.models.params import init_params
from repro.train import adamw
from repro.train import checkpoint as CKPT
from repro.train.train_step import make_train_step


@dataclass
class TrainReport:
    losses: list
    step_times: list
    final_step: int


def fit(cfg: ModelConfig, shape: InputShape, batches: Iterable[dict],
        n_steps: int, *, mesh=None, seed: int = 0,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, micro_steps: int = 1) -> TrainReport:
    step_fn, in_sh, out_sh, _ = make_train_step(cfg, shape, mesh,
                                                micro_steps=micro_steps)
    jit_step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1))
    defs = M.param_defs(cfg)
    params = init_params(defs, jax.random.key(seed))
    opt = adamw.init(params)

    start = 0
    if ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        (params, opt), start = CKPT.restore(ckpt_dir, (params, opt))

    losses, times = [], []
    it = iter(batches)
    for step in range(start, n_steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt, metrics = jit_step(params, opt, batch)
        loss = float(jax.block_until_ready(metrics["loss"]))
        times.append(time.perf_counter() - t0)
        losses.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"{times[-1]*1e3:.0f} ms", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            CKPT.save(ckpt_dir, step + 1, (params, opt))
    if ckpt_dir:
        CKPT.save(ckpt_dir, n_steps, (params, opt))
    return TrainReport(losses, times, n_steps)
