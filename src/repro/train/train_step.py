"""train/prefill/decode step factories with sharding annotations.

``make_train_step(cfg, mesh)`` returns (fn, in_shardings, out_shardings,
abstract-args) ready for ``jax.jit(...).lower(...)`` — the dry-run path —
or for direct execution on real devices.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models.params import (abstract_params, named_sharding,
                                 param_shardings, resolve_spec)
from repro.train import adamw


def _shard(mesh, logical, shape):
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh))


def loss_fn(cfg, params, batch, mesh=None, aux_weight=0.01):
    hidden, aux = M.forward(cfg, params, batch, mesh=mesh, return_hidden=True)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patches" in batch and batch["patches"] is not None:
        # loss only over the text positions (patches are prepended)
        npatch = batch["patches"].shape[1]
        hidden = hidden[:, npatch:]
    unembed = params["unembed"]
    if mesh is not None and os.environ.get("REPRO_LOSS_UNEMBED_TP"):
        # §Perf cell C: the unembed is stored (fsdp, tp)-sharded; using
        # it per CE chunk with a data-sharded contracting dim makes the
        # partitioner reshard activations/logits with large permutes.
        # Constrain the LOSS-path copy to vocab(TP)-only: ONE small
        # all-gather of the fsdp axis, then clean local chunk matmuls.
        unembed = lax.with_sharding_constraint(
            unembed, NamedSharding(mesh, resolve_spec(
                (None, "tp"), unembed.shape, mesh)))
    loss = L.chunked_cross_entropy(hidden, unembed, labels,
                                   softcap=cfg.logit_softcap, mesh=mesh)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, shape: InputShape,
                    mesh: Optional[Mesh] = None, micro_steps: int = 1):
    """micro_steps > 1 enables gradient accumulation: the global batch is
    split into microbatches scanned sequentially, with fp32 grads
    accumulated in param sharding.  Peak activation memory scales ~1/m,
    and the per-microbatch grad reductions overlap with the next
    microbatch's compute (XLA async collectives)."""
    defs = M.param_defs(cfg)
    abs_params = abstract_params(defs)
    abs_opt = adamw.abstract_state(abs_params)
    p_shardings = param_shardings(defs, mesh)
    opt_shardings = adamw.AdamWState(
        _shard(mesh, (), ()), p_shardings, p_shardings)
    in_sds = M.input_specs(cfg, shape)
    in_logical = M.input_logical_specs(cfg, shape)
    batch_shardings = {k: _shard(mesh, in_logical[k], in_sds[k].shape)
                       for k in in_sds}

    # beyond-paper collective optimization (§Perf cell B): cast f32
    # master params to the compute dtype ONCE at the top of the step, so
    # the FSDP all-gathers move bf16 (half the wire) instead of f32 with
    # a convert after the gather.  Grads still flow to the f32 masters
    # (grad of convert = convert).  Opt-in: REPRO_CAST_PARAMS_ONCE=1.
    cast_once = bool(os.environ.get("REPRO_CAST_PARAMS_ONCE"))
    comp_dt = jnp.dtype(cfg.dtype)

    def cast_tree(p):
        if not cast_once:
            return p
        return jax.tree.map(
            lambda x: x.astype(comp_dt)
            if (x.dtype == jnp.float32 and x.ndim >= 2) else x, p)

    def grads_of(params, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(cfg, cast_tree(p), batch, mesh=mesh),
            has_aux=True)
        (_, metrics), grads = grad_fn(params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if micro_steps <= 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = {k: v.reshape((micro_steps, v.shape[0] // micro_steps)
                                  + v.shape[1:])
                     for k, v in batch.items()}

            def body(acc, mb):
                g, metrics = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / micro_steps, grads)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        params, opt_state, opt_metrics = adamw.update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    in_shardings = (p_shardings, opt_shardings, batch_shardings)
    out_shardings = (p_shardings, opt_shardings, None)
    abstract_args = (abs_params, abs_opt, in_sds)
    return train_step, in_shardings, out_shardings, abstract_args


def make_prefill_step(cfg: ModelConfig, shape: InputShape,
                      mesh: Optional[Mesh] = None):
    defs = M.serve_param_defs(cfg)
    abs_params = abstract_params(defs)
    p_shardings = param_shardings(defs, mesh)
    in_sds = M.input_specs(cfg, shape)
    in_logical = M.input_logical_specs(cfg, shape)
    batch_shardings = {k: _shard(mesh, in_logical[k], in_sds[k].shape)
                       for k in in_sds}
    cache_len = shape.seq_len
    if cfg.family == "vlm":
        cache_len += cfg.n_patches

    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, cache_len, mesh=mesh)

    tp = mesh.shape["model"] if mesh is not None and "model" in mesh.axis_names else 1
    cache_abs = M.init_cache_abstract(cfg, shape.global_batch, cache_len)
    cache_logical = M.cache_logical_spec(cfg, tp)
    cache_shardings = _cache_shardings(mesh, cache_abs, cache_logical)
    in_shardings = (p_shardings, batch_shardings)
    out_shardings = (None, cache_shardings)
    return prefill_step, in_shardings, out_shardings, (abs_params, in_sds)


def _cache_shardings(mesh, cache_abs, cache_logical):
    if mesh is None:
        return None
    return tuple(_shard(mesh, lg, a.shape)
                 for a, lg in zip(cache_abs, cache_logical))


def make_decode_step(cfg: ModelConfig, shape: InputShape,
                     mesh: Optional[Mesh] = None):
    defs = M.serve_param_defs(cfg)
    abs_params = abstract_params(defs)
    p_shardings = param_shardings(defs, mesh)
    in_sds = M.input_specs(cfg, shape)
    cache_len = shape.seq_len
    tp = mesh.shape["model"] if mesh is not None and "model" in mesh.axis_names else 1
    cache_abs = M.init_cache_abstract(cfg, shape.global_batch, cache_len)
    cache_logical = M.cache_logical_spec(cfg, tp)
    cache_shardings = _cache_shardings(mesh, cache_abs, cache_logical)
    tok_sh = _shard(mesh, ("batch",), in_sds["tokens"].shape)
    pos_sh = _shard(mesh, ("batch",), in_sds["pos"].shape)

    def decode_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, mesh=mesh)

    in_shardings = (p_shardings, cache_shardings, tok_sh, pos_sh)
    out_shardings = (None, cache_shardings)
    abstract_args = (abs_params, cache_abs, in_sds["tokens"], in_sds["pos"])
    return decode_step, in_shardings, out_shardings, abstract_args


def make_step(cfg, shape, mesh=None, micro_steps: int = 1):
    if shape.kind == "train":
        return make_train_step(cfg, shape, mesh, micro_steps=micro_steps)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape, mesh)
    return make_decode_step(cfg, shape, mesh)
