"""AdamW with cosine schedule + global-norm clipping (pure JAX pytrees).

Optimizer state shards exactly like the params (same logical specs), so
FSDP covers m/v for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32), jax.tree.map(z, params),
                      jax.tree.map(z, params))


def abstract_state(abstract_param_tree) -> AdamWState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                      jax.tree.map(z, abstract_param_tree),
                      jax.tree.map(z, abstract_param_tree))


def cosine_lr(step, *, peak=3e-4, warmup=100, total=10_000, floor=0.1):
    warm = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm=1.0):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(grads, state: AdamWState, params, *, lr_fn=cosine_lr,
           b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip=1.0):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if clip:
        grads, gnorm = clip_by_global_norm(grads, clip)
    else:
        gnorm = jnp.zeros(())
    step = state.step + 1
    lr = lr_fn(step)
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        newp = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, AdamWState(step, m, v), {"lr": lr, "grad_norm": gnorm}
