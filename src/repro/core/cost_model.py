"""Network cost model — round trips + bytes, for RDMA and TPU ICI fabrics.

The container has no real fabric, so (exactly like the paper's latency
*breakdown* methodology) we count the communication events each scheme
issues and price them with calibrated constants.  Two calibrations:

* ``RDMA_100G``  — the paper's testbed (ConnectX-6 100 Gb NIC): one-sided
  READ round-trip ~2 us, ~12.5 GB/s payload bandwidth, and a per-doorbell
  -descriptor PCIe cost (~0.25 us) that models the NIC issuing multiple
  PCIe transactions inside one network round trip (§3.2's tradeoff).
* ``TPU_ICI``    — our target fabric: ~1 us collective launch latency,
  ~50 GB/s/link.  A doorbell batch maps to ONE collective launch whose
  payload is the union of requested blocks.

Both share the accounting: latency = round_trips * rtt
                                   + descriptors * per_op
                                   + bytes / bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Fabric:
    name: str
    rtt_s: float            # per network round trip
    bw_Bps: float           # payload bandwidth
    per_op_s: float = 0.0   # per doorbell descriptor (PCIe op / DMA engine op)
    max_doorbell: int = 32  # descriptors per round trip before it splits


RDMA_100G = Fabric("rdma-100g", rtt_s=2e-6, bw_Bps=12.5e9, per_op_s=0.25e-6,
                   max_doorbell=32)
TPU_ICI = Fabric("tpu-ici", rtt_s=1e-6, bw_Bps=50e9, per_op_s=0.05e-6,
                 max_doorbell=64)


@dataclass
class NetLedger:
    """Mutable tally a scheme run writes into; priced at the end."""

    fabric: Fabric
    round_trips: float = 0.0
    descriptors: float = 0.0
    bytes: float = 0.0
    bytes_saved: float = 0.0   # wire bytes avoided vs full-precision spans
    events: int = 0

    def read(self, n_bytes: float, *, descriptors: int = 1) -> None:
        """One round trip carrying ``descriptors`` doorbell'd reads."""
        import math
        trips = math.ceil(descriptors / self.fabric.max_doorbell)
        self.round_trips += trips
        self.descriptors += descriptors
        self.bytes += n_bytes
        self.events += 1

    def write(self, n_bytes: float, *, descriptors: int = 1) -> None:
        self.read(n_bytes, descriptors=descriptors)

    def save(self, n_bytes: float) -> None:
        """Record bytes the quantized tier / row re-rank kept OFF the
        wire relative to fetching the same spans in full precision."""
        self.bytes_saved += max(n_bytes, 0.0)

    def latency_s(self) -> float:
        f = self.fabric
        return (self.round_trips * f.rtt_s + self.descriptors * f.per_op_s
                + self.bytes / f.bw_Bps)

    def as_dict(self) -> dict:
        return {"fabric": self.fabric.name,
                "round_trips": self.round_trips,
                "descriptors": self.descriptors,
                "bytes": self.bytes,
                "bytes_saved": self.bytes_saved,
                "latency_s": self.latency_s()}
