"""Fixed-shape JAX HNSW search (greedy descent + ef beam at layer 0).

The paper's greedy walk has data-dependent control flow; on TPU we need
static shapes, so: adjacency is dense ``(L, N, deg)`` with -1 padding,
the visited set is an explicit ``(N,)`` bitmap, and the beam is a sorted
``(ef,)`` array updated with masked merges inside ``lax.while_loop``.
Semantics match host HNSW exactly (same stop rule: terminate when the
closest unexpanded candidate is farther than the worst of the ef set).

Two query paths over a *loaded* partition:
  * ``beam_search``      — the faithful graph walk (paper's algorithm);
  * ``scan_partition``   — beyond-paper TPU mode: brute-force the whole
    fetched partition through the MXU distance+top-k kernel.  On TPU the
    partition is already resident after the fetch, and a 2k-vector tiled
    matmul beats a pointer-chasing walk; the graph is still what decides
    WHICH partitions to fetch (the paper's actual bandwidth win).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

INF = jnp.inf


def _sq_dists(vectors, ids, q):
    """Squared L2 from q (D,) to vectors[ids]; invalid ids (<0) -> inf."""
    valid = ids >= 0
    rows = vectors[jnp.where(valid, ids, 0)]
    d = jnp.sum(jnp.square(rows - q[None, :]), axis=-1)
    return jnp.where(valid, d, INF)


def greedy_descent(vectors, adjacency, q, entry, n_levels: int,
                   max_hops: int = 64):
    """Layers top..1: hill-climb to the locally-closest node per layer."""
    d_entry = jnp.sum(jnp.square(vectors[entry] - q))

    def one_layer(carry, l_rev):
        u, du = carry
        layer = n_levels - 1 - l_rev  # top .. 1

        def cond(s):
            _, _, moved, hops = s
            return moved & (hops < max_hops)

        def body(s):
            u, du, _, hops = s
            nbrs = adjacency[layer, u]
            d = _sq_dists(vectors, nbrs, q)
            j = jnp.argmin(d)
            better = d[j] < du
            return (jnp.where(better, nbrs[j], u),
                    jnp.where(better, d[j], du), better, hops + 1)

        u, du, _, _ = lax.while_loop(cond, body, (u, du, True, 0))
        return (u, du), None

    if n_levels <= 1:
        return entry, d_entry
    (u, du), _ = lax.scan(one_layer, (entry, d_entry),
                          jnp.arange(n_levels - 1))
    return u, du


def beam_search(vectors, adjacency, q, entry, *, ef: int,
                n_levels: int = 1, max_iters: Optional[int] = None,
                visited_size: Optional[int] = None):
    """Full HNSW query for one vector.

    Returns (dists (ef,), ids (ef,)) sorted ascending; -1/inf padding.
    ``adjacency``: (L, N, deg) i32.  vmap over q/entry for batches.
    """
    n = vectors.shape[0] if visited_size is None else visited_size
    max_iters = max_iters or (2 * ef + 8)
    deg = adjacency.shape[2]

    ep, dep = greedy_descent(vectors, adjacency, q, entry, n_levels)

    beam_d = jnp.full((ef,), INF).at[0].set(dep)
    beam_i = jnp.full((ef,), -1, jnp.int32).at[0].set(ep)
    expanded = jnp.zeros((ef,), bool)
    visited = jnp.zeros((n,), bool).at[ep].set(True)

    def cond(state):
        beam_d, beam_i, expanded, visited, it = state
        cand = jnp.where(~expanded & (beam_i >= 0), beam_d, INF)
        best_un = jnp.min(cand)
        worst = jnp.max(jnp.where(beam_i >= 0, beam_d, -INF))
        return (it < max_iters) & jnp.isfinite(best_un) & (best_un <= worst)

    def body(state):
        beam_d, beam_i, expanded, visited, it = state
        cand = jnp.where(~expanded & (beam_i >= 0), beam_d, INF)
        pos = jnp.argmin(cand)
        u = beam_i[pos]
        expanded = expanded.at[pos].set(True)

        nbrs = adjacency[0, u]                      # (deg,)
        fresh = (nbrs >= 0) & ~visited[jnp.where(nbrs >= 0, nbrs, 0)]
        visited = visited.at[jnp.where(fresh, nbrs, 0)].set(True)
        nd = jnp.where(fresh, _sq_dists(vectors, nbrs, q), INF)

        all_d = jnp.concatenate([beam_d, nd])
        all_i = jnp.concatenate([beam_i, jnp.where(fresh, nbrs, -1)])
        all_e = jnp.concatenate([expanded, jnp.zeros((deg,), bool)])
        order = jnp.argsort(all_d)[:ef]
        return (all_d[order], all_i[order], all_e[order], visited, it + 1)

    beam_d, beam_i, expanded, visited, _ = lax.while_loop(
        cond, body, (beam_d, beam_i, expanded, visited, 0))
    return beam_d, beam_i


def batched_beam_search(vectors, adjacency, queries, entry, *, ef: int,
                        n_levels: int = 1, max_iters: Optional[int] = None):
    """vmap wrapper: queries (B, D) -> (B, ef) dists/ids."""
    fn = functools.partial(beam_search, vectors, adjacency, ef=ef,
                           n_levels=n_levels, max_iters=max_iters)
    return jax.vmap(lambda q: fn(q, entry))(queries)


# ------------------------------------------------------------- meta routing

@functools.partial(jax.jit, static_argnames=("b", "ef", "n_levels"))
def meta_route(meta_vectors, meta_adjacency, queries, entry, *, b: int,
               ef: int = 0, n_levels: int = 3):
    """Route a batch of queries through the cached meta-HNSW.

    Returns (B, b) partition ids (= L0 rep indices), nearest-first.  This
    is the only index the compute pool holds; everything else is fetched.
    """
    ef = max(ef, 2 * b, 8)
    d, i = batched_beam_search(meta_vectors, meta_adjacency, queries, entry,
                               ef=ef, n_levels=n_levels)
    return i[:, :b], d[:, :b]


# ------------------------------------------------------------- scan mode

def scan_partition(part_vectors, q, k: int, n_valid=None):
    """Exact top-k within one loaded partition ((Np, D) padded).

    ``n_valid`` masks layout padding / unused overflow slots.  Pure-jnp
    path; the Pallas MXU kernel (kernels/distance_topk) is the production
    route — engine.py picks by flag.
    """
    d = jnp.sum(jnp.square(part_vectors - q[None, :]), axis=-1)
    if n_valid is not None:
        d = jnp.where(jnp.arange(d.shape[0]) < n_valid, d, INF)
    nd, ni = lax.top_k(-d, k)
    return -nd, ni


def merge_topk(d_a, i_a, d_b, i_b, k: int):
    """Merge two sorted top-k lists (per-query running results across
    partition rounds).  Ids are globally unique (partitions are disjoint),
    so plain merge-sort-take-k."""
    d = jnp.concatenate([d_a, d_b], axis=-1)
    i = jnp.concatenate([i_a, i_b], axis=-1)
    order = jnp.argsort(d, axis=-1)[..., :k]
    return (jnp.take_along_axis(d, order, axis=-1),
            jnp.take_along_axis(i, order, axis=-1))
