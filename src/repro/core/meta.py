"""Representative index (meta-HNSW) construction — paper §3.1.

Uniformly sample ``n_rep`` (paper: 500) vectors, build a **3-layer**
HNSW over them (the meta-HNSW).  Each bottom-layer (L0) representative
defines a partition; every dataset vector is assigned to its nearest
representative, and each partition's vectors get their own *sub-HNSW*
whose entry point is the representative.

The meta-HNSW is tiny (paper: 0.373 MB on SIFT1M) and is **cached
replicated in every compute instance** — here, replicated on every
device.  ``MetaIndex.device_arrays()`` exports the fixed-shape arrays the
JAX search consumes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.hnsw import HNSW, HNSWParams, PaddedGraph, brute_force_knn


@dataclass
class MetaIndex:
    reps: np.ndarray           # (P, D) representative vectors (partition centers)
    rep_ids: np.ndarray        # (P,) ids of reps in the original dataset
    graph: PaddedGraph         # 3-layer meta-HNSW over reps
    assignments: np.ndarray    # (N,) partition id per dataset vector

    @property
    def n_partitions(self) -> int:
        return self.reps.shape[0]

    def size_bytes(self) -> int:
        """Footprint of what the compute pool caches (paper's 0.373 MB)."""
        return (self.reps.nbytes + self.graph.adjacency.nbytes
                + self.graph.node_level.nbytes)

    def partition_lists(self) -> list[np.ndarray]:
        order = np.argsort(self.assignments, kind="stable")
        sorted_assign = self.assignments[order]
        bounds = np.searchsorted(sorted_assign, np.arange(self.n_partitions + 1))
        return [order[bounds[p]:bounds[p + 1]] for p in range(self.n_partitions)]


def rep_sample_ids(n: int, n_rep: int, *, seed: int = 0) -> np.ndarray:
    """Uniform representative sample — a function of ``(n, seed)`` only.

    Split out so the out-of-core loader can pick the identical reps
    before the dataset is resident (it only needs the row count).
    """
    n_rep = min(n_rep, n)
    rng = np.random.default_rng(seed)
    return np.sort(rng.choice(n, size=n_rep, replace=False))


def build_meta_from_parts(reps: np.ndarray, rep_ids: np.ndarray,
                          assignments: np.ndarray, *, seed: int = 0,
                          meta_levels: int = 3,
                          params: Optional[HNSWParams] = None) -> MetaIndex:
    """Assemble a :class:`MetaIndex` from precomputed reps + assignments.

    The meta-HNSW construction lives here so the in-memory
    :func:`build_meta` and the streaming loader (which computes
    ``assignments`` chunk-by-chunk) share one code path bit-for-bit.
    """
    reps = np.asarray(reps, np.float32)
    p = params or HNSWParams(M=8, M0=16, ef_construction=64, seed=seed)
    h = HNSW(reps.shape[1], p)
    # force levels so the meta graph is exactly `meta_levels` deep: node 0
    # spans all layers (fixed entry point, paper: "fixed entry point in L2")
    for i, row in enumerate(reps):
        lvl = meta_levels - 1 if i == 0 else min(h._draw_level(), meta_levels - 1)
        h.insert(row, level=lvl)
    graph = h.export(max_levels=meta_levels)
    return MetaIndex(reps=reps, rep_ids=np.asarray(rep_ids),
                     graph=graph,
                     assignments=np.asarray(assignments, np.int32))


def build_meta(data: np.ndarray, n_rep: int = 500, *, seed: int = 0,
               meta_levels: int = 3,
               params: Optional[HNSWParams] = None) -> MetaIndex:
    """Sample reps uniformly, build the 3-layer meta-HNSW, assign vectors.

    Assignment is *exact* nearest-representative (the classifier role the
    paper gives meta-HNSW): with only ~500 reps a brute-force pass is
    cheaper and noise-free; query-time routing still goes through the
    graph (that is what we cache and traverse on device).
    """
    data = np.asarray(data, np.float32)
    rep_ids = rep_sample_ids(data.shape[0], n_rep, seed=seed)
    reps = data[rep_ids].copy()
    _, nn = brute_force_knn(reps, data, 1)
    assignments = nn[:, 0].astype(np.int32)
    return build_meta_from_parts(reps, rep_ids, assignments, seed=seed,
                                 meta_levels=meta_levels, params=params)


def balance_stats(meta: MetaIndex) -> dict:
    sizes = np.bincount(meta.assignments, minlength=meta.n_partitions)
    return {
        "n_partitions": int(meta.n_partitions),
        "min": int(sizes.min()), "max": int(sizes.max()),
        "mean": float(sizes.mean()), "p99": float(np.percentile(sizes, 99)),
        "empty": int((sizes == 0).sum()),
    }
