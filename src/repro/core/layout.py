"""RDMA-friendly graph-index storage layout — paper §3.2, TPU-adapted.

One registered memory region per buffer, divided into fixed-size blocks
(the doorbell/DMA granularity).  Groups of two sub-HNSW clusters share a
single overflow region in the middle:

    group g:  [ sub-HNSW A | shared overflow | sub-HNSW B ]
              `-- fetch A --------------'
                          `-------------- fetch B --'

so one contiguous read returns a cluster *and* every vector ever inserted
into it — the paper's core layout invariant.  A global metadata table
(per-partition offsets/counters) sits logically at the start of the
region; compute instances cache it (here: small replicated array + host
mirror).

TPU adaptation (recorded in DESIGN.md): JAX arrays are typed, so the
byte region becomes two lockstep block buffers — ``graph_buf`` (int32:
adjacency + global ids) and ``vec_buf`` (float32: vectors) — with
identical block indexing; and partitions are padded to the build-max
partition size ``np_max`` so every fetch span is the same number of
blocks (static shapes).  Uniform sampling makes partitions multinomial-
balanced (sigma/mean = 1/sqrt(mean)), so measured padding waste is ~7-15%
and is reported by ``Store.padding_waste()``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.hnsw import HNSW, HNSWParams, bulk_l0_graph
from repro.core.meta import MetaIndex

# meta_table columns (int32)
MT_BLK_START = 0   # first block of this partition's fetch span
MT_SIDE = 1        # 0 = A (data first), 1 = B (overflow first)
MT_N_BASE = 2      # base vectors in the sub-HNSW
MT_ENTRY = 3       # entry node (local id) = the representative
MT_OV_A = 4        # overflow slots used from the front (partner A)
MT_OV_B = 5        # overflow slots used from the back (partner B)
MT_GROUP = 6
META_COLS = 8      # padded for alignment / future fields


@dataclass(frozen=True)
class LayoutSpec:
    """All build-time constants the device decode path needs (static)."""

    dim: int
    deg: int               # sub-HNSW L0 degree (M0)
    np_max: int            # max base vectors per partition (pad target)
    ov_cap: int            # overflow vector slots per group (shared)
    slot_vecs: int         # vectors per block (VBLK = slot_vecs * dim)
    n_partitions: int
    quant_group: int = 0   # int8 codec group size (0 = no quantized mirror)

    @property
    def vblk(self) -> int:           # floats per vec block
        return self.slot_vecs * self.dim

    @property
    def gblk(self) -> int:           # ints per graph block
        return self.slot_vecs * (self.deg + 1)

    @property
    def data_blocks(self) -> int:    # blocks for one padded sub-HNSW
        g = math.ceil(self.np_max * (self.deg + 1) / self.gblk)
        v = math.ceil(self.np_max * self.dim / self.vblk)
        return max(g, v)

    @property
    def ov_blocks(self) -> int:      # blocks for one shared overflow region
        g = math.ceil(self.ov_cap / self.gblk)
        v = math.ceil(self.ov_cap * self.dim / self.vblk)
        return max(g, v)

    @property
    def fetch_blocks(self) -> int:   # every fetch span: data + overflow
        return self.data_blocks + self.ov_blocks

    @property
    def group_blocks(self) -> int:
        return 2 * self.data_blocks + self.ov_blocks

    @property
    def n_groups(self) -> int:
        return (self.n_partitions + 1) // 2

    @property
    def n_blocks(self) -> int:
        return self.n_groups * self.group_blocks

    def block_bytes(self) -> int:
        """Wire bytes of one block fetch (both lockstep buffers)."""
        return self.vblk * 4 + self.gblk * 4

    def partition_bytes(self) -> int:
        return self.fetch_blocks * self.block_bytes()

    # ------------------------------------------------- quantized mirror

    @property
    def n_qgroups(self) -> int:      # codec groups per vec block
        assert self.quant_group > 0
        return self.vblk // self.quant_group

    def quant_block_bytes(self, *, include_graph: bool = True) -> int:
        """Wire bytes of one quantized block fetch: int8 codes + f32
        codebook scales (+ the int32 graph block when the search mode
        walks the sub-HNSW).  In scan mode only the global-id tail of the
        graph span is needed, priced separately per span below."""
        b = self.vblk * 1 + self.n_qgroups * 4
        return b + (self.gblk * 4 if include_graph else 0)

    def quant_partition_bytes(self, *, include_graph: bool = True) -> int:
        """One quantized span fetch.  Without the graph, the span still
        carries the global-id tails (np_max + ov_cap int32) so the
        candidate pool can name real ids."""
        b = self.fetch_blocks * self.quant_block_bytes(
            include_graph=include_graph)
        if not include_graph:
            b += (self.np_max + self.ov_cap) * 4
        return b

    def row_bytes(self) -> int:      # one exact vector row (re-rank fetch)
        return self.dim * 4

    def data_blk_off(self, side: int) -> int:
        return side * self.ov_blocks        # B's data sits after the overflow

    def ov_blk_off(self, side: int) -> int:
        return (1 - side) * self.data_blocks  # A's overflow sits after its data


@dataclass
class Store:
    """The serialized memory-pool region (host copy; device_put to serve)."""

    spec: LayoutSpec
    graph_buf: np.ndarray   # (n_blocks, gblk) i32
    vec_buf: np.ndarray     # (n_blocks, vblk) f32
    meta_table: np.ndarray  # (P, META_COLS) i32  ("global metadata block")
    n_base: np.ndarray      # (P,) convenience copy of MT_N_BASE
    # quantized mirror (attach_quant_mirror): codebook blocks appended to
    # the region with IDENTICAL block indexing, so every span helper above
    # addresses both precisions
    qvec_buf: Optional[np.ndarray] = None    # (n_blocks, vblk) int8
    qscale_buf: Optional[np.ndarray] = None  # (n_blocks, n_qgroups) f32

    def total_bytes(self) -> int:
        return self.graph_buf.nbytes + self.vec_buf.nbytes

    def padding_waste(self) -> float:
        used = int(self.n_base.sum()) * (self.spec.dim * 4 + (self.spec.deg + 1) * 4)
        return 1.0 - used / max(self.total_bytes(), 1)

    def fetch_span(self, pid: int) -> tuple[int, int]:
        """(first_block, n_blocks) of partition ``pid`` — what one
        contiguous RDMA_READ (or one doorbell descriptor) covers."""
        row = self.meta_table[pid]
        return int(row[MT_BLK_START]), self.spec.fetch_blocks

    def span_block_ids(self, pid: int) -> np.ndarray:
        s, n = self.fetch_span(pid)
        return np.arange(s, s + n, dtype=np.int32)


def serialize_partition(store: Store, pid: int, local_gids: np.ndarray,
                        vectors: np.ndarray, entry_local: int = 0,
                        sub_params: Optional[HNSWParams] = None) -> None:
    """(Re)build partition ``pid``'s sub-HNSW and serialize it in place.

    ``local_gids``: global ids of the member vectors; ``vectors``: their
    rows, same order.  Requires ``len(local_gids) <= spec.np_max``.
    """
    spec = store.spec
    p = sub_params or HNSWParams(M=max(spec.deg // 2, 2), M0=spec.deg,
                                 ef_construction=80)
    n = len(local_gids)
    assert n <= spec.np_max, (n, spec.np_max)
    side = pid % 2
    group = pid // 2
    gstart = group * spec.group_blocks
    data_blk = gstart + (0 if side == 0 else spec.data_blocks + spec.ov_blocks)

    adj = np.full((spec.np_max, spec.deg), -1, np.int32)
    if n:
        # bulk offline L0 build (exact kNN + HNSW heuristic prune) — the
        # paper also builds sub-HNSWs offline; see hnsw.bulk_l0_graph
        adj[:n] = bulk_l0_graph(np.asarray(vectors, np.float32), spec.deg)

    gflat = store.graph_buf[data_blk:data_blk + spec.data_blocks].reshape(-1)
    gids = np.full((spec.np_max,), -1, np.int32)
    gids[:n] = local_gids
    gflat[: spec.np_max * spec.deg] = adj.reshape(-1)
    gflat[spec.np_max * spec.deg: spec.np_max * (spec.deg + 1)] = gids

    vflat = store.vec_buf[data_blk:data_blk + spec.data_blocks].reshape(-1)
    vecs = np.zeros((spec.np_max, spec.dim), np.float32)
    vecs[:n] = vectors
    vflat[: spec.np_max * spec.dim] = vecs.reshape(-1)

    row = store.meta_table[pid]
    # A's span: [data | ov] from the group start; B's: [ov | data] — the
    # shared overflow is covered by BOTH sides' single contiguous read
    row[MT_BLK_START] = gstart + side * spec.data_blocks
    row[MT_SIDE] = side
    row[MT_N_BASE] = n
    row[MT_ENTRY] = entry_local
    row[MT_GROUP] = group
    store.n_base[pid] = n


def plan_spec(meta: MetaIndex, dim: int, *, deg: int = 16,
              ov_cap: int = 0, slot_vecs: int = 64,
              np_max: Optional[int] = None):
    """Plan the region geometry for a partitioned dataset.

    Returns ``(spec, parts)`` where ``parts`` is
    ``meta.partition_lists()``.  Split out of :func:`build_store` so the
    out-of-core loader plans the *identical* layout from the same meta.
    """
    parts = meta.partition_lists()
    sizes = np.array([len(x) + 1 for x in parts])  # +1: rep always present
    npm = int(np_max or max(int(sizes.max()), 1))
    if ov_cap <= 0:
        # paper sizes the shared region as a small fraction of a group
        ov_cap = max(16, int(0.1 * 2 * npm))
    spec = LayoutSpec(dim=dim, deg=deg, np_max=npm, ov_cap=ov_cap,
                      slot_vecs=slot_vecs, n_partitions=meta.n_partitions)
    return spec, parts


def empty_store(spec: LayoutSpec) -> Store:
    """Allocate a zeroed region for ``spec`` (graph ids initialized -1)."""
    return Store(spec=spec,
                 graph_buf=np.full((spec.n_blocks, spec.gblk), -1, np.int32),
                 vec_buf=np.zeros((spec.n_blocks, spec.vblk), np.float32),
                 meta_table=np.zeros((spec.n_partitions, META_COLS),
                                     np.int32),
                 n_base=np.zeros((spec.n_partitions,), np.int32))


def partition_member_ids(meta: MetaIndex, parts, pid: int,
                         np_max: int) -> np.ndarray:
    """Member global ids of partition ``pid``, representative first,
    truncated to ``np_max`` — THE ordering rule every build path shares
    (entry_local = 0 relies on the rep being row 0)."""
    rep_gid = int(meta.rep_ids[pid])
    ids = [rep_gid] + [int(x) for x in parts[pid] if int(x) != rep_gid]
    return np.asarray(ids[:np_max], np.int64)


def build_store(data: np.ndarray, meta: MetaIndex, *,
                sub_params: Optional[HNSWParams] = None,
                ov_cap: int = 0, slot_vecs: int = 64,
                np_max: Optional[int] = None) -> Store:
    """Build every sub-HNSW and serialize the full memory-pool region."""
    data = np.asarray(data, np.float32)
    p = sub_params or HNSWParams(M=8, M0=16, ef_construction=80)
    spec, parts = plan_spec(meta, data.shape[1], deg=p.M0, ov_cap=ov_cap,
                            slot_vecs=slot_vecs, np_max=np_max)
    store = empty_store(spec)
    for pid in range(meta.n_partitions):
        ids = partition_member_ids(meta, parts, pid, spec.np_max)
        # entry_local = 0: the representative is inserted first
        serialize_partition(store, pid, ids, data[ids], 0, p)
    return store


# --------------------------------------------------- 1/N device staging

def owned_block_ids(spec: LayoutSpec, groups) -> np.ndarray:
    """Region block ids covered by the given partition groups, ascending.

    This is the staging set of a shard that serves only ``groups``: the
    concatenation of each owned group's contiguous block range.  Out-of-
    range group ids are dropped (a placement can mention groups a smaller
    re-adopted region no longer has)."""
    gs = sorted({int(g) for g in groups if 0 <= int(g) < spec.n_groups})
    if not gs:
        return np.zeros((0,), np.int64)
    return np.concatenate([np.arange(g * spec.group_blocks,
                                     (g + 1) * spec.group_blocks,
                                     dtype=np.int64) for g in gs])


def block_slot_map(spec: LayoutSpec, staged_ids) -> np.ndarray:
    """Region-block -> staged-slot indirection for a compacted staging.

    Returns an ``(n_blocks,)`` int32 map where staged blocks name their
    row in the compacted device region and every other block is ``-1``
    (a read hitting one is a placement bug — the pool asserts)."""
    ids = np.asarray(staged_ids, np.int64)
    m = np.full((spec.n_blocks,), -1, np.int32)
    m[ids] = np.arange(len(ids), dtype=np.int32)
    return m


# ----------------------------------------------------------------- insert

def insert_vector(store: Store, vec: np.ndarray, gid: int, pid: int):
    """Append one vector into partition ``pid``'s shared overflow region
    (host mirror).  Returns the slot index, or -1 when the group's shared
    region is full -> caller must repack the group (paper: offline
    re-pack), see ``repack_group``."""
    spec = store.spec
    row = store.meta_table[pid]
    side, group = int(row[MT_SIDE]), int(row[MT_GROUP])
    partner = group * 2 + (1 - side)
    cnt_a, cnt_b = int(row[MT_OV_A]), int(row[MT_OV_B])
    if cnt_a + cnt_b >= spec.ov_cap:
        return -1
    slot = cnt_a if side == 0 else spec.ov_cap - 1 - cnt_b

    co = overflow_write_coords(spec, group, slot)
    store.vec_buf[co["vec_block"],
                  co["vec_off"]:co["vec_off"] + spec.dim] = np.asarray(vec, np.float32)
    store.graph_buf[co["gid_block"], co["gid_off"]] = gid

    col = MT_OV_A if side == 0 else MT_OV_B
    for q in (pid, partner):
        if q < spec.n_partitions:
            store.meta_table[q, col] += 1
    return slot


def overflow_write_coords(spec: LayoutSpec, group: int, slot: int) -> dict:
    """Buffer coordinates of one overflow slot (device scatter uses the
    same numbers — ``device_store.overflow_append``)."""
    ov_blk = group * spec.group_blocks + spec.data_blocks
    vpos = slot * spec.dim
    return {
        "vec_block": ov_blk + vpos // spec.vblk,
        "vec_off": vpos % spec.vblk,
        "gid_block": ov_blk + slot // spec.gblk,
        "gid_off": slot % spec.gblk,
    }


def partition_gids(store: Store, pid: int) -> np.ndarray:
    """Global ids of the base (graph) vectors of ``pid``."""
    spec = store.spec
    row = store.meta_table[pid]
    side, group = int(row[MT_SIDE]), int(row[MT_GROUP])
    data_blk = group * spec.group_blocks + (
        0 if side == 0 else spec.data_blocks + spec.ov_blocks)
    gflat = store.graph_buf[data_blk:data_blk + spec.data_blocks].reshape(-1)
    gids = gflat[spec.np_max * spec.deg: spec.np_max * (spec.deg + 1)]
    return gids[: int(row[MT_N_BASE])].copy()


def overflow_gids(store: Store, pid: int) -> np.ndarray:
    """Global ids of ``pid``'s live overflow inserts (its side only)."""
    spec = store.spec
    row = store.meta_table[pid]
    side, group = int(row[MT_SIDE]), int(row[MT_GROUP])
    ov_blk = group * spec.group_blocks + spec.data_blocks
    gflat = store.graph_buf[ov_blk:ov_blk + spec.ov_blocks].reshape(-1)
    if side == 0:
        return gflat[: int(row[MT_OV_A])].copy()
    cb = int(row[MT_OV_B])
    return gflat[spec.ov_cap - cb: spec.ov_cap][::-1].copy() if cb else gflat[:0]


# ------------------------------------------------------ quantized mirror

def attach_quant_mirror(store: Store, group: int = 32) -> Store:
    """Build (or rebuild) the int8 mirror of ``vec_buf`` in place.

    ``group`` must divide ``dim`` (codec groups never straddle vectors).
    The mirror lives in the same registered region — quantized span
    fetches reuse ``fetch_span``/``span_block_ids`` verbatim.
    """
    from repro.quant.codec import quantize_blocks
    spec = store.spec
    if spec.dim % group != 0:
        raise ValueError(f"quant group {group} must divide dim {spec.dim}")
    if spec.quant_group != group:
        import dataclasses as DC
        store.spec = DC.replace(spec, quant_group=group)
    qb = quantize_blocks(store.vec_buf, group)
    store.qvec_buf = qb.codes
    store.qscale_buf = qb.scales
    return store


def refresh_quant_blocks(store: Store, block_ids) -> None:
    """Re-quantize specific blocks after their vec rows changed (insert /
    repack touched them).  No-op when no mirror is attached."""
    if store.qvec_buf is None:
        return
    from repro.quant.codec import quantize_groups
    ids = np.atleast_1d(np.asarray(block_ids, np.int64))
    codes, scales = quantize_groups(store.vec_buf[ids],
                                    store.spec.quant_group)
    store.qvec_buf[ids] = codes
    store.qscale_buf[ids] = scales


def refresh_quant_group(store: Store, group: int) -> None:
    """Re-quantize every block of one partition group (post-repack)."""
    if store.qvec_buf is None:
        return
    spec = store.spec
    start = group * spec.group_blocks
    refresh_quant_blocks(store, np.arange(start, start + spec.group_blocks))


def flat_quant_rows(store: Store):
    """Flat-database view of every LIVE vector row in the region.

    Returns ``(rows, gids, pids)`` — region row addresses (indices into
    ``vec_buf.reshape(-1, dim)`` and the lockstep quantized mirror), the
    matching global ids, and the owning partition of each row.  Base rows
    come first per partition, then that partition's live overflow slots
    (same order as ``overflow_gids``).  Every live row appears exactly
    once: a group's shared overflow region is split between the two
    partners by side, so the flat view never duplicates an insert.

    This is the compute-side index for the dense-resident stage-1 path:
    when the quantized tier can hold every partition, stage 1 is one flat
    ``quant_topk`` scan over these rows instead of per-pair decodes.
    """
    spec = store.spec
    rows, gids, pids = [], [], []
    for pid in range(spec.n_partitions):
        mrow = store.meta_table[pid]
        side, group = int(mrow[MT_SIDE]), int(mrow[MT_GROUP])
        blk_start = int(mrow[MT_BLK_START])
        n = int(mrow[MT_N_BASE])
        data_row0 = (blk_start + side * spec.ov_blocks) * spec.slot_vecs
        rows.append(data_row0 + np.arange(n, dtype=np.int64))
        gids.append(partition_gids(store, pid).astype(np.int64))
        ov_row0 = (blk_start + (1 - side) * spec.data_blocks) * spec.slot_vecs
        og = overflow_gids(store, pid).astype(np.int64)
        if side == 0:
            orows = ov_row0 + np.arange(len(og), dtype=np.int64)
        else:
            # side B fills back-to-front; overflow_gids reverses, so the
            # row addresses walk down from the last slot in lockstep
            orows = ov_row0 + (spec.ov_cap - 1 - np.arange(len(og),
                                                          dtype=np.int64))
        rows.append(orows)
        gids.append(og)
        pids.append(np.full(n + len(og), pid, np.int64))
    return (np.concatenate(rows), np.concatenate(gids),
            np.concatenate(pids))


def repack_group(store: Store, group: int, data_lookup,
                 sub_params: Optional[HNSWParams] = None) -> bool:
    """Fold both partitions' overflow inserts into rebuilt sub-HNSWs and
    re-serialize the group in place (paper's offline re-pack).  Returns
    False if a merged partition no longer fits ``np_max`` (caller must do
    a full ``build_store`` rebuild with a larger pad)."""
    spec = store.spec
    members: dict[int, np.ndarray] = {}
    for side in (0, 1):
        pid = group * 2 + side
        if pid >= spec.n_partitions:
            continue
        ids = np.concatenate([partition_gids(store, pid),
                              overflow_gids(store, pid)])
        if len(ids) > spec.np_max:
            return False
        members[pid] = ids
    ov_blk = group * spec.group_blocks + spec.data_blocks
    store.graph_buf[ov_blk:ov_blk + spec.ov_blocks] = -1
    store.vec_buf[ov_blk:ov_blk + spec.ov_blocks] = 0.0
    for pid, ids in members.items():
        serialize_partition(store, pid, ids, data_lookup(ids), 0, sub_params)
        store.meta_table[pid, MT_OV_A] = 0
        store.meta_table[pid, MT_OV_B] = 0
    return True
