"""Query-aware batched data loading — paper §3.3.

Given a batch of queries and each query's top-*b* partitions (from the
cached meta-HNSW), plan the fetches so that:

  * each required partition is loaded from the memory pool **at most
    once** per batch (the paper's headline invariant);
  * partitions already resident in the compute-node cache are not
    fetched at all;
  * fetches are grouped into *doorbell batches* of <= ``doorbell`` spans
    per round trip;
  * the number of simultaneously-resident partitions never exceeds the
    cache capacity *c*; processing is organized in **rounds**: fetch a
    set, serve every (query, partition) pair that hits it, evict LRU,
    repeat.  Per-query running top-k accumulates across rounds
    (Fig. 5's "temporarily stored for further comparison").

Planning is plain host code (numpy): it is the compute-instance CPU role
in the paper, and it only touches the (B, b) partition-id matrix the
meta-route already produced.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def pow2_pad(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floor ``lo``) — the shape-bucketing rule
    shared by the engine's round padding and the serve tier's fused-batch
    padding, so jitted stages see a bounded set of shapes."""
    m = lo
    while m < n:
        m *= 2
    return m


def doorbell_chunks(items, doorbell: int):
    """Split ``items`` into doorbell batches of <= ``doorbell`` entries —
    the one grouping rule shared by the planner (span fetches) and the
    memory-pool transports (descriptor submission), so verb accounting
    and the round schedule can never disagree on what one round trip
    carries."""
    doorbell = max(int(doorbell), 1)
    return [items[j:j + doorbell] for j in range(0, len(items), doorbell)]


def doorbell_chunks_sharded(items, doorbell: int, owner_of=None):
    """Destination-aware doorbell batching: descriptors are grouped by
    owning shard FIRST (``owner_of(item) -> shard``), then each
    destination's run is doorbell-chunked — one round trip never mixes
    destinations, because a doorbell rings ONE remote NIC.  With
    ``owner_of=None`` (single memory node) this is ``doorbell_chunks``.
    """
    if owner_of is None:
        return doorbell_chunks(items, doorbell)
    by: dict[int, list] = {}
    for it in np.asarray(items).reshape(-1):
        by.setdefault(int(owner_of(int(it))), []).append(it)
    out = []
    for s in sorted(by):
        out.extend(doorbell_chunks(np.asarray(by[s], np.int64), doorbell))
    return out


@dataclass
class Round:
    """One fetch-and-serve round.  Slot ids are assigned at *planning*
    time (a later round may evict this round's partitions, so executors
    must not re-derive slots from the final cache state)."""

    fetch_pids: np.ndarray          # partitions to pull this round (<= free slots)
    fetch_slots: np.ndarray         # cache slot for each fetched partition
    doorbells: list[np.ndarray]     # fetch_pids split into doorbell batches
    evict_pids: np.ndarray          # evicted before the fetch (LRU)
    serve_pairs: np.ndarray         # (n, 2) [query_idx, pid] served this round
    pair_slots: np.ndarray          # (n,) slot holding each pair's partition
    pair_ranks: np.ndarray = None   # (n,) occurrence index of the pair's
                                    # query within this round (0-based) —
                                    # the merge "lane" the pair lands in

    @property
    def n_lanes(self) -> int:
        """Merge lanes this round needs: max pairs any one query has."""
        if self.pair_ranks is None or not len(self.pair_ranks):
            return 1
        return int(self.pair_ranks.max()) + 1

    def serve_tensors(self, pad_to: int, n_queries: int):
        """Batch-major device feed for this round's serve pairs, padded
        to ``pad_to`` lanes: ``(qi, pids, slots, ranks, valid)``.

        Padding rows target the scatter dump row ``n_queries`` (one past
        the real batch) so a fixed-shape ``(B+1, n_lanes, k)`` scatter can
        drop them without a gather/where pass; pid/slot/rank padding is 0
        and masked by ``valid``.
        """
        n = len(self.serve_pairs)
        qi = np.full(pad_to, n_queries, np.int32)
        pids = np.zeros(pad_to, np.int32)
        slots = np.zeros(pad_to, np.int32)
        ranks = np.zeros(pad_to, np.int32)
        if n:
            qi[:n] = self.serve_pairs[:, 0]
            pids[:n] = self.serve_pairs[:, 1]
            slots[:n] = self.pair_slots
            ranks[:n] = self.pair_ranks
        valid = np.arange(pad_to) < n
        return qi, pids, slots, ranks, valid


@dataclass
class Plan:
    rounds: list[Round]
    unique_pids: np.ndarray         # all distinct partitions this batch needs
    n_cache_hits: int               # (query, partition) pairs already resident
    n_fetches: int                  # partitions actually transferred

    def loads_per_partition(self) -> dict[int, int]:
        cnt: dict[int, int] = {}
        for r in self.rounds:
            for p in r.fetch_pids.tolist():
                cnt[p] = cnt.get(p, 0) + 1
        return cnt


class LRUCacheState:
    """Host-side mirror of the compute-node resident-partition cache.

    Slot contents live on device (``engine.py``); this tracks pid->slot
    and recency.  Functionally updated by the plan executor so the most
    recently used *c* partitions persist into the next batch (§3.3)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.slots: list[int] = [-1] * capacity   # slot -> pid
        self._recency: list[int] = []             # pids, LRU first

    def resident(self) -> set[int]:
        return {p for p in self.slots if p >= 0}

    def slot_of(self, pid: int) -> int:
        return self.slots.index(pid)

    def touch(self, pid: int) -> None:
        if pid in self._recency:
            self._recency.remove(pid)
        self._recency.append(pid)

    def admit(self, pid: int) -> tuple[int, int]:
        """Returns (slot, evicted_pid or -1)."""
        if pid in self.slots:
            self.touch(pid)
            return self.slots.index(pid), -1
        if -1 in self.slots:
            slot = self.slots.index(-1)
            evicted = -1
        else:
            lru = self._recency.pop(0)
            slot = self.slots.index(lru)
            evicted = lru
        self.slots[slot] = pid
        self.touch(pid)
        return slot, evicted

    def drop(self, pid: int) -> None:
        """Invalidate ``pid`` if resident (stale after an insert)."""
        if pid in self.slots:
            self.slots[self.slots.index(pid)] = -1
        if pid in self._recency:
            self._recency.remove(pid)


class TieredCacheState:
    """Two-tier compute-node cache for the quantized search path.

    * ``quant`` — the LARGE tier: int8 spans + codebook blocks.  Stage-1
      planning runs ``plan_batch`` against it, so a quantized hit avoids
      the remote read entirely (the §3.3 invariant, at ~1/4 the bytes
      per miss).
    * ``exact`` — the SMALL tier: full-precision spans.  Stage-2 re-rank
      rows that land in an exact-resident partition cost zero wire
      bytes; everything else is fetched row-granular.

    Admission to the exact tier is cost-based: ``note_rerank_miss``
    accumulates each partition's missed re-rank rows and
    ``should_admit`` fires once the cumulative missed bytes exceed one
    full span fetch — i.e. only partitions whose re-rank traffic has
    already paid for a span get promoted (a decayed counter, so cold
    partitions age out instead of eventually all qualifying).
    """

    DECAY = 0.5          # eviction decay on the miss counter

    def __init__(self, quant_cap: int, exact_cap: int):
        self.quant = LRUCacheState(max(int(quant_cap), 1))
        self.exact = LRUCacheState(max(int(exact_cap), 1))
        self._miss_rows: dict[int, float] = {}   # pid -> missed rerank rows

    def invalidate(self, pid: int) -> None:
        self.quant.drop(pid)
        self.exact.drop(pid)
        self._miss_rows.pop(pid, None)

    def note_rerank_miss(self, pid: int, n_rows: int) -> None:
        self._miss_rows[pid] = self._miss_rows.get(pid, 0.0) + n_rows

    def should_admit(self, pid: int, row_bytes: int, span_bytes: int) -> bool:
        return (pid not in self.exact.resident()
                and self._miss_rows.get(pid, 0.0) * row_bytes >= span_bytes)

    def admit_exact(self, pid: int) -> tuple[int, int]:
        """Promote ``pid`` (caller fetches + installs the exact span).
        Returns (slot, evicted_pid or -1); the evictee's miss counter is
        decayed, not erased — re-promotion needs fresh traffic."""
        slot, evicted = self.exact.admit(pid)
        self._miss_rows[pid] = 0.0
        if evicted >= 0:
            self._miss_rows[evicted] = (
                self._miss_rows.get(evicted, 0.0) * self.DECAY)
        return slot, evicted


def _pair_ranks(pairs: np.ndarray) -> np.ndarray:
    """Occurrence index of each pair's query within its round (0-based).

    A query served against m partitions in one round occupies merge lanes
    0..m-1; the device merge scatters lane-major and tops-k once."""
    counts: dict[int, int] = {}
    ranks = np.zeros(len(pairs), np.int64)
    for j, (q, _) in enumerate(pairs):
        r = counts.get(int(q), 0)
        ranks[j] = r
        counts[int(q)] = r + 1
    return ranks


def plan_batch(topb_pids: np.ndarray, cache: LRUCacheState, *,
               doorbell: int = 8, owner_of=None) -> Plan:
    """Build the round schedule for one query batch.

    ``topb_pids``: (B, b) int — per-query required partitions, nearest
    first.  Mutates ``cache`` recency/slots to its post-batch state.
    ``owner_of`` (pid -> shard), when given, makes each round's
    advertised doorbell batches destination-aware (a sharded pool splits
    its descriptor submission the same way).
    """
    topb = np.asarray(topb_pids)
    B, b = topb.shape
    cap = cache.capacity

    # (query, pid) demand pairs, de-duplicated per query
    demand: dict[int, list[int]] = {}
    for q in range(B):
        for p in dict.fromkeys(int(x) for x in topb[q]):
            demand.setdefault(p, []).append(q)
    unique = np.array(sorted(demand), dtype=np.int64)

    resident = cache.resident()
    hits = [p for p in unique.tolist() if p in resident]
    n_cache_hits = sum(len(demand[p]) for p in hits)
    missing = [p for p in unique.tolist() if p not in resident]
    # fetch order: highest fan-in first — serves the most queries per
    # round and makes early rounds maximally useful
    missing.sort(key=lambda p: -len(demand[p]))

    rounds: list[Round] = []
    # round 0: serve everything already resident (zero fetches)
    if hits:
        pairs = np.array([(q, p) for p in hits for q in demand[p]], np.int64)
        slots = np.array([cache.slot_of(p) for p in hits], np.int64)
        pslots = np.array([cache.slot_of(p) for p in hits
                           for _ in demand[p]], np.int64)
        for p in hits:
            cache.touch(p)
        rounds.append(Round(np.array([], np.int64), np.array([], np.int64),
                            [], np.array([], np.int64), pairs, pslots,
                            _pair_ranks(pairs)))

    i = 0
    while i < len(missing):
        take = missing[i:i + cap]
        i += len(take)
        evicted, slots = [], []
        for p in take:
            slot, ev = cache.admit(p)
            slots.append(slot)
            if ev >= 0:
                evicted.append(ev)
        pairs = np.array([(q, p) for p in take for q in demand[p]], np.int64)
        pslots = np.array([s for p, s in zip(take, slots)
                           for _ in demand[p]], np.int64)
        fetch = np.array(take, np.int64)
        doorbells = doorbell_chunks_sharded(fetch, doorbell, owner_of)
        rounds.append(Round(fetch, np.array(slots, np.int64), doorbells,
                            np.array(evicted, np.int64), pairs, pslots,
                            _pair_ranks(pairs)))

    return Plan(rounds=rounds, unique_pids=unique,
                n_cache_hits=n_cache_hits, n_fetches=len(missing))


def naive_plan(topb_pids: np.ndarray) -> list[tuple[int, int]]:
    """The Naive d-HNSW baseline: every (query, partition) need is its own
    RDMA read — no dedup, no cache, no doorbell.  Returns the raw fetch
    list [(query, pid), ...] whose length is the round-trip count."""
    topb = np.asarray(topb_pids)
    out = []
    for q in range(topb.shape[0]):
        for p in dict.fromkeys(int(x) for x in topb[q]):
            out.append((q, p))
    return out
