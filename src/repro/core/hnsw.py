"""Host-side HNSW construction (numpy) — the graph the paper disaggregates.

Standard Malkov–Yashunin HNSW: exponentially-distributed insert levels,
per-layer greedy descent to the insert point, ``efConstruction`` beam at
the base layer, neighbor-set pruning with the distance heuristic.  This is
the *build* path only; it runs on the host (the paper builds the index on
the memory-pool loader before serving).  Query-time search lives in
``core/search.py`` as fixed-shape JAX.

Export format (``PaddedGraph``) is the dense -1-padded adjacency the JAX
search and the RDMA-friendly layout (``core/layout.py``) both consume.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def l2_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Squared L2 between one vector ``a`` (D,) and rows of ``b`` (N, D)."""
    d = b - a[None, :]
    return np.einsum("nd,nd->n", d, d)


@dataclass
class HNSWParams:
    M: int = 16              # max degree at layers > 0
    M0: int = 32             # max degree at layer 0 (2*M, standard)
    ef_construction: int = 100
    ml: float = 0.0          # level multiplier; 0 -> 1/ln(M)
    seed: int = 0
    heuristic: bool = True   # neighbor-selection distance heuristic

    def __post_init__(self):
        if self.ml == 0.0:
            self.ml = 1.0 / math.log(self.M)


@dataclass
class PaddedGraph:
    """Dense export: fixed shapes, -1 padding — directly device-puttable."""

    vectors: np.ndarray        # (N, D) f32
    adjacency: np.ndarray      # (L, N, deg) i32, -1 padded; L = n_levels
    entry: int                 # entry node id (top level)
    n_levels: int
    node_level: np.ndarray     # (N,) i32 max level of each node

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


class HNSW:
    """Incremental HNSW over float32 vectors with squared-L2 metric."""

    def __init__(self, dim: int, params: Optional[HNSWParams] = None):
        self.p = params or HNSWParams()
        self.dim = dim
        self.vectors: list[np.ndarray] = []
        self.levels: list[int] = []
        # neighbors[l][i] = list of node ids at layer l (only for i with level >= l)
        self.neighbors: list[list[list[int]]] = []
        self.entry: int = -1
        self.max_level: int = -1
        self._rng = np.random.default_rng(self.p.seed)
        self._mat: Optional[np.ndarray] = None  # lazily rebuilt (N, D) matrix

    # ------------------------------------------------------------ build

    def _matrix(self) -> np.ndarray:
        if self._mat is None or self._mat.shape[0] != len(self.vectors):
            self._mat = (np.stack(self.vectors) if self.vectors
                         else np.zeros((0, self.dim), np.float32))
        return self._mat

    def _draw_level(self) -> int:
        return int(-math.log(max(self._rng.random(), 1e-12)) * self.p.ml)

    def _search_layer(self, q: np.ndarray, entry: int, ef: int,
                      layer: int) -> list[tuple[float, int]]:
        """Beam search at one layer; returns sorted [(dist, id)] of <= ef."""
        mat = self._matrix()
        visited = {entry}
        d0 = float(l2_sq(q, mat[entry:entry + 1])[0])
        cand = [(d0, entry)]       # min-heap by dist (kept sorted, small ef)
        best = [(d0, entry)]       # result set, sorted ascending
        import heapq
        heapq.heapify(cand)
        while cand:
            d, u = heapq.heappop(cand)
            if d > best[-1][0] and len(best) >= ef:
                break
            nbrs = [v for v in self.neighbors[layer][u] if v not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            dists = l2_sq(q, mat[nbrs])
            worst = best[-1][0]
            for dv, v in zip(dists.tolist(), nbrs):
                if len(best) < ef or dv < worst:
                    heapq.heappush(cand, (dv, v))
                    best.append((dv, v))
                    best.sort()
                    if len(best) > ef:
                        best.pop()
                    worst = best[-1][0]
        return best

    def _select_neighbors(self, q: np.ndarray, cands: list[tuple[float, int]],
                          m: int) -> list[int]:
        """Distance heuristic (alg. 4 of the paper[20]): keep a candidate
        only if it is closer to q than to every already-kept neighbor."""
        if not self.p.heuristic or len(cands) <= m:
            return [i for _, i in sorted(cands)[:m]]
        mat = self._matrix()
        kept: list[int] = []
        for d, c in sorted(cands):
            if len(kept) >= m:
                break
            ok = True
            for k in kept:
                if float(l2_sq(mat[c], mat[k:k + 1])[0]) < d:
                    ok = False
                    break
            if ok:
                kept.append(c)
        # backfill with nearest pruned if underfull (keepPruned variant)
        if len(kept) < m:
            for d, c in sorted(cands):
                if c not in kept:
                    kept.append(c)
                    if len(kept) >= m:
                        break
        return kept

    def insert(self, vec: np.ndarray, level: Optional[int] = None) -> int:
        vec = np.asarray(vec, np.float32)
        nid = len(self.vectors)
        self.vectors.append(vec)
        self._mat = None
        lvl = self._draw_level() if level is None else level
        self.levels.append(lvl)
        while len(self.neighbors) <= lvl:
            self.neighbors.append([[] for _ in range(nid)])
        for layer in self.neighbors:
            while len(layer) <= nid:
                layer.append([])

        if self.entry < 0:
            self.entry, self.max_level = nid, lvl
            return nid

        ep = self.entry
        # greedy descent through layers above lvl
        for layer in range(self.max_level, lvl, -1):
            ep = self._search_layer(vec, ep, 1, layer)[0][1]
        # insert at layers min(lvl, max_level) .. 0
        for layer in range(min(lvl, self.max_level), -1, -1):
            cands = self._search_layer(vec, ep, self.p.ef_construction, layer)
            m = self.p.M0 if layer == 0 else self.p.M
            nbrs = self._select_neighbors(vec, cands, m)
            self.neighbors[layer][nid] = list(nbrs)
            mat = self._matrix()
            for v in nbrs:
                lst = self.neighbors[layer][v]
                lst.append(nid)
                if len(lst) > m:
                    cd = [(float(l2_sq(mat[v], mat[u:u + 1])[0]), u) for u in lst]
                    self.neighbors[layer][v] = self._select_neighbors(mat[v], cd, m)
            ep = cands[0][1]
        if lvl > self.max_level:
            self.entry, self.max_level = nid, lvl
        return nid

    def build(self, data: np.ndarray) -> "HNSW":
        for row in np.asarray(data, np.float32):
            self.insert(row)
        return self

    # ------------------------------------------------------------ query (host oracle)

    def search(self, q: np.ndarray, k: int, ef: int) -> list[tuple[float, int]]:
        if self.entry < 0:
            return []
        q = np.asarray(q, np.float32)
        ep = self.entry
        for layer in range(self.max_level, 0, -1):
            ep = self._search_layer(q, ep, 1, layer)[0][1]
        best = self._search_layer(q, ep, max(ef, k), 0)
        return best[:k]

    # ------------------------------------------------------------ export

    def export(self, max_levels: Optional[int] = None) -> PaddedGraph:
        n = len(self.vectors)
        n_levels = (self.max_level + 1 if max_levels is None
                    else min(self.max_level + 1, max_levels))
        deg = max(self.p.M0, self.p.M)
        adj = np.full((n_levels, n, deg), -1, np.int32)
        for l in range(n_levels):
            for i in range(n):
                nb = self.neighbors[l][i] if l < len(self.neighbors) else []
                adj[l, i, :len(nb)] = nb[:deg]
        entry = self.entry
        if self.max_level >= n_levels:  # cap: reroute entry to a top-capped node
            lvl = n_levels - 1
            # entry stays valid — it exists at every layer below its level
        return PaddedGraph(
            vectors=self._matrix().astype(np.float32).copy(),
            adjacency=adj,
            entry=entry,
            n_levels=n_levels,
            node_level=np.minimum(np.asarray(self.levels, np.int32),
                                  n_levels - 1),
        )


def bulk_l0_graph(vectors: np.ndarray, m0: int, *, heuristic: bool = True,
                  slack: int = 2) -> np.ndarray:
    """Fast offline L0 graph build for one (small) partition.

    Exact kNN graph via one matmul (partitions are ~1-10k vectors), then
    the HNSW neighbor-selection heuristic per node, then reverse-edge
    augmentation capped at m0.  This is the standard bulk/offline build
    (paper builds sub-HNSWs offline too) — same search semantics as
    incrementally-built HNSW L0, ~100x faster on the host, and the
    diversified neighborhood makes greedy routing at least as good.

    Returns (n, m0) int32 adjacency, -1 padded.
    """
    v = np.asarray(vectors, np.float32)
    n = v.shape[0]
    if n <= 1:
        return np.full((n, m0), -1, np.int32)
    k = min(m0 * slack + 1, n)
    x2 = np.einsum("nd,nd->n", v, v)
    adj = np.full((n, m0), -1, np.int32)
    chunk = max(1, int(2**26 / max(n, 1)))
    for s in range(0, n, chunk):
        d = x2[None, :] - 2.0 * v[s:s + chunk] @ v.T + x2[s:s + chunk, None]
        for i in range(d.shape[0]):
            d[i, s + i] = np.inf  # no self edge
        idx = np.argpartition(d, k - 1, axis=1)[:, :k]
        dd = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(dd, axis=1)
        idx = np.take_along_axis(idx, order, axis=1)
        dd = np.take_along_axis(dd, order, axis=1)
        for i in range(idx.shape[0]):
            node = s + i
            if not heuristic:
                adj[node, :min(m0, k)] = idx[i, :m0]
                continue
            kept: list[int] = []
            for dq, c in zip(dd[i], idx[i]):
                if len(kept) >= m0:
                    break
                dc = dq
                ok = True
                for kk in kept:
                    dk = float(np.sum(np.square(v[c] - v[kk])))
                    if dk < dc:
                        ok = False
                        break
                if ok:
                    kept.append(int(c))
            # backfill with nearest pruned (keepPruned)
            for c in idx[i]:
                if len(kept) >= m0:
                    break
                if int(c) not in kept:
                    kept.append(int(c))
            adj[node, :len(kept)] = kept
    # reverse-edge augmentation: ensure in-degree (greedy reachability)
    deg = (adj >= 0).sum(1)
    for node in range(n):
        for c in adj[node]:
            if c < 0:
                break
            if deg[c] < m0 and node not in adj[c, :deg[c]]:
                adj[c, deg[c]] = node
                deg[c] += 1
    return adj


def brute_force_knn(data: np.ndarray, queries: np.ndarray,
                    k: int) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k ground truth: (dists (Q,k), ids (Q,k)).  Chunked so the
    (Q, N) matrix never exceeds ~256 MB."""
    data = np.asarray(data, np.float32)
    queries = np.asarray(queries, np.float32)
    qn = queries.shape[0]
    ids = np.empty((qn, k), np.int64)
    dists = np.empty((qn, k), np.float32)
    x2 = np.einsum("nd,nd->n", data, data)
    chunk = max(1, int(2**28 / max(data.shape[0], 1) / 4))
    for s in range(0, qn, chunk):
        qc = queries[s:s + chunk]
        d = x2[None, :] - 2.0 * qc @ data.T + np.einsum("qd,qd->q", qc, qc)[:, None]
        idx = np.argpartition(d, min(k, d.shape[1] - 1), axis=1)[:, :k]
        dd = np.take_along_axis(d, idx, axis=1)
        order = np.argsort(dd, axis=1)
        ids[s:s + chunk] = np.take_along_axis(idx, order, axis=1)
        dists[s:s + chunk] = np.take_along_axis(dd, order, axis=1)
    return dists, ids


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |pred ∩ true| / k."""
    hits = 0
    k = true_ids.shape[1]
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(int(x) for x in p[:k]) & set(int(x) for x in t))
    return hits / (true_ids.shape[0] * k)
