"""Device-side store: fetched-span decode + per-partition search.

Everything here is static-shaped and jit-friendly.  A fetch span is
``(fetch_blocks, gblk)`` int32 + ``(fetch_blocks, vblk)`` float32 — the
unit one doorbell descriptor covers.  ``decode_span`` turns a span + its
metadata row into padded search arrays; the two search paths (faithful
graph walk / MXU scan) run on the decoded view.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import search as S
from repro.core.layout import (LayoutSpec, MT_BLK_START, MT_ENTRY,
                               MT_N_BASE, MT_OV_A, MT_OV_B, MT_SIDE)


class DecodedPartition(NamedTuple):
    vectors: jax.Array    # (np_max + ov_cap, D) — base then overflow slots
    adjacency: jax.Array  # (1, np_max, deg) local ids, -1 pad
    gids: jax.Array       # (np_max + ov_cap,) global ids, -1 pad
    valid: jax.Array      # (np_max + ov_cap,) bool — base n + live overflow
    entry: jax.Array      # () local entry id (the representative)


def decode_span(spec: LayoutSpec, g_span, v_span, meta_row) -> DecodedPartition:
    """g_span (fetch_blocks, gblk) i32; v_span (fetch_blocks, vblk) f32."""
    side = meta_row[MT_SIDE]
    n_base = meta_row[MT_N_BASE]
    gflat = g_span.reshape(-1)
    vflat = v_span.reshape(-1)

    data_g = lax.dynamic_slice(gflat, (side * spec.ov_blocks * spec.gblk,),
                               (spec.np_max * (spec.deg + 1),))
    adjacency = data_g[: spec.np_max * spec.deg].reshape(spec.np_max, spec.deg)
    base_gids = data_g[spec.np_max * spec.deg:]

    ov_goff = (1 - side) * spec.data_blocks * spec.gblk
    ov_gids = lax.dynamic_slice(gflat, (ov_goff,), (spec.ov_cap,))

    data_v = lax.dynamic_slice(vflat, (side * spec.ov_blocks * spec.vblk,),
                               (spec.np_max * spec.dim,))
    base_vecs = data_v.reshape(spec.np_max, spec.dim)
    ov_voff = (1 - side) * spec.data_blocks * spec.vblk
    ov_vecs = lax.dynamic_slice(vflat, (ov_voff,),
                                (spec.ov_cap * spec.dim,)).reshape(
                                    spec.ov_cap, spec.dim)

    cnt_a, cnt_b = meta_row[MT_OV_A], meta_row[MT_OV_B]
    ov_idx = jnp.arange(spec.ov_cap)
    # A's inserts fill the front, B's fill the back; a fetch sees both but
    # only its own side's slots belong to this partition
    ov_mine = jnp.where(side == 0, ov_idx < cnt_a,
                        ov_idx >= spec.ov_cap - cnt_b)
    base_valid = jnp.arange(spec.np_max) < n_base
    return DecodedPartition(
        vectors=jnp.concatenate([base_vecs, ov_vecs], axis=0),
        adjacency=adjacency[None],
        gids=jnp.concatenate([base_gids, ov_gids]),
        valid=jnp.concatenate([base_valid, ov_mine]),
        entry=meta_row[MT_ENTRY],
    )


def search_decoded_scan(part: DecodedPartition, q, k: int):
    """Exact top-k over every valid vector (base + overflow) — the
    beyond-paper MXU path.  Returns (dists (k,), global ids (k,))."""
    d = jnp.sum(jnp.square(part.vectors - q[None, :]), axis=-1)
    d = jnp.where(part.valid, d, jnp.inf)
    nd, ni = lax.top_k(-d, k)
    return -nd, part.gids[ni]


def search_decoded_graph(part: DecodedPartition, q, k: int, ef: int):
    """Paper-faithful: beam-search the sub-HNSW graph over the base
    vectors, then brute-scan the (tiny) live overflow slice and merge —
    exactly how the paper covers not-yet-relinked inserted vectors."""
    np_max = part.adjacency.shape[1]
    bd, bi = S.beam_search(part.vectors[:np_max], part.adjacency, q,
                           part.entry, ef=max(ef, k), n_levels=1)
    bd = jnp.where((bi >= 0) & part.valid[jnp.maximum(bi, 0)], bd, jnp.inf)
    base_d, base_i = bd[:k], jnp.where(jnp.isfinite(bd[:k]),
                                       part.gids[jnp.maximum(bi[:k], 0)], -1)
    ov_vecs = part.vectors[np_max:]
    ov_d = jnp.sum(jnp.square(ov_vecs - q[None, :]), axis=-1)
    ov_d = jnp.where(part.valid[np_max:], ov_d, jnp.inf)
    kk = min(k, ov_vecs.shape[0])
    od, oi = lax.top_k(-ov_d, kk)
    og = part.gids[np_max + oi]
    return S.merge_topk(base_d, base_i, -od, jnp.where(jnp.isfinite(-od), og, -1), k)


@functools.partial(jax.jit,
                   static_argnames=("spec", "k", "ef", "mode", "n_lanes"),
                   donate_argnums=(5, 6))
def serve_and_merge(spec: LayoutSpec, cache_g, cache_v, meta_table, queries,
                    run_d, run_g, pair_qi, pair_pids, pair_slots, pair_ranks,
                    pair_valid, *, k: int, ef: int, mode: str, n_lanes: int):
    """One round, fused: per-pair top-k inside the pair's partition, then a
    single vectorized scatter-merge into the batch's running top-k.

    Replaces the host loop that merged each pair's ``(k,)`` list into its
    query's running list one ``np.argsort`` at a time.  All staging is
    device-side gathers from arrays resident since batch start:

    meta_table: (n_partitions, META_COLS) — the whole cached table; each
                pair gathers its own row (no per-round host rebuild)
    queries:    (B, D) — the full query batch; gathered by ``pair_qi``
    run_d/run_g:(B, k) running top-k carried across rounds (donated)
    pair_qi:    (n_pairs,) query index; padding lanes point at row B so
                the ``(B+1, n_lanes, k)`` scatter drops them
    pair_ranks: (n_pairs,) merge lane — occurrence index of the pair's
                query within this round (unique per (query, round))
    Returns the updated (run_d, run_g): (B, k) each.

    Merge semantics are identical to folding the pairs in order through a
    stable sort (stable argsort over [running | lane 0 | lane 1 | ...] is
    associative with the sequential stable merges the host loop did), so
    results are bit-identical to the old path.
    """
    rows = meta_table[pair_pids]
    qs = queries[pair_qi]          # padding qi == B clamps; masked below

    def one(slot, row, q, ok):
        part = decode_span(spec, cache_g[slot], cache_v[slot], row)
        if mode == "graph":
            d, g = search_decoded_graph(part, q, k, ef)
        else:
            d, g = search_decoded_scan(part, q, k)
        return jnp.where(ok, d, jnp.inf), jnp.where(ok, g, -1)

    d, g = jax.vmap(one)(pair_slots, rows, qs, pair_valid)
    return merge_ranked(run_d, run_g, pair_qi, pair_ranks, d, g,
                        n_lanes=n_lanes)


@functools.partial(jax.jit, static_argnames=("n_lanes",))
def merge_ranked(run_d, run_g, pair_qi, pair_ranks, d, g, *, n_lanes: int):
    """Scatter-merge per-pair top-k lists into the running per-query top-k.

    Each pair lands in merge lane ``(pair_qi, pair_ranks)`` of a
    ``(B+1, n_lanes, k)`` buffer (row B is the dump row for padding pairs),
    then one stable argsort per query takes the new top-k.  Equivalent to
    folding the pairs through sequential stable merges.
    """
    k = run_d.shape[1]
    B = run_d.shape[0]
    buf_d = jnp.full((B + 1, n_lanes, k), jnp.inf, run_d.dtype)
    buf_g = jnp.full((B + 1, n_lanes, k), -1, run_g.dtype)
    buf_d = buf_d.at[pair_qi, pair_ranks].set(d)
    buf_g = buf_g.at[pair_qi, pair_ranks].set(g.astype(run_g.dtype))
    all_d = jnp.concatenate([run_d, buf_d[:B].reshape(B, n_lanes * k)], axis=1)
    all_g = jnp.concatenate([run_g, buf_g[:B].reshape(B, n_lanes * k)], axis=1)
    order = jnp.argsort(all_d, axis=1, stable=True)[:, :k]
    return (jnp.take_along_axis(all_d, order, axis=1),
            jnp.take_along_axis(all_g, order, axis=1))


# ------------------------------------------------------------ quantized tier
#
# The staged (quant=int8) search path: stage 1 decodes QUANTIZED spans
# resident in the large quantized tier into the same DecodedPartition
# view (dequantize = one fused multiply) and pools per-query candidates
# (distance, gid, exact-row address, pid); stage 2 gathers only the
# candidate rows in full precision and re-ranks to the final top-k.
# Everything below is additive — the full-precision serve path above is
# untouched so quant="none" stays bit-identical.


def decode_quant_span(spec: LayoutSpec, g_span, qv_span, qs_span, meta_row):
    """Quantized twin of ``decode_span``.

    g_span (fetch_blocks, gblk) i32; qv_span (fetch_blocks, vblk) int8;
    qs_span (fetch_blocks, n_qgroups) f32.  Returns (DecodedPartition
    with dequantized f32 vectors, rows (np_max + ov_cap,) i32) where
    ``rows`` are exact-row addresses into ``vec_buf.reshape(-1, dim)``
    — what stage 2 fetches for re-ranking.
    """
    g = spec.quant_group
    side = meta_row[MT_SIDE]
    n_base = meta_row[MT_N_BASE]
    gflat = g_span.reshape(-1)
    qvflat = qv_span.reshape(-1).astype(jnp.float32)
    qsflat = qs_span.reshape(-1)

    data_g = lax.dynamic_slice(gflat, (side * spec.ov_blocks * spec.gblk,),
                               (spec.np_max * (spec.deg + 1),))
    adjacency = data_g[: spec.np_max * spec.deg].reshape(spec.np_max, spec.deg)
    base_gids = data_g[spec.np_max * spec.deg:]
    ov_goff = (1 - side) * spec.data_blocks * spec.gblk
    ov_gids = lax.dynamic_slice(gflat, (ov_goff,), (spec.ov_cap,))

    def dequant(flat_off_floats, n_vecs):
        codes = lax.dynamic_slice(qvflat, (flat_off_floats,),
                                  (n_vecs * spec.dim,))
        scales = lax.dynamic_slice(qsflat, (flat_off_floats // g,),
                                   (n_vecs * spec.dim // g,))
        x = codes.reshape(-1, g) * scales[:, None]
        return x.reshape(n_vecs, spec.dim)

    base_vecs = dequant(side * spec.ov_blocks * spec.vblk, spec.np_max)
    ov_vecs = dequant((1 - side) * spec.data_blocks * spec.vblk, spec.ov_cap)

    cnt_a, cnt_b = meta_row[MT_OV_A], meta_row[MT_OV_B]
    ov_idx = jnp.arange(spec.ov_cap)
    ov_mine = jnp.where(side == 0, ov_idx < cnt_a,
                        ov_idx >= spec.ov_cap - cnt_b)
    base_valid = jnp.arange(spec.np_max) < n_base

    # exact-row addresses: vblk = slot_vecs * dim, so row r of the region
    # lives at flat row index block * slot_vecs + local offset
    blk_start = meta_row[MT_BLK_START]
    data_row0 = (blk_start + side * spec.ov_blocks) * spec.slot_vecs
    ov_row0 = (blk_start + (1 - side) * spec.data_blocks) * spec.slot_vecs
    rows = jnp.concatenate([data_row0 + jnp.arange(spec.np_max),
                            ov_row0 + jnp.arange(spec.ov_cap)]).astype(
                                jnp.int32)

    part = DecodedPartition(
        vectors=jnp.concatenate([base_vecs, ov_vecs], axis=0),
        adjacency=adjacency[None],
        gids=jnp.concatenate([base_gids, ov_gids]),
        valid=jnp.concatenate([base_valid, ov_mine]),
        entry=meta_row[MT_ENTRY],
    )
    return part, rows


def _pad_topk(d, i, k: int):
    """Pad a (kk,) top list to (k,) with inf/-1 when kk < k."""
    kk = d.shape[0]
    if kk >= k:
        return d[:k], i[:k]
    pad = k - kk
    return (jnp.concatenate([d, jnp.full((pad,), jnp.inf, d.dtype)]),
            jnp.concatenate([i, jnp.full((pad,), -1, i.dtype)]))


def search_decoded_scan_local(part: DecodedPartition, q, k: int):
    """Like ``search_decoded_scan`` but returns LOCAL indices (the
    candidate-pool path needs them to derive exact-row addresses)."""
    n = part.vectors.shape[0]
    d = jnp.sum(jnp.square(part.vectors - q[None, :]), axis=-1)
    d = jnp.where(part.valid, d, jnp.inf)
    nd, ni = lax.top_k(-d, min(k, n))
    return _pad_topk(-nd, ni.astype(jnp.int32), k)


def search_decoded_graph_local(part: DecodedPartition, q, k: int, ef: int):
    """Like ``search_decoded_graph`` but returns LOCAL indices: beam walk
    over the base graph + brute scan of the live overflow slice."""
    np_max = part.adjacency.shape[1]
    bd, bi = S.beam_search(part.vectors[:np_max], part.adjacency, q,
                           part.entry, ef=max(ef, k), n_levels=1)
    bd = jnp.where((bi >= 0) & part.valid[jnp.maximum(bi, 0)], bd, jnp.inf)
    ov_d = jnp.sum(jnp.square(part.vectors[np_max:] - q[None, :]), axis=-1)
    ov_d = jnp.where(part.valid[np_max:], ov_d, jnp.inf)
    all_d = jnp.concatenate([bd, ov_d])
    all_i = jnp.concatenate([bi.astype(jnp.int32),
                             np_max + jnp.arange(ov_d.shape[0],
                                                 dtype=jnp.int32)])
    kk = min(k, all_d.shape[0])
    nd, pos = lax.top_k(-all_d, kk)
    return _pad_topk(-nd, all_i[pos], k)


@functools.partial(jax.jit,
                   static_argnames=("spec", "m", "ef", "mode", "n_lanes"),
                   donate_argnums=(6, 7))
def serve_quant_pool(spec: LayoutSpec, cache_qg, cache_qv, cache_qs,
                     meta_table, queries, pool_d, pool_p, pair_qi,
                     pair_pids, pair_slots, pair_ranks, pair_valid, *,
                     m: int, ef: int, mode: str, n_lanes: int):
    """Stage-1 round, fused: per-pair top-m inside the pair's QUANTIZED
    partition, then one scatter-merge into the batch's running candidate
    pool.  ``pool_d`` (B, m) distances; ``pool_p`` (B, m, 3) int32
    payload columns [gid, exact_row, pid] carried through the merge.
    """
    mrows = meta_table[pair_pids]
    qs = queries[pair_qi]

    def one(slot, mrow, q, ok, pid):
        part, rows = decode_quant_span(spec, cache_qg[slot], cache_qv[slot],
                                       cache_qs[slot], mrow)
        if mode == "graph":
            d, li = search_decoded_graph_local(part, q, m, ef)
        else:
            d, li = search_decoded_scan_local(part, q, m)
        live = (li >= 0) & ok & jnp.isfinite(d)
        safe = jnp.maximum(li, 0)
        payload = jnp.stack([
            jnp.where(live, part.gids[safe], -1),
            jnp.where(live, rows[safe], -1),
            jnp.where(live, pid, -1),
        ], axis=-1).astype(jnp.int32)
        return jnp.where(live, d, jnp.inf), payload

    d, p = jax.vmap(one)(pair_slots, mrows, qs, pair_valid, pair_pids)
    return merge_ranked_payload(pool_d, pool_p, pair_qi, pair_ranks, d, p,
                                n_lanes=n_lanes)


@functools.partial(jax.jit, static_argnames=("n_lanes",))
def merge_ranked_payload(run_d, run_p, pair_qi, pair_ranks, d, p, *,
                         n_lanes: int):
    """``merge_ranked`` with an (…, P) int payload instead of a single id
    column — same (B+1, n_lanes, m) scatter + one stable argsort per
    query, so round grouping never changes the merged result."""
    B, m = run_d.shape
    P = run_p.shape[2]
    buf_d = jnp.full((B + 1, n_lanes, m), jnp.inf, run_d.dtype)
    buf_p = jnp.full((B + 1, n_lanes, m, P), -1, run_p.dtype)
    buf_d = buf_d.at[pair_qi, pair_ranks].set(d)
    buf_p = buf_p.at[pair_qi, pair_ranks].set(p.astype(run_p.dtype))
    all_d = jnp.concatenate([run_d, buf_d[:B].reshape(B, n_lanes * m)],
                            axis=1)
    all_p = jnp.concatenate([run_p, buf_p[:B].reshape(B, n_lanes * m, P)],
                            axis=1)
    order = jnp.argsort(all_d, axis=1, stable=True)[:, :m]
    return (jnp.take_along_axis(all_d, order, axis=1),
            jnp.take_along_axis(all_p, order[:, :, None], axis=1))


@functools.partial(jax.jit, static_argnames=("dim",))
def gather_rows(vec_buf, rows, *, dim: int):
    """The memory pool's row-granular READ verb: gather exact vector
    rows from the serialized region.  ``rows`` (..., ) region row
    addresses into ``vec_buf.reshape(-1, dim)`` (-1 lanes gather row 0
    and are masked by the caller).  Returns (..., D) f32."""
    return vec_buf.reshape(-1, dim)[jnp.maximum(rows, 0)]


@functools.partial(jax.jit, static_argnames=("k",))
def rerank_gathered(vrows, queries, rows, gids, *, k: int):
    """Stage 2, compute side: exact distances over already-gathered
    candidate rows (``gather_rows`` is the pool verb that produced
    ``vrows``).  rows (B, m) mark empty lanes with -1; gids (B, m).
    Returns the final (dists (B, k), gids (B, k))."""
    d = jnp.sum(jnp.square(vrows - queries[:, None, :]), axis=-1)
    d = jnp.where(rows >= 0, d, jnp.inf)
    nd, ni = lax.top_k(-d, k)
    g = jnp.take_along_axis(gids, ni, axis=1)
    return -nd, jnp.where(jnp.isfinite(-nd), g, -1)


def rerank_exact(vec_buf, queries, rows, gids, *, dim: int, k: int):
    """Fused legacy entry point: gather + re-rank in one call (kept for
    callers that hold the region buffer directly; the engine now splits
    this across the pool boundary as gather_rows -> rerank_gathered)."""
    vrows = gather_rows(vec_buf, rows, dim=dim)
    return rerank_gathered(vrows, queries, rows, gids, k=k)


@functools.partial(jax.jit, static_argnames=("dim", "group"))
def gather_quant_rows(qvec_buf, qscale_buf, rows, *, dim: int, group: int):
    """Row-granular gather from the QUANTIZED mirror: int8 codes plus the
    per-row codebook scales.  ``rows`` are the same region row addresses
    ``gather_rows`` takes (the mirror shares the block indexing)."""
    safe = jnp.maximum(rows, 0)
    codes = qvec_buf.reshape(-1, dim)[safe]
    scales = qscale_buf.reshape(-1, dim // group)[safe]
    return codes, scales


@functools.partial(jax.jit, static_argnames=("spec",),
                   donate_argnums=(1, 2, 3))
def write_slots_quant(spec: LayoutSpec, cache_qg, cache_qv, cache_qs,
                      slot_ids, g_blocks, qv_blocks, qs_blocks):
    """Install fetched QUANTIZED spans into quant-tier slots."""
    cache_qg = cache_qg.at[slot_ids].set(g_blocks)
    cache_qv = cache_qv.at[slot_ids].set(qv_blocks)
    cache_qs = cache_qs.at[slot_ids].set(qs_blocks)
    return cache_qg, cache_qv, cache_qs


@functools.partial(jax.jit, static_argnames=("spec",))
def overflow_append_quant(spec: LayoutSpec, qvec_buf, qscale_buf, vec,
                          vec_block, vec_off):
    """Device twin of the quantized mirror update for one overflow
    insert: quantize the row in place and scatter codes + codebook
    scales (coords from ``layout.overflow_write_coords``)."""
    from repro.quant.codec import quantize_row_jnp
    g = spec.quant_group
    codes, scales = quantize_row_jnp(vec, g)
    row = lax.dynamic_update_slice(qvec_buf[vec_block], codes, (vec_off,))
    qvec_buf = lax.dynamic_update_index_in_dim(qvec_buf, row, vec_block, 0)
    srow = lax.dynamic_update_slice(qscale_buf[vec_block], scales,
                                    (vec_off // g,))
    qscale_buf = lax.dynamic_update_index_in_dim(qscale_buf, srow,
                                                 vec_block, 0)
    return qvec_buf, qscale_buf


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(1, 2))
def write_slots(spec: LayoutSpec, cache_g, cache_v, slot_ids, g_blocks,
                v_blocks):
    """Install fetched spans into cache slots (functional scatter).

    g_blocks: (n_fetch, fetch_blocks, gblk); slot_ids: (n_fetch,).
    """
    cache_g = cache_g.at[slot_ids].set(g_blocks)
    cache_v = cache_v.at[slot_ids].set(v_blocks)
    return cache_g, cache_v


@functools.partial(jax.jit, static_argnames=("spec",))
def overflow_append(spec: LayoutSpec, graph_buf, vec_buf, vec, gid,
                    vec_block, vec_off, gid_block, gid_off):
    """Device twin of ``layout.insert_vector``: one-slot scatter into the
    shared overflow region (coords from ``overflow_write_coords``)."""
    row = lax.dynamic_update_slice(vec_buf[vec_block], vec, (vec_off,))
    vec_buf = lax.dynamic_update_index_in_dim(vec_buf, row, vec_block, 0)
    grow = graph_buf[gid_block].at[gid_off].set(gid)
    graph_buf = lax.dynamic_update_index_in_dim(graph_buf, grow, gid_block, 0)
    return graph_buf, vec_buf
