"""Device-side store: fetched-span decode + per-partition search.

Everything here is static-shaped and jit-friendly.  A fetch span is
``(fetch_blocks, gblk)`` int32 + ``(fetch_blocks, vblk)`` float32 — the
unit one doorbell descriptor covers.  ``decode_span`` turns a span + its
metadata row into padded search arrays; the two search paths (faithful
graph walk / MXU scan) run on the decoded view.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import search as S
from repro.core.layout import (LayoutSpec, MT_ENTRY, MT_N_BASE, MT_OV_A,
                               MT_OV_B, MT_SIDE)


class DecodedPartition(NamedTuple):
    vectors: jax.Array    # (np_max + ov_cap, D) — base then overflow slots
    adjacency: jax.Array  # (1, np_max, deg) local ids, -1 pad
    gids: jax.Array       # (np_max + ov_cap,) global ids, -1 pad
    valid: jax.Array      # (np_max + ov_cap,) bool — base n + live overflow
    entry: jax.Array      # () local entry id (the representative)


def decode_span(spec: LayoutSpec, g_span, v_span, meta_row) -> DecodedPartition:
    """g_span (fetch_blocks, gblk) i32; v_span (fetch_blocks, vblk) f32."""
    side = meta_row[MT_SIDE]
    n_base = meta_row[MT_N_BASE]
    gflat = g_span.reshape(-1)
    vflat = v_span.reshape(-1)

    data_g = lax.dynamic_slice(gflat, (side * spec.ov_blocks * spec.gblk,),
                               (spec.np_max * (spec.deg + 1),))
    adjacency = data_g[: spec.np_max * spec.deg].reshape(spec.np_max, spec.deg)
    base_gids = data_g[spec.np_max * spec.deg:]

    ov_goff = (1 - side) * spec.data_blocks * spec.gblk
    ov_gids = lax.dynamic_slice(gflat, (ov_goff,), (spec.ov_cap,))

    data_v = lax.dynamic_slice(vflat, (side * spec.ov_blocks * spec.vblk,),
                               (spec.np_max * spec.dim,))
    base_vecs = data_v.reshape(spec.np_max, spec.dim)
    ov_voff = (1 - side) * spec.data_blocks * spec.vblk
    ov_vecs = lax.dynamic_slice(vflat, (ov_voff,),
                                (spec.ov_cap * spec.dim,)).reshape(
                                    spec.ov_cap, spec.dim)

    cnt_a, cnt_b = meta_row[MT_OV_A], meta_row[MT_OV_B]
    ov_idx = jnp.arange(spec.ov_cap)
    # A's inserts fill the front, B's fill the back; a fetch sees both but
    # only its own side's slots belong to this partition
    ov_mine = jnp.where(side == 0, ov_idx < cnt_a,
                        ov_idx >= spec.ov_cap - cnt_b)
    base_valid = jnp.arange(spec.np_max) < n_base
    return DecodedPartition(
        vectors=jnp.concatenate([base_vecs, ov_vecs], axis=0),
        adjacency=adjacency[None],
        gids=jnp.concatenate([base_gids, ov_gids]),
        valid=jnp.concatenate([base_valid, ov_mine]),
        entry=meta_row[MT_ENTRY],
    )


def search_decoded_scan(part: DecodedPartition, q, k: int):
    """Exact top-k over every valid vector (base + overflow) — the
    beyond-paper MXU path.  Returns (dists (k,), global ids (k,))."""
    d = jnp.sum(jnp.square(part.vectors - q[None, :]), axis=-1)
    d = jnp.where(part.valid, d, jnp.inf)
    nd, ni = lax.top_k(-d, k)
    return -nd, part.gids[ni]


def search_decoded_graph(part: DecodedPartition, q, k: int, ef: int):
    """Paper-faithful: beam-search the sub-HNSW graph over the base
    vectors, then brute-scan the (tiny) live overflow slice and merge —
    exactly how the paper covers not-yet-relinked inserted vectors."""
    np_max = part.adjacency.shape[1]
    bd, bi = S.beam_search(part.vectors[:np_max], part.adjacency, q,
                           part.entry, ef=max(ef, k), n_levels=1)
    bd = jnp.where((bi >= 0) & part.valid[jnp.maximum(bi, 0)], bd, jnp.inf)
    base_d, base_i = bd[:k], jnp.where(jnp.isfinite(bd[:k]),
                                       part.gids[jnp.maximum(bi[:k], 0)], -1)
    ov_vecs = part.vectors[np_max:]
    ov_d = jnp.sum(jnp.square(ov_vecs - q[None, :]), axis=-1)
    ov_d = jnp.where(part.valid[np_max:], ov_d, jnp.inf)
    kk = min(k, ov_vecs.shape[0])
    od, oi = lax.top_k(-ov_d, kk)
    og = part.gids[np_max + oi]
    return S.merge_topk(base_d, base_i, -od, jnp.where(jnp.isfinite(-od), og, -1), k)


@functools.partial(jax.jit,
                   static_argnames=("spec", "k", "ef", "mode", "n_lanes"),
                   donate_argnums=(5, 6))
def serve_and_merge(spec: LayoutSpec, cache_g, cache_v, meta_table, queries,
                    run_d, run_g, pair_qi, pair_pids, pair_slots, pair_ranks,
                    pair_valid, *, k: int, ef: int, mode: str, n_lanes: int):
    """One round, fused: per-pair top-k inside the pair's partition, then a
    single vectorized scatter-merge into the batch's running top-k.

    Replaces the host loop that merged each pair's ``(k,)`` list into its
    query's running list one ``np.argsort`` at a time.  All staging is
    device-side gathers from arrays resident since batch start:

    meta_table: (n_partitions, META_COLS) — the whole cached table; each
                pair gathers its own row (no per-round host rebuild)
    queries:    (B, D) — the full query batch; gathered by ``pair_qi``
    run_d/run_g:(B, k) running top-k carried across rounds (donated)
    pair_qi:    (n_pairs,) query index; padding lanes point at row B so
                the ``(B+1, n_lanes, k)`` scatter drops them
    pair_ranks: (n_pairs,) merge lane — occurrence index of the pair's
                query within this round (unique per (query, round))
    Returns the updated (run_d, run_g): (B, k) each.

    Merge semantics are identical to folding the pairs in order through a
    stable sort (stable argsort over [running | lane 0 | lane 1 | ...] is
    associative with the sequential stable merges the host loop did), so
    results are bit-identical to the old path.
    """
    rows = meta_table[pair_pids]
    qs = queries[pair_qi]          # padding qi == B clamps; masked below

    def one(slot, row, q, ok):
        part = decode_span(spec, cache_g[slot], cache_v[slot], row)
        if mode == "graph":
            d, g = search_decoded_graph(part, q, k, ef)
        else:
            d, g = search_decoded_scan(part, q, k)
        return jnp.where(ok, d, jnp.inf), jnp.where(ok, g, -1)

    d, g = jax.vmap(one)(pair_slots, rows, qs, pair_valid)
    return merge_ranked(run_d, run_g, pair_qi, pair_ranks, d, g,
                        n_lanes=n_lanes)


@functools.partial(jax.jit, static_argnames=("n_lanes",))
def merge_ranked(run_d, run_g, pair_qi, pair_ranks, d, g, *, n_lanes: int):
    """Scatter-merge per-pair top-k lists into the running per-query top-k.

    Each pair lands in merge lane ``(pair_qi, pair_ranks)`` of a
    ``(B+1, n_lanes, k)`` buffer (row B is the dump row for padding pairs),
    then one stable argsort per query takes the new top-k.  Equivalent to
    folding the pairs through sequential stable merges.
    """
    k = run_d.shape[1]
    B = run_d.shape[0]
    buf_d = jnp.full((B + 1, n_lanes, k), jnp.inf, run_d.dtype)
    buf_g = jnp.full((B + 1, n_lanes, k), -1, run_g.dtype)
    buf_d = buf_d.at[pair_qi, pair_ranks].set(d)
    buf_g = buf_g.at[pair_qi, pair_ranks].set(g.astype(run_g.dtype))
    all_d = jnp.concatenate([run_d, buf_d[:B].reshape(B, n_lanes * k)], axis=1)
    all_g = jnp.concatenate([run_g, buf_g[:B].reshape(B, n_lanes * k)], axis=1)
    order = jnp.argsort(all_d, axis=1, stable=True)[:, :k]
    return (jnp.take_along_axis(all_d, order, axis=1),
            jnp.take_along_axis(all_g, order, axis=1))


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(1, 2))
def write_slots(spec: LayoutSpec, cache_g, cache_v, slot_ids, g_blocks,
                v_blocks):
    """Install fetched spans into cache slots (functional scatter).

    g_blocks: (n_fetch, fetch_blocks, gblk); slot_ids: (n_fetch,).
    """
    cache_g = cache_g.at[slot_ids].set(g_blocks)
    cache_v = cache_v.at[slot_ids].set(v_blocks)
    return cache_g, cache_v


@functools.partial(jax.jit, static_argnames=("spec",))
def overflow_append(spec: LayoutSpec, graph_buf, vec_buf, vec, gid,
                    vec_block, vec_off, gid_block, gid_off):
    """Device twin of ``layout.insert_vector``: one-slot scatter into the
    shared overflow region (coords from ``overflow_write_coords``)."""
    row = lax.dynamic_update_slice(vec_buf[vec_block], vec, (vec_off,))
    vec_buf = lax.dynamic_update_index_in_dim(vec_buf, row, vec_block, 0)
    grow = graph_buf[gid_block].at[gid_off].set(gid)
    graph_buf = lax.dynamic_update_index_in_dim(graph_buf, grow, gid_block, 0)
    return graph_buf, vec_buf
