"""DHNSWEngine — the paper's system, end to end.

Three schemes (exactly the paper's evaluation §4):

* ``naive``       — Naive d-HNSW: every (query, partition) need is its
                    own remote read; no meta-cache reuse across queries,
                    no dedup, no doorbell.
* ``no_doorbell`` — meta-HNSW caching + query-aware batched loading, but
                    each unique partition read is its own round trip.
* ``full``        — d-HNSW: + doorbell batching (many discontiguous span
                    reads per round trip).

Search inside a loaded partition:

* ``graph`` — paper-faithful sub-HNSW beam walk + overflow scan;
* ``scan``  — beyond-paper TPU mode: exact MXU brute scan of the fetched
              partition (see core/search.py docstring).

Architecture: the engine is a thin facade over the DISAGGREGATED split —
a ``ComputeClient`` (``repro/pool/compute.py``: cached meta-HNSW,
resident-partition cache tiers, round scheduler, Pallas serve kernels)
that talks to a ``MemoryPool`` transport (``repro/pool/``) through the
paper's RDMA verbs: span reads, row reads, doorbell-batched descriptor
submission, one-sided appends.  ``EngineConfig.pool`` picks the
transport:

* ``"local"``    — in-process device arrays (default; bit-identical to
                   the pre-pool monolithic engine);
* ``"sim_rdma"`` — same data path plus a per-verb latency/bandwidth
                   model, so ``stats["pool"]`` carries a modeled network
                   time breakdown next to the counted ``stats["net"]``;
* ``"sharded"``  — the region split group-granularly across n_shards
                   child pools with per-destination doorbell fan-out;
* ``"remote"``   — a REAL transport (``repro/net``): verbs marshaled
                   over TCP to ``PoolServer`` processes named by
                   ``endpoints``; several endpoints shard over one
                   RemotePool child per server process.

The compute/network split follows the paper's methodology: device (or
host-jax) wall time is measured for meta-HNSW and sub-HNSW compute; the
network term is *counted* (round trips, doorbell descriptors, bytes) and
priced by ``core/cost_model.py`` — this container has neither fabric,
and the paper's own breakdown tables are what we reproduce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.cost_model import (RDMA_100G, TPU_ICI, Fabric,  # noqa: F401
                                   NetLedger)
from repro.core.scheduler import pow2_pad  # noqa: F401  (re-export)
from repro.obs.trace import TRACER

MODES = ("naive", "no_doorbell", "full")
POOLS = ("local", "sim_rdma", "sharded", "remote")


@dataclass
class EngineConfig:
    mode: str = "full"              # naive | no_doorbell | full
    search_mode: str = "graph"      # graph (paper) | scan (beyond-paper)
    b: int = 2                      # partitions probed per query (top-b)
    ef: int = 48                    # sub-HNSW beam width (efSearch)
    n_rep: int = 500                # representatives (= partitions)
    cache_frac: float = 0.10        # compute-pool cache: 10% of partitions
    doorbell: int = 8               # spans per doorbell batch
    fabric: Fabric = TPU_ICI
    use_gather_kernel: bool = False  # Pallas doorbell gather (interpret on CPU)
    meta_levels: int = 3
    sub_M0: int = 16
    ef_construction: int = 80
    seed: int = 0
    # quantized resident tier (src/repro/quant): "none" keeps the exact
    # single-tier path bit-identical; "int8" searches in two stages —
    # quantized candidate generation over a LARGE int8 tier, then exact
    # re-ranking of only the candidate rows
    quant: str = "none"             # none | int8
    quant_group: int = 32           # int8 codec group size (divides dim)
    rerank_m: int = 0               # stage-2 candidate pool (0 = 2k)
    exact_frac: float = 0.25        # share of the cache BYTE budget kept
                                    # as full-precision (exact-tier) slots
    # memory-pool transport (repro/pool): "local" is in-process and
    # bit-identical; "sim_rdma" adds the per-verb latency model;
    # "sharded" splits the region group-granularly across n_shards
    # child pools (per-shard doorbell fan-out, pluggable placement)
    pool: str = "local"             # local | sim_rdma | sharded | remote
    n_shards: int = 2               # shards under pool="sharded"
    # pool="remote": TCP pool-server endpoints ("host:port" strings or
    # (host, port) tuples).  One endpoint = a single RemotePool; several
    # = a ShardedPool whose children are RemotePools, one per server
    # process (placement/shard_parallel apply).  Also used by
    # pool="sharded" + shard_transport="remote" (len == n_shards).
    endpoints: Optional[tuple] = None
    # pool="remote" bearer (repro/rdma): "tcp" frames WR lists over the
    # socket wire to PoolServer processes at `endpoints`; "loopback"
    # runs the same verbs/QP path against an in-process HostRegion (no
    # endpoints, no sockets) — the conformance bearer
    bearer: str = "tcp"             # tcp | loopback
    # placement: policy name ("round_robin" | "size_balanced" | "freq")
    # or a ready PlacementPolicy instance (one engine per instance —
    # policies are stateful)
    placement: object = "round_robin"
    shard_transport: str = "local"  # child transport: local | sim_rdma
    # per-shard fabrics (len == n_shards) to model stragglers; None
    # replicates `fabric` on every shard
    shard_fabrics: Optional[tuple] = None
    shard_parallel: bool = True     # shards answer doorbell batches
                                    # concurrently (trips/modeled time
                                    # reduce by max); False sums
    # replication: copies of every group across distinct shards (clamped
    # to the shard count).  R >= 2 makes the sharded/remote pool survive
    # a node death: reads fail over to a surviving replica and the dead
    # node's groups re-replicate from the host region.  R = 1 keeps the
    # pre-replication behavior (a death surfaces PoolUnavailableError).
    replication: int = 1
    # per-shard capacity budgets in bytes (len == shard count); groups
    # that would overflow a shard spill to the next-best one.  None =
    # unbounded shards.
    shard_budgets: Optional[tuple] = None
    # straggler detection cadence for sharded/remote pools: run the
    # tail-divergence detector over the per-(verb, shard) latency
    # histograms every N charged span reads and penalize flagged shards
    # in replica-read ranking (0 = off; manual pool.check_stragglers()
    # always works).  Needs replication >= 2 to actually reroute.
    straggler_check_every: int = 0
    # stage-1 flat kernel route: "off" keeps the per-pair jnp path;
    # "auto" routes flat (scan-mode) stage 1 through the fused
    # quant_topk kernel when the quantized tier is dense-resident
    # (capacity >= n_partitions) — Pallas on real accelerators, the jnp
    # ref on backends where Pallas would run interpreted (CPU); "ref"
    # forces the jnp oracle on every backend
    quant_kernel: str = "off"       # off | auto | ref
    # durable / streaming ingestion (repro.ingest): the default spill
    # directory for build_streaming and, for remote pools, where the
    # servers keep WAL + checkpoints (operational knob, not wired into
    # pool construction — servers own their own --data-dir)
    data_dir: Optional[str] = None


class DHNSWEngine:
    """Build once, then ``search``/``insert`` batches.

    Facade over ``ComputeClient + MemoryPool`` — constructing and using
    it is unchanged from the monolithic engine it replaced; code that
    needs the boundary itself should use ``engine.client`` and
    ``engine.pool`` (or build them directly from ``repro.pool``).
    """

    def __init__(self, config: Optional[EngineConfig] = None, **kw):
        from repro.pool import make_pool_factory
        from repro.pool.compute import ComputeClient
        self.cfg = config or EngineConfig(**kw)
        assert self.cfg.mode in MODES, self.cfg.mode
        assert self.cfg.quant in ("none", "int8"), self.cfg.quant
        assert self.cfg.pool in POOLS, self.cfg.pool
        assert self.cfg.quant_kernel in ("off", "auto", "ref"), \
            self.cfg.quant_kernel
        if self.cfg.pool == "sharded":
            assert self.cfg.n_shards >= 1, self.cfg.n_shards
            assert self.cfg.shard_transport in ("local", "sim_rdma",
                                                "remote"), \
                self.cfg.shard_transport
            if (self.cfg.shard_transport == "remote"
                    and self.cfg.bearer == "tcp"):
                assert (self.cfg.endpoints
                        and len(self.cfg.endpoints) == self.cfg.n_shards), \
                    "shard_transport='remote' needs one endpoint per shard"
        assert self.cfg.bearer in ("tcp", "loopback"), self.cfg.bearer
        if self.cfg.pool == "remote" and self.cfg.bearer == "tcp":
            assert self.cfg.endpoints, "pool='remote' needs endpoints"
        assert self.cfg.replication >= 1, self.cfg.replication
        if self.cfg.replication > 1:
            assert self.cfg.pool in ("sharded", "remote"), \
                "replication needs a multi-node pool (sharded/remote)"
        self.client = ComputeClient(self.cfg, make_pool_factory(self.cfg))

    # ------------------------------------------------------------ lifecycle

    def build(self, data: np.ndarray) -> "DHNSWEngine":
        self.client.build(data)
        return self

    def build_streaming(self, source, *, chunk_rows: int,
                        spill_dir: Optional[str] = None) -> "DHNSWEngine":
        """Out-of-core build: stream ``source`` (an iterator of row
        chunks) through ``repro.ingest.BulkLoader`` with O(chunk) peak
        builder memory.  Bit-identical to ``build`` on the concatenated
        data; the loader's :class:`~repro.ingest.loader.LoadReport`
        lands on ``self.last_load_report``."""
        from repro.core.hnsw import HNSWParams
        from repro.ingest.loader import BulkLoader
        cfg = self.cfg
        loader = BulkLoader(
            n_rep=cfg.n_rep, chunk_rows=chunk_rows, seed=cfg.seed,
            meta_levels=cfg.meta_levels,
            sub_params=HNSWParams(M=max(cfg.sub_M0 // 2, 2), M0=cfg.sub_M0,
                                  ef_construction=cfg.ef_construction),
            spill_dir=spill_dir or cfg.data_dir,
            quant_group=cfg.quant_group if cfg.quant == "int8" else 0)
        loader.add_chunks(source)
        meta, store, report = loader.finalize()
        # the disk-backed spill view backs repack/rebuild lookups, so
        # the full dataset never has to be resident on the builder
        view = loader.data_view()
        loader.close()
        self.client.adopt_built(meta, store, view)
        self.last_load_report = report
        return self

    # ------------------------------------------------------------ requests

    def search(self, queries: np.ndarray, k: int = 10,
               ef: Optional[int] = None, b: Optional[int] = None):
        """Batched top-k.  Returns (dists (B,k), gids (B,k), stats)."""
        with TRACER.span("compute.search", tier="compute", k=int(k),
                         quant=self.cfg.quant):
            return self.client.search(queries, k=k, ef=ef, b=b)

    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Dynamic insertion (paper §3.2) through the pool WRITE verb."""
        with TRACER.span("compute.insert", tier="compute"):
            return self.client.insert(vecs)

    # ------------------------------------------------------------ state
    # (compat views into the split — tests, benchmarks and notebooks
    # reach for these; they are the client's/pool's live state)

    @property
    def pool(self):
        return self.client.pool

    @property
    def meta(self):
        return self.client.meta

    @property
    def store(self):
        return None if self.client.pool is None else self.client.pool.store

    @property
    def cache(self):
        return self.client.cache

    @property
    def tiers(self):
        return self.client.tiers

    @property
    def _last_insert_net(self):
        return self.client._last_insert_net

    def _invalidate_pid(self, pid: int):
        self.client._invalidate_pid(pid)
