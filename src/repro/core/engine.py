"""DHNSWEngine — the paper's system, end to end.

Three schemes (exactly the paper's evaluation §4):

* ``naive``       — Naive d-HNSW: every (query, partition) need is its
                    own remote read; no meta-cache reuse across queries,
                    no dedup, no doorbell.
* ``no_doorbell`` — meta-HNSW caching + query-aware batched loading, but
                    each unique partition read is its own round trip.
* ``full``        — d-HNSW: + doorbell batching (many discontiguous span
                    reads per round trip).

Search inside a loaded partition:

* ``graph`` — paper-faithful sub-HNSW beam walk + overflow scan;
* ``scan``  — beyond-paper TPU mode: exact MXU brute scan of the fetched
              partition (see core/search.py docstring).

The compute/network split follows the paper's methodology: device (or
host-jax) wall time is measured for meta-HNSW and sub-HNSW compute; the
network term is *counted* (round trips, doorbell descriptors, bytes) and
priced by ``core/cost_model.py`` for the RDMA testbed and the TPU ICI
fabric — this container has neither fabric, and the paper's own breakdown
tables are what we reproduce.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device_store as DS
from repro.core import layout as LA
from repro.core import meta as ME
from repro.core import scheduler as SCH
from repro.core import search as S
from repro.core.cost_model import (RDMA_100G, TPU_ICI, Fabric, NetLedger)
from repro.core.hnsw import HNSWParams

MODES = ("naive", "no_doorbell", "full")


def pow2_pad(n: int, lo: int = 8) -> int:
    """Next power of two >= n (floor ``lo``) — the shape-bucketing rule
    shared by the engine's round padding and the serve tier's fused-batch
    padding, so jitted stages see a bounded set of shapes."""
    m = lo
    while m < n:
        m *= 2
    return m


@dataclass
class EngineConfig:
    mode: str = "full"              # naive | no_doorbell | full
    search_mode: str = "graph"      # graph (paper) | scan (beyond-paper)
    b: int = 2                      # partitions probed per query (top-b)
    ef: int = 48                    # sub-HNSW beam width (efSearch)
    n_rep: int = 500                # representatives (= partitions)
    cache_frac: float = 0.10        # compute-pool cache: 10% of partitions
    doorbell: int = 8               # spans per doorbell batch
    fabric: Fabric = TPU_ICI
    use_gather_kernel: bool = False  # Pallas doorbell gather (interpret on CPU)
    meta_levels: int = 3
    sub_M0: int = 16
    ef_construction: int = 80
    seed: int = 0
    # quantized resident tier (src/repro/quant): "none" keeps the exact
    # single-tier path bit-identical; "int8" searches in two stages —
    # quantized candidate generation over a LARGE int8 tier, then exact
    # re-ranking of only the candidate rows
    quant: str = "none"             # none | int8
    quant_group: int = 32           # int8 codec group size (divides dim)
    rerank_m: int = 0               # stage-2 candidate pool (0 = 2k)
    exact_frac: float = 0.25        # share of the cache BYTE budget kept
                                    # as full-precision (exact-tier) slots


class DHNSWEngine:
    """Build once, then ``search``/``insert`` batches."""

    def __init__(self, config: Optional[EngineConfig] = None, **kw):
        self.cfg = config or EngineConfig(**kw)
        assert self.cfg.mode in MODES, self.cfg.mode
        assert self.cfg.quant in ("none", "int8"), self.cfg.quant
        self.meta: Optional[ME.MetaIndex] = None
        self.store: Optional[LA.Store] = None
        self.tiers: Optional[SCH.TieredCacheState] = None
        self._extra: dict[int, np.ndarray] = {}   # inserted gid -> vector
        self._extra_pid: dict[int, int] = {}
        self._n0 = 0                              # base dataset size
        self._data: Optional[np.ndarray] = None

    # ------------------------------------------------------------ build

    def build(self, data: np.ndarray) -> "DHNSWEngine":
        cfg = self.cfg
        data = np.asarray(data, np.float32)
        self._data = data
        self._n0 = data.shape[0]
        self.meta = ME.build_meta(data, cfg.n_rep, seed=cfg.seed,
                                  meta_levels=cfg.meta_levels)
        self.store = LA.build_store(
            data, self.meta,
            sub_params=HNSWParams(M=max(cfg.sub_M0 // 2, 2), M0=cfg.sub_M0,
                                  ef_construction=cfg.ef_construction,
                                  seed=cfg.seed))
        self._device_put()
        cap = max(2, int(np.ceil(cfg.cache_frac * self.meta.n_partitions)))
        self._cap0 = cap
        if cfg.quant == "none":
            self.cache = SCH.LRUCacheState(cap)
            spec = self.store.spec
            self._cache_g = jnp.full((cap, spec.fetch_blocks, spec.gblk), -1,
                                     jnp.int32)
            self._cache_v = jnp.zeros((cap, spec.fetch_blocks, spec.vblk),
                                      jnp.float32)
        else:
            self._setup_quant(cap)
        return self

    def _setup_quant(self, cap: int):
        """Attach the int8 mirror and size the two device tiers from the
        SAME byte budget a quant="none" engine would spend on ``cap``
        full-precision slots: a small exact tier (``exact_frac`` of the
        budget) plus a quantized tier filling the remainder — ~3-4x the
        partitions per byte, so stage-1 hits replace remote reads."""
        cfg = self.cfg
        LA.attach_quant_mirror(self.store, cfg.quant_group)
        spec = self.store.spec
        self._qv_dev = jnp.asarray(self.store.qvec_buf)
        self._qs_dev = jnp.asarray(self.store.qscale_buf)
        pb = spec.partition_bytes()
        qpb = spec.quant_partition_bytes(
            include_graph=cfg.search_mode == "graph")
        exact_cap = max(1, int(round(cap * cfg.exact_frac)))
        quant_cap = max(2, int((cap - exact_cap) * pb // qpb))
        self.tiers = SCH.TieredCacheState(quant_cap, exact_cap)
        self.cache = self.tiers.exact   # legacy helpers see the exact tier
        self._cache_g = jnp.full((exact_cap, spec.fetch_blocks, spec.gblk),
                                 -1, jnp.int32)
        self._cache_v = jnp.zeros((exact_cap, spec.fetch_blocks, spec.vblk),
                                  jnp.float32)
        self._cache_qg = jnp.full((quant_cap, spec.fetch_blocks, spec.gblk),
                                  -1, jnp.int32)
        self._cache_qv = jnp.zeros((quant_cap, spec.fetch_blocks, spec.vblk),
                                   jnp.int8)
        self._cache_qs = jnp.zeros(
            (quant_cap, spec.fetch_blocks, spec.n_qgroups), jnp.float32)

    def _device_put(self):
        # memory pool (remote): the serialized region
        self._g_dev = jnp.asarray(self.store.graph_buf)
        self._v_dev = jnp.asarray(self.store.vec_buf)
        # compute pool (cached, replicated): meta-HNSW + metadata table
        self._meta_vecs = jnp.asarray(self.meta.graph.vectors)
        self._meta_adj = jnp.asarray(self.meta.graph.adjacency)
        self._meta_entry = int(self.meta.graph.entry)
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False
        if self.store.qvec_buf is not None:   # quantized mirror (if attached)
            self._qv_dev = jnp.asarray(self.store.qvec_buf)
            self._qs_dev = jnp.asarray(self.store.qscale_buf)

    def _meta_table_dev(self):
        """Device copy of the metadata table, restaged lazily after
        inserts touch the host counters (search gathers per-pair rows
        from this array instead of rebuilding numpy rows every round)."""
        if self._mt_dirty:
            self._mt_dev = jnp.asarray(self.store.meta_table)
            self._mt_dirty = False
        return self._mt_dev

    def _lookup(self, gids: np.ndarray) -> np.ndarray:
        out = np.zeros((len(gids), self.store.spec.dim), np.float32)
        for i, g in enumerate(int(x) for x in gids):
            out[i] = self._data[g] if g < self._n0 else self._extra[g]
        return out

    # ------------------------------------------------------------ fetch

    def _gather(self, block_ids: np.ndarray):
        """One doorbell batch: m span fetches in one launch.
        block_ids: (m, fetch_blocks)."""
        ids = jnp.asarray(block_ids.reshape(-1), jnp.int32)
        if self.cfg.use_gather_kernel:
            from repro.kernels.gather_blocks import ops as GO
            g = GO.gather_blocks(self._g_dev, ids)
            v = GO.gather_blocks(self._v_dev, ids)
        else:
            g = jnp.take(self._g_dev, ids, axis=0)
            v = jnp.take(self._v_dev, ids, axis=0)
        m = block_ids.shape[0]
        return (g.reshape(m, -1, self.store.spec.gblk),
                v.reshape(m, -1, self.store.spec.vblk))

    def _gather_quant(self, block_ids: np.ndarray):
        """Quantized twin of ``_gather``: one doorbell batch pulling the
        graph blocks plus the int8 codes + codebook-scale mirror.
        block_ids: (m, fetch_blocks)."""
        spec = self.store.spec
        ids = jnp.asarray(block_ids.reshape(-1), jnp.int32)
        if self.cfg.use_gather_kernel:
            from repro.kernels.gather_blocks import ops as GO
            g = GO.gather_blocks(self._g_dev, ids)
            qv = GO.gather_blocks(self._qv_dev, ids)
            qs = GO.gather_blocks(self._qs_dev, ids)
        else:
            g = jnp.take(self._g_dev, ids, axis=0)
            qv = jnp.take(self._qv_dev, ids, axis=0)
            qs = jnp.take(self._qs_dev, ids, axis=0)
        m = block_ids.shape[0]
        return (g.reshape(m, -1, spec.gblk), qv.reshape(m, -1, spec.vblk),
                qs.reshape(m, -1, spec.n_qgroups))

    # ------------------------------------------------------------ search

    def search(self, queries: np.ndarray, k: int = 10,
               ef: Optional[int] = None, b: Optional[int] = None):
        """Batched top-k.  Returns (dists (B,k), gids (B,k), stats)."""
        cfg = self.cfg
        ef = ef or cfg.ef
        b = b or cfg.b
        if cfg.quant != "none":
            return self._search_quant(queries, k=k, ef=ef, b=b)
        spec = self.store.spec
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        q_dev = jnp.asarray(queries)
        ledger = NetLedger(cfg.fabric)
        stats = {"meta_s": 0.0, "sub_s": 0.0, "plan_s": 0.0,
                 "n_rounds": 0, "n_pairs": 0}

        # 1. meta-HNSW routing (cached in the compute pool — no network)
        t0 = time.perf_counter()
        pids, _ = S.meta_route(self._meta_vecs, self._meta_adj, q_dev,
                               self._meta_entry, b=b,
                               n_levels=self.meta.graph.n_levels)
        pids = np.asarray(jax.block_until_ready(pids))
        stats["meta_s"] = time.perf_counter() - t0

        # 2. plan (compute-instance CPU role)
        t0 = time.perf_counter()
        if cfg.mode == "naive":
            raw = SCH.naive_plan(pids)
            # every pair is its own READ round trip (the 3.547 trips/query)
            for _ in raw:
                ledger.read(spec.partition_bytes(), descriptors=1)
            # fresh cache each batch, capacity = all unique (naive has no
            # cache discipline; dedup below is compute-only, transfers
            # were already fully charged)
            uniq = sorted({p for _, p in raw})
            cache = SCH.LRUCacheState(max(len(uniq), 1))
            plan = SCH.plan_batch(pids, cache, doorbell=1)
        else:
            plan = SCH.plan_batch(pids, self.cache, doorbell=cfg.doorbell)
            for rnd in plan.rounds:
                if cfg.mode == "no_doorbell":
                    for p in rnd.fetch_pids:
                        ledger.read(spec.partition_bytes(), descriptors=1)
                else:
                    for db in rnd.doorbells:
                        ledger.read(len(db) * spec.partition_bytes(),
                                    descriptors=len(db))
        stats["plan_s"] = time.perf_counter() - t0

        # 3. rounds: fetch -> serve -> merge (all device-side; the running
        # top-k is carried as (B, k) device arrays and each round folds in
        # with ONE fused scatter-merge — no host loop over pairs)
        mt_dev = self._meta_table_dev()
        run_d = jnp.full((B, k), jnp.inf, jnp.float32)
        run_g = jnp.full((B, k), -1, jnp.int32)
        cache_state = cache if cfg.mode == "naive" else self.cache
        if cfg.mode == "naive":
            cache_g = jnp.full((cache_state.capacity, spec.fetch_blocks,
                                spec.gblk), -1, jnp.int32)
            cache_v = jnp.zeros((cache_state.capacity, spec.fetch_blocks,
                                 spec.vblk), jnp.float32)
        else:
            cache_g, cache_v = self._cache_g, self._cache_v

        for rnd in plan.rounds:
            stats["n_rounds"] += 1
            if len(rnd.fetch_pids):
                ids = np.stack([self.store.span_block_ids(int(p))
                                for p in rnd.fetch_pids])
                g_blocks, v_blocks = self._gather(ids)
                slots = jnp.asarray(rnd.fetch_slots, jnp.int32)
                cache_g, cache_v = DS.write_slots(spec, cache_g, cache_v,
                                                  slots, g_blocks, v_blocks)
            if not len(rnd.serve_pairs):
                continue
            t0 = time.perf_counter()
            n = len(rnd.serve_pairs)
            npad = pow2_pad(n)
            qi, ppid, pslot, prank, valid = rnd.serve_tensors(npad, B)
            # n_lanes is fixed at b (a query never has more than b pairs
            # in one round) so recompiles depend only on (B, npad); no
            # per-round sync — rounds queue back-to-back on device and
            # the single block below charges the pipeline to sub_s
            run_d, run_g = DS.serve_and_merge(
                spec, cache_g, cache_v, mt_dev, q_dev, run_d, run_g,
                jnp.asarray(qi), jnp.asarray(ppid), jnp.asarray(pslot),
                jnp.asarray(prank), jnp.asarray(valid), k=k, ef=ef,
                mode=cfg.search_mode, n_lanes=b)
            stats["sub_s"] += time.perf_counter() - t0
            stats["n_pairs"] += n

        t0 = time.perf_counter()
        run_d = np.asarray(jax.block_until_ready(run_d))
        run_g = np.asarray(run_g).astype(np.int64)
        stats["sub_s"] += time.perf_counter() - t0
        if cfg.mode != "naive":
            self._cache_g, self._cache_v = cache_g, cache_v
        stats["net"] = ledger.as_dict()
        stats["round_trips_per_query"] = ledger.round_trips / max(B, 1)
        stats["cache_hits"] = plan.n_cache_hits
        stats["n_fetches"] = plan.n_fetches
        return run_d, run_g, stats

    # ------------------------------------------------------ staged search

    def _search_quant(self, queries: np.ndarray, k: int, ef: int, b: int):
        """Two-stage search over the quantized resident tier.

        Stage 1 plans against the LARGE quantized tier (same §3.3 round
        machinery, same doorbell batching — misses move int8 codes +
        codebook blocks, ~1/3-1/4 the bytes of an exact span) and pools
        per-query top-m candidates with their exact-row addresses.
        Stage 2 fetches ONLY the candidate rows in full precision (rows
        in exact-tier-resident partitions are free; the rest are row-
        granular doorbell'd reads) and re-ranks to the final top-k.
        ``NetLedger`` counts both the bytes moved and the bytes saved vs
        fetching the same spans at full precision.
        """
        cfg = self.cfg
        spec = self.store.spec
        include_graph = cfg.search_mode == "graph"
        pb = spec.partition_bytes()
        qpb = spec.quant_partition_bytes(include_graph=include_graph)
        row_b = spec.row_bytes()
        m = max(int(cfg.rerank_m) or 2 * k, k)
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        q_dev = jnp.asarray(queries)
        ledger = NetLedger(cfg.fabric)
        stats = {"meta_s": 0.0, "sub_s": 0.0, "plan_s": 0.0,
                 "n_rounds": 0, "n_pairs": 0, "quant": cfg.quant,
                 "rerank_m": m}

        # 1. meta-HNSW routing (cached in the compute pool — no network)
        t0 = time.perf_counter()
        pids, _ = S.meta_route(self._meta_vecs, self._meta_adj, q_dev,
                               self._meta_entry, b=b,
                               n_levels=self.meta.graph.n_levels)
        pids = np.asarray(jax.block_until_ready(pids))
        stats["meta_s"] = time.perf_counter() - t0

        # 2. stage-1 plan against the quantized tier.  A quantized span
        # read moves the codes + codebook (and, in graph mode, the
        # adjacency blocks); scan mode only adds the global-id tails.
        t0 = time.perf_counter()
        desc = 2     # data span + appended codebook span per descriptor
        if cfg.mode == "naive":
            raw = SCH.naive_plan(pids)
            for _ in raw:
                ledger.read(qpb, descriptors=desc)
                ledger.save(pb - qpb)
            uniq = sorted({p for _, p in raw})
            tiers = SCH.TieredCacheState(max(len(uniq), 1), 1)
            plan = SCH.plan_batch(pids, tiers.quant, doorbell=1)
        else:
            tiers = self.tiers
            plan = SCH.plan_batch(pids, tiers.quant, doorbell=cfg.doorbell)
            for rnd in plan.rounds:
                if cfg.mode == "no_doorbell":
                    for _ in rnd.fetch_pids:
                        ledger.read(qpb, descriptors=desc)
                        ledger.save(pb - qpb)
                else:
                    for db in rnd.doorbells:
                        ledger.read(len(db) * qpb,
                                    descriptors=desc * len(db))
                        ledger.save(len(db) * (pb - qpb))
        stats["plan_s"] = time.perf_counter() - t0

        # 3. stage-1 rounds: fetch quantized spans -> pool candidates
        mt_dev = self._meta_table_dev()
        pool_d = jnp.full((B, m), jnp.inf, jnp.float32)
        pool_p = jnp.full((B, m, 3), -1, jnp.int32)
        if cfg.mode == "naive":
            qcap = tiers.quant.capacity
            cache_qg = jnp.full((qcap, spec.fetch_blocks, spec.gblk), -1,
                                jnp.int32)
            cache_qv = jnp.zeros((qcap, spec.fetch_blocks, spec.vblk),
                                 jnp.int8)
            cache_qs = jnp.zeros((qcap, spec.fetch_blocks, spec.n_qgroups),
                                 jnp.float32)
        else:
            cache_qg, cache_qv, cache_qs = (self._cache_qg, self._cache_qv,
                                            self._cache_qs)

        for rnd in plan.rounds:
            stats["n_rounds"] += 1
            if len(rnd.fetch_pids):
                ids = np.stack([self.store.span_block_ids(int(p))
                                for p in rnd.fetch_pids])
                g_blocks, qv_blocks, qs_blocks = self._gather_quant(ids)
                slots = jnp.asarray(rnd.fetch_slots, jnp.int32)
                cache_qg, cache_qv, cache_qs = DS.write_slots_quant(
                    spec, cache_qg, cache_qv, cache_qs, slots, g_blocks,
                    qv_blocks, qs_blocks)
            if not len(rnd.serve_pairs):
                continue
            t0 = time.perf_counter()
            n = len(rnd.serve_pairs)
            npad = pow2_pad(n)
            qi, ppid, pslot, prank, valid = rnd.serve_tensors(npad, B)
            pool_d, pool_p = DS.serve_quant_pool(
                spec, cache_qg, cache_qv, cache_qs, mt_dev, q_dev,
                pool_d, pool_p, jnp.asarray(qi), jnp.asarray(ppid),
                jnp.asarray(pslot), jnp.asarray(prank), jnp.asarray(valid),
                m=m, ef=max(ef, m), mode=cfg.search_mode, n_lanes=b)
            stats["sub_s"] += time.perf_counter() - t0
            stats["n_pairs"] += n
        if cfg.mode != "naive":
            self._cache_qg, self._cache_qv, self._cache_qs = (
                cache_qg, cache_qv, cache_qs)

        # 4. stage-2 accounting: pool payload -> row fetch plan
        t0 = time.perf_counter()
        pool_p = jax.block_until_ready(pool_p)
        stats["sub_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        pool_h = np.asarray(pool_p)
        live = pool_h[:, :, 1] >= 0
        flat_rows = pool_h[:, :, 1][live]
        flat_pids = pool_h[:, :, 2][live]
        n_admitted = 0
        if cfg.mode == "naive":
            # every (query, row) need is its own remote read
            for _ in range(len(flat_rows)):
                ledger.read(row_b, descriptors=1)
            stats["rerank_rows"] = int(len(flat_rows))
            stats["rerank_hit_rows"] = 0
        else:
            # query-aware: each needed row moves at most once per batch
            uniq_rows, first = np.unique(flat_rows, return_index=True)
            uniq_pids = flat_pids[first]
            resident = tiers.exact.resident()
            hit = np.isin(uniq_pids, np.fromiter(resident, np.int64,
                                                 len(resident)))
            groups: dict[int, int] = {}
            for p in uniq_pids[~hit].tolist():
                groups[p] = groups.get(p, 0) + 1
            items = sorted(groups.items())
            if cfg.mode == "no_doorbell":
                for p, cnt in items:
                    ledger.read(cnt * row_b, descriptors=cnt)
            else:
                for j in range(0, len(items), cfg.doorbell):
                    chunk = items[j:j + cfg.doorbell]
                    ledger.read(sum(c for _, c in chunk) * row_b,
                                descriptors=sum(c for _, c in chunk))
            if items:
                ledger.save(pb * len(items)
                            - sum(c for _, c in items) * row_b)
            for p in set(uniq_pids[hit].tolist()):
                tiers.exact.touch(int(p))
            # cost-based admission: a partition whose cumulative missed
            # re-rank rows already outweigh one span fetch is promoted
            for p, cnt in items:
                tiers.note_rerank_miss(int(p), cnt)
                if tiers.should_admit(int(p), row_b, pb):
                    slot, _ = tiers.admit_exact(int(p))
                    g_b, v_b = self._gather(
                        self.store.span_block_ids(int(p))[None])
                    self._cache_g, self._cache_v = DS.write_slots(
                        spec, self._cache_g, self._cache_v,
                        jnp.asarray([slot], jnp.int32), g_b, v_b)
                    ledger.read(pb, descriptors=1)
                    n_admitted += 1
            stats["rerank_rows"] = int((~hit).sum())
            stats["rerank_hit_rows"] = int(hit.sum())
        stats["plan_s"] += time.perf_counter() - t0
        stats["exact_admitted"] = n_admitted

        # 5. stage-2 re-rank: exact distances over candidate rows only
        t0 = time.perf_counter()
        run_d, run_g = DS.rerank_exact(self._v_dev, q_dev,
                                       pool_p[:, :, 1], pool_p[:, :, 0],
                                       dim=spec.dim, k=k)
        run_d = np.asarray(jax.block_until_ready(run_d))
        run_g = np.asarray(run_g).astype(np.int64)
        stats["sub_s"] += time.perf_counter() - t0

        stats["net"] = ledger.as_dict()
        stats["round_trips_per_query"] = ledger.round_trips / max(B, 1)
        stats["cache_hits"] = plan.n_cache_hits
        stats["n_fetches"] = plan.n_fetches
        return run_d, run_g, stats

    # ------------------------------------------------------------ insert

    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Dynamic insertion (paper §3.2): route via the cached meta-HNSW,
        append vector+id into the target group's shared overflow region
        (one remote WRITE each), repack the group when it fills."""
        cfg = self.cfg
        spec = self.store.spec
        vecs = np.asarray(vecs, np.float32).reshape(-1, spec.dim)
        pids, _ = S.meta_route(self._meta_vecs, self._meta_adj,
                               jnp.asarray(vecs), self._meta_entry, b=1,
                               n_levels=self.meta.graph.n_levels)
        pids = np.asarray(pids)[:, 0]
        gids = np.arange(self._n0 + len(self._extra),
                         self._n0 + len(self._extra) + len(vecs))
        ledger = NetLedger(cfg.fabric)
        for vec, gid, pid in zip(vecs, gids, pids.tolist()):
            self._extra[int(gid)] = vec
            self._extra_pid[int(gid)] = int(pid)
            slot = LA.insert_vector(self.store, vec, int(gid), int(pid))
            if slot < 0:
                group = int(self.store.meta_table[pid, LA.MT_GROUP])
                ok = LA.repack_group(self.store, group, self._lookup)
                if not ok:
                    self._full_rebuild()
                else:
                    LA.refresh_quant_group(self.store, group)
                    self._device_put()       # re-register the region
                    self._invalidate_group(group)
                slot = LA.insert_vector(self.store, vec, int(gid), int(pid))
                assert slot >= 0, "overflow full right after repack"
                continue
            # device twin of the host write: one-sided WRITE of D floats
            group = int(self.store.meta_table[pid, LA.MT_GROUP])
            co = LA.overflow_write_coords(spec, group, slot)
            self._g_dev, self._v_dev = DS.overflow_append(
                spec, self._g_dev, self._v_dev, jnp.asarray(vec),
                jnp.int32(gid), co["vec_block"], co["vec_off"],
                co["gid_block"], co["gid_off"])
            wire = spec.dim * 4 + 8
            if self.tiers is not None:
                # quantized-mirror twin: re-quantize the touched block on
                # the host, scatter codes + codebook scales on device,
                # and pay the extra one-sided WRITE on the wire
                LA.refresh_quant_blocks(self.store, [co["vec_block"]])
                self._qv_dev, self._qs_dev = DS.overflow_append_quant(
                    spec, self._qv_dev, self._qs_dev, jnp.asarray(vec),
                    co["vec_block"], co["vec_off"])
                wire += spec.dim + (spec.dim // spec.quant_group) * 4
            ledger.write(wire, descriptors=1)
            self._invalidate_pid(int(pid))
        self._mt_dirty = True       # host overflow counters moved
        self._last_insert_net = ledger.as_dict()
        return gids

    def _invalidate_pid(self, pid: int):
        """Drop stale cached copies (both partners see the ov region)."""
        group = int(self.store.meta_table[pid, LA.MT_GROUP])
        self._invalidate_group(group)

    def _invalidate_group(self, group: int):
        for side in (0, 1):
            p = group * 2 + side
            if self.tiers is not None:
                self.tiers.invalidate(p)    # drops BOTH tiers
            self.cache.drop(p)

    def _full_rebuild(self):
        """np_max exhausted: rebuild the whole region with a larger pad
        (rare; the paper's offline re-pack path)."""
        all_ids = np.arange(self._n0 + len(self._extra))
        data = np.concatenate([self._data, np.stack(
            [self._extra[g] for g in sorted(self._extra)])]) \
            if self._extra else self._data
        assigns = np.concatenate([
            self.meta.assignments,
            np.array([self._extra_pid[g] for g in sorted(self._extra)],
                     np.int32)])
        import dataclasses as DC
        self.meta = DC.replace(self.meta, assignments=assigns)
        self._data = data
        self._n0 = data.shape[0]
        self._extra.clear()
        self._extra_pid.clear()
        self.store = LA.build_store(
            data, self.meta, ov_cap=self.store.spec.ov_cap,
            slot_vecs=self.store.spec.slot_vecs,
            sub_params=HNSWParams(M=max(self.cfg.sub_M0 // 2, 2),
                                  M0=self.cfg.sub_M0,
                                  ef_construction=self.cfg.ef_construction))
        self._device_put()
        if self.tiers is not None:
            self._setup_quant(self._cap0)
        else:
            cap = self.cache.capacity
            self.cache = SCH.LRUCacheState(cap)
            spec = self.store.spec
            self._cache_g = jnp.full((cap, spec.fetch_blocks, spec.gblk), -1,
                                     jnp.int32)
            self._cache_v = jnp.zeros((cap, spec.fetch_blocks, spec.vblk),
                                      jnp.float32)
        del all_ids
