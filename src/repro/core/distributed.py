"""Distributed memory pool: the store sharded across a mesh axis.

The paper's memory pool is one big registered region on memory nodes; a
compute node READs blocks by remote address.  On a TPU pod we shard the
block buffers over the ``model`` axis (each chip's HBM owns
``n_blocks/tp`` contiguous blocks = one "memory instance"), replicate
the (tiny) meta-HNSW + metadata table on every chip (the paper caches
them in every compute instance), and express a doorbell fetch as ONE
collective: every owner contributes its requested blocks, ``psum``
assembles the staging buffer on all requesters.

One fetch launch == one network round trip (the paper's metric); its
wire bytes are the psum operand — the same numbers the HLO collective
parser in launch/dryrun.py counts, so the cost model and the compiled
artifact agree.

Owner mapping is block-contiguous, so a partition's span lives on one
(or two, at a boundary) owners — the layout's contiguity survives
sharding, which is what makes straggler re-balancing a contiguous copy
per group (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.layout import Store

try:                        # jax >= 0.5: top-level export, check_vma kwarg
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
except AttributeError:      # jax 0.4.x: experimental module, check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across the 0.4/0.5 API rename."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def _pad_blocks(arr: np.ndarray, mult: int) -> np.ndarray:
    pad = (-arr.shape[0]) % mult
    if pad == 0:
        return arr
    return np.concatenate([arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])


class ShardedStore:
    """Device-resident store sharded over ``axis`` of ``mesh``."""

    def __init__(self, store: Store, mesh: Mesh, axis: str = "model"):
        self.spec = store.spec
        self.mesh = mesh
        self.axis = axis
        self.tp = int(mesh.shape[axis])
        shard = NamedSharding(mesh, P(axis, None))
        g = _pad_blocks(store.graph_buf, self.tp)
        v = _pad_blocks(store.vec_buf, self.tp)
        self.n_blocks = g.shape[0]
        self.per_shard = self.n_blocks // self.tp
        self.graph_buf = jax.device_put(g, shard)
        self.vec_buf = jax.device_put(v, shard)
        # compute-pool replicas (paper: cached in every compute instance)
        rep = NamedSharding(mesh, P())
        self.meta_table = jax.device_put(store.meta_table, rep)

    # -------------------------------------------------------------- fetch

    def fetch_fn(self):
        """Returns jit'd ``fetch(graph_buf, vec_buf, block_ids) ->
        (g_blocks, v_blocks)`` — ONE collective launch per call (= one
        doorbell round trip), replicated output."""
        spec = self.spec
        per_shard = self.per_shard
        axis = self.axis

        def local_gather(buf, ids):
            lo = lax.axis_index(axis) * per_shard
            local = ids - lo
            mine = (local >= 0) & (local < per_shard)
            rows = buf[jnp.where(mine, local, 0)]
            zero = jnp.zeros((), buf.dtype)
            rows = jnp.where(mine[:, None], rows, zero)
            return lax.psum(rows, axis)

        @functools.partial(
            jax.jit,
            in_shardings=(NamedSharding(self.mesh, P(axis, None)),
                          NamedSharding(self.mesh, P(axis, None)),
                          NamedSharding(self.mesh, P())),
            out_shardings=NamedSharding(self.mesh, P()))
        def fetch(graph_buf, vec_buf, block_ids):
            gather = shard_map_compat(
                local_gather,
                mesh=self.mesh,
                in_specs=(P(axis, None), P()),
                out_specs=P())
            g = gather(graph_buf, block_ids)
            v = shard_map_compat(
                local_gather, mesh=self.mesh,
                in_specs=(P(axis, None), P()),
                out_specs=P())(vec_buf, block_ids)
            return g, v

        return fetch

    def fetch(self, block_ids: np.ndarray):
        ids = jnp.asarray(np.asarray(block_ids).reshape(-1), jnp.int32)
        g, v = self.fetch_fn()(self.graph_buf, self.vec_buf, ids)
        return g, v

    # ------------------------------------------------------- rebalancing

    def owner_of(self, block_id: int) -> int:
        return block_id // self.per_shard

    def partition_owners(self, store: Store) -> np.ndarray:
        """(P,) owner shard of each partition's span start — the
        partition->memory-instance map the heartbeat monitor rebalances."""
        starts = store.meta_table[:, 0]
        return (starts // self.per_shard).astype(np.int32)


def abstract_fetch_lowered(store: Store, mesh: Mesh, m_blocks: int,
                           axis: str = "model"):
    """Dry-run: lower+compile the fetch collective for a doorbell batch of
    ``m_blocks`` spans WITHOUT allocating the store (ShapeDtypeStructs).
    Returns (lowered, compiled)."""
    spec = store.spec
    tp = int(mesh.shape[axis])
    n_blocks = store.graph_buf.shape[0] + ((-store.graph_buf.shape[0]) % tp)
    per_shard = n_blocks // tp

    def local_gather(buf, ids):
        lo = lax.axis_index(axis) * per_shard
        local = ids - lo
        mine = (local >= 0) & (local < per_shard)
        rows = buf[jnp.where(mine, local, 0)]
        rows = jnp.where(mine[:, None], rows, jnp.zeros((), buf.dtype))
        return lax.psum(rows, axis)

    def fetch(graph_buf, vec_buf, block_ids):
        f = lambda b, i: shard_map_compat(local_gather, mesh=mesh,
                                          in_specs=(P(axis, None), P()),
                                          out_specs=P())(b, i)
        return f(graph_buf, block_ids), f(vec_buf, block_ids)

    n_ids = m_blocks * spec.fetch_blocks
    args = (jax.ShapeDtypeStruct((n_blocks, spec.gblk), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, spec.vblk), jnp.float32),
            jax.ShapeDtypeStruct((n_ids,), jnp.int32))
    with mesh:
        lowered = jax.jit(
            fetch,
            in_shardings=(NamedSharding(mesh, P(axis, None)),
                          NamedSharding(mesh, P(axis, None)),
                          NamedSharding(mesh, P())),
            out_shardings=NamedSharding(mesh, P())).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled
