"""d-HNSW core: the paper's contribution.

Public API:
    DHNSWEngine / EngineConfig   — build + batched search + insert
    build_meta                   — representative index (§3.1)
    build_store / LayoutSpec     — RDMA-friendly layout (§3.2)
    plan_batch                   — query-aware batched loading (§3.3)
"""
from repro.core.cost_model import RDMA_100G, TPU_ICI, Fabric, NetLedger
from repro.core.engine import MODES, POOLS, DHNSWEngine, EngineConfig
from repro.core.hnsw import (HNSW, HNSWParams, PaddedGraph, brute_force_knn,
                             recall_at_k)
from repro.core.layout import LayoutSpec, Store, build_store
from repro.core.meta import MetaIndex, build_meta
from repro.core.scheduler import (LRUCacheState, Plan, TieredCacheState,
                                  naive_plan, plan_batch)

__all__ = [
    "DHNSWEngine", "EngineConfig", "MODES", "POOLS",
    "HNSW", "HNSWParams", "PaddedGraph", "brute_force_knn", "recall_at_k",
    "MetaIndex", "build_meta",
    "LayoutSpec", "Store", "build_store",
    "LRUCacheState", "TieredCacheState", "Plan", "plan_batch", "naive_plan",
    "Fabric", "NetLedger", "RDMA_100G", "TPU_ICI",
]
