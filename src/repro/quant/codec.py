"""Symmetric int8 per-group codec for the quantized resident tier.

Encoding: values are split into contiguous groups of ``group`` floats;
each group stores ``scale = absmax / 127`` in a codebook array and codes
``round(x / scale)`` clipped to [-127, 127].  Symmetric means the
zero-point is identically 0 (stored implicitly) — dequantization is a
single fused multiply, which is what lets the device serve path
dequantize in registers right before the MXU matmul.

The group size must divide the vector dimensionality so that group
boundaries never straddle two vectors of a serialized partition span
(``layout.py`` flattens vectors back-to-back inside each block); per-
vector-segment scales are what makes the codec density-aware: a dense,
small-magnitude vector is not forced onto the range of an outlier
neighbour in the same block.

Wire format per block (the doorbell/DMA granularity): ``vblk`` int8
codes + ``vblk / group`` f32 scales appended as codebook blocks —
``layout.LayoutSpec.quant_block_bytes`` prices it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EPS = 1e-12          # guards all-zero groups (scale 0 would divide by 0)
QMAX = 127.0


@dataclass(frozen=True)
class QuantizedBlocks:
    """A quantized mirror of a block buffer: lockstep (n_blocks, ...)"""

    codes: np.ndarray    # (n_blocks, vblk) int8
    scales: np.ndarray   # (n_blocks, vblk // group) f32
    group: int


def quantize_groups(x: np.ndarray, group: int):
    """(..., D) f32 -> codes (..., D) int8, scales (..., D // group) f32.

    ``group`` must divide the trailing dimension.
    """
    x = np.asarray(x, np.float32)
    d = x.shape[-1]
    assert d % group == 0, (d, group)
    gx = x.reshape(*x.shape[:-1], d // group, group)
    scales = np.abs(gx).max(axis=-1) / QMAX
    codes = np.rint(gx / np.maximum(scales, EPS)[..., None])
    codes = np.clip(codes, -QMAX, QMAX).astype(np.int8)
    return codes.reshape(x.shape), scales.astype(np.float32)


def dequantize_groups(codes: np.ndarray, scales: np.ndarray, group: int):
    """Inverse of ``quantize_groups`` (lossy): codes * scale per group."""
    c = np.asarray(codes, np.float32)
    d = c.shape[-1]
    gx = c.reshape(*c.shape[:-1], d // group, group)
    return (gx * scales[..., None]).reshape(c.shape).astype(np.float32)


def quantize_blocks(vec_buf: np.ndarray, group: int) -> QuantizedBlocks:
    """Quantize a whole (n_blocks, vblk) block buffer in one shot."""
    codes, scales = quantize_groups(vec_buf, group)
    return QuantizedBlocks(codes=codes, scales=scales, group=group)


# ------------------------------------------------------------- device twin

def quantize_row_jnp(vec, group: int):
    """jnp twin of ``quantize_groups`` for one (D,) row — used by the
    engine's insert path to scatter a quantized overflow write without a
    host round trip.  Returns (codes (D,) int8, scales (D//group,) f32).
    """
    import jax.numpy as jnp
    d = vec.shape[-1]
    gx = vec.reshape(d // group, group)
    scales = jnp.max(jnp.abs(gx), axis=-1) / QMAX
    codes = jnp.rint(gx / jnp.maximum(scales, EPS)[:, None])
    codes = jnp.clip(codes, -QMAX, QMAX).astype(jnp.int8)
    return codes.reshape(d), scales.astype(jnp.float32)
