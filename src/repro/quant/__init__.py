"""Quantized resident tier — per-partition int8 codecs + staged search.

The compute pool's cache is small relative to the memory pool, and every
miss costs bandwidth (paper §3.3).  This package shrinks the *bytes per
fetched partition*: a symmetric int8 per-group codec (``codec.py``)
mirrors each partition's vector payload, the engine keeps a large
quantized tier next to the small exact tier, and search runs in two
stages — quantized candidate generation, then exact re-ranking of only
the candidate rows (AQR-HNSW-style multi-stage re-ranking adapted to the
d-HNSW layout).
"""
from repro.quant.codec import (QuantizedBlocks, dequantize_groups,
                               quantize_blocks, quantize_groups,
                               quantize_row_jnp)

__all__ = [
    "QuantizedBlocks",
    "quantize_blocks", "quantize_groups", "dequantize_groups",
    "quantize_row_jnp",
]
