"""In-process bearers: loopback (real region) and model (accounting).

:class:`LoopbackBearer` completes every doorbell batch synchronously
against an in-process region (any object with the ``HostRegion.handle``
contract): the registered MRs are numpy views onto the same address
space, so a "one-sided READ" is a function call that gathers from them
— zero copies beyond the response encode, no sockets, no server
process.  Byte-for-byte it speaks the same frames as the TCP bearer
(the mapping lives in ``verbs.wr_frame``), which is what lets the
conformance suite run identical assertions across both.

:class:`ModelBearer` carries no bytes at all: it counts doorbells, work
requests and requested lengths so ``SimulatedRDMAPool`` can issue its
modeled verbs through the same QueuePair interface the real transports
use, while its clock stays priced by the fabric model.
"""
from __future__ import annotations

from collections import deque

from repro.rdma.verbs import _wire


class LoopbackBearer:
    """Synchronous in-process bearer over a duck-typed host region.

    ``region`` needs one method — ``handle(op, flags, payload, seq) ->
    (resp, rflags)`` — and the bearer mirrors the TCP server's error
    contract around it: a verb exception becomes an error *completion*
    (FLAG_ERROR + message), never a raised exception, so pipelined
    batches behind a failure still drain.  ``counters`` (shared with the
    pool's ``wire`` dict) sees the same frame/byte accounting a socket
    would, headers included.
    """

    #: bearer consumes framed submissions (see ``QueuePair.post_send``)
    frames = True

    def __init__(self, region, counters=None):
        self.region = region
        self.wire = counters if counters is not None else {}
        for k in ("frames_tx", "frames_rx", "bytes_tx", "bytes_rx"):
            self.wire.setdefault(k, 0)
        self._ready: deque = deque()
        self._seq = 0
        self.closed = False

    def submit(self, op: int, payload: bytes, flags: int = 0, *,
               prefix: bytes = b"", wrs=None) -> int:
        """Frame one doorbell batch and complete it synchronously."""
        if self.closed:
            raise ConnectionError("loopback bearer closed")
        W = _wire()
        pflags = flags | (W.FLAG_TRACE if prefix else 0)
        self._seq = (self._seq + 1) & 0xFFFFFFFF
        nb = W.HEADER_BYTES + len(prefix) + len(payload)
        self.wire["frames_tx"] += 1
        self.wire["bytes_tx"] += nb
        if op == W.OP_SHUTDOWN:
            # connection-level op on the socket path; in-process there
            # is no server to stop — ack and keep serving
            resp, rflags = b"", 0
        else:
            try:
                resp, rflags = self.region.handle(op, pflags,
                                                  prefix + payload,
                                                  self._seq)
            except Exception as e:        # verb error -> error completion
                resp, rflags = str(e).encode("utf-8"), W.FLAG_ERROR
        self._ready.append((op, rflags, resp))
        return nb

    def flush(self) -> None:
        """No-op: loopback submissions complete at post time."""

    def complete(self):
        """Next in-order completion -> ``(op, flags, payload)``."""
        if not self._ready:
            raise RuntimeError("no outstanding loopback work")
        W = _wire()
        op, rflags, resp = self._ready.popleft()
        self.wire["frames_rx"] += 1
        self.wire["bytes_rx"] += W.HEADER_BYTES + len(resp)
        return op, rflags, resp

    def close(self) -> None:
        """Mark the bearer closed (further submits raise)."""
        self.closed = True


class ModelBearer:
    """Accounting-only bearer for the simulated transport.

    Never frames or moves bytes (``frames = False`` short-circuits the
    WR -> frame mapping): each posted WR list is tallied — one doorbell,
    ``len(wrs)`` descriptors, ``sum(length)`` requested bytes — and
    completes immediately and empty.  The fabric model prices the clock
    from the verb's charge, exactly as before the QP re-plumb.
    """

    frames = False

    def __init__(self):
        self.doorbells = 0
        self.descriptors = 0
        self.req_bytes = 0
        self._ready: deque = deque()
        self.closed = False

    def submit(self, op: int, payload: bytes, flags: int = 0, *,
               prefix: bytes = b"", wrs=None) -> int:
        """Tally one doorbell batch; completes instantly."""
        n = len(wrs) if wrs else 1
        nb = int(sum(w.length for w in wrs)) if wrs else 0
        self.doorbells += 1
        self.descriptors += n
        self.req_bytes += nb
        self._ready.append((op, 0, b""))
        return nb

    def flush(self) -> None:
        """No-op: nothing is buffered."""

    def complete(self):
        """Next in-order (empty) completion."""
        if not self._ready:
            raise RuntimeError("no outstanding modeled work")
        return self._ready.popleft()

    def close(self) -> None:
        """Mark the bearer closed."""
        self.closed = True

    def snapshot(self) -> dict:
        """Cumulative doorbell/descriptor/byte tallies."""
        return {"doorbells": int(self.doorbells),
                "descriptors": int(self.descriptors),
                "req_bytes": int(self.req_bytes)}
