"""ibverbs-style verbs API for the memory-pool transport.

The paper's memory nodes are passive: a compute node *registers* the
remote region once and then moves bytes with one-sided work requests —
no per-verb server logic, no request handlers, just READ/WRITE against
``(rkey, addr, len)`` triples.  This module is that abstraction for the
repro:

* :class:`MemoryRegion` — a registered region slice named by an
  ``rkey``; addresses inside it are *logical* (partition ids for the
  span MR, region row addresses for the row MRs, block ids for the
  block MR) so the layout's indirection — NOT the transport — decides
  where bytes physically live.
* :class:`WorkRequest` — one descriptor: an opcode (``READ`` / ``WRITE``
  / ``WRITE_WITH_IMM`` / ``SEND``), a target ``(rkey, addr, length)``,
  optional immediate data and an inline payload for writes.
* :class:`QueuePair` — ``post_send`` of a WR *list* is exactly one
  doorbell batch: the whole list becomes one bearer submission (one
  wire frame on the TCP bearer), which is what keeps measured frames ==
  modeled round trips (``wire_vs_model``).
* :class:`CompletionQueue` — ``poll`` returns completions in posting
  order; a remote verb error surfaces as a completion with nonzero
  ``status`` (never an exception mid-drain, so pipelined batches behind
  the failure still complete).

Bearers (``rdma/loopback.py``, ``rdma/tcp.py``) move the framed bytes;
they share the WR-list -> frame mapping in :func:`wr_frame`, so the
in-process and TCP paths are byte-identical by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# ------------------------------------------------------------- opcodes

#: one-sided read from a registered region
READ = 1
#: one-sided write into a registered region
WRITE = 2
#: one-sided write whose completion carries immediate data (the control
#: notification the passive side consumes — e.g. an append's (gid, pid))
WRITE_WITH_IMM = 3
#: two-sided control-plane message (attach / stats / ping); ``imm``
#: names the message type
SEND = 4

OPCODE_NAMES = {READ: "READ", WRITE: "WRITE",
                WRITE_WITH_IMM: "WRITE_WITH_IMM", SEND: "SEND"}

# ------------------------------------------------------------- rkeys
# Deterministic rkeys, one per addressable view of the serialized
# region.  Logical addressing per MR: the span MR is addressed by
# partition id, the row MRs by region row address, the block MR by
# block id — the same indirection the layout's metadata table encodes,
# so a remote node can validate every address against its own region.

RKEY_SPANS = 0x10    #: span MR — addr = partition id, len = span bytes
RKEY_ROWS = 0x20     #: f32 row MR — addr = region row address
RKEY_QROWS = 0x30    #: int8 row MR — addr = region row address
RKEY_OVERFLOW = 0x40  #: shared-overflow write MR — addr = partition id
RKEY_REGION = 0x50   #: block-granular write MR — addr = block id

RKEY_NAMES = {RKEY_SPANS: "spans", RKEY_ROWS: "rows",
              RKEY_QROWS: "quant_rows", RKEY_OVERFLOW: "overflow",
              RKEY_REGION: "region"}

# completion status
WC_SUCCESS = 0
WC_REMOTE_ERROR = 1


@dataclass(frozen=True)
class MemoryRegion:
    """A registered region slice: ``(rkey, addr, length)`` + a name.

    ``addr`` is the base logical address and ``length`` the addressable
    extent in that MR's units (partitions, rows, or blocks); ``nbytes``
    is the physical size one unit resolves to.  Host-side MRs
    additionally carry live numpy views (``rdma/mr.py``); client-side
    registrations (:func:`region_mrs`) are descriptors only — exactly
    like an rkey handed to a remote peer.
    """

    rkey: int
    addr: int
    length: int
    nbytes: int
    name: str = ""


def region_mrs(spec, *, quant: bool = False) -> dict:
    """Client-side MR table for a region with layout ``spec``.

    Returns ``{rkey: MemoryRegion}`` describing every addressable view
    of the remote region — what a real verbs stack would receive from
    the remote's registration exchange.  ``quant`` adds the int8-mirror
    row MR.
    """
    n_rows = spec.n_blocks * spec.slot_vecs
    mrs = {
        RKEY_SPANS: MemoryRegion(RKEY_SPANS, 0, spec.n_partitions,
                                 spec.partition_bytes(), "spans"),
        RKEY_ROWS: MemoryRegion(RKEY_ROWS, 0, n_rows, spec.row_bytes(),
                                "rows"),
        RKEY_OVERFLOW: MemoryRegion(RKEY_OVERFLOW, 0, spec.n_partitions,
                                    spec.row_bytes() + 8, "overflow"),
        RKEY_REGION: MemoryRegion(RKEY_REGION, 0, spec.n_blocks,
                                  spec.block_bytes(), "region"),
    }
    if quant:
        nq = spec.dim + (spec.dim // spec.quant_group) * 4
        mrs[RKEY_QROWS] = MemoryRegion(RKEY_QROWS, 0, n_rows, nq,
                                       "quant_rows")
    return mrs


@dataclass
class WorkRequest:
    """One work descriptor of a doorbell batch.

    ``opcode`` is one of READ / WRITE / WRITE_WITH_IMM / SEND; ``rkey``
    + ``addr`` name the target inside a registered MR; ``length`` the
    bytes the request moves.  ``flags`` carries verb modifiers (the wire
    layer's quant/graph flags); ``payload`` is the inline data of a
    write; ``imm`` the immediate value (WRITE_WITH_IMM) or the message
    type (SEND).
    """

    opcode: int
    rkey: int = 0
    addr: int = 0
    length: int = 0
    flags: int = 0
    payload: bytes = b""
    imm: int = 0


@dataclass
class Completion:
    """One work completion, delivered in posting order.

    ``status`` is :data:`WC_SUCCESS` or :data:`WC_REMOTE_ERROR` (with
    ``error`` carrying the remote's message); ``data`` is the bytes a
    READ (or a control SEND's response) returned, ``flags`` the
    response's wire flags, and ``nbytes`` the payload bytes that moved.
    """

    opcode: int
    status: int = WC_SUCCESS
    data: bytes = b""
    error: str = ""
    flags: int = 0
    nbytes: int = 0


class CompletionQueue:
    """Poll-driven completion delivery, strictly in posting order.

    The queue drains its bearer lazily: ``poll`` asks the bearer for the
    next in-order completion only when called, so a caller can decode
    batch ``r`` while batch ``r+1``'s response is still in flight — the
    double-buffered doorbell submission ``RemotePool`` exploits.
    """

    def __init__(self, bearer):
        self._bearer = bearer
        self._outstanding = 0

    @property
    def outstanding(self) -> int:
        """Posted doorbell batches whose completion was not yet polled."""
        return self._outstanding

    def _posted(self) -> None:
        self._outstanding += 1

    def poll(self, n: int = 1) -> list:
        """Return the next ``n`` completions (blocking on the bearer)."""
        if n > self._outstanding:
            raise RuntimeError(
                f"polling {n} completions with {self._outstanding} "
                f"outstanding")
        out = []
        for _ in range(n):
            op, flags, payload = self._bearer.complete()
            self._outstanding -= 1
            if flags & _FLAG_ERROR:
                out.append(Completion(opcode=op, status=WC_REMOTE_ERROR,
                                      error=payload.decode("utf-8"),
                                      flags=flags))
            else:
                out.append(Completion(opcode=op, data=payload, flags=flags,
                                      nbytes=len(payload)))
        return out


class QueuePair:
    """A send queue over one bearer + its completion queue.

    ``post_send`` of a WR list is ONE doorbell batch: the list maps to a
    single bearer submission (:func:`wr_frame`), so frames == doorbell
    batches == modeled round trips.  ``post_recv`` exists for API shape
    (both bearers deliver responses without pre-posted buffers).
    """

    def __init__(self, bearer):
        self.bearer = bearer
        self.cq = CompletionQueue(bearer)

    def post_send(self, wrs, *, prefix: bytes = b"") -> int:
        """Submit one doorbell batch (a WR list) -> bytes submitted.

        ``prefix`` is an opaque trace-context prepended outside the verb
        payload (never priced).  The completion lands on ``self.cq`` in
        posting order.

        When a :class:`repro.rdma.inject.WRInjector` is attached to the
        bearer (``bearer.injector``) it is consulted here, before the
        list is framed or submitted: injected latency accrues on the
        injector (transports fold it into their observed clocks) and an
        injected fault raises before anything is posted, so a failed
        post charges nothing.
        """
        inj = getattr(self.bearer, "injector", None)
        if inj is not None:
            inj.on_post(wrs)
        if getattr(self.bearer, "frames", True):
            op, payload, flags = wr_frame(wrs)
        else:                       # accounting-only bearer: skip framing
            op, payload, flags = 0, b"", 0
        n = self.bearer.submit(op, payload, flags, prefix=prefix, wrs=wrs)
        self.cq._posted()
        return n

    def post_recv(self, n: int = 1) -> None:
        """Register receive capacity (a no-op on both bearers: responses
        are matched to sends by sequence, not to posted buffers)."""

    def close(self) -> None:
        """Close the underlying bearer (idempotent)."""
        self.bearer.close()


# ------------------------------------------------- WR-list <-> framing
# The TCP-emulated bearer maps WR lists onto the existing repro/net
# framing; the loopback bearer feeds the same frames to an in-process
# HostRegion.  Keeping the mapping HERE (shared) is what makes the two
# bearers byte-identical.

_FLAG_ERROR = 0x8000     # == wire.FLAG_ERROR (response error frames)


def _wire():
    # deferred: repro.net imports this package, so the wire module is
    # bound at first use, not at import time
    from repro.net import wire as W
    return W


_READ_OPS = None


def _read_ops():
    global _READ_OPS
    if _READ_OPS is None:
        W = _wire()
        _READ_OPS = {RKEY_SPANS: (W.OP_READ_SPANS, W.enc_pids),
                     RKEY_ROWS: (W.OP_READ_ROWS, W.enc_rows),
                     RKEY_QROWS: (W.OP_READ_QUANT_ROWS, W.enc_rows)}
    return _READ_OPS


def wr_frame(wrs) -> tuple:
    """Map one posted WR list (one doorbell batch) -> one wire frame.

    Returns ``(op, payload, flags)``:

    * a READ list (homogeneous rkey) becomes one read frame whose
      payload is the flat logical-address batch — addresses ship to the
      remote, so IT resolves and validates them against its region;
    * a write list (WRITEs closed by one WRITE_WITH_IMM) becomes one
      write frame carrying the concatenated inline payloads;
    * a single SEND becomes the control frame its ``imm`` names.

    Exactly one frame per list is the invariant the accounting rests on.
    """
    if not wrs:
        raise ValueError("empty work-request list")
    W = _wire()
    first = wrs[0]
    if first.opcode == READ:
        rkey = first.rkey
        op_enc = _read_ops().get(rkey)
        if op_enc is None or any(w.opcode != READ or w.rkey != rkey
                                 for w in wrs):
            raise ValueError("READ list must share one registered rkey")
        op, enc = op_enc
        flags = 0
        for w in wrs:
            flags |= w.flags
        return op, enc(np.asarray([w.addr for w in wrs], np.int64)), flags
    if first.opcode == SEND:
        if len(wrs) != 1:
            raise ValueError("SEND posts one WR per doorbell")
        return first.imm, first.payload, first.flags
    last = wrs[-1]
    if last.opcode != WRITE_WITH_IMM or any(
            w.opcode not in (WRITE, WRITE_WITH_IMM) for w in wrs):
        raise ValueError("write list must close with WRITE_WITH_IMM")
    op = {RKEY_OVERFLOW: W.OP_APPEND,
          RKEY_REGION: W.OP_WRITE_BLOCKS}.get(last.rkey)
    if op is None:
        raise ValueError(f"no write mapping for rkey {last.rkey:#x}")
    flags = 0
    for w in wrs:
        flags |= w.flags
    return op, b"".join(w.payload for w in wrs), flags


# --------------------------------------------------- WR constructors

def read_wr(rkey: int, addr: int, length: int, *,
            flags: int = 0) -> WorkRequest:
    """One one-sided READ descriptor against a registered MR."""
    return WorkRequest(READ, rkey=rkey, addr=int(addr), length=int(length),
                       flags=flags)


def write_wr(rkey: int, addr: int, payload: bytes = b"", *,
             length: int = 0, flags: int = 0) -> WorkRequest:
    """One one-sided WRITE descriptor (inline payload)."""
    return WorkRequest(WRITE, rkey=rkey, addr=int(addr),
                       length=length or len(payload), payload=payload,
                       flags=flags)


def write_imm_wr(rkey: int, addr: int, payload: bytes, imm: int, *,
                 flags: int = 0) -> WorkRequest:
    """The closing WRITE_WITH_IMM of a write batch: data + the immediate
    control word the passive side is notified with."""
    return WorkRequest(WRITE_WITH_IMM, rkey=rkey, addr=int(addr),
                       length=len(payload), payload=payload,
                       imm=int(imm), flags=flags)


def send_wr(op: int, payload: bytes = b"", *, flags: int = 0) -> WorkRequest:
    """A two-sided control SEND; ``op`` is the message type (wire op)."""
    return WorkRequest(SEND, payload=payload, imm=int(op), flags=flags)
