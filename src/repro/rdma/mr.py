"""Host-side registered memory regions (the passive memory node).

A pool server does not implement read verbs — it *registers* its
serialized region as a set of :class:`HostMR` objects (numpy views over
the ``core/layout.Store`` buffers, one per rkey) and answers any
one-sided READ by delegating to the MR the request's rkey names:
decode the logical address batch, gather the bytes those addresses
resolve to, encode the response.  ``repro/net/server.HostRegion`` keeps
exactly one generic dispatch line per read opcode; all span/row gather
logic lives here.

MRs hold their *owner* (any object with a ``.store`` attribute), not a
buffer: an ATTACH that replaces the store, or an append that mutates it
in place, is visible to every registered MR immediately — the region is
the source of truth, registration is just a named window onto it.
"""
from __future__ import annotations

import numpy as np

from repro.core import layout as LA
from repro.rdma import verbs as V


class HostMR:
    """One registered window onto the owner's region.

    Subclasses define ``rkey``/``name`` and implement :meth:`read` as
    ``(request_payload, flags) -> (response_payload, response_flags)``
    — the full one-sided READ service for that window.
    """

    rkey = 0
    name = ""

    def __init__(self, owner):
        self.owner = owner

    def _store(self):
        st = self.owner.store
        if st is None:
            raise RuntimeError("no region attached")
        return st

    def descriptor(self) -> V.MemoryRegion:
        """The ``(rkey, addr, len)`` registration this MR advertises."""
        spec = self._store().spec
        return V.region_mrs(spec, quant=True)[self.rkey]

    def read(self, payload: bytes, flags: int):
        """Serve one one-sided READ batch against this window."""
        raise NotImplementedError


class SpanMR(HostMR):
    """Span window: addr = partition id, one unit = one fetch span.

    Serves exact (graph + vec blocks) and quantized (int8 codes +
    codebooks, with full graph blocks or just the gid tails) span
    batches; the response payload is exactly the modeled span bytes.
    """

    rkey = V.RKEY_SPANS
    name = "spans"

    def _span_blocks(self, buf, pids):
        store = self._store()
        ids = np.stack([store.span_block_ids(int(p)) for p in pids]) \
            if len(pids) else np.zeros((0, store.spec.fetch_blocks),
                                       np.int64)
        return buf[ids.reshape(-1)].reshape(
            len(pids), store.spec.fetch_blocks, buf.shape[1])

    def _gid_tails(self, pids) -> np.ndarray:
        # slice the two gid runs of each span straight out of the region
        # (blocks are contiguous rows, so a run is contiguous in the
        # flat view) — no need to materialize the full graph span the
        # tails format exists to keep off the wire
        from repro.net import wire as W
        store = self._store()
        spec = store.spec
        gflat = store.graph_buf.reshape(-1)           # view, no copy
        tails = np.empty((len(pids), spec.np_max + spec.ov_cap), np.int32)
        for i, p in enumerate(pids):
            row = store.meta_table[int(p)]
            base = int(row[LA.MT_BLK_START]) * spec.gblk
            d, o = W.gid_tail_offsets(spec, int(row[LA.MT_SIDE]))
            tails[i, :spec.np_max] = gflat[base + d:base + d + spec.np_max]
            tails[i, spec.np_max:] = gflat[base + o:base + o + spec.ov_cap]
        return tails

    def read(self, payload: bytes, flags: int):
        """One doorbell batch of span READs -> the span bytes."""
        from repro.net import wire as W
        store = self._store()
        spec = store.spec
        pids = W.dec_pids(payload)
        quant = bool(flags & W.FLAG_QUANT)
        graph = bool(flags & W.FLAG_GRAPH)
        if not quant:
            g = self._span_blocks(store.graph_buf, pids)
            v = self._span_blocks(store.vec_buf, pids)
            return W.enc_spans_resp(spec, quant=False, g=g, v=v), 0
        if store.qvec_buf is None:
            raise RuntimeError("quant span read without an attached mirror")
        qv = self._span_blocks(store.qvec_buf, pids)
        qs = self._span_blocks(store.qscale_buf, pids)
        if graph:
            g = self._span_blocks(store.graph_buf, pids)
            return (W.enc_spans_resp(spec, quant=True, graph=True, qv=qv,
                                     qs=qs, g=g), flags)
        return (W.enc_spans_resp(spec, quant=True, graph=False, qv=qv,
                                 qs=qs, tails=self._gid_tails(pids)), flags)


class RowMR(HostMR):
    """f32 row window: addr = region row address, one unit = one row."""

    rkey = V.RKEY_ROWS
    name = "rows"

    def read(self, payload: bytes, flags: int):
        """Row-granular READ -> ``n_rows * row_bytes()`` f32."""
        from repro.net import wire as W
        store = self._store()
        rows = W.dec_rows(payload)
        safe = np.maximum(rows, 0)
        vrows = store.vec_buf.reshape(-1, store.spec.dim)[safe]
        return W.enc_rows_resp(vrows), 0


class QuantRowMR(HostMR):
    """int8-mirror row window: codes + group scales per row address."""

    rkey = V.RKEY_QROWS
    name = "quant_rows"

    def read(self, payload: bytes, flags: int):
        """Quant-mirror row READ -> codes + codebook scales."""
        from repro.net import wire as W
        store = self._store()
        if store.qvec_buf is None:
            raise RuntimeError("quant row read without an attached mirror")
        spec = store.spec
        rows = W.dec_rows(payload)
        safe = np.maximum(rows, 0)
        codes = store.qvec_buf.reshape(-1, spec.dim)[safe]
        scales = store.qscale_buf.reshape(
            -1, spec.dim // spec.quant_group)[safe]
        return W.enc_quant_rows_resp(codes, scales), 0


def host_mrs(owner) -> dict:
    """Register every readable window of ``owner``'s region.

    ``owner`` is any object with a ``.store`` attribute (a ``HostRegion``
    or a bare namespace); returns ``{rkey: HostMR}``.  Registration is
    done once — MRs dereference the owner's store per read, so region
    replacement (ATTACH) and in-place mutation both stay visible.
    """
    return {mr.rkey: mr for mr in (SpanMR(owner), RowMR(owner),
                                   QuantRowMR(owner))}
