"""Deterministic WR-level latency/error injection for straggler chaos.

A :class:`WRInjector` attaches to a bearer (``bearer.injector = inj``)
and is consulted by :meth:`QueuePair.post_send` for every posted WR
list, *before* the list is framed or submitted.  Schedules are pure
functions of ``(post index, seed)`` — a multiplicative-hash hit rule,
no RNG state, no wall clock — so a chaos run is reproducible bit for
bit and its assertions can be exact.

Three degradation shapes compose:

* ``delay_s`` — fixed per-post delay (a uniformly slow NIC/link);
* ``spike_s`` every ``spike_every`` posts — tail spikes (GC pause,
  congestion burst) that move p99 while leaving p50 alone;
* ``error_every`` — the selected posts raise :class:`InjectedFault`
  *instead of* posting, modeling a flushed QP send.  The fault fires
  before any submit/accounting, so a failed post charges nothing.

Injected delay accumulates in ``injected_s``; transports that model
time (``SimulatedRDMAPool``) read the delta around their post loop and
fold it into the *observed* clock (``sim_s``, histograms) — never into
the a-priori cost model — so the straggler detector, not a cheating
cost model, is what routes reads away from the degraded shard.
"""
from __future__ import annotations

import time

#: Knuth's multiplicative hash constant; spreads post indices uniformly.
_MIX = 2654435761


class InjectedFault(ConnectionError):
    """A WR post failed by injection (models a flushed QP send)."""


class WRInjector:
    """Seeded per-post latency/error schedule for one bearer.

    Parameters
    ----------
    seed:
        Mixes into the hit rule; two injectors with different seeds
        degrade different posts.
    delay_s:
        Fixed delay added to every post.
    spike_s, spike_every:
        Extra delay added when ``hit(i, spike_every)``; 0 disables.
    error_every:
        Posts where ``hit(i, error_every)`` raise
        :class:`InjectedFault` before submit; 0 disables.
    sleep:
        When True, injected delay also really sleeps (wall-clock
        chaos); default False keeps runs fast and deterministic.
    """

    def __init__(self, *, seed: int = 0, delay_s: float = 0.0,
                 spike_s: float = 0.0, spike_every: int = 0,
                 error_every: int = 0, sleep: bool = False):
        """Capture the schedule; counters start at zero."""
        self.seed = int(seed)
        self.delay_s = float(delay_s)
        self.spike_s = float(spike_s)
        self.spike_every = int(spike_every)
        self.error_every = int(error_every)
        self.sleep = bool(sleep)
        self.posts = 0
        self.injections = 0
        self.injected_s = 0.0
        self.faults = 0

    def hit(self, i: int, every: int) -> bool:
        """Deterministic hit rule: does post *i* land on an *every* slot."""
        if every <= 0:
            return False
        return (i * _MIX + self.seed) % every == 0

    def on_post(self, wrs) -> None:
        """Consulted once per posted WR list, before framing/submit.

        Raises :class:`InjectedFault` on error hits; otherwise adds the
        scheduled delay to ``injected_s`` (and optionally sleeps).
        """
        i = self.posts
        self.posts += 1
        if self.hit(i, self.error_every):
            self.faults += 1
            raise InjectedFault(
                f"injected WR fault at post {i} (seed={self.seed})")
        dt = self.delay_s
        if self.hit(i, self.spike_every):
            dt += self.spike_s
        if dt > 0.0:
            self.injections += 1
            self.injected_s += dt
            if self.sleep:
                time.sleep(dt)

    def snapshot(self) -> dict:
        """Counters + schedule parameters, JSON-ready."""
        return {"seed": self.seed, "posts": self.posts,
                "injections": self.injections,
                "injected_s": self.injected_s, "faults": self.faults,
                "delay_s": self.delay_s, "spike_s": self.spike_s,
                "spike_every": self.spike_every,
                "error_every": self.error_every}
