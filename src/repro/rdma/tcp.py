"""TCP-emulated bearer: WR frames over the ``repro/net`` socket wire.

Maps each posted doorbell batch onto exactly one ``wire.py`` frame and
moves it over a pipelined TCP connection to a ``PoolServer``.  The
server side needs no per-verb logic for reads — it resolves the frame's
logical address batch against its registered MRs (``rdma/mr.py``) —
which is what makes this an *emulation of one-sided access* rather than
an RPC protocol: the frame is the WR list, the response is the remote
memory, and ordering is the QP's submission order.

Batching: submissions accumulate in an output buffer and are flushed in
one ``sendall`` at the first completion poll (or an explicit
``flush()``), so a k-batch doorbell pipeline costs one syscall out and
k framed responses in — identical bytes and syscall pattern to the
pre-verbs ``RemotePool`` transport, byte-counted the same way (headers
in ``bytes_tx``/``bytes_rx``, payloads separate so the model cross-check
sees pure data bytes).

Failures surface as the exceptions the socket raises (``ConnectionError``
/ ``socket.timeout`` / ``OSError``); the pool above maps them to
``PoolUnavailableError``.  An out-of-sequence response is a
``ConnectionError`` — the connection is desynchronized and unusable.
"""
from __future__ import annotations

import socket
from collections import deque
from typing import Optional

from repro.rdma.verbs import _wire


class TcpBearer:
    """Pipelined frame bearer over one TCP connection.

    ``counters`` (usually the owning pool's ``wire`` dict, shared by
    reference) accumulates ``frames_tx``/``frames_rx``/``bytes_tx``/
    ``bytes_rx``; the bearer owns the socket, the sequence numbers and
    the in-order response matching.
    """

    #: bearer consumes framed submissions (see ``QueuePair.post_send``)
    frames = True

    def __init__(self, endpoint: tuple, *, timeout_s: float = 60.0,
                 connect_timeout_s: float = 10.0, counters=None):
        self.endpoint = endpoint
        self.wire = counters if counters is not None else {}
        for k in ("frames_tx", "frames_rx", "bytes_tx", "bytes_rx"):
            self.wire.setdefault(k, 0)
        self._sock: Optional[socket.socket] = socket.create_connection(
            endpoint, timeout=connect_timeout_s)
        self._sock.settimeout(timeout_s)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._seq = 0
        self._out = bytearray()
        self._pending: deque = deque()

    @property
    def closed(self) -> bool:
        """True once the connection is gone (submits will raise)."""
        return self._sock is None

    def submit(self, op: int, payload: bytes, flags: int = 0, *,
               prefix: bytes = b"", wrs=None) -> int:
        """Frame one doorbell batch into the output buffer.

        Nothing hits the socket yet — the k frames of a pipelined
        exchange coalesce into one ``sendall`` at the first
        :meth:`complete`.  Returns the framed bytes (header + trace
        prefix + payload), which is what ``bytes_tx`` records.
        """
        if self._sock is None:
            raise ConnectionError("bearer connection closed")
        W = _wire()
        pflags = flags | (W.FLAG_TRACE if prefix else 0)
        self._seq += 1
        buf = W.pack_frame(op, prefix + payload, flags=pflags,
                           seq=self._seq)
        self._out += buf
        self._pending.append((op, self._seq))
        self.wire["frames_tx"] += 1
        self.wire["bytes_tx"] += len(buf)
        return len(buf)

    def flush(self) -> None:
        """Push every buffered frame to the socket in one write."""
        if self._out and self._sock is not None:
            out, self._out = self._out, bytearray()
            self._sock.sendall(bytes(out))

    def complete(self):
        """Blocking read of the next in-order response.

        Flushes first (the doorbell ring), then receives exactly one
        frame and matches it against the oldest outstanding submission
        -> ``(op, flags, payload)``.
        """
        if not self._pending:
            raise RuntimeError("no outstanding work on this bearer")
        if self._sock is None:
            raise ConnectionError("bearer connection closed")
        W = _wire()
        self.flush()
        rop, rflags, rseq, payload = W.recv_frame(self._sock)
        op, seq = self._pending.popleft()
        self.wire["frames_rx"] += 1
        self.wire["bytes_rx"] += W.HEADER_BYTES + len(payload)
        if rseq != (seq & 0xFFFFFFFF) or rop != op:
            raise ConnectionError(
                f"out-of-order response (seq {rseq} != {seq})")
        return rop, rflags, payload

    def close(self) -> None:
        """Drop the connection (idempotent)."""
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None
                self._out = bytearray()
                self._pending.clear()
