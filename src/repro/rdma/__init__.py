"""RDMA-verbs bearer subsystem: MR/WR/QP/CQ over pluggable bearers.

The paper's transport is one-sided RDMA: compute nodes register the
memory pool's serialized region and move bytes with READ/WRITE work
requests — the memory side stays passive.  This package is that
abstraction for the repro, factored so TCP framing is just one *bearer*
among several:

* ``verbs``    — the API: :class:`MemoryRegion`, :class:`WorkRequest`,
  :class:`QueuePair` (``post_send`` of a WR list == one doorbell
  batch), :class:`CompletionQueue`, and the shared WR-list -> frame
  mapping;
* ``mr``       — host-side registered MRs (numpy views over the region)
  that serve one-sided READs without per-verb server logic;
* ``loopback`` — in-process bearer (synchronous completions) and the
  accounting-only model bearer the simulated transport posts through;
* ``tcp``      — the TCP-emulated bearer over ``repro/net`` framing to
  a ``PoolServer``.

``RemotePool(bearer="loopback"|"tcp")`` and ``SimulatedRDMAPool`` issue
every verb through a :class:`QueuePair`; ``wire_vs_model`` and the
LocalPool bit-identity conformance suite gate all of it.
"""
from repro.rdma.loopback import LoopbackBearer, ModelBearer
from repro.rdma.mr import HostMR, QuantRowMR, RowMR, SpanMR, host_mrs
from repro.rdma.tcp import TcpBearer
from repro.rdma.verbs import (READ, RKEY_OVERFLOW, RKEY_QROWS, RKEY_REGION,
                              RKEY_ROWS, RKEY_SPANS, SEND, WRITE,
                              WRITE_WITH_IMM, Completion, CompletionQueue,
                              MemoryRegion, QueuePair, WorkRequest,
                              read_wr, region_mrs, send_wr, wr_frame,
                              write_imm_wr, write_wr)

__all__ = [
    "READ", "WRITE", "WRITE_WITH_IMM", "SEND",
    "RKEY_SPANS", "RKEY_ROWS", "RKEY_QROWS", "RKEY_OVERFLOW", "RKEY_REGION",
    "MemoryRegion", "WorkRequest", "Completion", "CompletionQueue",
    "QueuePair", "wr_frame", "region_mrs",
    "read_wr", "write_wr", "write_imm_wr", "send_wr",
    "HostMR", "SpanMR", "RowMR", "QuantRowMR", "host_mrs",
    "LoopbackBearer", "ModelBearer", "TcpBearer",
]
