"""Real multi-node transport for the memory-pool boundary.

Everything before this subsystem *modeled* disaggregation in-process
(``LocalPool`` / ``SimulatedRDMAPool`` / ``ShardedPool``).  ``repro.net``
makes the index bytes actually cross a wire:

* ``wire.py``   — compact length-prefixed binary framing for every
                  ``MemoryPool`` verb; descriptor batches travel as
                  contiguous numpy buffers, one doorbell batch per frame.
* ``server.py`` — ``PoolServer``: a standalone memory-node process
                  (``python -m repro.net.server``) hosting a region and
                  serving verbs over TCP, plus the ``spawn_pool_servers``
                  loopback harness tests and benchmarks fork.
* ``client.py`` — ``RemotePool``: a full ``MemoryPool`` implementation
                  that marshals verbs to a server, charges the caller's
                  ``NetLedger`` from measured wire bytes (cross-checked
                  against the ``Fabric`` model), and plugs into
                  ``ShardedPool`` so an N-shard pool spans N processes.
"""
from repro.net.client import PoolUnavailableError, RemotePool, parse_endpoint
from repro.net.server import HostRegion, PoolServer, spawn_pool_servers

__all__ = ["RemotePool", "PoolUnavailableError", "parse_endpoint",
           "PoolServer", "HostRegion", "spawn_pool_servers"]
