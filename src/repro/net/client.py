"""RemotePool — the MemoryPool verbs issued as RDMA-style work requests.

A full ``MemoryPool`` implementation whose region lives behind a
:class:`repro.rdma.verbs.QueuePair`: span/row reads are WR-list READs
against the remote's registered memory regions (one ``post_send`` ==
one doorbell batch == one frame), appends are a ``WRITE_WITH_IMM`` into
the shared overflow MR, and repack/migration land as block-granular
WRITE batches closed by an IMM control message.  Two bearers carry the
frames:

* ``bearer="tcp"`` (default) — the TCP-emulated bearer
  (``repro.rdma.tcp``) to a standalone ``PoolServer`` process; bytes
  really cross a socket.
* ``bearer="loopback"`` — an in-process ``HostRegion`` behind the
  loopback bearer (``repro.rdma.loopback``): same frames, same MR
  delegation, synchronous completions, no sockets — the pool still
  uploads its region via ATTACH, so the loopback region is an
  independent deep copy and the bit-identity gate is as real as over
  TCP.

Completions are polled one at a time while later batches are still in
flight, so round r's payload is decoded while round r+1's response is
on the wire (double-buffered doorbell submission).  The pool keeps a
``wire`` tally of *measured* frames and payload bytes per verb next to
the modeled charge, and ``snapshot()["wire_vs_model"]`` cross-checks the
two — the protocol is constructed so that data-verb payloads equal the
``Fabric`` model's priced bytes exactly (see ``wire.py``).

Client-side mirror: the pool keeps the host ``Store`` it was built from
(the compute node built the index; ATTACH uploaded it).  The mirror is
**control-plane only** — the cached global metadata block the paper lets
compute instances hold, plus the write staging repack needs.  Every
index byte the search path consumes arrives through a wire verb; writes
are applied to both sides deterministically (``layout.insert_vector``
here, the same routine in the server) and the append response slot is
cross-checked so the two regions can never silently diverge.

Accounting parity: ``NetLedger`` charges use the measured response
payload for span reads (== the modeled bytes by protocol construction)
and the same model formulas as ``LocalPool`` for the ``post_*``
accounting verbs — so a RemotePool engine's ``stats["net"]`` is
bit-identical to LocalPool's, while ``snapshot()["wire"]`` additionally
reports what really moved.

Failure mode: any transport error (refused, reset, timeout, EOF) closes
the connection and raises ``PoolUnavailableError`` — a killed server is
a clean exception at the next verb, never a hang.
"""
from __future__ import annotations

import socket
import threading
import time
import zlib
from collections import Counter
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core import layout as LA
from repro.core.cost_model import RDMA_100G, Fabric, NetLedger
from repro.core.layout import Store
from repro.core.scheduler import doorbell_chunks
from repro.net import wire as W
from repro.obs.trace import TRACER
from repro.pool.protocol import (MemoryPool, PoolUnavailableError,
                                 _fresh_totals, span_wire_bytes)
from repro.rdma import verbs as V
from repro.rdma.loopback import LoopbackBearer
from repro.rdma.tcp import TcpBearer

__all__ = ["RemotePool", "PoolUnavailableError", "parse_endpoint"]

Endpoint = Union[str, tuple]


def parse_endpoint(ep: Endpoint) -> tuple:
    """'host:port' or (host, port) -> (host, port)."""
    if isinstance(ep, str):
        host, _, port = ep.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad endpoint {ep!r} (want host:port)")
        return host, int(port)
    host, port = ep
    return str(host), int(port)


class RemotePool(MemoryPool):
    """MemoryPool over TCP: verbs marshaled to a ``PoolServer``.

    Keeps a host mirror of the region (writes run the same
    deterministic insert on both sides), counts every byte that crosses
    the socket per verb (``wire``), and cross-checks measured payloads
    against the ledger model (``wire_vs_model``).  A dead or
    unreachable server raises ``PoolUnavailableError`` instead of
    hanging — the hook a replicated ``ShardedPool`` parent fails over
    on.
    """

    kind = "remote"

    def __init__(self, store: Store, endpoint: Optional[Endpoint] = None, *,
                 fabric: Optional[Fabric] = None, timeout_s: float = 60.0,
                 connect_timeout_s: float = 10.0, attach: str = "always",
                 bearer: str = "tcp"):
        assert attach in ("always", "auto"), attach
        assert bearer in ("tcp", "loopback"), bearer
        if bearer == "tcp" and endpoint is None:
            raise ValueError("bearer='tcp' requires an endpoint")
        self.store = store
        self.bearer_kind = bearer
        self.endpoint = (parse_endpoint(endpoint) if endpoint is not None
                         else ("loopback", 0))
        self.fabric = fabric or RDMA_100G
        self.timeout_s = timeout_s
        self.verbs: Counter = Counter()
        self.totals = _fresh_totals()
        # measured wire traffic (frame headers counted separately from
        # payloads so the model cross-check sees pure data bytes); the
        # dict is shared by reference with the bearer, which owns the
        # frame/byte counters
        self.wire = {"frames_tx": 0, "frames_rx": 0,
                     "bytes_tx": 0, "bytes_rx": 0,
                     "payload_by_verb": {}, "model_by_verb": {},
                     "frames_by_verb": {}, "wire_s": {},
                     "inflight_peak": 0}
        self._lock = threading.Lock()
        self._server_trace = False
        self.attached_via = "upload"
        if bearer == "tcp":
            try:
                self._bearer = TcpBearer(
                    self.endpoint, timeout_s=timeout_s,
                    connect_timeout_s=connect_timeout_s, counters=self.wire)
            except OSError as e:
                raise PoolUnavailableError(
                    f"pool server {self.endpoint} unreachable: {e}") from e
        else:
            # in-process MR host: the region is still populated through
            # the same ATTACH path (a deep copy of the mirror), so the
            # loopback pool exercises the full wire codec + MR
            # delegation stack the TCP bearer does
            from repro.net.server import HostRegion
            self._region = HostRegion()
            self._bearer = LoopbackBearer(self._region, counters=self.wire)
        self._qp = V.QueuePair(self._bearer)
        self.mrs = V.region_mrs(store.spec,
                                quant=store.qvec_buf is not None)
        self._probe_caps()
        # recovery handshake: a durable server that already holds a
        # region matching our mirror (it recovered from its data-dir)
        # does not need the multi-MB ATTACH re-upload
        if attach == "auto" and self._server_region_matches():
            self.attached_via = "recovered"
        else:
            self._attach()
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False

    # ------------------------------------------------------------ transport

    def _fail(self, e: Exception):
        self.close()
        raise PoolUnavailableError(
            f"pool server {self.endpoint} unavailable: {e}") from e

    def close(self) -> None:
        """Drop the connection (idempotent); the server keeps running."""
        b = getattr(self, "_bearer", None)
        if b is not None and not b.closed:
            b.close()

    def __del__(self):  # pragma: no cover - GC cleanup only
        try:
            self.close()
        except Exception:
            pass

    def _probe_caps(self) -> None:
        """One PING at connect: a server that understands the
        trace-context prefix acks with FLAG_TRACE on the response; the
        prefix is only ever sent to servers that acked (old servers are
        never shown bytes they would mis-decode)."""
        if self._bearer.closed:
            return
        with self._lock:
            try:
                self._bearer.submit(W.OP_PING, b"")
                rop, rflags, _ = self._bearer.complete()
                if rop != W.OP_PING:
                    raise ConnectionError("bad ping response")
            except (ConnectionError, socket.timeout, OSError) as e:
                self._fail(e)
        self._server_trace = bool(rflags & W.FLAG_TRACE)

    def _exchange(self, wr_lists, *, verb: str, decode=None):
        """Pipelined doorbell rounds through the queue pair.

        Every WR list is posted up front (one ``post_send`` == one
        doorbell batch == one frame == one counted trip), then
        completions are polled one at a time — so ``decode(i, payload)``
        for round ``i`` runs while round ``i+1``'s response is still in
        flight (double-buffered submission; ``wire["inflight_peak"]``
        records the deepest pipeline seen).

        With tracing enabled the whole exchange is one ``net.<verb>``
        span, and (when the server acked FLAG_TRACE at connect) each
        frame carries that span's trace context OUTSIDE the verb
        payload: ledger charges use response payloads and the modeled
        write bytes, so accounting is bit-identical with tracing on or
        off.

        A remote verb error surfaces as an error completion; the
        remaining completions are still drained (leaving them queued
        would desynchronize every later verb) and the first error is
        raised as ``RuntimeError`` after the drain.  Transport errors
        close the bearer and raise ``PoolUnavailableError``."""
        if self._bearer.closed:
            raise PoolUnavailableError(
                f"pool server {self.endpoint} connection closed")
        t0 = time.perf_counter()
        with TRACER.span("net." + verb, tier="net", frames=len(wr_lists),
                         endpoint=f"{self.endpoint[0]}:{self.endpoint[1]}") \
                as vspan:
            prefix = b""
            if TRACER.enabled and self._server_trace:
                prefix = W.enc_trace_ctx(TRACER.trace_id,
                                         getattr(vspan, "span_id", 0))
            with self._lock:
                try:
                    with TRACER.span("net.encode", tier="net"):
                        for wrs in wr_lists:
                            self._qp.post_send(wrs, prefix=prefix)
                    self.wire["inflight_peak"] = max(
                        self.wire["inflight_peak"], len(wr_lists))
                    outs, error = [], None
                    with TRACER.span("net.wire", tier="net"):
                        for i in range(len(wr_lists)):
                            comp = self._qp.cq.poll(1)[0]
                            if comp.status != V.WC_SUCCESS:
                                if error is None:
                                    error = comp.error
                                outs.append(comp.data)
                            elif decode is not None and error is None:
                                outs.append(decode(i, comp.data))
                            else:
                                outs.append(comp.data)
                        if error is not None:
                            raise RuntimeError(f"pool server error: {error}")
                except (ConnectionError, socket.timeout, OSError) as e:
                    self._fail(e)
        dt = time.perf_counter() - t0
        self.wire["wire_s"][verb] = (self.wire["wire_s"].get(verb, 0.0)
                                     + dt)
        self.wire["frames_by_verb"][verb] = (
            self.wire["frames_by_verb"].get(verb, 0) + len(wr_lists))
        # measured post->poll seconds into the per-(verb, shard) latency
        # histogram — the real-wire twin of the simulated transports'
        # modeled dt (protocol._charge records those)
        self._observe(verb, dt)
        return outs

    def _rpc(self, op, payload=b"", *, flags=0, verb="misc"):
        """Control-plane round trip: one two-sided SEND work request."""
        return self._exchange([[V.send_wr(op, payload, flags=flags)]],
                              verb=verb)[0]

    def _note(self, verb: str, measured: int, modeled: float) -> None:
        w = self.wire
        w["payload_by_verb"][verb] = (w["payload_by_verb"].get(verb, 0)
                                      + measured)
        w["model_by_verb"][verb] = (w["model_by_verb"].get(verb, 0.0)
                                    + modeled)

    def model_dt(self, n_bytes: float, descriptors: float,
                 trips: float) -> float:
        """Modeled seconds of one charge slice — lets ShardedPool's
        placement policies rank remote shards like simulated ones."""
        f = self.fabric
        return (trips * f.rtt_s + descriptors * f.per_op_s
                + n_bytes / f.bw_Bps)

    # ------------------------------------------------------------ staging

    def _local_fingerprint(self) -> dict:
        """Mirror-side twin of ``HostRegion.fingerprint`` (same CRC)."""
        st = self.store
        crc = zlib.crc32(st.meta_table.tobytes())
        crc = zlib.crc32(st.n_base.tobytes(), crc)
        return {"n_blocks": int(st.spec.n_blocks),
                "n_partitions": int(st.spec.n_partitions),
                "n_base": int(st.n_base.sum()), "crc": int(crc)}

    def _server_region_matches(self) -> bool:
        """Recovery handshake: does the server already hold our region?

        True only when the server advertises a fingerprint equal to the
        local mirror's AND (if the mirror carries a quantized tier) the
        recovered region carries one too.
        """
        st = self.server_stats()
        if not st.get("attached"):
            return False
        if st.get("region_fingerprint") != self._local_fingerprint():
            return False
        if self.store.qvec_buf is not None and not st.get("quant_attached"):
            return False
        return True

    def _attach(self) -> None:
        payload, flags = W.enc_attach(self.store)
        self._rpc(W.OP_ATTACH, payload, flags=flags, verb="attach")
        self._note("attach", len(payload), 0.0)

    def adopt(self, store: Store) -> None:
        """See ``MemoryPool.adopt``; re-uploads the full region and
        re-registers the client-side MR table against the new spec."""
        self.store = store
        self.mrs = V.region_mrs(store.spec,
                                quant=store.qvec_buf is not None)
        self._attach()
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False

    def attach_quant(self, group: int) -> None:
        """See ``MemoryPool.attach_quant``; uploads the mirror and
        registers the quant-row MR."""
        LA.attach_quant_mirror(self.store, group)
        self.mrs = V.region_mrs(self.spec, quant=True)
        self._stage_quant()

    def _stage_quant(self) -> None:
        """Ship the (already attached) host mirror to the server — the
        hook a sharded parent calls on every child after attaching the
        mirror once on the shared host store."""
        payload = W.enc_attach_quant(self.store)
        self._rpc(W.OP_ATTACH_QUANT, payload, verb="attach")
        self._note("attach", len(payload), 0.0)

    def _write_blocks(self, block_ids, verb: str) -> int:
        """Block-granular region write as one doorbell batch: a WRITE
        descriptor per block (addr = block id, len = block bytes) closed
        by a WRITE_WITH_IMM carrying the serialized payload + metadata
        table, IMM = block count.  Returns the payload bytes shipped."""
        payload, flags = W.enc_write_blocks(self.store, block_ids)
        ids = np.asarray(block_ids, np.int64).reshape(-1)
        bb = self.spec.block_bytes()
        wrs = [V.write_wr(V.RKEY_REGION, b, length=bb) for b in ids]
        wrs.append(V.write_imm_wr(V.RKEY_REGION, 0, payload, len(ids),
                                  flags=flags))
        self._exchange([wrs], verb=verb)
        return len(payload)

    def refresh_blocks(self, block_ids) -> None:
        """Migration landing on this node: ship the group's blocks (and
        the metadata table, so the destination's overflow counters match
        the sender's) from the host region."""
        shipped = self._write_blocks(block_ids, "migrate")
        self._note("migrate", shipped, 0.0)

    # ------------------------------------------------------------ reads

    # read_meta is the shared MemoryPool implementation: the paper's
    # cached global metadata block is the client mirror — never a wire
    # round trip

    def server_meta(self):
        """The server's own metadata table — a coherence probe for tests
        and tools, not part of the serve path."""
        payload = self._rpc(W.OP_READ_META, verb="read_meta")
        return W.dec_meta_resp(payload, self.spec.n_partitions)

    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """See ``MemoryPool.read_spans``; one doorbell batch is one
        request frame, and the measured response payload must equal the
        modeled ``span_wire_bytes`` charge (``wire_vs_model``)."""
        spec = self.spec
        pids = np.asarray(pids).reshape(-1)
        verb = "read_spans_quant" if quant else "read_spans"
        self.verbs[verb] += len(pids)
        per_bytes, per_desc = span_wire_bytes(spec, quant=quant,
                                              quant_graph=quant_graph)
        flags = ((W.FLAG_QUANT if quant else 0)
                 | (W.FLAG_GRAPH if quant and quant_graph else 0))
        chunks = doorbell_chunks(pids, doorbell) if len(pids) else []
        wr_lists = [[V.read_wr(V.RKEY_SPANS, p, per_bytes, flags=flags)
                     for p in db] for db in chunks]

        def dec(i, payload):
            db = chunks[i]
            measured = len(payload)
            self._note(verb, measured, len(db) * per_bytes)
            # the ledger is charged from the MEASURED response payload —
            # equal to the modeled bytes by protocol construction, which
            # wire_vs_model() verifies instead of assumes
            self._charge(verb, ledger, measured, per_desc * len(db))
            with TRACER.span("net.decode", tier="net", bytes=measured):
                return W.dec_spans_resp(spec, payload, m=len(db),
                                        quant=quant, graph=quant_graph)

        parts = (self._exchange(wr_lists, verb=verb, decode=dec)
                 if chunks else [])
        m = len(pids)
        if not quant:
            g = np.concatenate([p[0] for p in parts]) if parts else \
                np.zeros((0, spec.fetch_blocks, spec.gblk), np.int32)
            v = np.concatenate([p[1] for p in parts]) if parts else \
                np.zeros((0, spec.fetch_blocks, spec.vblk), np.float32)
            return jnp.asarray(g), jnp.asarray(v)
        qv = np.concatenate([p[0] for p in parts]) if parts else \
            np.zeros((0, spec.fetch_blocks, spec.vblk), np.int8)
        qs = np.concatenate([p[1] for p in parts]) if parts else \
            np.zeros((0, spec.fetch_blocks, spec.n_qgroups), np.float32)
        if quant_graph:
            g = np.concatenate([p[2] for p in parts]) if parts else \
                np.zeros((0, spec.fetch_blocks, spec.gblk), np.int32)
        else:
            tails = (np.concatenate([p[2] for p in parts]) if parts else
                     np.zeros((0, spec.np_max + spec.ov_cap), np.int32))
            g = W.rebuild_quant_gspans(
                spec, tails, W.span_sides(self.store.meta_table, pids))
        assert qv.shape[0] == m
        return jnp.asarray(g), jnp.asarray(qv), jnp.asarray(qs)

    def _fetch_rows(self, rows, rkey, unit_bytes, verb):
        """Deduplicated row fetch: one WR-list READ against the row MR
        moves each distinct region row once; the full (possibly
        duplicated / dead-lane) tensor is rebuilt client-side — same
        values ``LocalPool``'s device gather produces, minus the
        redundant wire bytes."""
        rows_h = np.asarray(rows)
        safe = np.maximum(rows_h.astype(np.int64), 0)
        uniq, inv = np.unique(safe, return_inverse=True)
        if uniq.size == 0:                 # nothing to fetch, no frame
            return rows_h, uniq, inv, b""
        wrs = [V.read_wr(rkey, r, unit_bytes) for r in uniq]
        payload = self._exchange([wrs], verb=verb)[0]
        return rows_h, uniq, inv, payload

    def read_rows(self, rows):
        """See ``MemoryPool.read_rows``; unique rows cross the wire once
        (``n_uniq * row_bytes()``), duplicates rebuilt client-side."""
        self.verbs["read_rows"] += 1
        spec = self.spec
        rows_h, uniq, inv, payload = self._fetch_rows(
            rows, V.RKEY_ROWS, spec.row_bytes(), "read_rows")
        self._note("read_rows", len(payload),
                   len(uniq) * spec.row_bytes())
        with TRACER.span("net.decode", tier="net", bytes=len(payload)):
            vrows = W.dec_rows_resp(payload, len(uniq), spec.dim)
        out = vrows[inv].reshape(rows_h.shape + (spec.dim,))
        return jnp.asarray(out)

    def read_quant_rows(self, rows):
        """See ``MemoryPool.read_quant_rows``; ships int8 codes + f32
        group scales per unique row."""
        self.verbs["read_quant_rows"] += 1
        spec = self.spec
        nq = spec.dim // spec.quant_group
        rows_h, uniq, inv, payload = self._fetch_rows(
            rows, V.RKEY_QROWS, spec.dim + nq * 4, "read_quant_rows")
        self._note("read_quant_rows", len(payload),
                   len(uniq) * (spec.dim + nq * 4))
        with TRACER.span("net.decode", tier="net", bytes=len(payload)):
            codes, scales = W.dec_quant_rows_resp(payload, len(uniq),
                                                  spec.dim,
                                                  spec.quant_group)
        codes = codes[inv].reshape(rows_h.shape + (spec.dim,))
        scales = scales[inv].reshape(rows_h.shape + (nq,))
        return jnp.asarray(codes), jnp.asarray(scales)

    # the post_* accounting verbs are the shared MemoryPool
    # implementations: they charge without moving data, so nothing
    # crosses the wire and the math is LocalPool's by construction

    # ------------------------------------------------------------ writes

    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """See ``MemoryPool.append``; charges the modeled write bytes
        while the wire carries the same payload + the 8-byte partition
        address, and asserts the server landed the identical slot."""
        spec = self.spec
        vec = np.asarray(vec, np.float32)
        # stage on the mirror first: a full overflow region is decided
        # locally (both sides run the same deterministic insert, so a
        # local -1 means the server would refuse too — no wasted trip)
        slot = LA.insert_vector(self.store, vec, int(gid), int(pid))
        if slot < 0:
            return slot
        codes = scales = None
        wire_model = spec.dim * 4 + 8
        if self.store.qvec_buf is not None:
            from repro.quant.codec import quantize_groups
            codes, scales = quantize_groups(vec, spec.quant_group)
            wire_model += spec.dim + (spec.dim // spec.quant_group) * 4
            group = int(self.store.meta_table[pid, LA.MT_GROUP])
            co = LA.overflow_write_coords(spec, group, slot)
            LA.refresh_quant_blocks(self.store, [co["vec_block"]])
        payload, flags = W.enc_append(vec, int(gid), int(pid), codes, scales)
        # one-sided WRITE_WITH_IMM into the shared overflow MR: the
        # descriptor names the partition address, the immediate carries
        # the gid the passive side is notified with
        wrs = [V.write_imm_wr(V.RKEY_OVERFLOW, pid, payload, gid,
                              flags=flags)]
        resp = self._exchange([wrs], verb="append")[0]
        rslot = W.dec_append_resp(resp)
        if rslot != slot:
            raise RuntimeError(
                f"remote region diverged: append slot {rslot} != "
                f"mirror slot {slot} (pid {pid})")
        self.verbs["append"] += 1
        self._note("append", len(payload), wire_model)
        self._charge_write("append", ledger, wire_model)
        self._mt_dirty = True
        self._notify_mutation("append",
                              group=int(self.store.meta_table[
                                  pid, LA.MT_GROUP]),
                              pid=int(pid), slot=int(slot))
        return slot

    def repack(self, group: int, data_lookup) -> bool:
        """Offline re-pack: rebuild on the compute side (it owns the
        vectors), then WRITE the rewritten group region to the server in
        one block-granular frame."""
        self.verbs["repack"] += 1
        ok = LA.repack_group(self.store, group, data_lookup)
        if not ok:
            return False
        LA.refresh_quant_group(self.store, group)
        spec = self.spec
        blocks = np.arange(group * spec.group_blocks,
                           (group + 1) * spec.group_blocks)
        shipped = self._write_blocks(blocks, "repack")
        self._note("repack", shipped, 0.0)
        self._mt_dirty = True
        self._notify_mutation("repack", group=int(group))
        return True

    # ------------------------------------------------------------ stats

    def wire_vs_model(self) -> dict:
        """Measured payload bytes vs the Fabric model's priced bytes,
        per data verb.  Span verbs must match exactly (the conformance
        suite asserts it); row verbs may exceed the model by exactly the
        rows the compute-side residency policy counts as free."""
        out = {}
        for verb, measured in self.wire["payload_by_verb"].items():
            modeled = self.wire["model_by_verb"].get(verb, 0.0)
            if not modeled:
                continue
            out[verb] = {"measured": int(measured),
                         "modeled": float(modeled),
                         "ratio": measured / modeled}
        return out

    def server_stats(self, *, drain_trace: bool = False) -> dict:
        """The server process's own counters (one wire round trip).

        ``drain_trace=True`` asks the server to include (and drain) its
        buffered service-time trace spans; old servers ignore the
        request payload, so the key is simply absent."""
        payload = (W.enc_json({"drain_trace": True}) if drain_trace
                   else b"")
        return W.dec_json(self._rpc(W.OP_STATS, payload, verb="stats"))

    def harvest_trace(self) -> int:
        """Drain the server's service-time spans into the local tracer.

        Each harvested span is stitched under the client-side
        ``net.<verb>`` span whose trace context the request carried
        (clocks differ across processes, so the span is re-based to sit
        centered inside its parent — durations are authoritative, wall
        positions are presentational).  Returns the number of spans
        adopted; 0 when tracing is off or the server never acked
        FLAG_TRACE."""
        if not (TRACER.enabled and self._server_trace):
            return 0
        stats = self.server_stats(drain_trace=True)
        ep = f"{self.endpoint[0]}:{self.endpoint[1]}"
        n = 0
        for s in stats.get("trace_spans", ()):
            if int(s.get("trace", 0)) != TRACER.trace_id:
                continue
            parent_id = int(s.get("parent", 0))
            dur = float(s["dur"])
            parent = TRACER.find(parent_id)
            if parent is not None:
                t0 = parent["t0"] + max(parent["dur"] - dur, 0.0) / 2
            else:
                t0 = float(s["t0"])
            TRACER.add_span("server." + s["op"], "server", t0, dur,
                            parent_id=parent_id,
                            attrs={"seq": int(s.get("seq", 0)),
                                   "rx": int(s.get("rx", 0)),
                                   "tx": int(s.get("tx", 0)),
                                   "endpoint": ep, "clock": "server"})
            n += 1
        return n

    def shutdown_server(self) -> None:
        """Ask the server process to exit (harness teardown helper)."""
        try:
            self._rpc(W.OP_SHUTDOWN, verb="shutdown")
        except PoolUnavailableError:
            pass

    def snapshot(self) -> dict:
        """See ``MemoryPool.snapshot``; adds endpoint, fabric, measured
        wire counters, and the wire-vs-model cross-check."""
        from repro.pool.sim_rdma import fabric_params
        out = super().snapshot()
        out["endpoint"] = f"{self.endpoint[0]}:{self.endpoint[1]}"
        out["bearer"] = self.bearer_kind
        out["fabric"] = fabric_params(self.fabric)   # same schema as sim
        out["wire"] = {k: (dict(v) if isinstance(v, dict) else v)
                       for k, v in self.wire.items()}
        out["wire_vs_model"] = self.wire_vs_model()
        out["attached_via"] = self.attached_via
        return out
