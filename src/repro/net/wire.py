"""Wire protocol for the MemoryPool verbs — compact binary framing.

Every verb of ``pool/protocol.py`` has a frame: a fixed 20-byte header
(magic, version, opcode, flags, sequence number, payload length) followed
by a verb-specific payload of contiguous numpy buffers.  Descriptor
batches are encoded as flat arrays — ONE doorbell batch is ONE request
frame, so measured frames map 1:1 onto the round trips the ``NetLedger``
model counts.

Payloads are sized so that the *data* verbs carry exactly the bytes the
cost model prices (``protocol.span_wire_bytes`` / ``LayoutSpec``):

* exact span response      — ``m * partition_bytes()`` (graph + vec
  blocks of each span, back to back);
* quantized span response  — ``m * quant_partition_bytes(include_graph)``
  (int8 codes + f32 codebook blocks, plus either the full graph blocks
  or, in scan mode, only the global-id tails: ``np_max + ov_cap`` int32
  per span — the only graph lanes the scan path reads; the client
  rebuilds the span around them, see ``rebuild_quant_gspans``);
* row response             — ``n_rows * row_bytes()``;
* append request           — vector + gid (+ int8 codes + codebook
  scales when the quantized mirror is attached) + the 8-byte partition
  address the WRITE names.

so the ``wire_vs_model`` cross-check in ``client.RemotePool`` can assert
measured-bytes == modeled-bytes instead of trusting the model.

Integers are little-endian; arrays are C-order raw bytes with dtypes
fixed by the protocol.  Decoders copy out of the receive buffer so the
returned arrays are owned and writable.
"""
from __future__ import annotations

import json
import struct
from typing import Optional

import numpy as np

from repro.core.layout import META_COLS, MT_SIDE, LayoutSpec, Store

MAGIC = b"dHNW"
VERSION = 1

# header: magic(4) version(1) opcode(1) flags(2) seq(4) payload_len(8)
HEADER = struct.Struct("<4sBBHIQ")
HEADER_BYTES = HEADER.size

# opcodes
OP_PING = 1
OP_ATTACH = 2            # upload a full region (build / adopt)
OP_ATTACH_QUANT = 3      # upload the int8 + codebook mirror
OP_READ_SPANS = 4
OP_READ_ROWS = 5
OP_READ_QUANT_ROWS = 6
OP_READ_META = 7
OP_APPEND = 8            # one-sided WRITE into a shared overflow region
OP_WRITE_BLOCKS = 9      # block-granular region write (repack / migration)
OP_STATS = 10
OP_SHUTDOWN = 11

OP_NAMES = {
    OP_PING: "ping", OP_ATTACH: "attach", OP_ATTACH_QUANT: "attach_quant",
    OP_READ_SPANS: "read_spans", OP_READ_ROWS: "read_rows",
    OP_READ_QUANT_ROWS: "read_quant_rows", OP_READ_META: "read_meta",
    OP_APPEND: "append", OP_WRITE_BLOCKS: "write_blocks",
    OP_STATS: "stats", OP_SHUTDOWN: "shutdown",
}

# flags
FLAG_QUANT = 0x0001      # span/append verbs: quantized mirror involved
FLAG_GRAPH = 0x0002      # quant spans: include the full graph blocks
FLAG_HAS_QUANT = 0x0004  # attach/write_blocks payload carries the mirror
FLAG_TRACE = 0x0008      # request: payload starts with a trace-context
                         # prefix (see enc_trace_ctx); on a PING response
                         # it advertises that the server understands the
                         # prefix (capability negotiation — clients never
                         # send the prefix to servers that did not ack,
                         # so old servers stay byte-compatible)
FLAG_ERROR = 0x8000      # response: payload is a utf-8 error message

_MAX_PAYLOAD = 1 << 36   # decode sanity bound (64 GiB)


class WireError(ValueError):
    """Malformed frame or payload."""


def pack_frame(op: int, payload: bytes = b"", *, flags: int = 0,
               seq: int = 0) -> bytes:
    """Header (20 B) + payload; the unit every byte counter sees."""
    return HEADER.pack(MAGIC, VERSION, op, flags, seq & 0xFFFFFFFF,
                       len(payload)) + payload


def unpack_header(buf: bytes):
    """-> (op, flags, seq, payload_len).  Raises WireError on garbage."""
    if len(buf) != HEADER_BYTES:
        raise WireError(f"short header: {len(buf)} bytes")
    magic, ver, op, flags, seq, length = HEADER.unpack(buf)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r}")
    if ver != VERSION:
        raise WireError(f"protocol version {ver} != {VERSION}")
    if length > _MAX_PAYLOAD:
        raise WireError(f"payload length {length} over bound")
    return op, flags, seq, length


# --------------------------------------------------------- trace context

# two 8-byte ids (trace id, parent span id) prepended to a request
# payload when FLAG_TRACE is set; the server strips the prefix before
# decoding the verb payload and tags its service-time span with the ids
_TRACE_CTX = struct.Struct("<QQ")
TRACE_CTX_BYTES = _TRACE_CTX.size


def enc_trace_ctx(trace_id: int, span_id: int) -> bytes:
    """Encode the 16-byte FLAG_TRACE request-payload prefix."""
    return _TRACE_CTX.pack(trace_id & 0xFFFFFFFFFFFFFFFF,
                           span_id & 0xFFFFFFFFFFFFFFFF)


def dec_trace_ctx(payload: bytes):
    """Strip the prefix -> ``((trace_id, span_id), verb_payload)``."""
    if len(payload) < TRACE_CTX_BYTES:
        raise WireError("short trace-context prefix")
    tid, sid = _TRACE_CTX.unpack_from(payload, 0)
    return (tid, sid), payload[TRACE_CTX_BYTES:]


# --------------------------------------------------------------- helpers

def _take(payload: bytes, off: int, dtype, shape):
    """Copy one array out of ``payload`` at ``off`` -> (arr, new_off)."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    arr = np.frombuffer(payload, dtype=dtype, count=n, offset=off)
    itemsize = np.dtype(dtype).itemsize
    return arr.reshape(shape).copy(), off + n * itemsize


def _b(arr, dtype) -> bytes:
    return np.ascontiguousarray(arr, dtype=dtype).tobytes()


_SPEC = struct.Struct("<7q")


def enc_spec(spec: LayoutSpec) -> bytes:
    """LayoutSpec as seven little-endian i64 (56 B, fixed)."""
    return _SPEC.pack(spec.dim, spec.deg, spec.np_max, spec.ov_cap,
                      spec.slot_vecs, spec.n_partitions, spec.quant_group)


def dec_spec(payload: bytes, off: int = 0):
    """-> (LayoutSpec, new_off); inverse of ``enc_spec``."""
    vals = _SPEC.unpack_from(payload, off)
    spec = LayoutSpec(dim=vals[0], deg=vals[1], np_max=vals[2],
                      ov_cap=vals[3], slot_vecs=vals[4], n_partitions=vals[5],
                      quant_group=vals[6])
    return spec, off + _SPEC.size


# --------------------------------------------------------------- attach

def enc_attach(store: Store):
    """Full region upload -> (payload, flags)."""
    spec = store.spec
    parts = [enc_spec(spec), _b(store.n_base, np.int32),
             _b(store.meta_table, np.int32), _b(store.graph_buf, np.int32),
             _b(store.vec_buf, np.float32)]
    flags = 0
    if store.qvec_buf is not None:
        flags |= FLAG_HAS_QUANT
        parts += [_b(store.qvec_buf, np.int8),
                  _b(store.qscale_buf, np.float32)]
    return b"".join(parts), flags


def dec_attach(payload: bytes, flags: int) -> Store:
    """Rebuild a full owned ``Store`` from an attach payload."""
    spec, off = dec_spec(payload)
    P, nb = spec.n_partitions, spec.n_blocks
    n_base, off = _take(payload, off, np.int32, (P,))
    meta, off = _take(payload, off, np.int32, (P, META_COLS))
    graph, off = _take(payload, off, np.int32, (nb, spec.gblk))
    vec, off = _take(payload, off, np.float32, (nb, spec.vblk))
    qv = qs = None
    if flags & FLAG_HAS_QUANT:
        qv, off = _take(payload, off, np.int8, (nb, spec.vblk))
        qs, off = _take(payload, off, np.float32, (nb, spec.n_qgroups))
    if off != len(payload):
        raise WireError(f"attach payload trailing {len(payload) - off} B")
    return Store(spec=spec, graph_buf=graph, vec_buf=vec, meta_table=meta,
                 n_base=n_base, qvec_buf=qv, qscale_buf=qs)


def enc_attach_quant(store: Store) -> bytes:
    """Quantized-mirror upload: spec + int8 codes + f32 codebooks."""
    return b"".join([enc_spec(store.spec), _b(store.qvec_buf, np.int8),
                     _b(store.qscale_buf, np.float32)])


def dec_attach_quant(payload: bytes):
    """-> (spec, qvec_buf, qscale_buf)."""
    spec, off = dec_spec(payload)
    qv, off = _take(payload, off, np.int8, (spec.n_blocks, spec.vblk))
    qs, off = _take(payload, off, np.float32,
                    (spec.n_blocks, spec.n_qgroups))
    if off != len(payload):
        raise WireError("attach_quant payload size mismatch")
    return spec, qv, qs


# ---------------------------------------------------------------- spans

def enc_pids(pids) -> bytes:
    """One descriptor batch: u32 count + i64 partition ids."""
    pids = np.asarray(pids, np.int64).reshape(-1)
    return struct.pack("<I", len(pids)) + _b(pids, np.int64)


def dec_pids(payload: bytes) -> np.ndarray:
    """Inverse of ``enc_pids`` -> i64 partition ids."""
    (n,) = struct.unpack_from("<I", payload, 0)
    arr, off = _take(payload, 4, np.int64, (n,))
    if off != len(payload):
        raise WireError("pid batch size mismatch")
    return arr


def gid_tail_offsets(spec: LayoutSpec, side: int):
    """Flat offsets of the two global-id runs inside one span's graph
    blocks (``fetch_blocks * gblk`` int32): the base-gid tail of the data
    region and the overflow gid run — the only graph lanes the scan-mode
    quant path reads (``device_store.decode_quant_span``)."""
    data_off = side * spec.ov_blocks * spec.gblk + spec.np_max * spec.deg
    ov_off = (1 - side) * spec.data_blocks * spec.gblk
    return data_off, ov_off


def extract_gid_tails(spec: LayoutSpec, g_spans: np.ndarray,
                      sides) -> np.ndarray:
    """(m, fetch_blocks, gblk) graph spans -> (m, np_max + ov_cap) i32."""
    m = g_spans.shape[0]
    out = np.empty((m, spec.np_max + spec.ov_cap), np.int32)
    flat = g_spans.reshape(m, -1)
    for i in range(m):
        d, o = gid_tail_offsets(spec, int(sides[i]))
        out[i, :spec.np_max] = flat[i, d:d + spec.np_max]
        out[i, spec.np_max:] = flat[i, o:o + spec.ov_cap]
    return out


def rebuild_quant_gspans(spec: LayoutSpec, tails: np.ndarray,
                         sides) -> np.ndarray:
    """Inverse of ``extract_gid_tails``: scatter the id runs back into
    -1-filled graph spans.  Adjacency lanes are NOT reconstructed (the
    scan path never reads them); graph-mode quant fetches ship the full
    blocks instead (FLAG_GRAPH)."""
    m = tails.shape[0]
    flat = np.full((m, spec.fetch_blocks * spec.gblk), -1, np.int32)
    for i in range(m):
        d, o = gid_tail_offsets(spec, int(sides[i]))
        flat[i, d:d + spec.np_max] = tails[i, :spec.np_max]
        flat[i, o:o + spec.ov_cap] = tails[i, spec.np_max:]
    return flat.reshape(m, spec.fetch_blocks, spec.gblk)


def enc_spans_resp(spec: LayoutSpec, *, quant: bool, graph: bool = True,
                   g: Optional[np.ndarray] = None,
                   v: Optional[np.ndarray] = None,
                   qv: Optional[np.ndarray] = None,
                   qs: Optional[np.ndarray] = None,
                   tails: Optional[np.ndarray] = None) -> bytes:
    """Span READ response; payload bytes == the modeled span bytes."""
    if not quant:
        return _b(g, np.int32) + _b(v, np.float32)
    parts = [_b(qv, np.int8), _b(qs, np.float32)]
    parts.append(_b(g, np.int32) if graph else _b(tails, np.int32))
    return b"".join(parts)


def dec_spans_resp(spec: LayoutSpec, payload: bytes, *, m: int, quant: bool,
                   graph: bool = True):
    """-> (g, v) exact | (qv, qs, g) quant+graph | (qv, qs, tails)."""
    fb = spec.fetch_blocks
    off = 0
    if not quant:
        g, off = _take(payload, off, np.int32, (m, fb, spec.gblk))
        v, off = _take(payload, off, np.float32, (m, fb, spec.vblk))
        if off != len(payload):
            raise WireError("span response size mismatch")
        return g, v
    qv, off = _take(payload, off, np.int8, (m, fb, spec.vblk))
    qs, off = _take(payload, off, np.float32, (m, fb, spec.n_qgroups))
    if graph:
        g, off = _take(payload, off, np.int32, (m, fb, spec.gblk))
        tail = g
    else:
        tail, off = _take(payload, off, np.int32,
                          (m, spec.np_max + spec.ov_cap))
    if off != len(payload):
        raise WireError("quant span response size mismatch")
    return qv, qs, tail


# ----------------------------------------------------------------- rows

def enc_rows(rows) -> bytes:
    """Row-READ descriptor batch: u32 count + i64 row addresses."""
    rows = np.asarray(rows, np.int64).reshape(-1)
    return struct.pack("<I", len(rows)) + _b(rows, np.int64)


dec_rows = dec_pids      # identical encoding: u32 count + i64 addresses


def enc_rows_resp(vrows: np.ndarray) -> bytes:
    """Row READ response: exactly ``n_rows * row_bytes()`` f32."""
    return _b(vrows, np.float32)


def dec_rows_resp(payload: bytes, n: int, dim: int) -> np.ndarray:
    """-> (n, dim) f32 rows; inverse of ``enc_rows_resp``."""
    arr, off = _take(payload, 0, np.float32, (n, dim))
    if off != len(payload):
        raise WireError("rows response size mismatch")
    return arr


def enc_quant_rows_resp(codes: np.ndarray, scales: np.ndarray) -> bytes:
    """Quant row response: int8 codes + f32 group scales, the modeled
    ``quant_row_bytes()`` per row."""
    return _b(codes, np.int8) + _b(scales, np.float32)


def dec_quant_rows_resp(payload: bytes, n: int, dim: int, group: int):
    """-> (codes (n, dim) i8, scales (n, dim/group) f32)."""
    codes, off = _take(payload, 0, np.int8, (n, dim))
    scales, off = _take(payload, off, np.float32, (n, dim // group))
    if off != len(payload):
        raise WireError("quant rows response size mismatch")
    return codes, scales


# --------------------------------------------------------------- append

_APPEND_HDR = struct.Struct("<qq")   # gid, pid


def enc_append(vec: np.ndarray, gid: int, pid: int,
               codes: Optional[np.ndarray] = None,
               scales: Optional[np.ndarray] = None):
    """One-sided WRITE -> (payload, flags).  Payload = the modeled wire
    bytes (vec + 8B id [+ codes + codebook scales]) plus the 8-byte
    partition address the descriptor names."""
    parts = [_APPEND_HDR.pack(gid, pid), _b(vec, np.float32)]
    flags = 0
    if codes is not None:
        flags |= FLAG_QUANT
        parts += [_b(codes, np.int8), _b(scales, np.float32)]
    return b"".join(parts), flags


def dec_append(payload: bytes, flags: int, dim: int, group: int):
    """-> (vec, gid, pid, codes | None, scales | None)."""
    gid, pid = _APPEND_HDR.unpack_from(payload, 0)
    off = _APPEND_HDR.size
    vec, off = _take(payload, off, np.float32, (dim,))
    codes = scales = None
    if flags & FLAG_QUANT:
        codes, off = _take(payload, off, np.int8, (dim,))
        scales, off = _take(payload, off, np.float32, (dim // group,))
    if off != len(payload):
        raise WireError("append payload size mismatch")
    return vec, int(gid), int(pid), codes, scales


def enc_append_resp(slot: int) -> bytes:
    """Append acknowledgment: the i64 overflow slot the WRITE landed in."""
    return struct.pack("<q", slot)


def dec_append_resp(payload: bytes) -> int:
    """-> overflow slot index from an append response."""
    return struct.unpack("<q", payload)[0]


# --------------------------------------------------- block writes / meta

def enc_write_blocks(store: Store, block_ids):
    """Block-granular region WRITE (repack result / migration landing):
    block ids + their graph/vec (+ mirror) bytes + the metadata table, so
    the receiving node's counters stay coherent with the sender's."""
    ids = np.asarray(block_ids, np.int64).reshape(-1)
    parts = [struct.pack("<I", len(ids)), _b(ids, np.int64),
             _b(store.graph_buf[ids], np.int32),
             _b(store.vec_buf[ids], np.float32)]
    flags = 0
    if store.qvec_buf is not None:
        flags |= FLAG_HAS_QUANT
        parts += [_b(store.qvec_buf[ids], np.int8),
                  _b(store.qscale_buf[ids], np.float32)]
    parts += [_b(store.n_base, np.int32), _b(store.meta_table, np.int32)]
    return b"".join(parts), flags


def dec_write_blocks(payload: bytes, flags: int, spec: LayoutSpec):
    """-> dict(ids, g, v, qv, qs, n_base, meta)."""
    (n,) = struct.unpack_from("<I", payload, 0)
    off = 4
    ids, off = _take(payload, off, np.int64, (n,))
    g, off = _take(payload, off, np.int32, (n, spec.gblk))
    v, off = _take(payload, off, np.float32, (n, spec.vblk))
    qv = qs = None
    if flags & FLAG_HAS_QUANT:
        qv, off = _take(payload, off, np.int8, (n, spec.vblk))
        qs, off = _take(payload, off, np.float32, (n, spec.n_qgroups))
    P = spec.n_partitions
    n_base, off = _take(payload, off, np.int32, (P,))
    meta, off = _take(payload, off, np.int32, (P, META_COLS))
    if off != len(payload):
        raise WireError("write_blocks payload size mismatch")
    return {"ids": ids, "g": g, "v": v, "qv": qv, "qs": qs,
            "n_base": n_base, "meta": meta}


def enc_meta_resp(store: Store) -> bytes:
    """Metadata READ response: the full meta table + per-partition base
    counts (the client refreshes its cached copy wholesale)."""
    return _b(store.meta_table, np.int32) + _b(store.n_base, np.int32)


def dec_meta_resp(payload: bytes, n_partitions: int):
    """-> (meta_table, n_base); inverse of ``enc_meta_resp``."""
    meta, off = _take(payload, 0, np.int32, (n_partitions, META_COLS))
    n_base, off = _take(payload, off, np.int32, (n_partitions,))
    if off != len(payload):
        raise WireError("meta response size mismatch")
    return meta, n_base


# ---------------------------------------------------------- json / misc

def enc_json(obj) -> bytes:
    """Control-plane payload (stats/errors): utf-8 JSON, never priced."""
    return json.dumps(obj).encode("utf-8")


def dec_json(payload: bytes):
    """Inverse of ``enc_json``."""
    return json.loads(payload.decode("utf-8"))


def span_sides(meta_table: np.ndarray, pids) -> np.ndarray:
    """Per-span MT_SIDE lookup shared by the two tail codecs' callers."""
    return meta_table[np.asarray(pids, np.int64), MT_SIDE]


# ------------------------------------------------------- socket helpers

def recv_exact(sock, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ConnectionError (clean EOF
    included — a vanished peer must never look like a short frame)."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ConnectionError("connection closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock, op: int, payload: bytes = b"", *, flags: int = 0,
               seq: int = 0) -> int:
    """Pack + sendall one frame -> total bytes written (header included),
    which is what the ``bytes_tx`` wire counter records."""
    buf = pack_frame(op, payload, flags=flags, seq=seq)
    sock.sendall(buf)
    return len(buf)


def recv_frame(sock):
    """-> (op, flags, seq, payload)."""
    op, flags, seq, length = unpack_header(recv_exact(sock, HEADER_BYTES))
    payload = recv_exact(sock, length) if length else b""
    return op, flags, seq, payload
