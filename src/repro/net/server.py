"""PoolServer — a standalone memory-pool node process.

Hosts one serialized region (``core/layout.Store``, host numpy buffers)
and serves every ``MemoryPool`` verb over TCP using the ``wire.py``
framing.  The data plane is deliberately jax-free AND verb-free on the
read side: the region is *registered* as a set of memory-region windows
(``repro.rdma.mr.host_mrs`` — span / row / quant-row numpy views keyed
by rkey), and a read frame is answered by delegating the address batch
to the MR its opcode names — one generic dispatch line per read opcode,
no per-verb server logic, exactly like the paper's passive memory nodes
that own bytes and nothing else.  Appends are ``layout.insert_vector``
host writes; the *compute* side (RemotePool's caller) owns all device
work.

Run standalone:

    python -m repro.net.server --port 0        # auto-pick, prints port

or embed (``PoolServer(region=...).start()``) — tests and benchmarks use
``spawn_pool_servers(n)`` to fork n loopback servers and tear them down
with a timeout.

Concurrency: a threaded accept loop, one handler thread per connection,
requests on a connection answered strictly in order (the client
pipelines doorbell batches by writing k frames before reading k
responses).  A region-wide lock serializes verb bodies — the region is
the shared state, and numpy gathers are fast enough that per-verb
locking is not the bottleneck at this scale.

The server starts EMPTY: a client uploads the region with an ATTACH
frame (the offline "load the index into the memory pool" step; repeated
ATTACH replaces the region — one region per server).  ``--demo-n``
pre-builds a synthetic region (seeded by ``--seed``) for standalone
poking without a client build.

Durability (``--data-dir``): every mutating verb is appended to a WAL
before its ack and the region is checkpointed on a cadence
(``repro.ingest``); on restart the server recovers checkpoint + WAL
tail and resumes serving the identical region — memory-pool state now
survives the process, so failover can rejoin a recovered server instead
of re-replicating from the host region.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import socket
import subprocess
import sys
import threading
import time
import zlib
from collections import Counter, deque

import numpy as np

from repro.core import layout as LA
from repro.net import wire as W
from repro.rdma import mr as RM
from repro.rdma import verbs as V

#: verbs that change region state — exactly the set the WAL captures
MUTATING_OPS = frozenset({W.OP_ATTACH, W.OP_ATTACH_QUANT, W.OP_APPEND,
                          W.OP_WRITE_BLOCKS})


class HostRegion:
    """The server-side region + verb handlers (pure numpy)."""

    #: bound on buffered server-side trace spans (oldest dropped first)
    TRACE_CAP = 4096

    def __init__(self, store=None, durability=None):
        self.store = store
        self.durability = durability
        # registered memory regions: rkey -> numpy window onto the
        # store; read frames are answered by delegating to these, so
        # the server has no per-verb read logic.  MRs dereference
        # ``self.store`` per read — ATTACH replacement and in-place
        # mutation are both immediately visible.
        self.mrs = RM.host_mrs(self)
        self.lock = threading.RLock()
        self.verbs: Counter = Counter()
        self.payload_tx = 0      # response payload bytes served
        self.payload_rx = 0      # request payload bytes received
        self.t0 = time.time()
        # per-verb service time (seconds inside the verb body, always
        # on) and the service-time spans recorded for FLAG_TRACE
        # requests, drained by a stats({"drain_trace": true}) call
        self.service_s: Counter = Counter()
        # per-verb service-time histograms (mergeable log buckets) — the
        # server-side tail view a STATS drain ships to the compute node
        from repro.obs.hist import LatencyHistogram
        self.service_hist: dict = {}
        self._hist_cls = LatencyHistogram
        self.trace_spans: deque = deque(maxlen=self.TRACE_CAP)

    # ------------------------------------------------------------ durability

    def attach_durability(self, dur) -> None:
        """Recover from ``dur``'s data-dir and log all future mutations.

        Loads the checkpoint (if any), replays the committed WAL tail
        through the normal handler table (replay is never re-logged),
        and folds a non-empty tail into a fresh checkpoint so the next
        restart starts from a shorter log.
        """
        from repro.obs.trace import TRACER
        self.durability = dur
        store, tail = dur.recover()
        if store is not None:
            self.store = store
        if tail:
            t0 = time.perf_counter()
            with dur.replay_guard():
                for rec in tail:
                    self.handle(rec.op, rec.flags, rec.payload)
            if TRACER.enabled:
                TRACER.add("ingest.replay", "ingest", t0,
                           time.perf_counter() - t0, records=len(tail))
            if self.store is not None:
                dur.checkpoint(self.store)

    def fingerprint(self) -> dict:
        """Cheap region identity for the recovery handshake: geometry +
        a CRC over the metadata table and base counts (the mutable
        directory every verb goes through)."""
        st = self._require()
        crc = zlib.crc32(st.meta_table.tobytes())
        crc = zlib.crc32(st.n_base.tobytes(), crc)
        return {"n_blocks": int(st.spec.n_blocks),
                "n_partitions": int(st.spec.n_partitions),
                "n_base": int(st.n_base.sum()), "crc": int(crc)}

    # ------------------------------------------------------------ helpers

    def _require(self):
        if self.store is None:
            raise RuntimeError("no region attached")
        return self.store

    # ------------------------------------------------------------ verbs

    def attach(self, payload, flags):
        """Adopt a full uploaded region as this node's source of truth."""
        self.store = W.dec_attach(payload, flags)
        return b"", 0

    def attach_quant(self, payload, flags):
        """Adopt an uploaded int8 + codebook mirror of the region."""
        store = self._require()
        spec, qv, qs = W.dec_attach_quant(payload)
        if spec.quant_group != store.spec.quant_group:
            import dataclasses as DC
            store.spec = DC.replace(store.spec,
                                    quant_group=spec.quant_group)
        store.qvec_buf, store.qscale_buf = qv, qs
        return b"", 0

    def read_spans(self, payload, flags):
        """One-sided span READ: delegate to the registered span MR."""
        return self.mrs[V.RKEY_SPANS].read(payload, flags)

    def read_rows(self, payload, flags):
        """One-sided row READ: delegate to the registered row MR."""
        return self.mrs[V.RKEY_ROWS].read(payload, flags)

    def read_quant_rows(self, payload, flags):
        """One-sided quant-row READ: delegate to the mirror's row MR."""
        return self.mrs[V.RKEY_QROWS].read(payload, flags)

    def read_meta(self, payload, flags):
        """Ship the metadata table + base counts (client cache refresh)."""
        return W.enc_meta_resp(self._require()), 0

    def append(self, payload, flags):
        """Land a one-sided WRITE in the named partition's overflow
        region; replies with the slot so the client can cross-check its
        mirror ran the identical deterministic insert."""
        store = self._require()
        spec = store.spec
        vec, gid, pid, codes, scales = W.dec_append(
            payload, flags, spec.dim, spec.quant_group or 1)
        slot = LA.insert_vector(store, vec, gid, pid)
        if slot >= 0 and store.qvec_buf is not None:
            # mirror twin of the WRITE: the client shipped the quantized
            # row; a deterministic block refresh from the f32 region
            # yields the same bytes, which keeps both paths honest
            group = int(store.meta_table[pid, LA.MT_GROUP])
            co = LA.overflow_write_coords(spec, group, slot)
            LA.refresh_quant_blocks(store, [co["vec_block"]])
        return W.enc_append_resp(slot), 0

    def write_blocks(self, payload, flags):
        """Block-granular region WRITE (repack result / migration /
        replica sync): overwrite the named blocks + metadata."""
        store = self._require()
        upd = W.dec_write_blocks(payload, flags, store.spec)
        ids = upd["ids"]
        store.graph_buf[ids] = upd["g"]
        store.vec_buf[ids] = upd["v"]
        if upd["qv"] is not None:
            if store.qvec_buf is None:
                raise RuntimeError("mirror blocks for an unattached mirror")
            store.qvec_buf[ids] = upd["qv"]
            store.qscale_buf[ids] = upd["qs"]
        store.n_base[:] = upd["n_base"]
        store.meta_table[:] = upd["meta"]
        return b"", 0

    def stats(self, payload, flags):
        """Control-plane JSON: verb counts, payload totals, per-verb
        service seconds, region info.  A ``{"drain_trace": true}``
        request payload additionally returns (and drains) the buffered
        server-side trace spans — old servers ignore the payload, so the
        extension is backward-compatible in both directions."""
        req = {}
        if payload:
            try:
                req = W.dec_json(payload)
            except Exception:
                req = {}
        out = {"verbs": dict(self.verbs),
               "payload_tx": self.payload_tx,
               "payload_rx": self.payload_rx,
               "service_s": {k: float(v) for k, v in self.service_s.items()},
               "service_hist": {k: h.to_dict()
                                for k, h in sorted(self.service_hist.items())},
               "uptime_s": round(time.time() - self.t0, 3),
               "attached": self.store is not None}
        if self.store is not None:
            out["n_partitions"] = int(self.store.spec.n_partitions)
            out["region_bytes"] = int(self.store.total_bytes())
            out["quant_attached"] = self.store.qvec_buf is not None
            out["region_fingerprint"] = self.fingerprint()
        if self.durability is not None:
            out["ingest"] = self.durability.stats()
        if req.get("drain_trace"):
            out["trace_spans"] = list(self.trace_spans)
            self.trace_spans.clear()
        return W.enc_json(out), 0

    # ------------------------------------------------------------ dispatch

    HANDLERS = {
        W.OP_ATTACH: attach, W.OP_ATTACH_QUANT: attach_quant,
        W.OP_READ_SPANS: read_spans, W.OP_READ_ROWS: read_rows,
        W.OP_READ_QUANT_ROWS: read_quant_rows, W.OP_READ_META: read_meta,
        W.OP_APPEND: append, W.OP_WRITE_BLOCKS: write_blocks,
        W.OP_STATS: stats,
    }

    def handle(self, op: int, flags: int, payload: bytes, seq: int = 0):
        """One verb -> (response_payload, response_flags)."""
        tctx = None
        if flags & W.FLAG_TRACE:
            # strip the trace-context prefix before the verb decoder
            # sees the payload; the ids tag this verb's service span
            tctx, payload = W.dec_trace_ctx(payload)
            flags &= ~W.FLAG_TRACE
        if op == W.OP_PING:
            # ping response advertises trace-context support — clients
            # only ever send the prefix to servers that acked it here
            return payload, W.FLAG_TRACE
        fn = self.HANDLERS.get(op)
        if fn is None:
            raise RuntimeError(f"unknown opcode {op}")
        name = W.OP_NAMES.get(op, str(op))
        with self.lock:
            self.verbs[name] += 1
            self.payload_rx += len(payload)
            t0 = time.perf_counter()
            resp, rflags = fn(self, payload, flags)
            if (op in MUTATING_OPS and self.durability is not None
                    and not self.durability.replaying):
                # WAL before ack: the handler already mutated the
                # region, but the client only sees success once the
                # record is down; a crash in between replays it.
                self.durability.log(op, flags, payload)
                self.durability.maybe_checkpoint(self.store)
            dur = time.perf_counter() - t0
            self.service_s[name] += dur
            h = self.service_hist.get(name)
            if h is None:
                h = self.service_hist[name] = self._hist_cls()
            h.record(dur)
            self.payload_tx += len(resp)
            if tctx is not None:
                self.trace_spans.append(
                    {"op": name, "trace": int(tctx[0]),
                     "parent": int(tctx[1]), "seq": int(seq),
                     "t0": t0, "dur": dur,
                     "rx": len(payload), "tx": len(resp)})
            return resp, rflags


class PoolServer:
    """Threaded TCP front-end around one ``HostRegion``."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 region: HostRegion | None = None):
        self.region = region or HostRegion()
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(32)
        self.host, self.port = self._lsock.getsockname()[:2]
        self._stop = threading.Event()
        self._accept_thread: threading.Thread | None = None

    @property
    def endpoint(self) -> str:
        """``host:port`` actually bound (port 0 resolves at bind)."""
        return f"{self.host}:{self.port}"

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "PoolServer":
        """Serve in a daemon thread; returns self for chaining."""
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"poolserver-{self.port}")
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread until ``stop()`` (CLI mode)."""
        self._accept_loop()

    def stop(self) -> None:
        """Stop accepting and close the listener (idempotent)."""
        self._stop.set()
        with contextlib.suppress(OSError):
            self._lsock.close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ------------------------------------------------------------ serving

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                break                      # listener closed
            # daemon handler threads are not tracked: they exit with
            # their connection, and a long-lived server must not grow a
            # list entry per client that ever connected
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            while not self._stop.is_set():
                try:
                    op, flags, seq, payload = W.recv_frame(conn)
                except (ConnectionError, OSError, W.WireError):
                    return                 # client went away / garbage
                if op == W.OP_SHUTDOWN:
                    W.send_frame(conn, op, b"", seq=seq)
                    self.stop()
                    return
                try:
                    resp, rflags = self.region.handle(op, flags, payload,
                                                      seq)
                except Exception as e:     # verb error -> error frame
                    resp = str(e).encode("utf-8")
                    rflags = W.FLAG_ERROR
                try:
                    W.send_frame(conn, op, resp, flags=rflags, seq=seq)
                except (ConnectionError, OSError):
                    return
        finally:
            with contextlib.suppress(OSError):
                conn.close()


# ------------------------------------------------------------- harness

def _src_path() -> str:
    import repro
    # repro may be a namespace package (no __init__.py): use __path__
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else next(iter(repro.__path__)))
    return os.path.dirname(os.path.abspath(pkg_dir))


@contextlib.contextmanager
def spawn_pool_servers(n: int = 1, *, host: str = "127.0.0.1", seed: int = 0,
                       startup_timeout_s: float = 60.0, demo_n: int = 0,
                       with_procs: bool = False, data_dirs=None,
                       checkpoint_every: int = 0):
    """Fork ``n`` loopback pool-server processes; yield their endpoints.

    Each server binds ``--port 0`` (OS-assigned — no CI port clashes) and
    announces ``POOLSERVER LISTENING host port`` on stdout; teardown
    sends SIGTERM and escalates to SIGKILL after a timeout, so a hung
    server can never wedge a test run.

    ``with_procs=True`` yields ``(endpoints, procs)`` instead — the
    ``subprocess.Popen`` handles let chaos tests and benchmarks kill -9
    individual servers mid-run to exercise the failover path; teardown
    copes with already-dead processes.

    ``data_dirs`` (one directory per server) makes the servers durable:
    each runs with ``--data-dir`` (WAL + checkpoints, recovery on
    restart); ``checkpoint_every`` overrides the snapshot cadence.
    """
    assert data_dirs is None or len(data_dirs) == n, data_dirs
    env = os.environ.copy()
    src = _src_path()
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    procs, endpoints, drains = [], [], []
    try:
        for i in range(n):
            cmd = [sys.executable, "-m", "repro.net.server", "--host", host,
                   "--port", "0", "--seed", str(seed + i)]
            if demo_n:
                cmd += ["--demo-n", str(demo_n)]
            if data_dirs is not None:
                cmd += ["--data-dir", data_dirs[i]]
                if checkpoint_every:
                    cmd += ["--checkpoint-every", str(checkpoint_every)]
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True,
                                 env=env)
            procs.append(p)
        deadline = time.time() + startup_timeout_s
        for p in procs:
            ep = _await_listening(p, deadline)
            endpoints.append(ep)
            t = threading.Thread(target=_drain, args=(p,), daemon=True)
            t.start()
            drains.append(t)
        yield (endpoints, procs) if with_procs else endpoints
    finally:
        for p in procs:
            with contextlib.suppress(OSError):
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
                with contextlib.suppress(subprocess.TimeoutExpired):
                    p.wait(timeout=5)


def _await_listening(p: subprocess.Popen, deadline: float) -> str:
    """Read the announce line with a hard deadline (a crashed server hits
    EOF and reports its captured output instead of hanging)."""
    out: list[str] = []
    result: list = []

    def reader():
        for line in p.stdout:
            out.append(line)
            if line.startswith("POOLSERVER LISTENING"):
                _, _, h, prt = line.split()
                result.append(f"{h}:{prt}")
                return
        result.append(None)               # EOF before announce

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t.join(max(deadline - time.time(), 0.1))
    if not result or result[0] is None:
        with contextlib.suppress(OSError):
            p.kill()
        raise RuntimeError("pool server failed to start:\n" + "".join(out))
    return result[0]


def _drain(p: subprocess.Popen) -> None:
    """Keep consuming server stdout so a chatty server can't fill the
    pipe and block."""
    with contextlib.suppress(Exception):
        for _ in p.stdout:
            pass


def _build_demo_region(n: int, seed: int) -> HostRegion:
    from repro.core.hnsw import HNSWParams
    from repro.core.meta import build_meta
    from repro.data.synthetic import sift_like
    ds = sift_like(n=n, n_queries=8, seed=seed)
    meta = build_meta(ds.data, max(8, n // 128), seed=seed, meta_levels=2)
    store = LA.build_store(ds.data, meta,
                           sub_params=HNSWParams(M=8, M0=16,
                                                 ef_construction=60))
    return HostRegion(store)


def main(argv=None) -> int:
    """CLI entry point: host one memory-pool node (see --help)."""
    ap = argparse.ArgumentParser(
        description="d-HNSW memory-pool node: host a region, serve "
                    "MemoryPool verbs over TCP")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = auto-pick a free port (printed on stdout)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the --demo-n synthetic region")
    ap.add_argument("--demo-n", type=int, default=0,
                    help="pre-build a synthetic region of this many "
                         "vectors (0 = start empty, await ATTACH)")
    ap.add_argument("--data-dir", default=None,
                    help="durable state directory (WAL + checkpoints); "
                         "recovers the region on restart")
    ap.add_argument("--checkpoint-every", type=int, default=256,
                    help="checkpoint after this many logged mutations")
    ap.add_argument("--wal-fsync", action="store_true",
                    help="fsync the WAL on every append (power-loss "
                         "safety; default flushes to the OS only)")
    args = ap.parse_args(argv)
    region = (_build_demo_region(args.demo_n, args.seed) if args.demo_n
              else HostRegion())
    if args.data_dir:
        from repro.ingest import Durability
        region.attach_durability(
            Durability(args.data_dir, checkpoint_every=args.checkpoint_every,
                       fsync=args.wal_fsync))
    srv = PoolServer(args.host, args.port, region=region)
    print(f"POOLSERVER LISTENING {srv.host} {srv.port}", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
