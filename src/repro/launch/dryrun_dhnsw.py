"""Multi-pod dry-run for the d-HNSW serving step itself.

Lowers + compiles the distributed fetch+serve step (the paper's
technique) at SIFT1M scale on the production meshes, WITHOUT allocating
the store (ShapeDtypeStructs only), and reports the roofline terms from
the compiled artifact — the "most representative of the paper" cell of
the §Perf hillclimb.

Step under test (one batch round, steady state):
  1. doorbell fetch: m partition spans gathered from the sharded block
     region (one collective);
  2. decode + MXU distance/top-k over the fetched partitions for the
     round's (query, partition) pairs;
  3. per-query top-k merge.

Variants (--variant):
  baseline   — paper-faithful mapping: store sharded over `model`, psum
               fetch replicated to every compute instance.
  sharded    — beyond-paper: queries/pairs sharded over `data`; each
               replica psums only ITS round's spans (wire / data-degree).
  quantized  — + int8 wire format for the vector payload (4x fewer
               bytes on the fetch collective; dequantized on arrival).
  int8_rest  — + the store itself holds int8 vectors (quantized once at
               build, not per fetch): kills the per-launch full-shard
               quantize pass AND shrinks the memory-pool footprint 4x.
  span_dma   — + fetch each span with ONE contiguous dynamic-slice DMA
               instead of a row gather (the paper's layout guarantee:
               a partition + its overflow is one contiguous read; shard
               boundaries are group-aligned so spans never straddle
               owners).  Row-gather HLO charges the whole operand in
               bytes-accessed; contiguous slices touch only the spans.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import shard_map_compat  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

# SIFT1M-scale store geometry (paper: 1M x 128d, 500 partitions)
DIM = 128
DEG = 16
NP_MAX = 2_560            # ~1M/500 padded
OV_CAP = 512
SLOT_VECS = 64
N_PARTS = 500
M_FETCH = 16              # spans per doorbell batch (per compute replica)
PAIRS = 64                # (query, partition) pairs served per round
K = 10

GBLK = SLOT_VECS * (DEG + 1)
VBLK = SLOT_VECS * DIM
DATA_BLOCKS = -(-NP_MAX * (DEG + 1) // GBLK)
_DB_V = -(-NP_MAX * DIM // VBLK)
DATA_BLOCKS = max(DATA_BLOCKS, _DB_V)
OV_BLOCKS = max(-(-OV_CAP // GBLK), -(-OV_CAP * DIM // VBLK))
FETCH_BLOCKS = DATA_BLOCKS + OV_BLOCKS
N_BLOCKS = ((N_PARTS + 1) // 2) * (2 * DATA_BLOCKS + OV_BLOCKS)


def make_step(mesh, variant: str):
    axis = "model"
    tp = int(mesh.shape[axis])
    n_blocks = N_BLOCKS + ((-N_BLOCKS) % tp)
    per_shard = n_blocks // tp
    if variant in ("span_dma", "bf16_serve"):
        # group-align the shard boundary so no fetch span straddles two
        # memory owners (production build rule; costs <1 group of pad)
        group_blocks = 2 * DATA_BLOCKS + OV_BLOCKS
        per_shard = -(-per_shard // group_blocks) * group_blocks
        n_blocks = per_shard * tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local_gather(buf, ids, zero):
        lo = lax.axis_index(axis) * per_shard
        local = ids - lo
        mine = (local >= 0) & (local < per_shard)
        rows = buf[jnp.where(mine, local, 0)]
        rows = jnp.where(mine[:, None], rows, zero)
        return lax.psum(rows, axis)

    def serve(v_rows, queries, pair_valid, dtype=jnp.float32):
        # v_rows: (PAIRS, FETCH_BLOCKS*VBLK) fetched spans
        vecs = v_rows[:, : NP_MAX * DIM].reshape(PAIRS, NP_MAX, DIM)
        vecs = vecs.astype(dtype)
        qd = queries.astype(dtype)
        q2 = jnp.sum(qd.astype(jnp.float32) ** 2, -1)[:, None]
        x2 = jnp.sum(vecs.astype(jnp.float32) ** 2, -1)
        dots = jax.lax.dot_general(
            qd, vecs, (((1,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        dist = q2 + x2 - 2.0 * dots
        dist = jnp.where(pair_valid[:, None], dist, jnp.inf)
        nd, ni = lax.top_k(-dist, K)
        return -nd, ni

    if variant == "baseline":
        # replicated fetch: every chip receives every span (paper's
        # "cache in each compute instance" done naively on-pod)
        def step(vec_buf, block_ids, queries, pair_slot, pair_valid):
            v = shard_map_compat(
                lambda b, i: local_gather(b, i, jnp.zeros((), b.dtype)),
                mesh=mesh, in_specs=(P(axis, None), P()),
                out_specs=P())(vec_buf, block_ids)
            rows = v.reshape(M_FETCH, -1)[pair_slot]
            return serve(rows, queries, pair_valid)

        specs = dict(
            vec=jax.ShapeDtypeStruct((n_blocks, VBLK), jnp.float32),
            ids=jax.ShapeDtypeStruct((M_FETCH * FETCH_BLOCKS,), jnp.int32),
            q=jax.ShapeDtypeStruct((PAIRS, DIM), jnp.float32),
            slot=jax.ShapeDtypeStruct((PAIRS,), jnp.int32),
            valid=jax.ShapeDtypeStruct((PAIRS,), bool))
        in_sh = (NamedSharding(mesh, P(axis, None)),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()))
        out_sh = NamedSharding(mesh, P())
        return step, specs, in_sh, out_sh

    # sharded / quantized: each data-replica fetches ITS OWN doorbell
    # batch and serves ITS OWN pairs — wire bytes / data-degree
    dp = 1
    for a in batch_axes:
        dp *= int(mesh.shape[a])
    bspec = P(batch_axes, None) if batch_axes else P()

    def step(vec_buf, block_ids, queries, pair_slot, pair_valid):
        qspec = (P(axis, None), P(batch_axes, None), P(batch_axes, None),
                 P(batch_axes, None), P(batch_axes, None))

        def span_dma_gather(buf, starts):
            """M_FETCH contiguous span DMAs (the layout's payoff: one
            READ per partition+overflow), psum-assembled."""
            lo = lax.axis_index(axis) * per_shard
            outs = []
            for m in range(M_FETCH):
                s = starts[m]
                mine = (s >= lo) & (s < lo + per_shard)
                sl = jnp.clip(s - lo, 0, per_shard - FETCH_BLOCKS)
                rows = lax.dynamic_slice(buf, (sl, 0), (FETCH_BLOCKS, VBLK))
                outs.append(jnp.where(mine, rows, jnp.zeros((), buf.dtype)))
            spans = jnp.stack(outs)        # (M_FETCH, FETCH_BLOCKS, VBLK)
            return lax.psum(spans, axis)

        def shard_body(buf, ids, q, slot, valid):
            scale = jnp.float32(1.0 / 127.0)
            if variant in ("span_dma", "bf16_serve"):
                starts = ids.reshape(M_FETCH, FETCH_BLOCKS)[:, 0]
                rows8 = span_dma_gather(buf, starts)
                sdt = jnp.bfloat16 if variant == "bf16_serve" else jnp.float32
                rows = rows8.astype(sdt) * scale.astype(sdt)
                rows = rows.reshape(M_FETCH, -1)[slot[0]]
                d, i = serve(rows, q[0], valid[0], dtype=sdt)
                return d[None], i[None]
            ids = ids.reshape(-1)
            if variant == "quantized":
                q8 = jnp.clip(jnp.round(buf / scale), -127, 127
                              ).astype(jnp.int8)
                rows8 = local_gather(q8, ids, jnp.zeros((), jnp.int8))
                rows = rows8.astype(jnp.float32) * scale
            elif variant == "int8_rest":
                rows8 = local_gather(buf, ids, jnp.zeros((), jnp.int8))
                rows = rows8.astype(jnp.float32) * scale
            else:
                rows = local_gather(buf, ids, jnp.zeros((), jnp.float32))
            rows = rows.reshape(M_FETCH, -1)[slot[0]]
            d, i = serve(rows, q[0], valid[0])
            return d[None], i[None]

        return shard_map_compat(
            shard_body, mesh=mesh, in_specs=qspec,
            out_specs=(bspec, bspec))(
                vec_buf, block_ids, queries, pair_slot, pair_valid)

    vec_dtype = (jnp.int8 if variant in ("int8_rest", "span_dma", "bf16_serve")
                 else jnp.float32)
    specs = dict(
        vec=jax.ShapeDtypeStruct((n_blocks, VBLK), vec_dtype),
        ids=jax.ShapeDtypeStruct((dp, M_FETCH * FETCH_BLOCKS), jnp.int32),
        q=jax.ShapeDtypeStruct((dp, PAIRS, DIM), jnp.float32),
        slot=jax.ShapeDtypeStruct((dp, PAIRS), jnp.int32),
        valid=jax.ShapeDtypeStruct((dp, PAIRS), bool))
    in_sh = (NamedSharding(mesh, P(axis, None)),
             NamedSharding(mesh, bspec),
             NamedSharding(mesh, bspec),
             NamedSharding(mesh, bspec),
             NamedSharding(mesh, bspec))
    out_sh = (NamedSharding(mesh, bspec), NamedSharding(mesh, bspec))
    return step, specs, in_sh, out_sh


def run(variant: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    step, specs, in_sh, out_sh = make_step(mesh, variant)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh,
                          out_shardings=out_sh).lower(
            specs["vec"], specs["ids"], specs["q"], specs["slot"],
            specs["valid"])
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    res = {
        "cell": f"dhnsw-serve/{variant}",
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size,
        "flops_dev": float(ca.get("flops", 0.0)),
        "bytes_dev": float(ca.get("bytes accessed", 0.0)),
        "wire_dev": float(coll["wire_bytes_per_device"]),
        "coll_kinds": coll["operand_bytes_by_kind"],
        "n_collectives": coll["n_collectives"],
        "arg_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
    }
    res["t_compute"] = res["flops_dev"] / 197e12
    res["t_memory"] = res["bytes_dev"] / 819e9
    res["t_collective"] = res["wire_dev"] / 50e9
    terms = {k: res[f"t_{k}"] for k in ("compute", "memory", "collective")}
    res["dominant"] = max(terms, key=terms.get)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all",
                    choices=["baseline", "sharded", "quantized",
                             "int8_rest", "span_dma", "bf16_serve", "all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    variants = (["baseline", "sharded", "quantized", "int8_rest",
                 "span_dma", "bf16_serve"]
                if args.variant == "all" else [args.variant])
    for v in variants:
        res = run(v, args.multi_pod)
        line = json.dumps(res)
        print(line, flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")


if __name__ == "__main__":
    main()
