"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the fake-device flag before any other import (jax locks device
count on first init).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.train.train_step import make_step  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9_\[\],x\s{}:()]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|c64|c128|f8e4m3|f8e5m2)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum operand sizes of collective ops in post-SPMD HLO, per op kind,
    plus a ring-model wire-bytes estimate per participating device."""
    per_kind: dict[str, float] = {}
    wire = 0.0
    count = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        if m.group(4) == "-done":
            continue  # count each async pair once (at -start)
        # operand/result sizes: the type annotation before the op name is
        # the RESULT; operands inside the parens are often printed as
        # bare names (no types), so derive operand size from the result
        # when the inline parse comes up empty.
        lhs, rhs = line.split("=", 1)
        result_b = _shape_bytes(rhs.split("(")[0])
        args_b = _shape_bytes(rhs.split("(", 1)[1])
        # group size (for ring model)
        g = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 2)
        if kind == "all-gather":
            op_b = args_b or result_b / g
            w = result_b * (g - 1) / g
        elif kind == "all-reduce":
            op_b = args_b or result_b
            w = 2 * op_b * (g - 1) / g
        elif kind == "reduce-scatter":
            op_b = args_b or result_b * g
            w = result_b * (g - 1)
        elif kind == "all-to-all":
            op_b = args_b or result_b
            w = op_b * (g - 1) / g
        else:  # collective-permute
            op_b = args_b or result_b
            w = op_b
        per_kind[kind] = per_kind.get(kind, 0.0) + op_b
        wire += w
        count += 1
    return {"operand_bytes_by_kind": per_kind,
            "operand_bytes_total": sum(per_kind.values()),
            "wire_bytes_per_device": wire,
            "n_collectives": count}


# per-cell gradient-accumulation overrides: biggest models need
# microbatching to fit 16 GB/chip at global batch 256.  SSM/hybrid train
# cells hold per-chunk SSD states (B x nchunks x heads x hp x state), so
# they microbatch the hardest.
MICRO_OVERRIDES = {
    ("llama4-scout-17b-a16e", "train_4k"): 4,
    ("gemma2-27b", "train_4k"): 2,
    ("qwen3-moe-30b-a3b", "train_4k"): 2,
    ("whisper-tiny", "train_4k"): 8,
    ("mamba2-370m", "train_4k"): 8,
    ("zamba2-2.7b", "train_4k"): 32,
}


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             keep_hlo: bool = False, micro_steps: int = 0) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_id,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    micro = micro_steps or MICRO_OVERRIDES.get((arch, shape_id), 1)
    t0 = time.time()
    fn, in_sh, out_sh, abstract_args = make_step(cfg, shape, mesh,
                                                 micro_steps=micro)
    # steady-state aliasing: train donates (params, opt); decode donates cache
    donate = ()
    if shape.kind == "train":
        donate = (0, 1)
    elif shape.kind == "decode":
        donate = (1,)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    res = {"arch": arch, "shape": shape_id,
           "mesh": "multi" if multi_pod else "single",
           "status": "ok", "lower_s": round(t_lower, 1),
           "compile_s": round(t_compile, 1),
           "micro_steps": micro,
           "n_devices": mesh.size,
           "n_params": int(cfg.param_count()),
           "n_params_active": int(cfg.param_count(active_only=True)),
           "model_flops": M.model_flops(cfg, shape)}
    try:
        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_size_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        res["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        res["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       (k in ("flops", "bytes accessed", "optimal_seconds")
                        or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        res["cost"] = {"error": str(e)}
    hlo = compiled.as_text()
    res["collectives"] = parse_collectives(hlo)
    res["hlo_chars"] = len(hlo)
    hlo_dir = os.environ.get("DRYRUN_HLO_DIR")
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        fname = f"{arch}_{shape_id}_{'multi' if multi_pod else 'single'}.hlo.gz"
        with gzip.open(os.path.join(hlo_dir, fname), "wt") as f:
            f.write(hlo)
        res["hlo_file"] = fname
    if keep_hlo:
        res["hlo"] = hlo
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape id or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    for arch in archs:
        for shape_id in shapes:
            for mp in meshes:
                key = (arch, shape_id, "multi" if mp else "single")
                if key in done:
                    print(f"[skip-done] {key}", flush=True)
                    continue
                print(f"[run] {key}", flush=True)
                try:
                    res = run_cell(arch, shape_id, mp)
                except Exception as e:
                    res = {"arch": arch, "shape": shape_id,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                line = json.dumps(res)
                print(f"[res] {res['status']} {key} "
                      f"compile={res.get('compile_s', '-')}s", flush=True)
                if res["status"] == "error":
                    print(res["traceback"], flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
                else:
                    print(line, flush=True)


if __name__ == "__main__":
    main()
