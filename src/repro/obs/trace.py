"""Lightweight span tracing for the d-HNSW stack.

A single process-global :data:`TRACER` records spans into a bounded,
thread-safe ring buffer.  When disabled (the default) every entry point is
a no-op that allocates nothing: :meth:`Tracer.span` returns a shared null
context manager and :meth:`Tracer.add` / :meth:`Tracer.event` return
immediately, so traced code paths stay bit-identical and ledger-identical
to untraced ones.

Span model
----------
Each span is a plain dict::

    {"name": "compute.fetch", "tier": "compute", "t0": <perf_counter s>,
     "dur": <s>, "id": 17, "parent": 12, "trace": <64-bit id>,
     "tid": 0, "attrs": {"bytes": 4096.0, ...}}

Parentage is tracked per-thread: entering a ``with TRACER.span(...)``
block pushes the span onto that thread's stack, so nested calls (serve
window -> dispatch -> compute round -> pool verb) form a tree without any
explicit plumbing.  Externally-timed spans (queue waits, harvested
server-side spans) are attached with :meth:`Tracer.add` /
:meth:`Tracer.add_span`.

Tiers are free-form strings; the conventional taxonomy is documented in
``docs/observability.md`` (serve / compute / pool / net / server / kernel
/ bench).

Tail-based sampling
-------------------
``configure(tail=True)`` switches the ring from "last N spans" to "the
interesting traces": spans still record always-on and cheap, but a
non-root span is *staged* per-thread instead of entering the ring, and
only when its root closes is the whole trace either promoted (root +
staged children append together) or discarded.  A root is promoted when
it is explicitly marked (``keep=True`` attr), touched an error or
failover (``error``/``failover`` attrs), or its latency — ``model_s``
attr when present (deterministic modeled seconds), wall ``dur``
otherwise — reaches an adaptive quantile threshold over a rolling
window of recent roots.  The promoted root carries ``why_kept`` in its
attrs (``marked`` / ``error`` / ``latency`` / ``warmup``); ``kept`` and
``discarded`` count root decisions and :meth:`Tracer.health` exposes
them next to ring occupancy, so the ring holds the p99 outliers instead
of the last N requests and silent span loss stays visible.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional


class _NullSpan:
    """Shared no-op context manager returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        """Enter without side effects and return self."""
        return self

    def __exit__(self, *exc: object) -> bool:
        """Exit without recording; never swallows exceptions."""
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        """Discard attribute updates."""
        return self

    @property
    def span_id(self) -> int:
        """Null spans have id 0 (meaning "no span")."""
        return 0


_NULL = _NullSpan()


class _Span:
    """Live span context manager; records itself into the tracer on exit."""

    __slots__ = ("_tracer", "name", "tier", "attrs", "t0", "span_id", "parent_id")

    def __init__(self, tracer: "Tracer", name: str, tier: str, attrs: Dict[str, Any]):
        """Bind the span to *tracer*; nothing is recorded until ``__exit__``."""
        self._tracer = tracer
        self.name = name
        self.tier = tier
        self.attrs = attrs
        self.t0 = 0.0
        self.span_id = 0
        self.parent_id = 0

    def __enter__(self) -> "_Span":
        """Allocate an id, push onto the thread's parent stack, start the clock."""
        tr = self._tracer
        self.parent_id = tr._current_id()
        self.span_id = next(tr._ids)
        tr._tls.span_id = self.span_id
        self.t0 = time.perf_counter()
        return self

    def set(self, **attrs: Any) -> "_Span":
        """Merge extra attributes into the span before it closes."""
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc: object) -> bool:
        """Stop the clock, pop the parent stack, and record the span."""
        dur = time.perf_counter() - self.t0
        tr = self._tracer
        tr._tls.span_id = self.parent_id
        tr._record(self.name, self.tier, self.t0, dur, self.span_id, self.parent_id, self.attrs)
        return False


class Tracer:
    """Thread-safe bounded span recorder with a per-thread parent stack."""

    def __init__(self, capacity: int = 65536):
        """Create a disabled tracer with room for *capacity* spans."""
        self.enabled = False
        self.capacity = int(capacity)
        self.trace_id = 0
        self.dropped = 0
        self.tail = False
        self.tail_quantile = 0.95
        self.tail_window = 256
        self.kept = 0
        self.discarded = 0
        self._root_durs: deque = deque(maxlen=self.tail_window)
        self._spans: deque = deque(maxlen=self.capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._tids: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._phase: Optional[str] = None

    # -- lifecycle ---------------------------------------------------------

    def configure(
        self,
        enabled: bool = True,
        capacity: Optional[int] = None,
        trace_id: Optional[int] = None,
        tail: Optional[bool] = None,
        tail_quantile: Optional[float] = None,
        tail_window: Optional[int] = None,
    ) -> "Tracer":
        """Enable (or reconfigure) tracing and reset the buffer.

        *trace_id* defaults to a fresh 63-bit id derived from the wall
        clock; pass an explicit value for reproducible tests.  *tail*
        switches on tail-based sampling (see module docstring):
        *tail_quantile* is the adaptive latency threshold over a rolling
        window of *tail_window* recent root latencies.
        """
        with self._lock:
            if capacity is not None:
                self.capacity = int(capacity)
            if tail is not None:
                self.tail = bool(tail)
            if tail_quantile is not None:
                self.tail_quantile = float(tail_quantile)
            if tail_window is not None:
                self.tail_window = int(tail_window)
            self._spans = deque(maxlen=self.capacity)
            self._ids = itertools.count(1)
            self._tids = {}
            self.dropped = 0
            self.kept = 0
            self.discarded = 0
            self._root_durs = deque(maxlen=self.tail_window)
            self._tls = threading.local()
            self._phase = None
            if trace_id is not None:
                self.trace_id = int(trace_id)
            elif not self.trace_id:
                self.trace_id = (time.time_ns() & 0x7FFFFFFFFFFFFFFF) | 1
            self.enabled = bool(enabled)
        return self

    def disable(self) -> None:
        """Turn tracing off and drop all buffered spans."""
        with self._lock:
            self.enabled = False
            self.tail = False
            self._spans.clear()
            self._root_durs.clear()
            self.kept = 0
            self.discarded = 0
            self._tls = threading.local()
            self._phase = None
            self.trace_id = 0

    def reset(self) -> None:
        """Drop buffered spans but keep the enabled state and trace id."""
        with self._lock:
            self._spans.clear()
            self._root_durs.clear()
            self.dropped = 0
            self.kept = 0
            self.discarded = 0
            self._tls = threading.local()

    def set_phase(self, phase: Optional[str]) -> None:
        """Tag subsequently recorded spans with ``attrs["phase"] = phase``."""
        self._phase = phase

    # -- recording ---------------------------------------------------------

    def span(self, name: str, tier: str = "-", **attrs: Any) -> Any:
        """Open a timed span context; returns a shared no-op when disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, tier, attrs)

    def event(self, name: str, tier: str = "-", **attrs: Any) -> None:
        """Record a zero-duration event parented to the current span."""
        if not self.enabled:
            return
        t0 = time.perf_counter()
        self._record(name, tier, t0, 0.0, next(self._ids), self._current_id(), attrs)

    def add(self, name: str, tier: str, t0: float, dur: float, **attrs: Any) -> None:
        """Record an externally-timed span parented to the current span."""
        if not self.enabled:
            return
        self._record(name, tier, t0, dur, next(self._ids), self._current_id(), attrs)

    def add_span(
        self,
        name: str,
        tier: str,
        t0: float,
        dur: float,
        *,
        parent_id: int = 0,
        attrs: Optional[Dict[str, Any]] = None,
    ) -> int:
        """Record a span with an explicit parent (e.g. harvested server spans)."""
        if not self.enabled:
            return 0
        sid = next(self._ids)
        # explicit-parent spans (harvested from a server, stitched after
        # the fact) bypass tail staging: their root may have closed long
        # ago on another node, so they enter the ring directly
        self._record(name, tier, t0, dur, sid, parent_id, dict(attrs or {}),
                     stack=False)
        return sid

    def _current_id(self) -> int:
        """Return the innermost open span id on this thread (0 if none)."""
        return getattr(self._tls, "span_id", 0)

    def current(self) -> tuple:
        """Return ``(trace_id, current_span_id)`` for wire propagation."""
        return (self.trace_id, self._current_id())

    def _tid(self) -> int:
        """Map the OS thread ident to a small stable integer for exporters."""
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _record(
        self,
        name: str,
        tier: str,
        t0: float,
        dur: float,
        span_id: int,
        parent_id: int,
        attrs: Dict[str, Any],
        stack: bool = True,
    ) -> None:
        """Route one finished span: straight into the ring, or — under
        tail sampling, for stack-parented spans — through per-thread
        staging until its root trace is promoted or discarded."""
        if self._phase is not None and "phase" not in attrs:
            attrs["phase"] = self._phase
        rec = {
            "name": name,
            "tier": tier,
            "t0": t0,
            "dur": dur,
            "id": span_id,
            "parent": parent_id,
            "trace": self.trace_id,
            "tid": self._tid(),
            "attrs": attrs,
        }
        if not self.tail or not stack:
            self._append(rec)
            return
        if parent_id != 0:
            stage = getattr(self._tls, "stage", None)
            if stage is None:
                stage = self._tls.stage = []
            stage.append(rec)
            return
        # a root closed: decide the whole trace at once
        why = self._tail_decide(dur, attrs)
        staged = getattr(self._tls, "stage", None) or []
        self._tls.stage = []
        if why is None:
            self.discarded += 1
            return
        self.kept += 1
        attrs["why_kept"] = why
        for s in staged:
            self._append(s)
        self._append(rec)

    def _append(self, rec: Dict[str, Any]) -> None:
        """Append one span dict to the ring, counting overflow drops."""
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(rec)

    def _tail_decide(self, dur: float, attrs: Dict[str, Any]) -> Optional[str]:
        """Keep/drop verdict for one closed root trace.

        Effective latency is ``attrs["model_s"]`` when present (modeled
        seconds — deterministic under simulated transports and WR
        injection) and the wall ``dur`` otherwise.  Returns the
        ``why_kept`` reason or None to discard.
        """
        eff = float(attrs.get("model_s", dur))
        why = None
        if attrs.get("keep"):
            why = "marked"
        elif attrs.get("error") or attrs.get("failover"):
            why = "error"
        else:
            durs = sorted(self._root_durs)
            if len(durs) < 8:
                why = "warmup"     # no stable threshold yet: keep
            else:
                k = min(len(durs) - 1,
                        int(self.tail_quantile * len(durs)))
                if eff >= durs[k] and eff > 0.0:
                    why = "latency"
        self._root_durs.append(eff)
        return why

    def health(self) -> Dict[str, Any]:
        """Tracer health gauges: ring occupancy/drops + tail counters."""
        durs = sorted(self._root_durs)
        thr = 0.0
        if len(durs) >= 8:
            thr = durs[min(len(durs) - 1,
                           int(self.tail_quantile * len(durs)))]
        return {"enabled": int(self.enabled), "tail": int(self.tail),
                "capacity": self.capacity, "occupancy": len(self._spans),
                "dropped": self.dropped, "kept": self.kept,
                "discarded": self.discarded, "threshold_s": thr}

    # -- inspection / export ----------------------------------------------

    def snapshot(self) -> List[Dict[str, Any]]:
        """Return a stable copy of the buffered spans (oldest first)."""
        with self._lock:
            return list(self._spans)

    def find(self, span_id: int) -> Optional[Dict[str, Any]]:
        """Return the most recent buffered span with *span_id*, if any."""
        if not span_id:
            return None
        with self._lock:
            for s in reversed(self._spans):
                if s["id"] == span_id:
                    return s
        return None

    def save(self, path: str) -> int:
        """Write the buffer as Chrome-trace JSON to *path*; returns span count."""
        spans = self.snapshot()
        with open(path, "w") as f:
            json.dump(chrome_trace(spans), f)
        return len(spans)


#: Process-global tracer used by every instrumented tier.
TRACER = Tracer()


def chrome_trace(spans: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert raw spans to the Chrome trace-event ("Perfetto") format.

    Each span becomes a complete event (``ph="X"``) with microsecond
    ``ts``/``dur``; the raw span/parent ids and attrs ride along in
    ``args`` so :mod:`repro.obs.report` can rebuild the tree losslessly.
    """
    events = []
    for s in spans:
        args = {k: v for k, v in s["attrs"].items()}
        args["id"] = s["id"]
        args["parent"] = s["parent"]
        args["trace"] = s["trace"]
        events.append(
            {
                "name": s["name"],
                "cat": s["tier"],
                "ph": "X",
                "ts": s["t0"] * 1e6,
                "dur": max(s["dur"], 0.0) * 1e6,
                "pid": 0,
                "tid": s.get("tid", 0),
                "args": args,
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Load a Chrome-trace JSON file back into raw span dicts."""
    with open(path) as f:
        blob = json.load(f)
    events = blob["traceEvents"] if isinstance(blob, dict) else blob
    spans = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        spans.append(
            {
                "name": ev["name"],
                "tier": ev.get("cat", "-"),
                "t0": ev.get("ts", 0.0) / 1e6,
                "dur": ev.get("dur", 0.0) / 1e6,
                "id": args.pop("id", 0),
                "parent": args.pop("parent", 0),
                "trace": args.pop("trace", 0),
                "tid": ev.get("tid", 0),
                "attrs": args,
            }
        )
    return spans
