"""Mergeable log-bucketed latency histograms + the straggler detector.

Median-only metrics cannot explain tail latency under disaggregation:
one replica answering its doorbell batches 10x slower moves a fleet's
p99 while every mean stays flat.  This module is the per-(verb, shard)
tail visibility layer:

* :class:`LatencyHistogram` — one log-bucketed series (fixed geometric
  bucket bounds, ~3 per decade from 100 ns to 10 s).  Recording is an
  O(log buckets) bisect; histograms merge by bucket-wise addition, so
  per-child series roll up into a fleet view losslessly.  Quantiles are
  bucket-upper-bound estimates: monotone, deterministic, and identical
  on every machine for the same recorded values.
* :class:`VerbShardHist` — a dict of histograms keyed ``(verb, shard)``.
  Pools record into it from the ``MemoryPool._charge`` hook (modeled
  transport seconds, injection included) and the RDMA completion-poll
  path (measured wire seconds on remote transports); ``ShardedPool``
  merges its children's series into the fleet view its snapshot and the
  Prometheus exporter render.
* :class:`StragglerDetector` — flags a shard whose per-verb tail
  quantile diverges from the fleet median.  The verdict feeds
  ``ShardedPool`` replica-read ranking (flagged shards are penalized by
  their observed excess seconds-per-read, so reads route to a healthy
  replica) and the ``stats()["stragglers"]`` report.

Everything here is pure Python over plain numbers — no numpy, no jax —
so the jax-free ``PoolServer`` data plane can record into it too.
"""
from __future__ import annotations

from bisect import bisect_left
from statistics import median
from typing import Dict, Iterable, List, Optional, Tuple

#: Geometric bucket upper bounds (seconds), ~3 per decade, 100 ns .. 10 s.
#: Shared by every latency histogram so any two series merge bucket-wise.
HIST_BOUNDS: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 3.0), 12) for e in range(-21, 4))


class LatencyHistogram:
    """One mergeable log-bucketed latency series.

    ``counts`` has ``len(HIST_BOUNDS) + 1`` slots (the last is the
    overflow bucket); ``sum_s``/``count`` make the series renderable as
    a Prometheus histogram and let merged views keep exact means.
    """

    __slots__ = ("counts", "sum_s", "count")

    def __init__(self):
        """Start empty: all buckets zero."""
        self.counts: List[int] = [0] * (len(HIST_BOUNDS) + 1)
        self.sum_s = 0.0
        self.count = 0

    def record(self, seconds: float) -> None:
        """Record one observation (negative values clamp to zero)."""
        s = max(float(seconds), 0.0)
        self.counts[bisect_left(HIST_BOUNDS, s)] += 1
        self.sum_s += s
        self.count += 1

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Add *other*'s buckets into this series (bucket-wise; exact)."""
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum_s += other.sum_s
        self.count += other.count
        return self

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound estimate of the *q* quantile (seconds).

        Deterministic and monotone in *q*; the overflow bucket reports
        one log-step past the last bound.  Returns 0.0 when empty.
        """
        if self.count <= 0:
            return 0.0
        target = max(min(float(q), 1.0), 0.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target and c:
                if i < len(HIST_BOUNDS):
                    return HIST_BOUNDS[i]
                return HIST_BOUNDS[-1] * (10.0 ** (1.0 / 3.0))
        return HIST_BOUNDS[-1] * (10.0 ** (1.0 / 3.0))

    def mean(self) -> float:
        """Exact mean of the recorded values (0.0 when empty)."""
        return self.sum_s / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        """JSON-ready form: bucket counts + exact sum/count."""
        return {"counts": list(self.counts), "sum_s": self.sum_s,
                "count": self.count}

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyHistogram":
        """Rebuild a series from :meth:`to_dict` output."""
        h = cls()
        counts = list(d.get("counts", ()))
        for i in range(min(len(counts), len(h.counts))):
            h.counts[i] = int(counts[i])
        h.sum_s = float(d.get("sum_s", 0.0))
        h.count = int(d.get("count", 0))
        return h


class VerbShardHist:
    """Latency histograms keyed by ``(verb, shard)``.

    The recording surface for the ``MemoryPool._charge`` hook and the
    completion-poll path; mergeable across children so ``ShardedPool``
    can roll its fleet into one view.
    """

    def __init__(self):
        """Start with no series; they appear on first record."""
        self._h: Dict[Tuple[str, int], LatencyHistogram] = {}

    def __len__(self) -> int:
        """Number of (verb, shard) series held."""
        return len(self._h)

    def record(self, verb: str, shard: int, seconds: float) -> None:
        """Record one observation under ``(verb, shard)``."""
        key = (verb, int(shard))
        h = self._h.get(key)
        if h is None:
            h = self._h[key] = LatencyHistogram()
        h.record(seconds)

    def get(self, verb: str, shard: int) -> Optional[LatencyHistogram]:
        """The series for ``(verb, shard)``, or None if never recorded."""
        return self._h.get((verb, int(shard)))

    def items(self) -> Iterable[Tuple[Tuple[str, int], LatencyHistogram]]:
        """Iterate ``((verb, shard), histogram)`` pairs (sorted keys)."""
        return iter(sorted(self._h.items()))

    def verbs(self) -> List[str]:
        """Distinct verbs with at least one recorded series."""
        return sorted({v for v, _ in self._h})

    def shards(self) -> List[int]:
        """Distinct shards with at least one recorded series."""
        return sorted({s for _, s in self._h})

    def merge(self, other: "VerbShardHist") -> "VerbShardHist":
        """Fold *other*'s series into this view (bucket-wise; exact)."""
        for key, h in other._h.items():
            mine = self._h.get(key)
            if mine is None:
                mine = self._h[key] = LatencyHistogram()
            mine.merge(h)
        return self

    def to_dict(self) -> dict:
        """JSON-ready nested form ``{verb: {str(shard): series}}``."""
        out: Dict[str, dict] = {}
        for (verb, shard), h in sorted(self._h.items()):
            out.setdefault(verb, {})[str(shard)] = h.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "VerbShardHist":
        """Rebuild a keyed view from :meth:`to_dict` output."""
        vh = cls()
        for verb, by_shard in d.items():
            for shard, series in by_shard.items():
                vh._h[(verb, int(shard))] = LatencyHistogram.from_dict(series)
        return vh


class StragglerDetector:
    """Flag shards whose per-verb tail diverges from the fleet.

    For every verb with enough samples on at least two shards, the
    detector estimates each shard's tail quantile and compares it to the
    fleet *median* of those estimates (the median is robust: one
    straggler cannot drag its own baseline up).  A shard is flagged when
    its tail exceeds ``ratio`` times the fleet median AND the absolute
    excess clears ``min_excess_s`` (so all-zero in-process fleets never
    flag on noise).  Verdicts are pure functions of the histogram
    counts — deterministic, no wall clock.
    """

    def __init__(self, *, quantile: float = 0.99, ratio: float = 4.0,
                 min_count: int = 32, min_excess_s: float = 1e-6):
        """Thresholds: tail *quantile* compared at ``ratio`` x fleet
        median, requiring ``min_count`` samples per shard series and an
        absolute excess of ``min_excess_s`` seconds."""
        self.quantile = float(quantile)
        self.ratio = float(ratio)
        self.min_count = int(min_count)
        self.min_excess_s = float(min_excess_s)

    def verdicts(self, hist: VerbShardHist) -> dict:
        """Evaluate one histogram view -> the straggler report.

        Returns ``{"flagged": {shard: {verb, shard_q_s, fleet_q_s,
        excess_s, ratio}}, "quantile": q, "ratio": r}``; when a shard
        diverges on several verbs the worst (largest excess) wins.
        """
        flagged: Dict[int, dict] = {}
        for verb in hist.verbs():
            qs = {}
            for shard in hist.shards():
                h = hist.get(verb, shard)
                if h is not None and h.count >= self.min_count:
                    qs[shard] = h.quantile(self.quantile)
            if len(qs) < 2:
                continue
            fleet = median(qs.values())
            for shard, q in qs.items():
                excess = q - fleet
                if (q > self.ratio * max(fleet, 1e-12)
                        and excess >= self.min_excess_s):
                    prev = flagged.get(shard)
                    if prev is None or excess > prev["excess_s"]:
                        flagged[shard] = {
                            "verb": verb, "shard_q_s": q,
                            "fleet_q_s": fleet, "excess_s": excess,
                            "ratio": q / max(fleet, 1e-12)}
        return {"flagged": flagged, "quantile": self.quantile,
                "ratio": self.ratio}
