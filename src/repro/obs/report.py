"""Per-stage breakdown reports over a saved trace.

``python -m repro.obs.report trace.json`` aggregates *self time* (span
duration minus the duration of its children) per stage name and prints a
breakdown table per phase, naming the dominant stage.  When the trace
contains both a ``serial`` and a ``batched`` phase (the serving benchmark
emits these) it additionally prints a per-request gap table: the stages
whose per-request self time grew the most going from serial to batched —
the direct diagnosis for a batched-vs-serial slowdown.
"""

from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import load_trace

#: Span name counted as one end-to-end request when normalising per request.
REQUEST_SPAN = "request"


def self_times(spans: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Annotate each span with ``self`` = dur minus the dur of its children."""
    child_dur: Dict[int, float] = defaultdict(float)
    for s in spans:
        if s["parent"]:
            child_dur[s["parent"]] += s["dur"]
    out = []
    for s in spans:
        t = dict(s)
        t["self"] = max(s["dur"] - child_dur.get(s["id"], 0.0), 0.0)
        out.append(t)
    return out


def by_phase(spans: Iterable[Dict[str, Any]]) -> Dict[str, List[Dict[str, Any]]]:
    """Group spans by their ``attrs["phase"]`` tag ("-" when untagged)."""
    phases: Dict[str, List[Dict[str, Any]]] = defaultdict(list)
    for s in spans:
        phases[str(s["attrs"].get("phase", "-"))].append(s)
    return phases


def stage_table(spans: List[Dict[str, Any]]) -> List[Tuple[str, str, int, float, float]]:
    """Aggregate to ``(tier, name, count, total_self_s, total_dur_s)`` rows.

    Rows are sorted by total self time, descending — the first row is the
    dominant stage.
    """
    agg: Dict[Tuple[str, str], List[float]] = {}
    for s in spans:
        key = (s["tier"], s["name"])
        row = agg.setdefault(key, [0, 0.0, 0.0])
        row[0] += 1
        row[1] += s["self"]
        row[2] += s["dur"]
    rows = [(tier, name, int(c), st, dur) for (tier, name), (c, st, dur) in agg.items()]
    rows.sort(key=lambda r: -r[3])
    return rows


def request_count(spans: List[Dict[str, Any]]) -> int:
    """Count end-to-end ``request`` spans in a phase (0 when absent)."""
    return sum(1 for s in spans if s["name"] == REQUEST_SPAN)


def gap_table(
    a: List[Dict[str, Any]], b: List[Dict[str, Any]]
) -> List[Tuple[str, str, float, float, float]]:
    """Per-request self-time deltas between phase *a* and phase *b*.

    Returns ``(tier, name, a_ms_per_req, b_ms_per_req, delta_ms)`` sorted
    by delta descending; positive delta means the stage costs more per
    request in phase *b*.
    """
    na, nb = max(request_count(a), 1), max(request_count(b), 1)

    def per_req(spans: List[Dict[str, Any]], n: int) -> Dict[Tuple[str, str], float]:
        out: Dict[Tuple[str, str], float] = defaultdict(float)
        for s in spans:
            if s["name"] == REQUEST_SPAN:
                continue
            out[(s["tier"], s["name"])] += s["self"] / n
        return out

    pa, pb = per_req(a, na), per_req(b, nb)
    rows = []
    for key in set(pa) | set(pb):
        va, vb = pa.get(key, 0.0), pb.get(key, 0.0)
        rows.append((key[0], key[1], va * 1e3, vb * 1e3, (vb - va) * 1e3))
    rows.sort(key=lambda r: -r[4])
    return rows


def render(spans: List[Dict[str, Any]], top: int = 20) -> str:
    """Render the full breakdown report for raw spans as text."""
    lines: List[str] = []
    annotated = self_times(spans)
    phases = by_phase(annotated)
    for phase in sorted(phases):
        ps = phases[phase]
        rows = stage_table(ps)
        total_self = sum(r[3] for r in rows) or 1.0
        n_req = request_count(ps)
        lines.append(f"== phase: {phase}  ({len(ps)} spans"
                     + (f", {n_req} requests" if n_req else "") + ") ==")
        lines.append(f"{'tier':<8} {'stage':<28} {'count':>7} {'self_ms':>10} "
                     f"{'share':>7} {'total_ms':>10}")
        for tier, name, cnt, st, dur in rows[:top]:
            lines.append(
                f"{tier:<8} {name:<28} {cnt:>7} {st * 1e3:>10.3f} "
                f"{st / total_self:>6.1%} {dur * 1e3:>10.3f}"
            )
        if rows:
            dom = rows[0]
            lines.append(
                f"-> dominant stage [{phase}]: {dom[1]} ({dom[0]}) — "
                f"{dom[3] * 1e3:.3f} ms self, {dom[3] / total_self:.1%} of phase"
            )
        lines.append("")
    if "serial" in phases and "batched" in phases:
        rows = gap_table(phases["serial"], phases["batched"])
        lines.append("== batched-vs-serial gap (per-request self time) ==")
        lines.append(f"{'tier':<8} {'stage':<28} {'serial_ms':>10} "
                     f"{'batched_ms':>11} {'delta_ms':>10}")
        for tier, name, va, vb, dv in rows[:top]:
            lines.append(f"{tier:<8} {name:<28} {va:>10.3f} {vb:>11.3f} {dv:>+10.3f}")
        pos = [r for r in rows if r[4] > 0]
        if pos:
            dom = pos[0]
            lines.append(
                f"-> dominant stage of the batched-vs-serial gap: {dom[1]} "
                f"({dom[0]}) — +{dom[4]:.3f} ms per request"
            )
        lines.append("")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    """CLI entry point: ``python -m repro.obs.report trace.json``."""
    ap = argparse.ArgumentParser(description="Per-stage breakdown of a repro trace")
    ap.add_argument("trace", help="Chrome-trace JSON written by TRACER.save()")
    ap.add_argument("--top", type=int, default=20, help="rows per table")
    args = ap.parse_args(argv)
    spans = load_trace(args.trace)
    if not spans:
        print(f"{args.trace}: no spans")
        return 0
    print(render(spans, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
