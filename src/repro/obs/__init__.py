"""Observability: tracing, metrics exporters, and breakdown reporting.

The package is dependency-free within ``repro`` (only ``trace`` is imported
by the hot paths) so every tier — serve, compute, pool, net, kernels — can
emit spans without import cycles.  See ``docs/observability.md``.
"""

from repro.obs.hist import (HIST_BOUNDS, LatencyHistogram,
                            StragglerDetector, VerbShardHist)
from repro.obs.slo import SLO, SLOTracker, parse_slo
from repro.obs.trace import TRACER, Tracer, chrome_trace, load_trace

__all__ = ["TRACER", "Tracer", "chrome_trace", "load_trace",
           "HIST_BOUNDS", "LatencyHistogram", "VerbShardHist",
           "StragglerDetector", "SLO", "SLOTracker", "parse_slo"]
