"""Latency SLOs with multi-window burn-rate evaluation.

An :class:`SLO` is a target quantile plus a latency threshold —
"p99 < 5ms" means "at least 99% of requests finish under 5 ms", which
leaves an *error budget* of 1% of requests allowed over the threshold.
The :class:`SLOTracker` evaluates SLOs per (tier, key) over rolling
request-counted windows and reports the SRE-standard *burn rate*:

    burn = observed violation rate / error budget

burn == 1 means the budget is being consumed exactly as provisioned;
burn > 1 means the tail is degrading faster than the SLO tolerates (a
straggling replica, a degraded bearer); burn < 1 is healthy headroom.
Two windows are kept — a short one that reacts within a few requests
and a long one that smooths it — mirroring the multi-window burn-rate
alerting pattern: page when BOTH burn, so a single slow request can't
page but a sustained regression can't hide.

Windows are counted in *requests*, not seconds, so a test or benchmark
feeding deterministic modeled latencies gets deterministic burn rates —
no wall clock anywhere.  ``SearchServer.stats()["slo"]`` and
``metrics_text`` surface the report; ``examples/online_serving.py
--slo "p99<5ms"`` prints it as a table.
"""
from __future__ import annotations

import re
from collections import deque
from dataclasses import dataclass
from typing import Dict, Optional, Union

_SPEC = re.compile(
    r"^\s*p(?P<q>\d+(?:\.\d+)?)\s*<\s*(?P<v>\d+(?:\.\d+)?)\s*"
    r"(?P<u>us|ms|s)\s*$", re.IGNORECASE)

_UNIT_S = {"us": 1e-6, "ms": 1e-3, "s": 1.0}


@dataclass(frozen=True)
class SLO:
    """One latency objective: ``quantile`` of requests under
    ``threshold_s`` seconds.  ``budget`` is the tolerated violation
    fraction (``1 - quantile``)."""

    quantile: float
    threshold_s: float
    name: str = ""

    @property
    def budget(self) -> float:
        """Error budget: the fraction of requests allowed to violate."""
        return max(1.0 - self.quantile, 1e-9)


def parse_slo(spec: Union[str, SLO]) -> SLO:
    """Parse ``"p99<5ms"`` (units: us / ms / s) into an :class:`SLO`."""
    if isinstance(spec, SLO):
        return spec
    m = _SPEC.match(str(spec))
    if not m:
        raise ValueError(
            f"bad SLO spec {spec!r} (want e.g. 'p99<5ms', 'p95<250us')")
    q = float(m.group("q")) / 100.0
    if not 0.0 < q < 1.0:
        raise ValueError(f"SLO quantile must be in (0, 100): {spec!r}")
    thr = float(m.group("v")) * _UNIT_S[m.group("u").lower()]
    return SLO(quantile=q, threshold_s=thr, name=str(spec).strip())


class SLOTracker:
    """Rolling per-(tier, key) SLO evaluation with two burn windows.

    ``slos`` configures what to watch: a single spec (string or
    :class:`SLO`) applies to tier ``"serve"`` (end-to-end request
    latency), or a ``{tier: spec}`` dict attaches an objective per tier
    (``"serve"`` / ``"fetch"`` / ``"queue"`` — whatever the caller
    records).  ``record`` is a no-op for unconfigured tiers, so the
    serve tier can feed every stage unconditionally.  ``key`` is the
    within-tier series — the serve tier passes the tenant.
    """

    def __init__(self, slos, *, short_window: int = 64,
                 long_window: int = 512):
        """Normalize ``slos`` (see class docstring) and size the rolling
        request-counted windows."""
        if isinstance(slos, (str, SLO)):
            slos = {"serve": slos}
        self.slos: Dict[str, SLO] = {t: parse_slo(s)
                                     for t, s in dict(slos).items()}
        self.short_window = int(short_window)
        self.long_window = int(long_window)
        # (tier, key) -> (short deque, long deque) of 0/1 violations
        self._win: Dict[tuple, tuple] = {}
        self._n: Dict[tuple, int] = {}
        self._viol: Dict[tuple, int] = {}

    def record(self, tier: str, key: str, latency_s: float) -> None:
        """Score one request latency against the tier's SLO (if any)."""
        slo = self.slos.get(tier)
        if slo is None:
            return
        k = (tier, str(key))
        win = self._win.get(k)
        if win is None:
            win = self._win[k] = (deque(maxlen=self.short_window),
                                  deque(maxlen=self.long_window))
            self._n[k] = 0
            self._viol[k] = 0
        bad = 1 if float(latency_s) > slo.threshold_s else 0
        win[0].append(bad)
        win[1].append(bad)
        self._n[k] += 1
        self._viol[k] += bad

    @staticmethod
    def _burn(win: deque, budget: float) -> float:
        """Burn rate over one window (0.0 while the window is empty)."""
        if not win:
            return 0.0
        return (sum(win) / len(win)) / budget

    def report(self) -> dict:
        """Attainment + burn rates per (tier, key), JSON-ready.

        ``burn`` is the min of the short- and long-window burns (the
        multi-window AND: both must burn to alert); ``met`` is whether
        lifetime attainment meets the objective.
        """
        out: Dict[str, dict] = {}
        for (tier, key), (short, long_) in sorted(self._win.items()):
            slo = self.slos[tier]
            n = self._n[(tier, key)]
            viol = self._viol[(tier, key)]
            attain = (n - viol) / n if n else 1.0
            bs = self._burn(short, slo.budget)
            bl = self._burn(long_, slo.budget)
            out.setdefault(tier, {})[key] = {
                "slo": slo.name or f"p{slo.quantile * 100:g}"
                       f"<{slo.threshold_s * 1e3:g}ms",
                "quantile": slo.quantile,
                "threshold_ms": slo.threshold_s * 1e3,
                "n": n, "violations": viol,
                "attainment": attain,
                "met": attain >= slo.quantile,
                "burn_short": bs, "burn_long": bl,
                "burn": min(bs, bl),
            }
        return out
