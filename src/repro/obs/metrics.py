"""Prometheus-style text exporters for the serving and pool tiers.

Two renderers produce the classic ``# HELP / # TYPE / name{labels} value``
text exposition format:

* :func:`render_prometheus` — from a ``SearchServer.stats()`` snapshot
  (request counters, stage seconds, latency quantiles, queue depth,
  cache hit ratio, failover counters, pool verb totals), optionally
  joined by per-span duration histograms from the live tracer ring.
* :func:`render_pool_server` — from a ``PoolServer`` ``stats()`` payload
  (the STATS verb): per-verb request counts, service seconds, payload
  byte totals, and (for durable servers) the WAL/checkpoint/replay
  counters under ``ingest``.
* :func:`render_ingest` — from a bulk-load ``LoadReport`` (and
  optionally a ``Compactor.stats()`` snapshot).

Pure functions over plain dicts — no scrape endpoint is included; embed
the text wherever your deployment exposes it.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional

#: Histogram bucket upper bounds (seconds) for span-duration histograms.
BUCKETS = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
           1.0, 3.0)


def _line(name: str, value, labels: Optional[Dict[str, Any]] = None) -> str:
    """One exposition line: ``name{labels} value``."""
    lab = ""
    if labels:
        inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
        lab = "{" + inner + "}"
    return f"{name}{lab} {float(value):.9g}"


def _head(out: List[str], name: str, help_: str, type_: str) -> None:
    """Append the # HELP / # TYPE preamble for a metric family."""
    out.append(f"# HELP {name} {help_}")
    out.append(f"# TYPE {name} {type_}")


def span_histograms(spans: Iterable[Dict[str, Any]]) -> List[str]:
    """Cumulative duration histograms per (tier, name) over raw spans."""
    counts: Dict[tuple, List[int]] = defaultdict(
        lambda: [0] * (len(BUCKETS) + 1))
    sums: Dict[tuple, float] = defaultdict(float)
    bytes_sum: Dict[tuple, float] = defaultdict(float)
    for s in spans:
        key = (s["tier"], s["name"])
        dur = float(s["dur"])
        sums[key] += dur
        bytes_sum[key] += float(s["attrs"].get("bytes", 0.0))
        row = counts[key]
        for i, ub in enumerate(BUCKETS):
            if dur <= ub:
                row[i] += 1
                break
        else:
            row[len(BUCKETS)] += 1
    out: List[str] = []
    if not counts:
        return out
    _head(out, "repro_span_seconds", "span duration by tier/name",
          "histogram")
    for key in sorted(counts):
        tier, name = key
        cum = 0
        for i, ub in enumerate(BUCKETS):
            cum += counts[key][i]
            out.append(_line("repro_span_seconds_bucket", cum,
                             {"tier": tier, "name": name, "le": repr(ub)}))
        cum += counts[key][len(BUCKETS)]
        out.append(_line("repro_span_seconds_bucket", cum,
                         {"tier": tier, "name": name, "le": "+Inf"}))
        out.append(_line("repro_span_seconds_sum", sums[key],
                         {"tier": tier, "name": name}))
        out.append(_line("repro_span_seconds_count", cum,
                         {"tier": tier, "name": name}))
    byted = {k: v for k, v in bytes_sum.items() if v}
    if byted:
        _head(out, "repro_span_bytes_total", "bytes attributed to spans",
              "counter")
        for key in sorted(byted):
            out.append(_line("repro_span_bytes_total", byted[key],
                             {"tier": key[0], "name": key[1]}))
    return out


def slo_lines(report: Dict[str, Any]) -> List[str]:
    """Exposition lines for an ``SLOTracker.report()`` dict."""
    out: List[str] = []
    if not report:
        return out
    _head(out, "repro_slo", "SLO attainment and burn rates", "gauge")
    for tier in sorted(report):
        for key in sorted(report[tier]):
            row = report[tier][key]
            lab = {"tier": tier, "key": key}
            for what in ("attainment", "burn_short", "burn_long", "burn",
                         "violations", "n"):
                out.append(_line("repro_slo", row.get(what, 0.0),
                                 dict(lab, what=what)))
            out.append(_line("repro_slo", 1.0 if row.get("met") else 0.0,
                             dict(lab, what="met")))
    return out


def tracer_lines(tracer) -> List[str]:
    """Tracer-health gauges (ring occupancy/drops, tail kept/discarded)
    from a live :class:`~repro.obs.trace.Tracer`."""
    out: List[str] = []
    if tracer is None:
        return out
    _head(out, "repro_tracer", "tracer ring + tail-sampler health",
          "gauge")
    for what, v in sorted(tracer.health().items()):
        out.append(_line("repro_tracer", float(v), {"what": what}))
    return out


def pool_hist_lines(hist: Dict[str, Any]) -> List[str]:
    """Prometheus histogram lines for a pool's nested per-(verb, shard)
    latency view (``snapshot()["hist"]``, i.e. ``VerbShardHist.to_dict``
    output).  Only buckets that advance the cumulative count are
    emitted (plus ``+Inf``) to keep the exposition compact — still a
    valid, monotone Prometheus histogram."""
    out: List[str] = []
    if not hist:
        return out
    from repro.obs.hist import HIST_BOUNDS
    name = "repro_pool_verb_latency_seconds"
    _head(out, name, "observed transport latency by (verb, shard)",
          "histogram")
    for verb in sorted(hist):
        for shard in sorted(hist[verb], key=int):
            d = hist[verb][shard]
            counts = list(d.get("counts", ()))
            lab = {"verb": verb, "shard": shard}
            cum = 0
            for i, ub in enumerate(HIST_BOUNDS):
                c = counts[i] if i < len(counts) else 0
                if c:
                    cum += c
                    out.append(_line(name + "_bucket", cum,
                                     dict(lab, le=repr(ub))))
            total = sum(counts)
            out.append(_line(name + "_bucket", total,
                             dict(lab, le="+Inf")))
            out.append(_line(name + "_sum", d.get("sum_s", 0.0), lab))
            out.append(_line(name + "_count", d.get("count", total), lab))
    return out


def straggler_lines(stragglers: Dict[str, Any]) -> List[str]:
    """Gauges for a ``ShardedPool`` straggler report (detector counters
    + per-shard flags with their tail excess)."""
    out: List[str] = []
    if not stragglers:
        return out
    _head(out, "repro_straggler", "straggler-detector counters", "gauge")
    for what in ("checks", "flagged_now", "reroutes", "moved_groups"):
        if what in stragglers:
            out.append(_line("repro_straggler", stragglers[what],
                             {"what": what}))
    flagged = stragglers.get("flagged", {})
    if flagged:
        _head(out, "repro_straggler_excess_seconds",
              "flagged shard tail excess vs fleet", "gauge")
        for shard in sorted(flagged, key=int):
            info = flagged[shard]
            out.append(_line("repro_straggler_excess_seconds",
                             info.get("excess_s", 0.0),
                             {"shard": shard,
                              "verb": info.get("verb", "-")}))
    return out


def render_prometheus(stats: Dict[str, Any],
                      spans: Optional[Iterable[Dict[str, Any]]] = None,
                      tracer=None) -> str:
    """Render a ``SearchServer.stats()`` snapshot (and optionally the
    tracer's spans + the tracer's own health gauges) as Prometheus text
    exposition."""
    out: List[str] = []
    _head(out, "repro_serve_requests_total", "requests completed", "counter")
    out.append(_line("repro_serve_requests_total",
                     stats.get("n_requests", 0)))
    _head(out, "repro_serve_queries_total", "query rows served", "counter")
    out.append(_line("repro_serve_queries_total", stats.get("n_queries", 0)))
    _head(out, "repro_serve_fused_calls_total", "fused engine calls",
          "counter")
    out.append(_line("repro_serve_fused_calls_total",
                     stats.get("n_fused_calls", 0)))
    _head(out, "repro_serve_rejected_total", "admission rejections",
          "counter")
    out.append(_line("repro_serve_rejected_total",
                     stats.get("n_rejected", 0)))
    _head(out, "repro_serve_mean_fused_batch", "mean fused batch size",
          "gauge")
    out.append(_line("repro_serve_mean_fused_batch",
                     stats.get("mean_fused_batch", 0.0)))
    _head(out, "repro_serve_latency_ms", "request latency quantiles",
          "gauge")
    for p in (50, 95, 99):
        out.append(_line("repro_serve_latency_ms",
                         stats.get(f"p{p}_ms", 0.0),
                         {"quantile": f"0.{p}"}))
    _head(out, "repro_serve_stage_seconds_total",
          "cumulative per-stage seconds", "counter")
    for stage, v in sorted(stats.get("breakdown_s", {}).items()):
        out.append(_line("repro_serve_stage_seconds_total", v,
                         {"stage": stage.removesuffix("_s")}))
    _head(out, "repro_net_total", "NetLedger roll-up", "counter")
    for key, v in sorted(stats.get("net", {}).items()):
        out.append(_line("repro_net_total", v, {"what": key}))
    eng = stats.get("engine", {})
    if eng:
        _head(out, "repro_engine_total", "engine counters across fused "
              "calls", "counter")
        for key, v in sorted(eng.items()):
            out.append(_line("repro_engine_total", v, {"what": key}))
        denom = eng.get("cache_hits", 0.0) + eng.get("n_fetches", 0.0)
        _head(out, "repro_cache_hit_ratio", "span-cache hit ratio", "gauge")
        out.append(_line("repro_cache_hit_ratio",
                         eng.get("cache_hits", 0.0) / denom if denom
                         else 0.0))
    tenants = stats.get("tenants", {})
    if tenants:
        _head(out, "repro_tenant_requests_total",
              "per-tenant admission counters", "counter")
        for t, row in sorted(tenants.items()):
            for what in ("admitted", "rejected", "served"):
                out.append(_line("repro_tenant_requests_total",
                                 row.get(what, 0),
                                 {"tenant": t, "what": what}))
        _head(out, "repro_queue_depth", "live queued requests", "gauge")
        out.append(_line("repro_queue_depth",
                         sum(r.get("queued", 0) for r in tenants.values())))
    fo = stats.get("failover")
    if fo:
        _head(out, "repro_failover", "replication/failover counters",
              "gauge")
        for key, v in sorted(fo.items()):
            out.append(_line("repro_failover", v, {"what": key}))
    pool = stats.get("pool")
    if pool:
        _head(out, "repro_pool_verbs_total", "memory-pool verb counts",
              "counter")
        for verb, v in sorted(pool.get("verbs", {}).items()):
            out.append(_line("repro_pool_verbs_total", v, {"verb": verb}))
        _head(out, "repro_pool_total", "memory-pool charged totals",
              "counter")
        for key, v in sorted(pool.get("totals", {}).items()):
            out.append(_line("repro_pool_total", v, {"what": key}))
        out.extend(pool_hist_lines(pool.get("hist", {})))
    out.extend(slo_lines(stats.get("slo", {})))
    out.extend(straggler_lines(stats.get("stragglers", {})))
    out.extend(tracer_lines(tracer))
    if spans is not None:
        out.extend(span_histograms(spans))
    return "\n".join(out) + "\n"


def render_pool_server(stats: Dict[str, Any]) -> str:
    """Render a ``PoolServer`` STATS payload as Prometheus text."""
    out: List[str] = []
    _head(out, "repro_poolserver_verbs_total", "verb requests handled",
          "counter")
    for verb, v in sorted(stats.get("verbs", {}).items()):
        out.append(_line("repro_poolserver_verbs_total", v, {"verb": verb}))
    _head(out, "repro_poolserver_service_seconds_total",
          "seconds inside verb bodies", "counter")
    for verb, v in sorted(stats.get("service_s", {}).items()):
        out.append(_line("repro_poolserver_service_seconds_total", v,
                         {"verb": verb}))
    _head(out, "repro_poolserver_payload_bytes_total",
          "request/response payload bytes", "counter")
    out.append(_line("repro_poolserver_payload_bytes_total",
                     stats.get("payload_rx", 0), {"dir": "rx"}))
    out.append(_line("repro_poolserver_payload_bytes_total",
                     stats.get("payload_tx", 0), {"dir": "tx"}))
    _head(out, "repro_poolserver_uptime_seconds", "server uptime", "gauge")
    out.append(_line("repro_poolserver_uptime_seconds",
                     stats.get("uptime_s", 0.0)))
    sh = stats.get("service_hist")
    if sh:
        from repro.obs.hist import HIST_BOUNDS
        name = "repro_poolserver_service_seconds"
        _head(out, name, "per-verb service-time histogram", "histogram")
        for verb in sorted(sh):
            d = sh[verb]
            counts = list(d.get("counts", ()))
            cum = 0
            for i, ub in enumerate(HIST_BOUNDS):
                c = counts[i] if i < len(counts) else 0
                if c:
                    cum += c
                    out.append(_line(name + "_bucket", cum,
                                     {"verb": verb, "le": repr(ub)}))
            total = sum(counts)
            out.append(_line(name + "_bucket", total,
                             {"verb": verb, "le": "+Inf"}))
            out.append(_line(name + "_sum", d.get("sum_s", 0.0),
                             {"verb": verb}))
            out.append(_line(name + "_count", d.get("count", total),
                             {"verb": verb}))
    ing = stats.get("ingest")
    if ing:
        _head(out, "repro_poolserver_ingest_total",
              "durability counters (WAL/checkpoint/replay)", "counter")
        for key, v in sorted(ing.items()):
            out.append(_line("repro_poolserver_ingest_total", float(v),
                             {"what": key}))
    return "\n".join(out) + "\n"


def render_ingest(report: Dict[str, Any],
                  compactor: Optional[Dict[str, Any]] = None) -> str:
    """Render a bulk-load :class:`~repro.ingest.loader.LoadReport` dict
    (``dataclasses.asdict``) and optionally a ``Compactor.stats()``
    snapshot as Prometheus text."""
    out: List[str] = []
    _head(out, "repro_ingest_load", "bulk-load counters", "gauge")
    for key in ("rows", "chunks_total", "chunks_ok", "chunks_failed",
                "chunks_retried", "chunk_bytes", "dataset_bytes",
                "peak_builder_bytes", "verbs_issued", "groups_shipped"):
        out.append(_line("repro_ingest_load", report.get(key, 0),
                         {"what": key}))
    if compactor:
        _head(out, "repro_ingest_compactor_total",
              "background compaction counters", "counter")
        for key, v in sorted(compactor.items()):
            out.append(_line("repro_ingest_compactor_total", float(v),
                             {"what": key}))
    return "\n".join(out) + "\n"
