"""Simulated-RDMA memory pool: LocalPool's data path + a modeled NIC.

The container has no fabric, so — exactly like the paper's latency
*breakdown* methodology — the transport is simulated: every charged verb
advances a per-verb simulated clock by

    trips * rtt  +  descriptors * per_op  +  bytes / bandwidth

using a ``Fabric`` calibration (defaults to the paper's ConnectX-6
testbed, ``RDMA_100G``).  Results are bit-identical to ``LocalPool`` —
the data movement is the same device gathers — but search stats carry a
nonzero modeled network latency with a per-verb breakdown, so benchmark
numbers reflect round trips and wire time rather than event counts
alone.  ``benchmarks/pool.py`` sweeps the fabric parameters.

Fan-out semantics: ``_transport`` accepts either scalars (one
destination — the single-node case, bit-identical to before) or
per-destination sequences.  With ``parallel=True`` a multi-destination
charge is reduced by ``max`` (destinations answer their doorbell
batches concurrently, so the critical path is the slowest slice);
serial mode sums.  ``fanout_dt`` is the shared reduction —
``ShardedPool`` uses it to aggregate its children's modeled clocks the
same way.

Optionally (``sleep=True``) the pool also *injects* the modeled latency
as real wall time — useful to make the serving tier feel remote reads in
end-to-end latency percentiles; off by default so tests stay fast.
Since the verbs re-plumb, every modeled charge slice is also *issued*
through a :class:`repro.rdma.verbs.QueuePair` over the accounting-only
``ModelBearer``: one ``post_send`` per modeled round trip, one
``WorkRequest`` per descriptor.  The bearer carries no bytes and the
clock is still priced from the aggregate slice (so ``sim_s`` stays
bit-identical to the pre-verbs math), but the doorbell/descriptor
structure of the simulated fabric now flows through the same QP
interface the real bearers use — ``snapshot()["qp"]`` reports the
tallies.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import RDMA_100G, Fabric
from repro.core.layout import Store
from repro.pool.local import LocalPool
from repro.rdma import verbs as V
from repro.rdma.loopback import ModelBearer

Slices = Union[float, int, Sequence[float]]


def fanout_dt(dts: Sequence[float], parallel: bool) -> float:
    """Reduce per-destination modeled times: concurrent destinations
    cost the max (critical path), serial destinations the sum."""
    dts = list(dts)
    if not dts:
        return 0.0
    return max(dts) if parallel else float(sum(dts))


class SimulatedRDMAPool(LocalPool):
    """LocalPool + a per-verb latency/bandwidth model: every charge
    slice is priced on this node's ``Fabric`` into ``sim_s``."""

    kind = "sim_rdma"

    def __init__(self, store: Store, *, fabric: Optional[Fabric] = None,
                 use_gather_kernel: bool = False, sleep: bool = False,
                 parallel: bool = False):
        self.fabric = fabric or RDMA_100G
        self.sleep = sleep
        self.parallel = parallel
        self.sim_s: dict[str, float] = {}      # per-verb modeled seconds
        # the simulated NIC: every charge slice posts its descriptor
        # structure through this QP (accounting only, no bytes move)
        self._qp = V.QueuePair(ModelBearer())
        super().__init__(store, use_gather_kernel=use_gather_kernel)

    def _post_slice(self, n_bytes: float, descriptors: float,
                    trips: float) -> None:
        """Issue one charge slice as WR lists: ``trips`` doorbell
        batches carrying ``descriptors`` READ WRs between them (the
        first batch also names the slice's bytes).  Completions are
        polled immediately — the model bearer is synchronous."""
        t = max(int(trips), 1) if trips else 0
        if t == 0:
            return
        d = max(int(descriptors), t)
        base, extra = divmod(d, t)
        for i in range(t):
            n = base + (1 if i < extra else 0)
            wrs = [V.WorkRequest(V.READ, rkey=V.RKEY_SPANS,
                                 length=int(n_bytes) if i == 0 and k == 0
                                 else 0)
                   for k in range(n)]
            self._qp.post_send(wrs)
        self._qp.cq.poll(t)

    def model_dt(self, n_bytes: float, descriptors: float,
                 trips: float) -> float:
        """Modeled seconds of one charge slice on this node's fabric."""
        f = self.fabric
        return (trips * f.rtt_s + descriptors * f.per_op_s
                + n_bytes / f.bw_Bps)

    def set_injector(self, injector) -> None:
        """Attach (or with None, detach) a WR-level fault injector to the
        simulated NIC's bearer; see :mod:`repro.rdma.inject`.  Injected
        latency lands in the *observed* clock (``sim_s``, histograms)
        but never in :meth:`model_dt` — the a-priori cost model stays
        honest and only the straggler detector can route around it."""
        self._qp.bearer.injector = injector

    def _transport(self, verb: str, n_bytes: Slices, descriptors: Slices,
                   trips: Slices) -> float:
        b = np.atleast_1d(np.asarray(n_bytes, np.float64))
        d = np.atleast_1d(np.asarray(descriptors, np.float64))
        t = np.atleast_1d(np.asarray(trips, np.float64))
        inj = getattr(self._qp.bearer, "injector", None)
        inj0 = inj.injected_s if inj is not None else 0.0
        for bi, di, ti in zip(b, d, t):
            self._post_slice(bi, di, ti)
        # the clock is priced from the aggregate slice (not summed over
        # WR lists) so the float math is bit-identical to the pre-QP
        # accounting; WR-injected delay (chaos) adds on top
        dt = fanout_dt([self.model_dt(bi, di, ti)
                        for bi, di, ti in zip(b, d, t)],
                       self.parallel and len(b) > 1)
        if inj is not None:
            dt += inj.injected_s - inj0
        self.sim_s[verb] = self.sim_s.get(verb, 0.0) + dt
        if self.sleep:
            time.sleep(dt)
        return dt

    @property
    def sim_total_s(self) -> float:
        """Total modeled wire seconds across all verbs."""
        return sum(self.sim_s.values())

    def snapshot(self) -> dict:
        """See ``MemoryPool.snapshot``; adds fabric calibration and the
        per-verb modeled-seconds breakdown."""
        out = super().snapshot()
        # full fabric calibration, not just the name: benchmark rows
        # built from this snapshot are self-describing
        out["fabric"] = fabric_params(self.fabric)
        out["sim_s"] = dict(self.sim_s)
        out["sim_total_s"] = self.sim_total_s
        out["qp"] = self._qp.bearer.snapshot()
        return out


def fabric_params(f: Fabric) -> dict:
    """The parameters the latency model prices with, JSON-ready."""
    return {"name": f.name, "rtt_us": f.rtt_s * 1e6,
            "bw_GBps": f.bw_Bps / 1e9, "per_op_us": f.per_op_s * 1e6,
            "max_doorbell": f.max_doorbell}
