"""Simulated-RDMA memory pool: LocalPool's data path + a modeled NIC.

The container has no fabric, so — exactly like the paper's latency
*breakdown* methodology — the transport is simulated: every charged verb
advances a per-verb simulated clock by

    trips * rtt  +  descriptors * per_op  +  bytes / bandwidth

using a ``Fabric`` calibration (defaults to the paper's ConnectX-6
testbed, ``RDMA_100G``).  Results are bit-identical to ``LocalPool`` —
the data movement is the same device gathers — but search stats carry a
nonzero modeled network latency with a per-verb breakdown, so benchmark
numbers reflect round trips and wire time rather than event counts
alone.  ``benchmarks/pool.py`` sweeps the fabric parameters.

Optionally (``sleep=True``) the pool also *injects* the modeled latency
as real wall time — useful to make the serving tier feel remote reads in
end-to-end latency percentiles; off by default so tests stay fast.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.cost_model import RDMA_100G, Fabric
from repro.core.layout import Store
from repro.pool.local import LocalPool


class SimulatedRDMAPool(LocalPool):

    kind = "sim_rdma"

    def __init__(self, store: Store, *, fabric: Optional[Fabric] = None,
                 use_gather_kernel: bool = False, sleep: bool = False):
        self.fabric = fabric or RDMA_100G
        self.sleep = sleep
        self.sim_s: dict[str, float] = {}      # per-verb modeled seconds
        super().__init__(store, use_gather_kernel=use_gather_kernel)

    def _transport(self, verb: str, n_bytes: float, descriptors: int,
                   trips: int) -> None:
        f = self.fabric
        dt = (trips * f.rtt_s + descriptors * f.per_op_s
              + n_bytes / f.bw_Bps)
        self.sim_s[verb] = self.sim_s.get(verb, 0.0) + dt
        if self.sleep:
            time.sleep(dt)

    @property
    def sim_total_s(self) -> float:
        return sum(self.sim_s.values())

    def snapshot(self) -> dict:
        out = super().snapshot()
        out["fabric"] = self.fabric.name
        out["sim_s"] = dict(self.sim_s)
        out["sim_total_s"] = self.sim_total_s
        return out
