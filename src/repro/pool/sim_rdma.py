"""Simulated-RDMA memory pool: LocalPool's data path + a modeled NIC.

The container has no fabric, so — exactly like the paper's latency
*breakdown* methodology — the transport is simulated: every charged verb
advances a per-verb simulated clock by

    trips * rtt  +  descriptors * per_op  +  bytes / bandwidth

using a ``Fabric`` calibration (defaults to the paper's ConnectX-6
testbed, ``RDMA_100G``).  Results are bit-identical to ``LocalPool`` —
the data movement is the same device gathers — but search stats carry a
nonzero modeled network latency with a per-verb breakdown, so benchmark
numbers reflect round trips and wire time rather than event counts
alone.  ``benchmarks/pool.py`` sweeps the fabric parameters.

Fan-out semantics: ``_transport`` accepts either scalars (one
destination — the single-node case, bit-identical to before) or
per-destination sequences.  With ``parallel=True`` a multi-destination
charge is reduced by ``max`` (destinations answer their doorbell
batches concurrently, so the critical path is the slowest slice);
serial mode sums.  ``fanout_dt`` is the shared reduction —
``ShardedPool`` uses it to aggregate its children's modeled clocks the
same way.

Optionally (``sleep=True``) the pool also *injects* the modeled latency
as real wall time — useful to make the serving tier feel remote reads in
end-to-end latency percentiles; off by default so tests stay fast.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import RDMA_100G, Fabric
from repro.core.layout import Store
from repro.pool.local import LocalPool

Slices = Union[float, int, Sequence[float]]


def fanout_dt(dts: Sequence[float], parallel: bool) -> float:
    """Reduce per-destination modeled times: concurrent destinations
    cost the max (critical path), serial destinations the sum."""
    dts = list(dts)
    if not dts:
        return 0.0
    return max(dts) if parallel else float(sum(dts))


class SimulatedRDMAPool(LocalPool):
    """LocalPool + a per-verb latency/bandwidth model: every charge
    slice is priced on this node's ``Fabric`` into ``sim_s``."""

    kind = "sim_rdma"

    def __init__(self, store: Store, *, fabric: Optional[Fabric] = None,
                 use_gather_kernel: bool = False, sleep: bool = False,
                 parallel: bool = False):
        self.fabric = fabric or RDMA_100G
        self.sleep = sleep
        self.parallel = parallel
        self.sim_s: dict[str, float] = {}      # per-verb modeled seconds
        super().__init__(store, use_gather_kernel=use_gather_kernel)

    def model_dt(self, n_bytes: float, descriptors: float,
                 trips: float) -> float:
        """Modeled seconds of one charge slice on this node's fabric."""
        f = self.fabric
        return (trips * f.rtt_s + descriptors * f.per_op_s
                + n_bytes / f.bw_Bps)

    def _transport(self, verb: str, n_bytes: Slices, descriptors: Slices,
                   trips: Slices) -> None:
        b = np.atleast_1d(np.asarray(n_bytes, np.float64))
        d = np.atleast_1d(np.asarray(descriptors, np.float64))
        t = np.atleast_1d(np.asarray(trips, np.float64))
        dt = fanout_dt([self.model_dt(bi, di, ti)
                        for bi, di, ti in zip(b, d, t)],
                       self.parallel and len(b) > 1)
        self.sim_s[verb] = self.sim_s.get(verb, 0.0) + dt
        if self.sleep:
            time.sleep(dt)

    @property
    def sim_total_s(self) -> float:
        """Total modeled wire seconds across all verbs."""
        return sum(self.sim_s.values())

    def snapshot(self) -> dict:
        """See ``MemoryPool.snapshot``; adds fabric calibration and the
        per-verb modeled-seconds breakdown."""
        out = super().snapshot()
        # full fabric calibration, not just the name: benchmark rows
        # built from this snapshot are self-describing
        out["fabric"] = fabric_params(self.fabric)
        out["sim_s"] = dict(self.sim_s)
        out["sim_total_s"] = self.sim_total_s
        return out


def fabric_params(f: Fabric) -> dict:
    """The parameters the latency model prices with, JSON-ready."""
    return {"name": f.name, "rtt_us": f.rtt_s * 1e6,
            "bw_GBps": f.bw_Bps / 1e9, "per_op_us": f.per_op_s * 1e6,
            "max_doorbell": f.max_doorbell}
