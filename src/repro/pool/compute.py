"""ComputeClient — the compute-pool node of the disaggregated system.

Owns exactly what the paper lets a compute instance hold: the cached
representative meta-HNSW (§3.1), the resident-partition cache tiers
(§3.3, exact and/or quantized), the round scheduler, and the device
serve kernels.  Every byte of index data it touches arrives through a
``MemoryPool`` verb (``pool/protocol.py``) — span reads, row reads, and
one-sided appends — so swapping the transport (in-process, simulated
RDMA, and later a real fabric) never changes a line here.

``core/engine.py DHNSWEngine`` is a thin facade over (ComputeClient +
pool); the search/insert bodies below are the engine's previous
monolithic paths re-expressed on the boundary, kept bit-identical for
``pool="local"``.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import device_store as DS
from repro.core import layout as LA
from repro.core import meta as ME
from repro.core import scheduler as SCH
from repro.core import search as S
from repro.core.cost_model import NetLedger
from repro.core.hnsw import HNSWParams
from repro.core.scheduler import pow2_pad
from repro.obs.trace import TRACER
from repro.pool.protocol import MemoryPool


class ComputeClient:
    """Plans greedy search against a ``MemoryPool`` (build once, then
    ``search``/``insert`` batches)."""

    def __init__(self, cfg, pool_factory):
        self.cfg = cfg
        self._pool_factory = pool_factory   # Store -> MemoryPool
        self.pool: Optional[MemoryPool] = None
        self.meta: Optional[ME.MetaIndex] = None
        self.tiers: Optional[SCH.TieredCacheState] = None
        self._extra: dict[int, np.ndarray] = {}   # inserted gid -> vector
        self._extra_pid: dict[int, int] = {}
        self._n0 = 0                              # base dataset size
        self._data: Optional[np.ndarray] = None
        self._last_insert_net: Optional[dict] = None
        # dense-resident flat stage-1 state (quant_kernel route)
        self._flat_synced = False
        self._flat_idx = None

    @property
    def store(self):
        """The pool's host ``Store`` (compat view for tests/benchmarks)."""
        return self.pool.store

    # ------------------------------------------------------------ build

    def build(self, data: np.ndarray) -> "ComputeClient":
        """Partition ``data``, build the meta-HNSW + serialized region,
        hand the region to the pool, and warm the compute-side caches."""
        cfg = self.cfg
        data = np.asarray(data, np.float32)
        self._data = data
        self._n0 = data.shape[0]
        self.meta = ME.build_meta(data, cfg.n_rep, seed=cfg.seed,
                                  meta_levels=cfg.meta_levels)
        store = LA.build_store(
            data, self.meta,
            sub_params=HNSWParams(M=max(cfg.sub_M0 // 2, 2), M0=cfg.sub_M0,
                                  ef_construction=cfg.ef_construction))
        self._adopt(store)
        return self

    def adopt_built(self, meta: ME.MetaIndex, store,
                    data: np.ndarray) -> "ComputeClient":
        """Wire a meta + region built elsewhere (the streaming
        ``repro.ingest.BulkLoader``) into the client and warm the same
        caches ``build`` would.  ``data`` backs repack/rebuild lookups
        and may be a read-only disk-backed view (np.memmap) — the
        builder never needs the full dataset resident."""
        self._data = data
        self._n0 = data.shape[0]
        self.meta = meta
        self._adopt(store)
        return self

    def _adopt(self, store) -> None:
        """Shared tail of ``build``/``adopt_built``: hand the region to
        the pool and warm the compute-side caches."""
        cfg = self.cfg
        self.pool = self._pool_factory(store)
        # compute pool (cached, replicated): the meta-HNSW
        self._meta_vecs = jnp.asarray(self.meta.graph.vectors)
        self._meta_adj = jnp.asarray(self.meta.graph.adjacency)
        self._meta_entry = int(self.meta.graph.entry)
        cap = max(2, int(np.ceil(cfg.cache_frac * self.meta.n_partitions)))
        self._cap0 = cap
        self._setup_caches(cap)

    def _setup_caches(self, cap: int):
        cfg = self.cfg
        if cfg.quant == "none":
            self.tiers = None
            self.cache = SCH.LRUCacheState(cap)
            spec = self.pool.spec
            self._cache_g = jnp.full((cap, spec.fetch_blocks, spec.gblk), -1,
                                     jnp.int32)
            self._cache_v = jnp.zeros((cap, spec.fetch_blocks, spec.vblk),
                                      jnp.float32)
        else:
            self._setup_quant(cap)
        self._flat_synced = False

    def _setup_quant(self, cap: int):
        """Attach the int8 mirror and size the two device tiers from the
        SAME byte budget a quant="none" engine would spend on ``cap``
        full-precision slots: a small exact tier (``exact_frac`` of the
        budget) plus a quantized tier filling the remainder — ~3-4x the
        partitions per byte, so stage-1 hits replace remote reads."""
        cfg = self.cfg
        st = self.pool.store
        if (st.qvec_buf is not None
                and st.spec.quant_group == cfg.quant_group):
            # the loader (or a previous attach) already built the mirror
            # host-side with the same codec geometry — stage it, don't
            # re-quantize the whole region
            self.pool._stage_quant()
        else:
            self.pool.attach_quant(cfg.quant_group)
        spec = self.pool.spec
        pb = spec.partition_bytes()
        qpb = spec.quant_partition_bytes(
            include_graph=cfg.search_mode == "graph")
        exact_cap = max(1, int(round(cap * cfg.exact_frac)))
        quant_cap = max(2, int((cap - exact_cap) * pb // qpb))
        self.tiers = SCH.TieredCacheState(quant_cap, exact_cap)
        self.cache = self.tiers.exact   # legacy helpers see the exact tier
        self._cache_g = jnp.full((exact_cap, spec.fetch_blocks, spec.gblk),
                                 -1, jnp.int32)
        self._cache_v = jnp.zeros((exact_cap, spec.fetch_blocks, spec.vblk),
                                  jnp.float32)
        self._cache_qg = jnp.full((quant_cap, spec.fetch_blocks, spec.gblk),
                                  -1, jnp.int32)
        self._cache_qv = jnp.zeros((quant_cap, spec.fetch_blocks, spec.vblk),
                                   jnp.int8)
        self._cache_qs = jnp.zeros(
            (quant_cap, spec.fetch_blocks, spec.n_qgroups), jnp.float32)

    def _lookup(self, gids: np.ndarray) -> np.ndarray:
        out = np.zeros((len(gids), self.pool.spec.dim), np.float32)
        for i, g in enumerate(int(x) for x in gids):
            out[i] = self._data[g] if g < self._n0 else self._extra[g]
        return out

    # ------------------------------------------------------------ search

    def _route(self, q_dev, b: int):
        """Meta-HNSW routing — cached in the compute pool, no network."""
        pids, _ = S.meta_route(self._meta_vecs, self._meta_adj, q_dev,
                               self._meta_entry, b=b,
                               n_levels=self.meta.graph.n_levels)
        return np.asarray(jax.block_until_ready(pids))

    def search(self, queries: np.ndarray, k: int = 10,
               ef: Optional[int] = None, b: Optional[int] = None):
        """Batched top-k.  Returns (dists (B,k), gids (B,k), stats)."""
        cfg = self.cfg
        ef = ef or cfg.ef
        b = b or cfg.b
        if cfg.quant != "none":
            return self._search_quant(queries, k=k, ef=ef, b=b)
        pool = self.pool
        spec = pool.spec
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        q_dev = jnp.asarray(queries)
        ledger = NetLedger(cfg.fabric)
        stats = {"meta_s": 0.0, "sub_s": 0.0, "plan_s": 0.0,
                 "n_rounds": 0, "n_pairs": 0}

        t0 = time.perf_counter()
        pids = self._route(q_dev, b)
        stats["meta_s"] = time.perf_counter() - t0
        TRACER.add("compute.route", "compute", t0, stats["meta_s"], B=B)

        # plan (compute-instance CPU role)
        t0 = time.perf_counter()
        owner_of = getattr(pool, "owner_of_pid", None)
        if cfg.mode == "naive":
            raw = SCH.naive_plan(pids)
            # every pair is its own READ round trip (the 3.547 trips/
            # query); dedup below is compute-only, so movement through
            # the pool goes uncharged (ledger=None) — already posted
            pool.post_span_reads(len(raw), ledger=ledger, doorbell=1,
                                 pids=[p for _, p in raw])
            uniq = sorted({p for _, p in raw})
            cache = SCH.LRUCacheState(max(len(uniq), 1))
            plan = SCH.plan_batch(pids, cache, doorbell=1)
        else:
            plan = SCH.plan_batch(pids, self.cache, doorbell=cfg.doorbell,
                                  owner_of=owner_of)
        stats["plan_s"] = time.perf_counter() - t0
        TRACER.add("compute.plan", "compute", t0, stats["plan_s"],
                   rounds=len(plan.rounds), fetches=plan.n_fetches,
                   hits=plan.n_cache_hits)

        # rounds: fetch -> serve -> merge (all device-side; the running
        # top-k is carried as (B, k) device arrays and each round folds
        # in with ONE fused scatter-merge — no host loop over pairs)
        mt_dev = pool.read_meta()
        run_d = jnp.full((B, k), jnp.inf, jnp.float32)
        run_g = jnp.full((B, k), -1, jnp.int32)
        cache_state = cache if cfg.mode == "naive" else self.cache
        if cfg.mode == "naive":
            cache_g = jnp.full((cache_state.capacity, spec.fetch_blocks,
                                spec.gblk), -1, jnp.int32)
            cache_v = jnp.zeros((cache_state.capacity, spec.fetch_blocks,
                                 spec.vblk), jnp.float32)
            fetch_ledger = None          # naive pre-charged every demand
            fetch_doorbell = 1
        else:
            cache_g, cache_v = self._cache_g, self._cache_v
            fetch_ledger = ledger
            fetch_doorbell = 1 if cfg.mode == "no_doorbell" else cfg.doorbell

        for rnd in plan.rounds:
            stats["n_rounds"] += 1
            with TRACER.span("compute.round", tier="compute",
                             fetch=int(len(rnd.fetch_pids)),
                             pairs=int(len(rnd.serve_pairs))):
                if len(rnd.fetch_pids):
                    with TRACER.span("compute.fetch", tier="compute",
                                     spans=int(len(rnd.fetch_pids))):
                        g_blocks, v_blocks = pool.read_spans(
                            rnd.fetch_pids, ledger=fetch_ledger,
                            doorbell=fetch_doorbell)
                        slots = jnp.asarray(rnd.fetch_slots, jnp.int32)
                        cache_g, cache_v = DS.write_slots(
                            spec, cache_g, cache_v, slots, g_blocks,
                            v_blocks)
                if not len(rnd.serve_pairs):
                    continue
                t0 = time.perf_counter()
                n = len(rnd.serve_pairs)
                npad = pow2_pad(n)
                qi, ppid, pslot, prank, valid = rnd.serve_tensors(npad, B)
                # n_lanes is fixed at b (a query never has more than b
                # pairs in one round) so recompiles depend only on
                # (B, npad)
                run_d, run_g = DS.serve_and_merge(
                    spec, cache_g, cache_v, mt_dev, q_dev, run_d, run_g,
                    jnp.asarray(qi), jnp.asarray(ppid), jnp.asarray(pslot),
                    jnp.asarray(prank), jnp.asarray(valid), k=k, ef=ef,
                    mode=cfg.search_mode, n_lanes=b)
                dt = time.perf_counter() - t0
                stats["sub_s"] += dt
                TRACER.add("compute.serve", "compute", t0, dt, pairs=n)
                stats["n_pairs"] += n

        t0 = time.perf_counter()
        run_d = np.asarray(jax.block_until_ready(run_d))
        run_g = np.asarray(run_g).astype(np.int64)
        stats["sub_s"] += time.perf_counter() - t0
        if cfg.mode != "naive":
            self._cache_g, self._cache_v = cache_g, cache_v
        stats["net"] = ledger.as_dict()
        stats["round_trips_per_query"] = ledger.round_trips / max(B, 1)
        stats["cache_hits"] = plan.n_cache_hits
        stats["n_fetches"] = plan.n_fetches
        stats["pool"] = pool.snapshot()
        return run_d, run_g, stats

    # ------------------------------------------------------ staged search

    def _search_quant(self, queries: np.ndarray, k: int, ef: int, b: int):
        """Two-stage search over the quantized resident tier.

        Stage 1 plans against the LARGE quantized tier (same §3.3 round
        machinery, same doorbell batching — misses move int8 codes +
        codebook blocks, ~1/3-1/4 the bytes of an exact span) and pools
        per-query top-m candidates with their exact-row addresses.
        Stage 2 fetches ONLY the candidate rows in full precision
        (rows in exact-tier-resident partitions are free) and re-ranks.
        When the quantized tier is dense-resident (it can hold every
        partition) and the in-partition search is the flat scan, stage 1
        routes through the fused ``quant_topk`` Pallas kernel instead
        (``_stage1_flat``); the per-pair jnp path is the fallback.
        """
        cfg = self.cfg
        pool = self.pool
        spec = pool.spec
        include_graph = cfg.search_mode == "graph"
        pb = spec.partition_bytes()
        qpb = spec.quant_partition_bytes(include_graph=include_graph)
        row_b = spec.row_bytes()
        m = max(int(cfg.rerank_m) or 2 * k, k)
        queries = np.asarray(queries, np.float32)
        B = queries.shape[0]
        q_dev = jnp.asarray(queries)
        ledger = NetLedger(cfg.fabric)
        stats = {"meta_s": 0.0, "sub_s": 0.0, "plan_s": 0.0,
                 "n_rounds": 0, "n_pairs": 0, "quant": cfg.quant,
                 "rerank_m": m}

        if self._flat_kernel_active():
            pool_d, pool_p, plan = self._stage1_flat(q_dev, B, m, ledger,
                                                     stats)
            tiers = self.tiers
        else:
            pool_d, pool_p, plan, tiers = self._stage1_pairs(
                q_dev, B, m, ef, b, qpb, pb, ledger, stats)

        # stage-2 accounting: pool payload -> row fetch plan
        t0 = time.perf_counter()
        pool_p = jax.block_until_ready(pool_p)
        stats["sub_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        pool_h = np.asarray(pool_p)
        live = pool_h[:, :, 1] >= 0
        flat_rows = pool_h[:, :, 1][live]
        flat_pids = pool_h[:, :, 2][live]
        n_admitted = 0
        if cfg.mode == "naive":
            # every (query, row) need is its own remote read (real pids
            # so a sharded pool can attribute each to its destination)
            pool.post_row_reads([(int(p), 1) for p in flat_pids],
                                ledger=ledger, doorbell=1)
            stats["rerank_rows"] = int(len(flat_rows))
            stats["rerank_hit_rows"] = 0
        else:
            # query-aware: each needed row moves at most once per batch
            uniq_rows, first = np.unique(flat_rows, return_index=True)
            uniq_pids = flat_pids[first]
            resident = tiers.exact.resident()
            hit = np.isin(uniq_pids, np.fromiter(resident, np.int64,
                                                 len(resident)))
            groups: dict[int, int] = {}
            for p in uniq_pids[~hit].tolist():
                groups[p] = groups.get(p, 0) + 1
            items = sorted(groups.items())
            pool.post_row_reads(
                items, ledger=ledger,
                doorbell=1 if cfg.mode == "no_doorbell" else cfg.doorbell)
            if items:
                ledger.save(pb * len(items)
                            - sum(c for _, c in items) * row_b)
            for p in set(uniq_pids[hit].tolist()):
                tiers.exact.touch(int(p))
            # cost-based admission: a partition whose cumulative missed
            # re-rank rows already outweigh one span fetch is promoted
            for p, cnt in items:
                tiers.note_rerank_miss(int(p), cnt)
                if tiers.should_admit(int(p), row_b, pb):
                    slot, _ = tiers.admit_exact(int(p))
                    g_b, v_b = pool.read_spans(np.array([int(p)]),
                                               ledger=ledger, doorbell=1)
                    self._cache_g, self._cache_v = DS.write_slots(
                        spec, self._cache_g, self._cache_v,
                        jnp.asarray([slot], jnp.int32), g_b, v_b)
                    n_admitted += 1
            stats["rerank_rows"] = int((~hit).sum())
            stats["rerank_hit_rows"] = int(hit.sum())
        dt = time.perf_counter() - t0
        stats["plan_s"] += dt
        TRACER.add("compute.rerank_plan", "compute", t0, dt,
                   admitted=n_admitted)
        stats["exact_admitted"] = n_admitted

        # stage-2 re-rank: exact distances over candidate rows only
        t0 = time.perf_counter()
        with TRACER.span("compute.rerank", tier="compute", m=m):
            vrows = pool.read_rows(pool_p[:, :, 1])
            run_d, run_g = DS.rerank_gathered(vrows, q_dev, pool_p[:, :, 1],
                                              pool_p[:, :, 0], k=k)
            run_d = np.asarray(jax.block_until_ready(run_d))
        run_g = np.asarray(run_g).astype(np.int64)
        stats["sub_s"] += time.perf_counter() - t0

        stats["net"] = ledger.as_dict()
        stats["round_trips_per_query"] = ledger.round_trips / max(B, 1)
        stats["cache_hits"] = plan["n_cache_hits"]
        stats["n_fetches"] = plan["n_fetches"]
        stats["pool"] = pool.snapshot()
        return run_d, run_g, stats

    def _stage1_pairs(self, q_dev, B: int, m: int, ef: int, b: int,
                      qpb: int, pb: int, ledger, stats):
        """Per-pair stage 1 (the jnp fallback): plan against the
        quantized tier with the §3.3 round machinery and pool top-m
        candidates via fused per-round scatter-merges."""
        cfg = self.cfg
        pool = self.pool
        spec = pool.spec
        include_graph = cfg.search_mode == "graph"

        t0 = time.perf_counter()
        pids = self._route(q_dev, b)
        stats["meta_s"] = time.perf_counter() - t0
        TRACER.add("compute.route", "compute", t0, stats["meta_s"], B=B)

        # stage-1 plan against the quantized tier.  A quantized span
        # read moves the codes + codebook (and, in graph mode, the
        # adjacency blocks): 2 descriptors per span
        t0 = time.perf_counter()
        if cfg.mode == "naive":
            raw = SCH.naive_plan(pids)
            pool.post_span_reads(len(raw), ledger=ledger, doorbell=1,
                                 quant=True, quant_graph=include_graph,
                                 pids=[p for _, p in raw])
            ledger.save(len(raw) * (pb - qpb))
            uniq = sorted({p for _, p in raw})
            tiers = SCH.TieredCacheState(max(len(uniq), 1), 1)
            plan = SCH.plan_batch(pids, tiers.quant, doorbell=1)
        else:
            tiers = self.tiers
            plan = SCH.plan_batch(pids, tiers.quant, doorbell=cfg.doorbell,
                                  owner_of=getattr(pool, "owner_of_pid",
                                                   None))
        stats["plan_s"] = time.perf_counter() - t0
        TRACER.add("compute.plan", "compute", t0, stats["plan_s"],
                   rounds=len(plan.rounds), fetches=plan.n_fetches,
                   hits=plan.n_cache_hits)

        # stage-1 rounds: fetch quantized spans -> pool candidates
        mt_dev = pool.read_meta()
        pool_d = jnp.full((B, m), jnp.inf, jnp.float32)
        pool_p = jnp.full((B, m, 3), -1, jnp.int32)
        if cfg.mode == "naive":
            qcap = tiers.quant.capacity
            cache_qg = jnp.full((qcap, spec.fetch_blocks, spec.gblk), -1,
                                jnp.int32)
            cache_qv = jnp.zeros((qcap, spec.fetch_blocks, spec.vblk),
                                 jnp.int8)
            cache_qs = jnp.zeros((qcap, spec.fetch_blocks, spec.n_qgroups),
                                 jnp.float32)
            fetch_ledger = None
            fetch_doorbell = 1
        else:
            cache_qg, cache_qv, cache_qs = (self._cache_qg, self._cache_qv,
                                            self._cache_qs)
            fetch_ledger = ledger
            fetch_doorbell = 1 if cfg.mode == "no_doorbell" else cfg.doorbell

        for rnd in plan.rounds:
            stats["n_rounds"] += 1
            with TRACER.span("compute.round", tier="compute",
                             fetch=int(len(rnd.fetch_pids)),
                             pairs=int(len(rnd.serve_pairs))):
                if len(rnd.fetch_pids):
                    with TRACER.span("compute.fetch", tier="compute",
                                     spans=int(len(rnd.fetch_pids)),
                                     quant=True):
                        g_blocks, qv_blocks, qs_blocks = pool.read_spans(
                            rnd.fetch_pids, ledger=fetch_ledger,
                            doorbell=fetch_doorbell, quant=True,
                            quant_graph=include_graph)
                        if fetch_ledger is not None:
                            ledger.save(len(rnd.fetch_pids) * (pb - qpb))
                        slots = jnp.asarray(rnd.fetch_slots, jnp.int32)
                        cache_qg, cache_qv, cache_qs = DS.write_slots_quant(
                            spec, cache_qg, cache_qv, cache_qs, slots,
                            g_blocks, qv_blocks, qs_blocks)
                if not len(rnd.serve_pairs):
                    continue
                t0 = time.perf_counter()
                n = len(rnd.serve_pairs)
                npad = pow2_pad(n)
                qi, ppid, pslot, prank, valid = rnd.serve_tensors(npad, B)
                pool_d, pool_p = DS.serve_quant_pool(
                    spec, cache_qg, cache_qv, cache_qs, mt_dev, q_dev,
                    pool_d, pool_p, jnp.asarray(qi), jnp.asarray(ppid),
                    jnp.asarray(pslot), jnp.asarray(prank),
                    jnp.asarray(valid), m=m, ef=max(ef, m),
                    mode=cfg.search_mode, n_lanes=b)
                dt = time.perf_counter() - t0
                stats["sub_s"] += dt
                TRACER.add("compute.serve", "compute", t0, dt, pairs=n,
                           quant=True)
                stats["n_pairs"] += n
        if cfg.mode != "naive":
            self._cache_qg, self._cache_qv, self._cache_qs = (
                cache_qg, cache_qv, cache_qs)
        return pool_d, pool_p, {"n_cache_hits": plan.n_cache_hits,
                                "n_fetches": plan.n_fetches}, tiers

    # ------------------------------------------------ flat stage-1 (kernel)

    def _flat_kernel_active(self) -> bool:
        """The quant_topk route: only for flat (scan) stage 1, and only
        when the quantized tier is dense-resident — it can hold every
        partition, so after one sweep the whole int8 database lives at
        the compute node and stage 1 never touches the wire again."""
        cfg = self.cfg
        return (cfg.quant_kernel != "off" and cfg.search_mode == "scan"
                and self.tiers is not None
                and self.tiers.quant.capacity >= self.pool.spec.n_partitions)

    def _sync_flat(self, ledger) -> None:
        """Populate (or refresh) the dense-resident flat view.

        Cold sync charges one quantized-span read per partition,
        doorbell-batched — the same bytes the per-pair path would pay to
        warm a tier of this size.  Afterwards the view stays coherent
        for free on inserts (the writer already holds the rows it
        appends — its own one-sided WRITE moved them); repacks and
        rebuilds force a full resync.
        """
        cfg = self.cfg
        spec = self.pool.spec
        self.pool.post_span_reads(
            spec.n_partitions, ledger=ledger,
            doorbell=1 if cfg.mode in ("naive", "no_doorbell")
            else cfg.doorbell,
            quant=True, quant_graph=False,
            pids=np.arange(spec.n_partitions))
        rows, gids, pids = LA.flat_quant_rows(self.pool.store)
        n = len(rows)
        npad = pow2_pad(max(n, 1), lo=256)
        self._flat_idx = np.full(npad, -1, np.int64)
        self._flat_idx[:n] = rows
        self._flat_gid = np.full(npad, -1, np.int64)
        self._flat_gid[:n] = gids
        self._flat_pid = np.full(npad, -1, np.int64)
        self._flat_pid[:n] = pids
        self._flat_n = n
        codes, scales = self.pool.read_quant_rows(
            jnp.asarray(self._flat_idx, jnp.int32))
        self._flat_codes = jax.block_until_ready(codes)
        self._flat_scales = scales
        # mark every partition resident so insert invalidation (drop)
        # has something to invalidate -> forces a resync
        for p in range(spec.n_partitions):
            self.tiers.quant.admit(p)
        self._flat_synced = True

    def _stage1_flat(self, q_dev, B: int, m: int, ledger, stats):
        """Stage 1 as ONE fused int8 scan: ``quant_topk`` (Pallas on
        real accelerators; under ``quant_kernel="auto"`` the jnp ref on
        CPU, where Pallas would interpret) over the flat dense-resident
        database.
        No meta routing, no rounds — every live row is a candidate, so
        recall is bounded below by the per-pair path at equal m."""
        from repro.kernels.quant_topk.ops import auto_use_ref, quant_topk

        cfg = self.cfg
        # "ref" forces the jnp oracle everywhere; "auto" picks it only
        # where Pallas would run interpreted (CPU), and Pallas elsewhere
        use_ref = (cfg.quant_kernel == "ref"
                   or (cfg.quant_kernel == "auto" and auto_use_ref()))
        t0 = time.perf_counter()
        cold = not self._flat_synced
        if cold:
            with TRACER.span("compute.flat_sync", tier="compute"):
                self._sync_flat(ledger)
            ledger.save(self.pool.spec.n_partitions
                        * (self.pool.spec.partition_bytes()
                           - self.pool.spec.quant_partition_bytes(
                               include_graph=False)))
        stats["plan_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        with TRACER.span("compute.stage1_flat", tier="compute",
                         rows=int(self._flat_n), B=B):
            d, idx = quant_topk(q_dev, self._flat_codes, self._flat_scales,
                                min(m, self._flat_n), cfg.quant_group,
                                n_valid=self._flat_n, use_ref=use_ref)
            d, idx = jax.block_until_ready((d, idx))
        safe = jnp.maximum(idx, 0)
        live = idx >= 0
        pool_p = jnp.stack([
            jnp.where(live, jnp.asarray(self._flat_gid)[safe], -1),
            jnp.where(live, jnp.asarray(self._flat_idx)[safe], -1),
            jnp.where(live, jnp.asarray(self._flat_pid)[safe], -1),
        ], axis=-1).astype(jnp.int32)
        pool_d = jnp.where(live, d, jnp.inf)
        if pool_d.shape[1] < m:           # flat DB smaller than the pool
            pad = m - pool_d.shape[1]
            pool_d = jnp.pad(pool_d, ((0, 0), (0, pad)),
                             constant_values=jnp.inf)
            pool_p = jnp.pad(pool_p, ((0, 0), (0, pad), (0, 0)),
                             constant_values=-1)
        stats["sub_s"] += time.perf_counter() - t0
        stats["n_rounds"] = 1
        stats["n_pairs"] = B
        stats["quant_kernel"] = "flat"
        stats["stage1_impl"] = "ref" if use_ref else "pallas"
        stats["flat_rows"] = int(self._flat_n)
        return pool_d, pool_p, {
            "n_cache_hits": 0 if cold else B,
            "n_fetches": self.pool.spec.n_partitions if cold else 0}

    # ------------------------------------------------------------ insert

    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Dynamic insertion (paper §3.2): route via the cached meta-
        HNSW, append vector+id into the target group's shared overflow
        region through the pool ``append`` verb (one remote WRITE each),
        repack the group when it fills."""
        cfg = self.cfg
        pool = self.pool
        spec = pool.spec
        vecs = np.asarray(vecs, np.float32).reshape(-1, spec.dim)
        t0 = time.perf_counter()
        pids = self._route(jnp.asarray(vecs), b=1)[:, 0]
        TRACER.add("compute.route", "compute", t0,
                   time.perf_counter() - t0, B=int(len(vecs)))
        gids = np.arange(self._n0 + len(self._extra),
                         self._n0 + len(self._extra) + len(vecs))
        ledger = NetLedger(cfg.fabric)
        for vec, gid, pid in zip(vecs, gids, pids.tolist()):
            self._extra[int(gid)] = vec
            self._extra_pid[int(gid)] = int(pid)
            slot = pool.append(vec, int(gid), int(pid), ledger=ledger)
            if slot < 0:
                group = int(pool.store.meta_table[pid, LA.MT_GROUP])
                ok = pool.repack(group, self._lookup)
                if not ok:
                    # the full rebuild folds _extra — INCLUDING this
                    # vector — into the rebuilt base partitions, so
                    # appending it again would duplicate its gid
                    self._full_rebuild()
                    continue
                self._invalidate_group(group)
                # re-stage through the pool append verb: unlike the old
                # monolithic path (which wrote the host mirror only and
                # left the device twin stale until the next repack), the
                # verb performs the device + quant-mirror twin writes
                slot = pool.append(vec, int(gid), int(pid), ledger=ledger)
                assert slot >= 0, "overflow full right after repack"
                self._flat_synced = False   # repack moved base rows
                continue
            self._invalidate_pid(int(pid))
            if self._flat_synced:
                self._append_flat(int(gid), int(pid))
        self._last_insert_net = ledger.as_dict()
        return gids

    def _append_flat(self, gid: int, pid: int):
        """Keep the dense-resident flat view coherent with one append:
        the writer already holds the row (it produced the WRITE), so
        this is pure compute-side bookkeeping — no wire traffic."""
        n = self._flat_n
        if n >= len(self._flat_idx):
            self._flat_synced = False        # outgrew the pad: resync
            return
        mrow = self.pool.store.meta_table[pid]
        side = int(mrow[LA.MT_SIDE])
        cnt = int(mrow[LA.MT_OV_A if side == 0 else LA.MT_OV_B])
        slot = cnt - 1 if side == 0 else self.pool.spec.ov_cap - cnt
        group = int(mrow[LA.MT_GROUP])
        co = LA.overflow_write_coords(self.pool.spec, group, slot)
        row = (co["vec_block"] * self.pool.spec.slot_vecs
               + co["vec_off"] // self.pool.spec.dim)
        self._flat_idx[n] = row
        self._flat_gid[n] = gid
        self._flat_pid[n] = pid
        self._flat_n = n + 1
        # only row n changed: single-row gather + in-place scatter, so a
        # flat-route insert stays O(D), not O(N*D)
        codes, scales = self.pool.read_quant_rows(
            jnp.asarray([row], jnp.int32))
        self._flat_codes = self._flat_codes.at[n].set(codes[0])
        self._flat_scales = self._flat_scales.at[n].set(scales[0])

    def _invalidate_pid(self, pid: int):
        """Drop stale cached copies (both partners see the ov region)."""
        group = int(self.pool.store.meta_table[pid, LA.MT_GROUP])
        self._invalidate_group(group)

    def _invalidate_group(self, group: int):
        for side in (0, 1):
            p = group * 2 + side
            if self.tiers is not None:
                self.tiers.invalidate(p)    # drops BOTH tiers
            self.cache.drop(p)

    def _full_rebuild(self):
        """np_max exhausted: rebuild the whole region with a larger pad
        (rare; the paper's offline re-pack path)."""
        data = np.concatenate([self._data, np.stack(
            [self._extra[g] for g in sorted(self._extra)])]) \
            if self._extra else self._data
        assigns = np.concatenate([
            self.meta.assignments,
            np.array([self._extra_pid[g] for g in sorted(self._extra)],
                     np.int32)])
        import dataclasses as DC
        self.meta = DC.replace(self.meta, assignments=assigns)
        self._data = data
        self._n0 = data.shape[0]
        self._extra.clear()
        self._extra_pid.clear()
        old_spec = self.pool.spec
        store = LA.build_store(
            data, self.meta, ov_cap=old_spec.ov_cap,
            slot_vecs=old_spec.slot_vecs,
            sub_params=HNSWParams(M=max(self.cfg.sub_M0 // 2, 2),
                                  M0=self.cfg.sub_M0,
                                  ef_construction=self.cfg.ef_construction))
        self.pool.adopt(store)
        if self.tiers is not None:
            self._setup_quant(self._cap0)
        else:
            cap = self.cache.capacity
            self._setup_caches(cap)
        self._flat_synced = False
