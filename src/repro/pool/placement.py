"""Group-granular placement policies for the sharded memory pool.

The layout's unit of locality is the *group*: two partner sub-HNSWs
around one shared overflow region, serialized contiguously (§3.2).  A
fetch span never crosses a group boundary, so assigning whole groups to
shards guarantees every doorbell descriptor names blocks on exactly one
memory node — the invariant that lets ``ShardedPool`` form descriptor
batches per destination.

A ``PlacementPolicy`` owns the group -> shard map and (optionally) its
evolution under load:

* ``RoundRobinPlacement``   — group g lives on shard g % N.  The
  baseline; ignores sizes and heat.
* ``SizeBalancedPlacement`` — greedy LPT over live rows per group, so
  shards hold near-equal bytes even when partition sizes are skewed.
* ``FrequencyAwarePlacement`` — starts round-robin, counts span
  accesses per group, and every ``migrate_every`` accesses plans up to
  ``max_moves`` migrations of the hottest groups away from the most
  loaded (slowest × hottest) shard toward the fastest/least-loaded one.
  Per-shard load is modeled as ``cost_s * hits_s`` where ``cost_s`` is
  the shard's modeled seconds per span read (0 for an in-process
  child), i.e. exactly the term that dominates a parallel fan-out's
  critical path.  Counters decay after each rebalance so stale heat
  ages out instead of pinning history forever.

Replication and capacity (both beyond the policies themselves) are
layered on top by two pure functions:

* ``apply_budgets``     — enforce per-shard byte budgets on an existing
  assignment: an overflowing group spills to the *next-best* shard
  (cheapest, then least loaded, among shards with room), and only when
  no shard has room does it land on the globally least-loaded one — the
  budgets are capacity targets, not hard admission control, because the
  region has to live somewhere.
* ``place_replicated``  — expand a primary assignment to an
  ``(n_groups, n_replicas)`` replica matrix: column 0 is the (budgeted)
  primary, every further column picks a *distinct* shard per group
  ranked by (has-room, cost, load).  ``ShardedPool`` serves reads from
  the fastest/least-loaded live replica and fans writes to all of them.

Policies are stateful and owned by ONE pool each (``place`` resets the
state); ``make_placement`` accepts either a policy name or an instance.
"""
from __future__ import annotations

import abc
from typing import Optional, Sequence, Union

import numpy as np


class PlacementPolicy(abc.ABC):
    """Group -> shard assignment (+ optional migration under load)."""

    name: str = "abstract"

    @abc.abstractmethod
    def place(self, n_groups: int, n_shards: int, *,
              group_sizes: Optional[np.ndarray] = None,
              shard_costs: Optional[Sequence[float]] = None) -> np.ndarray:
        """Initial assignment: (n_groups,) int array of shard indices.
        Resets any per-instance counters."""

    def note_access(self, group: int) -> bool:
        """Record one span access to ``group``.  Returns True when the
        policy wants the pool to run ``plan_moves`` (rebalance due)."""
        return False

    def plan_moves(self, owner: np.ndarray, *,
                   group_sizes: Optional[np.ndarray] = None,
                   shard_costs: Optional[Sequence[float]] = None
                   ) -> list[tuple[int, int, int]]:
        """Migrations to apply now: [(group, src_shard, dst_shard)].
        Static policies return []."""
        return []


class RoundRobinPlacement(PlacementPolicy):
    """Static baseline: group g lives on shard g % n_shards."""

    name = "round_robin"

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        """See ``PlacementPolicy.place``; sizes and costs are ignored."""
        return np.arange(n_groups, dtype=np.int64) % max(n_shards, 1)


class SizeBalancedPlacement(PlacementPolicy):
    """Greedy LPT on live rows per group: biggest group first, each to
    the currently lightest shard — shards end within one group of even
    byte load even under skewed partition sizes."""

    name = "size_balanced"

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        """See ``PlacementPolicy.place``; LPT over ``group_sizes``."""
        n_shards = max(n_shards, 1)
        sizes = (np.ones(n_groups) if group_sizes is None
                 else np.asarray(group_sizes, np.float64))
        owner = np.zeros(n_groups, np.int64)
        loads = np.zeros(n_shards, np.float64)
        # stable sort keeps equal-size groups in index order -> with
        # uniform sizes this degrades gracefully to round-robin-like
        for g in np.argsort(-sizes, kind="stable"):
            s = int(np.argmin(loads))
            owner[g] = s
            loads[s] += sizes[g]
        return owner


class FrequencyAwarePlacement(PlacementPolicy):
    """Hot-group migration toward the fastest / least-loaded shard.

    ``note_access`` accumulates per-group span-read counts; every
    ``migrate_every`` accesses the pool is asked to rebalance.  A move
    is accepted only while it strictly lowers the busiest shard's
    modeled time ``cost_s * hits_s`` by at least ``min_gain`` — the
    hysteresis that keeps near-balanced loads from ping-ponging.
    """

    name = "freq"

    def __init__(self, *, migrate_every: int = 512, max_moves: int = 4,
                 decay: float = 0.5, min_gain: float = 0.05):
        self.migrate_every = max(int(migrate_every), 1)
        self.max_moves = max(int(max_moves), 1)
        self.decay = float(decay)
        self.min_gain = float(min_gain)
        self._counts = np.zeros(0, np.float64)
        self._since = 0

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        """See ``PlacementPolicy.place``; round-robin start, resets the
        access counters that drive later ``plan_moves``."""
        self._counts = np.zeros(n_groups, np.float64)
        self._since = 0
        return np.arange(n_groups, dtype=np.int64) % max(n_shards, 1)

    def note_access(self, group: int) -> bool:
        """See ``PlacementPolicy.note_access``; True every
        ``migrate_every`` accesses."""
        if group < len(self._counts):
            self._counts[group] += 1.0
        self._since += 1
        if self._since >= self.migrate_every:
            self._since = 0
            return True
        return False

    @staticmethod
    def _norm_costs(n_shards: int, shard_costs) -> np.ndarray:
        """Per-shard seconds per span read; all-equal (incl. all-zero,
        the in-process case) collapses to uniform cost 1 so the policy
        still balances pure load."""
        if shard_costs is None:
            return np.ones(n_shards, np.float64)
        c = np.asarray(shard_costs, np.float64)
        if np.allclose(c, c[0]):
            return np.ones(n_shards, np.float64)
        return c

    def plan_moves(self, owner: np.ndarray, *, group_sizes=None,
                   shard_costs=None) -> list[tuple[int, int, int]]:
        """See ``PlacementPolicy.plan_moves``; greedy hottest-group
        moves off the busiest shard while the max load strictly drops."""
        owner = np.asarray(owner).copy()
        n_shards = int(owner.max()) + 1 if len(owner) else 1
        if shard_costs is not None:
            n_shards = max(n_shards, len(shard_costs))
        cost = self._norm_costs(n_shards, shard_costs)
        counts = self._counts[: len(owner)]
        loads = np.array([cost[s] * counts[owner == s].sum()
                          for s in range(n_shards)])
        moves: list[tuple[int, int, int]] = []
        for _ in range(self.max_moves):
            src = int(np.argmax(loads))
            dst = int(np.argmin(loads))
            if src == dst:
                break
            cand = np.nonzero(owner == src)[0]
            cand = cand[counts[cand] > 0]
            if not len(cand):
                break
            g = int(cand[np.argmax(counts[cand])])
            h = counts[g]
            new_src = loads[src] - cost[src] * h
            new_dst = loads[dst] + cost[dst] * h
            # accept only if the pair's max strictly drops (with margin)
            if max(new_src, new_dst) >= loads[src] * (1.0 - self.min_gain):
                break
            loads[src], loads[dst] = new_src, new_dst
            owner[g] = dst
            moves.append((g, src, dst))
        self._counts *= self.decay
        return moves


# ------------------------------------------------------- capacity layer

def _norm_sizes(n_groups: int, group_sizes) -> np.ndarray:
    """Per-group size signal (live rows or bytes); uniform 1 when the
    caller has none — budgets then count groups instead of bytes."""
    if group_sizes is None:
        return np.ones(n_groups, np.float64)
    return np.asarray(group_sizes, np.float64)


def _shard_rank(costs: np.ndarray, loads: np.ndarray) -> np.ndarray:
    """Shards ordered best-first: cheapest (modeled seconds per span)
    wins, load breaks cost ties, index keeps it deterministic."""
    return np.lexsort((np.arange(len(costs)), loads, costs))


def apply_budgets(owner: np.ndarray, *, group_sizes=None,
                  shard_budgets: Optional[Sequence[float]] = None,
                  shard_costs: Optional[Sequence[float]] = None
                  ) -> np.ndarray:
    """Capacity-aware repair of a group -> shard assignment.

    Groups are kept where the policy put them while the owning shard
    stays within its budget (``shard_budgets[s]`` in the same unit as
    ``group_sizes``, typically bytes).  A group that would overflow its
    shard *spills to the next-best shard* — cheapest, then least
    loaded, among the shards that still have room — processed biggest
    group first so the large spans get first pick of the remaining
    capacity.  When every shard is full the group lands on the globally
    least-loaded one: budgets shape placement, they never reject data.
    Returns a new owner array; the input is not mutated.
    """
    owner = np.asarray(owner, np.int64).copy()
    if shard_budgets is None or not len(owner):
        return owner
    n_shards = max(int(owner.max()) + 1, len(shard_budgets))
    sizes = _norm_sizes(len(owner), group_sizes)
    budgets = np.asarray(shard_budgets, np.float64)
    costs = (np.asarray(shard_costs, np.float64) if shard_costs is not None
             else np.zeros(n_shards))
    loads = np.zeros(n_shards, np.float64)
    for g in np.argsort(-sizes, kind="stable"):
        s = int(owner[g])
        if loads[s] + sizes[g] <= budgets[s]:
            loads[s] += sizes[g]
            continue
        room = loads + sizes[g] <= budgets
        cand = _shard_rank(costs, loads)
        cand = [c for c in cand if room[c]]
        s2 = int(cand[0]) if cand else int(np.argmin(loads))
        owner[g] = s2
        loads[s2] += sizes[g]
    return owner


def place_replicated(owner: np.ndarray, n_shards: int, n_replicas: int, *,
                     group_sizes=None,
                     shard_budgets: Optional[Sequence[float]] = None,
                     shard_costs: Optional[Sequence[float]] = None
                     ) -> np.ndarray:
    """Expand a primary assignment into an (n_groups, R) replica matrix.

    Column 0 is ``owner`` verbatim (already budget-repaired by the
    caller); each further column assigns every group one more *distinct*
    shard, chosen best-first by (still-has-room, cost, load) with loads
    accumulated across all columns — so replicas both avoid their own
    primaries and spread by capacity.  ``n_replicas`` is clamped to
    ``n_shards`` (R distinct shards cannot exceed the fleet).
    """
    owner = np.asarray(owner, np.int64)
    r = max(1, min(int(n_replicas), int(n_shards)))
    reps = np.full((len(owner), r), -1, np.int64)
    reps[:, 0] = owner
    if r == 1:
        return reps
    sizes = _norm_sizes(len(owner), group_sizes)
    budgets = (np.asarray(shard_budgets, np.float64)
               if shard_budgets is not None
               else np.full(n_shards, np.inf))
    costs = (np.asarray(shard_costs, np.float64) if shard_costs is not None
             else np.zeros(n_shards))
    loads = np.zeros(n_shards, np.float64)
    for g in range(len(owner)):
        loads[owner[g]] += sizes[g]
    for col in range(1, r):
        for g in np.argsort(-sizes, kind="stable"):
            taken = set(reps[g, :col].tolist())
            cand = [int(s) for s in _shard_rank(costs, loads)
                    if s not in taken]
            with_room = [s for s in cand
                         if loads[s] + sizes[g] <= budgets[s]]
            s = (with_room or cand)[0]
            reps[g, col] = s
            loads[s] += sizes[g]
    return reps


_POLICIES = {
    "round_robin": RoundRobinPlacement,
    "size_balanced": SizeBalancedPlacement,
    "freq": FrequencyAwarePlacement,
}


def make_placement(spec: Union[str, PlacementPolicy, None] = "round_robin",
                   **kw) -> PlacementPolicy:
    """Policy name (or ready instance) -> ``PlacementPolicy``."""
    if spec is None:
        spec = "round_robin"
    if isinstance(spec, PlacementPolicy):
        return spec
    try:
        return _POLICIES[spec](**kw)
    except KeyError:
        raise ValueError(f"unknown placement policy {spec!r} "
                         f"(have {sorted(_POLICIES)})") from None


# ----------------------------------------------------- rescale / rebalance
# (migration planning over the block-contiguous owner mapping — folded in
# from the retired repro.distributed.elastic / .fault_tolerance stubs)

def plan_store_migration(n_blocks: int, old_tp: int, new_tp: int):
    """Block moves for rescaling the memory-pool owner count.

    Returns ``[(src_owner, dst_owner, first_block, n)]`` — contiguous
    spans only (the layout guarantee).  Total moved bytes is the
    rescale cost.
    """
    old_per = -(-n_blocks // old_tp)
    new_per = -(-n_blocks // new_tp)
    moves = []
    b = 0
    while b < n_blocks:
        src = min(b // old_per, old_tp - 1)
        dst = min(b // new_per, new_tp - 1)
        # span until either owner boundary changes
        nxt = min((b // old_per + 1) * old_per,
                  (b // new_per + 1) * new_per, n_blocks)
        if src != dst:
            moves.append((src, dst, b, nxt - b))
        b = nxt
    return moves


def rebalance_partitions(owners, sick: set, n_owners: int):
    """Reassign partitions owned by sick memory instances to the
    least-loaded healthy ones.  The paper's layout makes each migration
    a contiguous copy of one group span.  Returns (new_owners, moves).
    """
    owners = np.asarray(owners).copy()
    healthy = [o for o in range(n_owners) if o not in sick]
    if not healthy:
        raise RuntimeError("no healthy memory instances left")
    load = {o: int((owners == o).sum()) for o in healthy}
    moves = []
    for pid in np.nonzero(np.isin(owners, list(sick)))[0]:
        tgt = min(load, key=load.get)
        moves.append((int(pid), int(owners[pid]), tgt))
        owners[pid] = tgt
        load[tgt] += 1
    return owners, moves
