"""Group-granular placement policies for the sharded memory pool.

The layout's unit of locality is the *group*: two partner sub-HNSWs
around one shared overflow region, serialized contiguously (§3.2).  A
fetch span never crosses a group boundary, so assigning whole groups to
shards guarantees every doorbell descriptor names blocks on exactly one
memory node — the invariant that lets ``ShardedPool`` form descriptor
batches per destination.

A ``PlacementPolicy`` owns the group -> shard map and (optionally) its
evolution under load:

* ``RoundRobinPlacement``   — group g lives on shard g % N.  The
  baseline; ignores sizes and heat.
* ``SizeBalancedPlacement`` — greedy LPT over live rows per group, so
  shards hold near-equal bytes even when partition sizes are skewed.
* ``FrequencyAwarePlacement`` — starts round-robin, counts span
  accesses per group, and every ``migrate_every`` accesses plans up to
  ``max_moves`` migrations of the hottest groups away from the most
  loaded (slowest × hottest) shard toward the fastest/least-loaded one.
  Per-shard load is modeled as ``cost_s * hits_s`` where ``cost_s`` is
  the shard's modeled seconds per span read (0 for an in-process
  child), i.e. exactly the term that dominates a parallel fan-out's
  critical path.  Counters decay after each rebalance so stale heat
  ages out instead of pinning history forever.

Policies are stateful and owned by ONE pool each (``place`` resets the
state); ``make_placement`` accepts either a policy name or an instance.
"""
from __future__ import annotations

import abc
from typing import Optional, Sequence, Union

import numpy as np


class PlacementPolicy(abc.ABC):
    """Group -> shard assignment (+ optional migration under load)."""

    name: str = "abstract"

    @abc.abstractmethod
    def place(self, n_groups: int, n_shards: int, *,
              group_sizes: Optional[np.ndarray] = None,
              shard_costs: Optional[Sequence[float]] = None) -> np.ndarray:
        """Initial assignment: (n_groups,) int array of shard indices.
        Resets any per-instance counters."""

    def note_access(self, group: int) -> bool:
        """Record one span access to ``group``.  Returns True when the
        policy wants the pool to run ``plan_moves`` (rebalance due)."""
        return False

    def plan_moves(self, owner: np.ndarray, *,
                   group_sizes: Optional[np.ndarray] = None,
                   shard_costs: Optional[Sequence[float]] = None
                   ) -> list[tuple[int, int, int]]:
        """Migrations to apply now: [(group, src_shard, dst_shard)].
        Static policies return []."""
        return []


class RoundRobinPlacement(PlacementPolicy):

    name = "round_robin"

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        return np.arange(n_groups, dtype=np.int64) % max(n_shards, 1)


class SizeBalancedPlacement(PlacementPolicy):
    """Greedy LPT on live rows per group: biggest group first, each to
    the currently lightest shard — shards end within one group of even
    byte load even under skewed partition sizes."""

    name = "size_balanced"

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        n_shards = max(n_shards, 1)
        sizes = (np.ones(n_groups) if group_sizes is None
                 else np.asarray(group_sizes, np.float64))
        owner = np.zeros(n_groups, np.int64)
        loads = np.zeros(n_shards, np.float64)
        # stable sort keeps equal-size groups in index order -> with
        # uniform sizes this degrades gracefully to round-robin-like
        for g in np.argsort(-sizes, kind="stable"):
            s = int(np.argmin(loads))
            owner[g] = s
            loads[s] += sizes[g]
        return owner


class FrequencyAwarePlacement(PlacementPolicy):
    """Hot-group migration toward the fastest / least-loaded shard.

    ``note_access`` accumulates per-group span-read counts; every
    ``migrate_every`` accesses the pool is asked to rebalance.  A move
    is accepted only while it strictly lowers the busiest shard's
    modeled time ``cost_s * hits_s`` by at least ``min_gain`` — the
    hysteresis that keeps near-balanced loads from ping-ponging.
    """

    name = "freq"

    def __init__(self, *, migrate_every: int = 512, max_moves: int = 4,
                 decay: float = 0.5, min_gain: float = 0.05):
        self.migrate_every = max(int(migrate_every), 1)
        self.max_moves = max(int(max_moves), 1)
        self.decay = float(decay)
        self.min_gain = float(min_gain)
        self._counts = np.zeros(0, np.float64)
        self._since = 0

    def place(self, n_groups: int, n_shards: int, *, group_sizes=None,
              shard_costs=None) -> np.ndarray:
        self._counts = np.zeros(n_groups, np.float64)
        self._since = 0
        return np.arange(n_groups, dtype=np.int64) % max(n_shards, 1)

    def note_access(self, group: int) -> bool:
        if group < len(self._counts):
            self._counts[group] += 1.0
        self._since += 1
        if self._since >= self.migrate_every:
            self._since = 0
            return True
        return False

    @staticmethod
    def _norm_costs(n_shards: int, shard_costs) -> np.ndarray:
        """Per-shard seconds per span read; all-equal (incl. all-zero,
        the in-process case) collapses to uniform cost 1 so the policy
        still balances pure load."""
        if shard_costs is None:
            return np.ones(n_shards, np.float64)
        c = np.asarray(shard_costs, np.float64)
        if np.allclose(c, c[0]):
            return np.ones(n_shards, np.float64)
        return c

    def plan_moves(self, owner: np.ndarray, *, group_sizes=None,
                   shard_costs=None) -> list[tuple[int, int, int]]:
        owner = np.asarray(owner).copy()
        n_shards = int(owner.max()) + 1 if len(owner) else 1
        if shard_costs is not None:
            n_shards = max(n_shards, len(shard_costs))
        cost = self._norm_costs(n_shards, shard_costs)
        counts = self._counts[: len(owner)]
        loads = np.array([cost[s] * counts[owner == s].sum()
                          for s in range(n_shards)])
        moves: list[tuple[int, int, int]] = []
        for _ in range(self.max_moves):
            src = int(np.argmax(loads))
            dst = int(np.argmin(loads))
            if src == dst:
                break
            cand = np.nonzero(owner == src)[0]
            cand = cand[counts[cand] > 0]
            if not len(cand):
                break
            g = int(cand[np.argmax(counts[cand])])
            h = counts[g]
            new_src = loads[src] - cost[src] * h
            new_dst = loads[dst] + cost[dst] * h
            # accept only if the pair's max strictly drops (with margin)
            if max(new_src, new_dst) >= loads[src] * (1.0 - self.min_gain):
                break
            loads[src], loads[dst] = new_src, new_dst
            owner[g] = dst
            moves.append((g, src, dst))
        self._counts *= self.decay
        return moves


_POLICIES = {
    "round_robin": RoundRobinPlacement,
    "size_balanced": SizeBalancedPlacement,
    "freq": FrequencyAwarePlacement,
}


def make_placement(spec: Union[str, PlacementPolicy, None] = "round_robin",
                   **kw) -> PlacementPolicy:
    """Policy name (or ready instance) -> ``PlacementPolicy``."""
    if spec is None:
        spec = "round_robin"
    if isinstance(spec, PlacementPolicy):
        return spec
    try:
        return _POLICIES[spec](**kw)
    except KeyError:
        raise ValueError(f"unknown placement policy {spec!r} "
                         f"(have {sorted(_POLICIES)})") from None
