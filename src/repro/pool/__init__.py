"""Disaggregated-memory boundary: MemoryPool transports + ComputeClient.

The paper's architecture as an API (see ``protocol.py``): compute nodes
(``ComputeClient``) plan greedy search and talk to the serialized region
only through ``MemoryPool`` verbs.  Transports:

* ``LocalPool``         — in-process device arrays (bit-identical to the
                          pre-pool monolithic engine);
* ``SimulatedRDMAPool`` — + per-verb latency/bandwidth model;
* ``ShardedPool``       — the region split group-granularly across N
                          child pools with per-shard doorbell fan-out
                          and pluggable (migrating) placement;
* ``RemotePool``        — (``repro/net``) the verbs marshaled over TCP
                          to a ``PoolServer`` process, measured wire
                          bytes cross-checked against the model.
"""
from repro.pool.compute import ComputeClient
from repro.pool.local import LocalPool
from repro.pool.placement import (FrequencyAwarePlacement, PlacementPolicy,
                                  RoundRobinPlacement, SizeBalancedPlacement,
                                  make_placement)
from repro.pool.protocol import MemoryPool, span_wire_bytes
from repro.pool.sharded import ShardedPool
from repro.pool.sim_rdma import SimulatedRDMAPool, fabric_params, fanout_dt

__all__ = ["MemoryPool", "LocalPool", "SimulatedRDMAPool", "ShardedPool",
           "ComputeClient", "PlacementPolicy", "RoundRobinPlacement",
           "SizeBalancedPlacement", "FrequencyAwarePlacement",
           "make_placement", "make_pool_factory", "span_wire_bytes",
           "fanout_dt", "fabric_params"]


def make_pool_factory(cfg):
    """Store -> MemoryPool, per ``EngineConfig.pool``."""
    if cfg.pool == "local":
        return lambda store: LocalPool(
            store, use_gather_kernel=cfg.use_gather_kernel)
    if cfg.pool == "sim_rdma":
        return lambda store: SimulatedRDMAPool(
            store, fabric=cfg.fabric,
            use_gather_kernel=cfg.use_gather_kernel)
    if cfg.pool == "remote":
        # lazy import: the net subsystem is only needed when it is used
        from repro.net.client import RemotePool
        bearer = getattr(cfg, "bearer", "tcp")
        if bearer == "loopback":
            # in-process HostRegion behind the same verbs/QP path — no
            # endpoints, no sockets (the conformance bearer)
            return lambda store: RemotePool(store, None, fabric=cfg.fabric,
                                            bearer="loopback")
        eps = tuple(cfg.endpoints or ())
        if not eps:
            raise ValueError("pool='remote' needs EngineConfig.endpoints")
        if len(eps) == 1:
            return lambda store: RemotePool(store, eps[0],
                                            fabric=cfg.fabric)
        # several server processes: shard over one RemotePool per node
        children = [lambda store, ep=ep: RemotePool(store, ep,
                                                    fabric=cfg.fabric)
                    for ep in eps]
        return lambda store: ShardedPool(
            store, children, placement=make_placement(cfg.placement),
            parallel=cfg.shard_parallel,
            replication=getattr(cfg, "replication", 1),
            shard_budgets=getattr(cfg, "shard_budgets", None),
            straggler_check_every=getattr(cfg, "straggler_check_every", 0))
    if cfg.pool == "sharded":
        def child(fabric, ep=None):
            if cfg.shard_transport == "local":
                return lambda store: LocalPool(
                    store, use_gather_kernel=cfg.use_gather_kernel)
            if cfg.shard_transport == "sim_rdma":
                return lambda store: SimulatedRDMAPool(
                    store, fabric=fabric,
                    use_gather_kernel=cfg.use_gather_kernel)
            if cfg.shard_transport == "remote":
                from repro.net.client import RemotePool
                bearer = getattr(cfg, "bearer", "tcp")
                return lambda store: RemotePool(store, ep, fabric=fabric,
                                                bearer=bearer)
            raise ValueError(
                f"unknown shard transport {cfg.shard_transport!r}")

        fabrics = (cfg.shard_fabrics if cfg.shard_fabrics is not None
                   else (cfg.fabric,) * cfg.n_shards)
        if len(fabrics) != cfg.n_shards:
            raise ValueError(f"shard_fabrics has {len(fabrics)} entries "
                             f"for n_shards={cfg.n_shards}")
        if (cfg.shard_transport == "remote"
                and getattr(cfg, "bearer", "tcp") == "tcp"):
            eps = tuple(cfg.endpoints or ())
            if len(eps) != cfg.n_shards:
                raise ValueError(f"endpoints has {len(eps)} entries "
                                 f"for n_shards={cfg.n_shards}")
        else:
            # in-process children never take endpoints — ignore any so
            # zip below can't silently truncate the shard list
            eps = (None,) * cfg.n_shards
        return lambda store: ShardedPool(
            store, [child(f, ep) for f, ep in zip(fabrics, eps)],
            placement=make_placement(cfg.placement),
            parallel=cfg.shard_parallel,
            replication=getattr(cfg, "replication", 1),
            shard_budgets=getattr(cfg, "shard_budgets", None),
            straggler_check_every=getattr(cfg, "straggler_check_every", 0))
    raise ValueError(f"unknown pool transport {cfg.pool!r}")
