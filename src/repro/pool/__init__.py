"""Disaggregated-memory boundary: MemoryPool transports + ComputeClient.

The paper's architecture as an API (see ``protocol.py``): compute nodes
(``ComputeClient``) plan greedy search and talk to the serialized region
only through ``MemoryPool`` verbs.  Transports:

* ``LocalPool``         — in-process device arrays (bit-identical to the
                          pre-pool monolithic engine);
* ``SimulatedRDMAPool`` — + per-verb latency/bandwidth model.
"""
from repro.pool.compute import ComputeClient
from repro.pool.local import LocalPool
from repro.pool.protocol import MemoryPool, span_wire_bytes
from repro.pool.sim_rdma import SimulatedRDMAPool

__all__ = ["MemoryPool", "LocalPool", "SimulatedRDMAPool", "ComputeClient",
           "make_pool_factory", "span_wire_bytes"]


def make_pool_factory(cfg):
    """Store -> MemoryPool, per ``EngineConfig.pool``."""
    if cfg.pool == "local":
        return lambda store: LocalPool(
            store, use_gather_kernel=cfg.use_gather_kernel)
    if cfg.pool == "sim_rdma":
        return lambda store: SimulatedRDMAPool(
            store, fabric=cfg.fabric,
            use_gather_kernel=cfg.use_gather_kernel)
    raise ValueError(f"unknown pool transport {cfg.pool!r}")
