"""ShardedPool — the region split (and replicated) across N memory nodes.

One memory node cannot hold a production-scale region, and §3.3's
doorbell batching only pays off at scale when descriptor batches are
formed *per destination node*.  ``ShardedPool`` implements the full
``MemoryPool`` protocol over N child pools (any mix of ``LocalPool`` /
``SimulatedRDMAPool`` / ``RemotePool``, including heterogeneous fabrics
per shard to model stragglers):

* **Group-granular placement** — the unit of ownership is the layout
  *group* (two partner sub-HNSWs + their shared overflow, §3.2), so a
  fetch span never straddles shards and every doorbell descriptor names
  blocks on exactly one node.  A pluggable ``PlacementPolicy``
  (``pool/placement.py``) owns the group -> shard map; the
  frequency-aware policy migrates hot groups toward the fastest /
  least-loaded shard at runtime (``refresh_blocks`` re-stages the
  arriving group on the destination node; results are bit-identical
  before and after a migration).
* **Replication** (``replication=R``) — every group is placed on R
  distinct shards under optional per-shard byte budgets
  (``placement.apply_budgets`` / ``place_replicated``).  Reads are
  served by the fastest / least-loaded live replica of each group
  (recomputed whenever liveness or placement changes); committed writes
  (``append`` / ``repack``) fan out to the remaining replicas as
  block-granular ``refresh_blocks`` re-stages, accounted under
  ``replication_io`` — background traffic, never charged to a request
  ledger, so ledger parity with a single pool is preserved exactly.
* **Failover** — a child raising ``PoolUnavailableError`` is marked
  dead: in-flight reads transparently retry on a surviving replica,
  and every group the dead shard held is *re-replicated* from the host
  region (the source of truth) onto the best surviving shard with room.
  With ``replication=1`` there is nothing to fail over to and the error
  surfaces, exactly as before.
* **Elastic scale** — ``add_shard`` stages the region on a new child
  and moves only the groups the placement policy would newly put there
  (incremental rebalance); ``remove_shard`` drains a node through the
  same re-replication path as a failure, minus the failure.
* **Per-shard doorbell fan-out** — ``read_spans`` / ``read_rows`` /
  ``read_quant_rows`` / ``post_*`` split each descriptor batch by
  serving shard and charge each slice on that shard's own fabric; the
  caller's ledger sees summed bytes/descriptors and ``trips = max``
  over shards when ``parallel=True`` (nodes answer their batches
  concurrently — the critical path is the slowest slice) or the sum in
  serial mode.  With one shard this reduces exactly to the child's own
  accounting.
* **Write routing** — ``append``/``repack`` execute once on the
  primary live replica, which keeps its device twin (and the quantized
  mirror / flat-quant row index) coherent; the shared host region stays
  the single source of truth, so a rebuild (``adopt``), migration,
  replica fan-out, or post-failure re-replication can always re-stage
  any node from it.

1/N staging: the children share the serialized host region (this
container has one address space), but each capable child compacts its
*device* copy to just the groups it holds replicas of
(``LocalPool.restrict_staging`` — block-compacted, with a region-block
-> staged-slot indirection), so per-shard device bytes scale ~1/N with
the fleet.  Migration, replica fan-out, and failover healing re-stage
only the moved blocks (an arriving group is adopted onto the compacted
tail at group granularity); children without the hook (``RemotePool``
— the server already holds only bytes it was sent) are left alone.
What the model measures — per-destination verb counts, wire bytes, and
modeled time — is exactly what a multi-node deployment would see over
real transports.
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import layout as LA
from repro.core.cost_model import NetLedger
from repro.core.layout import Store
from repro.pool.placement import (PlacementPolicy, _shard_rank,
                                  apply_budgets, make_placement,
                                  place_replicated)
from repro.pool.protocol import (MemoryPool, PoolUnavailableError,
                                 _fresh_totals)
from repro.pool.sim_rdma import fanout_dt


class ShardedPool(MemoryPool):
    """The region split group-granularly across N child pools.

    Reads fan out per destination shard (doorbell batches formed per
    node); a ``PlacementPolicy`` owns the group -> shard map and may
    migrate hot groups at runtime.  With ``replication >= 2`` every
    group lives on R distinct shards (``placement.place_replicated``):
    reads are served from the fastest/least-loaded live replica,
    committed writes fan to the others via ``refresh_blocks``, and a
    ``PoolUnavailableError`` from a child marks the shard dead, retries
    the read on a survivor, and re-replicates the dead shard's groups
    from the host region.  Request ledgers are charged once regardless
    of R — replication/failover/elastic traffic is accounted in its own
    counters (``replication_io``/``failover``/``elastic``), never on
    the query wire, so ledger parity with a single-node pool holds.
    """

    kind = "sharded"

    def __init__(self, store: Store,
                 child_factories: Sequence[Callable[[Store], MemoryPool]],
                 *, placement="round_robin", parallel: bool = True,
                 replication: int = 1,
                 shard_budgets: Optional[Sequence[float]] = None,
                 straggler: Optional[dict] = None,
                 straggler_check_every: int = 0):
        assert len(child_factories) >= 1, "need at least one shard"
        self.store = store
        self.children = [f(store) for f in child_factories]
        for s, c in enumerate(self.children):
            c.shard_id = s        # keys the per-(verb, shard) histograms
        self.placement: PlacementPolicy = make_placement(placement)
        self.parallel = parallel
        self.replication = max(1, int(replication))
        self.shard_budgets = (None if shard_budgets is None
                              else [float(b) for b in shard_budgets])
        self.verbs: Counter = Counter()
        self.totals = _fresh_totals()
        self.sim_s: dict[str, float] = {}
        self.migration = {"n": 0, "bytes": 0.0, "sim_s": 0.0}
        # background replica fan-out of committed writes (not request-
        # charged, like migration)
        self.replication_io = {"fanout_writes": 0, "bytes": 0.0,
                               "sim_s": 0.0}
        # failure handling: deaths seen, read batches that had to retry
        # on a survivor, the healing copies that followed, and shards
        # that rejoined from their own durable state (recover_shard)
        self.failover = {"deaths": 0, "read_retries": 0,
                         "rereplicated_groups": 0,
                         "rereplicate_bytes": 0.0, "lost_groups": 0,
                         "recovered_shards": 0, "recovered_groups": 0}
        # groups each dead shard held at death, for recover_shard
        self._dead_held: dict[int, list[int]] = {}
        # planned fleet changes (add_shard / remove_shard)
        self.elastic = {"added": 0, "removed": 0, "moved_groups": 0,
                        "bytes": 0.0}
        # tail-divergence detection over the children's per-(verb, shard)
        # latency histograms; a flagged shard's serving cost is penalized
        # by its observed tail excess so replica reads route around it
        from repro.obs.hist import StragglerDetector
        self.straggler = StragglerDetector(**(straggler or {}))
        self._check_every = max(0, int(straggler_check_every))
        self._since_check = 0
        self._straggler_penalty: dict[int, float] = {}
        self._last_straggler_report: Optional[dict] = None
        self.straggler_stats = {"checks": 0, "flagged_now": 0,
                                "reroutes": 0, "moved_groups": 0}
        # dead children skipped during a trace drain (satellite: a dying
        # PoolServer must never poison the query path via observability)
        self.trace_harvest_failures = 0
        self._alive = np.ones(len(self.children), bool)
        self._reset_placement()
        self._stage_meta()

    # ------------------------------------------------------------ ownership

    @property
    def n_shards(self) -> int:
        """Fleet size, dead shards included (indices stay stable)."""
        return len(self.children)

    def owner_of_group(self, group: int) -> int:
        """Shard currently *serving* the group's reads (its fastest /
        least-loaded live replica; the only replica when R=1)."""
        return int(self._serve[group])

    def owner_of_pid(self, pid: int) -> int:
        """Destination shard of one partition's fetch span (a partition
        is served where its group is served) — also the shard-aware
        doorbell key the round scheduler groups descriptors by."""
        return int(self._serve[int(pid) // 2])

    def replicas_of_group(self, group: int) -> list[int]:
        """All shards holding the group (live or not; -1 = unfilled)."""
        return [int(s) for s in self._replicas[group]]

    def _owners_of_pids(self, pids) -> np.ndarray:
        return self._serve[np.asarray(pids, np.int64) // 2]

    def _owners_of_rows(self, rows) -> np.ndarray:
        """Serving shard per region row address (-1 rows -> -1)."""
        rows = np.asarray(rows, np.int64)
        grp = (rows // self.spec.slot_vecs) // self.spec.group_blocks
        own = self._serve[np.clip(grp, 0, len(self._serve) - 1)]
        return np.where(rows >= 0, own, -1)

    def _live_replicas(self, group: int) -> list[int]:
        """Live replicas of one group, primary first; raises when the
        group has lost every copy (nothing left to serve or write)."""
        reps = [int(s) for s in self._replicas[group]
                if s >= 0 and self._alive[s]]
        if not reps:
            raise PoolUnavailableError(
                f"group {group} has no live replica (replication="
                f"{self._replicas.shape[1]}, alive="
                f"{int(self._alive.sum())}/{self.n_shards})")
        return reps

    def _require_live(self, owners: np.ndarray, pids: np.ndarray) -> None:
        if (owners < 0).any():
            lost = sorted({int(p) // 2 for p in pids[owners < 0]})
            raise PoolUnavailableError(
                f"groups {lost} have no live replica "
                f"(alive={int(self._alive.sum())}/{self.n_shards})")

    def _group_rows(self) -> np.ndarray:
        """Live rows per group (base + overflow) — the size signal for
        size-balanced placement."""
        spec, mt = self.spec, self.store.meta_table
        rows = np.zeros(spec.n_groups, np.int64)
        for pid in range(spec.n_partitions):
            rows[pid // 2] += int(self.store.n_base[pid])
        first = 2 * np.arange(spec.n_groups)
        rows += mt[first, LA.MT_OV_A].astype(np.int64)
        rows += mt[first, LA.MT_OV_B].astype(np.int64)
        return rows

    def _shard_costs(self) -> list[float]:
        """Modeled seconds per span read, per shard (0 = in-process) —
        the speed signal for replica selection and hot-group migration."""
        pb = float(self.spec.partition_bytes())
        return [c.model_dt(pb, 1.0, 1.0) if hasattr(c, "model_dt") else 0.0
                for c in self.children]

    def _block_copy_bytes(self, n_blocks: int) -> float:
        """Host -> node bytes of re-staging ``n_blocks`` region blocks
        (graph + vectors, plus the quantized mirror when attached) —
        the unit of migration / replication / failover accounting."""
        spec = self.spec
        nb = float(n_blocks * spec.block_bytes())
        if self.store.qvec_buf is not None:
            nb += float(n_blocks * (spec.vblk + spec.n_qgroups * 4))
        return nb

    def _group_footprint_bytes(self) -> float:
        """Serialized bytes of one group — the capacity unit per-shard
        byte budgets are enforced in (groups are fixed-size regions)."""
        return self._block_copy_bytes(self.spec.group_blocks)

    def _reset_placement(self) -> None:
        costs = self._shard_costs()
        owner = np.asarray(
            self.placement.place(self.spec.n_groups, self.n_shards,
                                 group_sizes=self._group_rows(),
                                 shard_costs=costs), np.int64)
        sizes_b = np.full(self.spec.n_groups,
                          self._group_footprint_bytes())
        if self.shard_budgets is not None:
            owner = apply_budgets(owner, group_sizes=sizes_b,
                                  shard_budgets=self.shard_budgets,
                                  shard_costs=costs)
        self._replicas = place_replicated(
            owner, self.n_shards, self.replication,
            group_sizes=sizes_b, shard_budgets=self.shard_budgets,
            shard_costs=costs)
        if not self._alive.all():
            dead = np.nonzero(~self._alive)[0]
            self._replicas[np.isin(self._replicas, dead)] = -1
        self._recompute_serving()
        self._apply_staging()

    def _apply_staging(self, only: Optional[int] = None) -> None:
        """Compact each capable child's device region to the groups it
        holds replicas of (1/N staging).  A full placement (re)build is
        the only time this runs — incremental placement changes go
        through ``refresh_blocks``, which adopts an arriving group onto
        the compacted tail without re-staging anything else.  Children
        without the hook (remote transports) keep their own staging."""
        for s, c in enumerate(self.children):
            if only is not None and s != only:
                continue
            if not self._alive[s] or not hasattr(c, "restrict_staging"):
                continue
            held = [g for g in range(len(self._replicas))
                    if (self._replicas[g] == s).any()]
            c.restrict_staging(held)

    def _recompute_serving(self) -> None:
        """Re-pick each group's serving replica: cheapest (modeled
        seconds per span) live replica, with accumulated serving load
        breaking cost ties so equal-speed replicas split the groups.
        Shards the straggler detector flagged carry their observed tail
        excess as a cost penalty, so reads prefer a healthy replica."""
        costs = np.asarray(self._shard_costs(), np.float64)
        for s, p in getattr(self, "_straggler_penalty", {}).items():
            if 0 <= s < len(costs):
                costs[s] += p
        loads = np.zeros(self.n_shards, np.float64)
        serve = np.full(len(self._replicas), -1, np.int64)
        for g in range(len(self._replicas)):
            best = -1
            for s in self._replicas[g]:
                s = int(s)
                if s < 0 or not self._alive[s]:
                    continue
                if (best < 0 or (costs[s], loads[s], s)
                        < (costs[best], loads[best], best)):
                    best = s
            if best >= 0:
                serve[g] = best
                loads[best] += 1.0
        self._serve = serve

    # ------------------------------------------------------------ charging

    def _child_sim(self, child) -> float:
        return getattr(child, "sim_total_s", 0.0)

    def _scratch(self, shard: int, ledger: NetLedger) -> NetLedger:
        """Per-destination ledger slice, priced on that shard's own
        fabric (falling back to the caller's for in-process children)."""
        fabric = getattr(self.children[shard], "fabric", ledger.fabric)
        return NetLedger(fabric)

    def _charged_call(self, shard: int, ledger: NetLedger, fn):
        """Run one child verb under a scratch ledger; returns the verb
        result and its charge slice (bytes, descriptors, trips, sim_dt)
        — the single place the per-destination bookkeeping lives."""
        child = self.children[shard]
        scratch = self._scratch(shard, ledger)
        t0 = self._child_sim(child)
        res = fn(child, scratch)
        return res, (scratch.bytes, scratch.descriptors,
                     scratch.round_trips, self._child_sim(child) - t0)

    def _charge_fanout(self, verb: str, ledger: Optional[NetLedger],
                       slices: list[tuple]) -> None:
        """Fold per-shard slices [(bytes, descriptors, trips, sim_dt)]
        into the caller's ledger and the pool totals: bytes and
        descriptors sum; trips (and modeled time) reduce by max when the
        shards answer in parallel, by sum in serial mode."""
        if ledger is None or not slices:
            return
        nb = float(sum(s[0] for s in slices))
        nd = float(sum(s[1] for s in slices))
        trips = fanout_dt([s[2] for s in slices], self.parallel)
        dt = fanout_dt([s[3] for s in slices], self.parallel)
        ledger.round_trips += trips
        ledger.descriptors += nd
        ledger.bytes += nb
        ledger.events += 1
        self.totals["round_trips"] += trips
        self.totals["descriptors"] += nd
        self.totals["bytes"] += nb
        if dt:
            self.sim_s[verb] = self.sim_s.get(verb, 0.0) + dt

    # ------------------------------------------------------------ meta

    def _stage_meta(self) -> None:
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False

    # read_meta: the shared MemoryPool implementation (serves the
    # parent's own cached table — children are never consulted)

    def adopt(self, store: Store) -> None:
        """See ``MemoryPool.adopt``; re-registers every live child and
        rebuilds placement (a child dying here is only marked dead —
        the fresh placement already excludes it)."""
        self.store = store
        for s, c in enumerate(self.children):
            if not self._alive[s]:
                continue
            try:
                c.adopt(store)
            except PoolUnavailableError:
                # placement is rebuilt below, so no re-replication here
                self._alive[s] = False
                self.failover["deaths"] += 1
        self._reset_placement()
        self._stage_meta()

    def attach_quant(self, group: int) -> None:
        """See ``MemoryPool.attach_quant``; attaches the mirror once on
        the shared host store, then every live child stages it."""
        LA.attach_quant_mirror(self.store, group)
        self._stage_quant()

    def _stage_quant(self) -> None:
        """Stage the already-attached host mirror on every live child
        (same split as ``LocalPool._stage_quant``: attach once, stage
        everywhere — used when the loader built the mirror host-side)."""
        for s, c in enumerate(self.children):
            if not self._alive[s]:
                continue
            try:
                c._stage_quant()
            except PoolUnavailableError:
                self._on_shard_down(s)

    # ------------------------------------------------------------ reads

    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """See ``MemoryPool.read_spans``; descriptors are batched per
        serving shard (each batch charges its own slice), and a failed
        slice retries on a surviving replica — the failed attempt
        charges nothing, so the total equals the single-node charge."""
        pids = np.asarray(pids).reshape(-1)
        verb = "read_spans_quant" if quant else "read_spans"
        self.verbs[verb] += len(pids)
        if self._check_every and ledger is not None:
            self._since_check += 1
            if self._since_check >= self._check_every:
                self._since_check = 0
                self.check_stragglers()
        m = len(pids)
        parts, slices = [], []
        todo = np.arange(m, dtype=np.int64)
        while len(todo):
            owners = self._owners_of_pids(pids[todo])
            self._require_live(owners, pids[todo])
            retry = []
            for s in np.unique(owners):
                s = int(s)
                idx = todo[owners == s]
                sub = pids[idx]
                try:
                    if ledger is None:
                        res = self.children[s].read_spans(
                            sub, ledger=None, doorbell=doorbell,
                            quant=quant, quant_graph=quant_graph)
                        sl = None
                    else:
                        res, sl = self._charged_call(
                            s, ledger,
                            lambda c, l: c.read_spans(sub, ledger=l,
                                                      doorbell=doorbell,
                                                      quant=quant,
                                                      quant_graph=quant_graph))
                except PoolUnavailableError:
                    # failed slice charged nothing (transports charge
                    # after the wire answers): mark the shard dead, heal,
                    # and re-issue these spans on a surviving replica
                    self._on_shard_down(s)
                    retry.append(idx)
                    continue
                if sl is not None:
                    slices.append(sl)
                parts.append((idx, res))
            if retry:
                self.failover["read_retries"] += 1
                todo = np.concatenate(retry)
            else:
                todo = todo[:0]
        self._charge_fanout(verb, ledger, slices)
        outs = None
        for idx, res in parts:
            if outs is None:
                outs = [jnp.zeros((m,) + r.shape[1:], r.dtype) for r in res]
            di = jnp.asarray(idx, jnp.int32)
            outs = [o.at[di].set(r) for o, r in zip(outs, res)]
        if ledger is not None:        # heat accrues on charged traffic
            self._note_span_access(pids)
        return tuple(outs)

    def _masked_fanout(self, rows, gather):
        """Row-granular fan-out: each shard gathers the full tensor with
        non-owned lanes masked to -1, and the owner's lanes are selected
        back — dead (-1) lanes keep gather-row-0 placeholders exactly
        like a single pool, masked by the caller.  A shard failing
        mid-fan marks it dead and restarts the fan on the healed
        serving map (child gathers are side-effect-free)."""
        rows_h = np.asarray(rows)
        while True:
            owners = self._owners_of_rows(rows_h)
            if ((owners < 0) & (np.asarray(rows_h, np.int64) >= 0)).any():
                raise PoolUnavailableError(
                    f"row read names groups with no live replica (alive="
                    f"{int(self._alive.sum())}/{self.n_shards})")
            out, failed = None, False
            for s in np.unique(owners[owners >= 0]):
                s = int(s)
                mask = owners == s
                sub = jnp.asarray(
                    np.where(mask, rows_h, -1).astype(np.int32))
                try:
                    res = gather(self.children[s], sub)
                except PoolUnavailableError:
                    self._on_shard_down(s)
                    failed = True
                    break
                if not isinstance(res, tuple):
                    res = (res,)
                mdev = jnp.asarray(mask)
                if out is None:
                    out = list(res)
                else:
                    out = [jnp.where(
                        mdev.reshape(mdev.shape + (1,) * (r.ndim - mdev.ndim)),
                        r, o) for o, r in zip(out, res)]
            if failed:
                self.failover["read_retries"] += 1
                continue
            if out is None:           # every lane dead: any child serves
                live = np.nonzero(self._alive)[0]
                s = int(live[0]) if len(live) else 0
                return gather(self.children[s], jnp.asarray(
                    np.asarray(rows_h, np.int64).astype(np.int32)))
            return out[0] if len(out) == 1 else tuple(out)

    def read_rows(self, rows):
        """See ``MemoryPool.read_rows``; fanned by row ownership with
        transparent replica failover."""
        self.verbs["read_rows"] += 1
        return self._masked_fanout(rows, lambda c, r: c.read_rows(r))

    def read_quant_rows(self, rows):
        """See ``MemoryPool.read_quant_rows``; fanned like ``read_rows``."""
        self.verbs["read_quant_rows"] += 1
        return self._masked_fanout(rows,
                                   lambda c, r: c.read_quant_rows(r))

    # ------------------------------------------------- accounting posts

    def post_span_reads(self, n: int, *, ledger: NetLedger,
                        doorbell: int = 1, quant: bool = False,
                        quant_graph: bool = True, pids=None) -> None:
        """See ``MemoryPool.post_span_reads``; with ``pids`` each
        charge is attributed to the span's serving shard."""
        if pids is None:
            # no destination info: price on the caller's fabric, like a
            # single-node pool (callers that know the spans pass pids)
            return super().post_span_reads(n, ledger=ledger,
                                           doorbell=doorbell, quant=quant,
                                           quant_graph=quant_graph)
        self.verbs["post_span_reads"] += n
        pids = np.asarray(pids).reshape(-1)
        owners = self._owners_of_pids(pids)
        slices = []
        for s in range(self.n_shards):
            k = int((owners == s).sum())
            if not k:
                continue
            _, sl = self._charged_call(
                s, ledger,
                lambda c, l: c.post_span_reads(k, ledger=l,
                                               doorbell=doorbell,
                                               quant=quant,
                                               quant_graph=quant_graph))
            slices.append(sl)
        self._charge_fanout("post_span_reads", ledger, slices)
        self._note_span_access(pids)

    def post_row_reads(self, groups, *, ledger: NetLedger,
                       doorbell: int = 1) -> None:
        """See ``MemoryPool.post_row_reads``; groups are charged on
        their owning shard's slice."""
        groups = list(groups)
        self.verbs["post_row_reads"] += len(groups)
        by: dict[int, list] = {}
        for pid, cnt in groups:
            s = self.owner_of_pid(pid) if pid >= 0 else 0
            by.setdefault(max(s, 0), []).append((pid, cnt))
        slices = []
        for s, sub in sorted(by.items()):
            _, sl = self._charged_call(
                s, ledger,
                lambda c, l: c.post_row_reads(sub, ledger=l,
                                              doorbell=doorbell))
            slices.append(sl)
        self._charge_fanout("post_row_reads", ledger, slices)

    # ------------------------------------------------------------ writes

    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """See ``MemoryPool.append``; executes on the primary live
        replica (children share the host store, so exactly one may run
        the insert), charges the write once, then syncs the touched
        blocks to the other replicas via ``refresh_blocks`` (accounted
        in ``replication_io``, not on the request ledger).  A primary
        that dies mid-call is checked for commit via the overflow
        counters before retrying on a survivor."""
        spec = self.spec
        pid_i, gid_i = int(pid), int(gid)
        group = pid_i // 2
        side = int(self.store.meta_table[pid_i, LA.MT_SIDE])
        col = LA.MT_OV_A if side == 0 else LA.MT_OV_B
        slot, sl = -1, None
        while True:
            primary = self._live_replicas(group)[0]
            pre = int(self.store.meta_table[pid_i, col])
            try:
                if ledger is None:
                    slot, sl = self.children[primary].append(
                        vec, gid_i, pid_i, ledger=None), None
                else:
                    slot, sl = self._charged_call(
                        primary, ledger,
                        lambda c, l: c.append(vec, gid_i, pid_i, ledger=l))
                break
            except PoolUnavailableError:
                self._on_shard_down(primary)
                cnt = int(self.store.meta_table[pid_i, col])
                if cnt != pre:
                    # the deterministic insert committed to the host
                    # region (the source of truth) before the wire died:
                    # the write exists, the dead node no longer matters,
                    # and healing already re-staged it onto a survivor.
                    # Charge the caller exactly once, like LocalPool.
                    slot = cnt - 1 if side == 0 else spec.ov_cap - cnt
                    sl = None
                    if ledger is not None:
                        wire = spec.dim * 4 + 8
                        if self.store.qvec_buf is not None:
                            wire += (spec.dim
                                     + (spec.dim // spec.quant_group) * 4)
                        ledger.write(wire, descriptors=1)
                        self.totals["round_trips"] += 1
                        self.totals["descriptors"] += 1
                        self.totals["bytes"] += wire
                    break
                # nothing landed anywhere: clean retry on a survivor
        if slot < 0:
            return slot
        self.verbs["append"] += 1
        self._mt_dirty = True
        if sl is not None:
            self._charge_fanout("append", ledger, [sl])
        lay_group = int(self.store.meta_table[pid_i, LA.MT_GROUP])
        co = LA.overflow_write_coords(spec, lay_group, slot)
        blocks = sorted({int(co["vec_block"]), int(co["gid_block"])})
        self._fan_write(group, blocks, exclude=primary)
        self._notify_mutation("append", group=lay_group, pid=pid_i,
                              slot=int(slot))
        return slot

    def repack(self, group: int, data_lookup) -> bool:
        """See ``MemoryPool.repack``; primary-replica execution with
        the same commit-detection/fan-out discipline as ``append``."""
        group = int(group)
        self.verbs["repack"] += 1
        mt, first = self.store.meta_table, 2 * group
        while True:
            primary = self._live_replicas(group)[0]
            pre = (int(mt[first, LA.MT_OV_A]), int(mt[first, LA.MT_OV_B]))
            try:
                ok = self.children[primary].repack(group, data_lookup)
                break
            except PoolUnavailableError:
                self._on_shard_down(primary)
                if (int(mt[first, LA.MT_OV_A]),
                        int(mt[first, LA.MT_OV_B])) != pre:
                    # the host-side re-pack committed before the block
                    # WRITE shipped; the host region is the source of
                    # truth and the dead node no longer needs the blocks
                    ok = True
                    break
                # host untouched: the re-pack is deterministic — retry
                # wholesale on a survivor
        if ok:
            self._mt_dirty = True
            spec = self.spec
            blocks = np.arange(group * spec.group_blocks,
                               (group + 1) * spec.group_blocks)
            self._fan_write(group, blocks, exclude=primary)
            self._notify_mutation("repack", group=group)
        return ok

    def _fan_write(self, group: int, block_ids, exclude: int) -> None:
        """Propagate a committed write to the group's other live
        replicas: re-stage the touched blocks from the host region (the
        write landed there first).  Background replication traffic —
        accounted in ``replication_io``, never charged to a request
        ledger, exactly like migration — so request-side ledger parity
        with a single pool holds at any R."""
        ids = np.asarray(sorted({int(b) for b in np.asarray(block_ids)
                                 .reshape(-1)}), np.int64)
        nb = self._block_copy_bytes(len(ids))
        for s in [int(x) for x in self._replicas[group]]:
            if s < 0 or s == exclude or not self._alive[s]:
                continue
            try:
                self.children[s].refresh_blocks(ids)
            except PoolUnavailableError:
                self._on_shard_down(s)
                continue
            child = self.children[s]
            dt = (child.model_dt(nb, 1.0, 1.0)
                  if hasattr(child, "model_dt") else 0.0)
            self.replication_io["fanout_writes"] += 1
            self.replication_io["bytes"] += nb
            self.replication_io["sim_s"] += dt
            if dt:
                self.sim_s["replicate"] = (self.sim_s.get("replicate", 0.0)
                                           + dt)

    # ------------------------------------------------------------ failover

    def _stage_group(self, shard: int, group: int) -> None:
        """Re-stage one whole group on ``shard`` from the host region."""
        spec = self.spec
        blocks = np.arange(group * spec.group_blocks,
                           (group + 1) * spec.group_blocks)
        self.children[shard].refresh_blocks(blocks)

    def _on_shard_down(self, shard: int, *, planned: bool = False) -> None:
        """Mark one shard dead and heal: every group replicated there
        gets a replacement replica re-staged from the host region onto
        the best surviving shard (cheapest, then least replica-loaded)
        that holds no copy of it; when no such shard exists the group
        keeps serving from its remaining replicas.  Planned removals
        (``remove_shard``) take the same path but count under
        ``elastic`` instead of ``failover``."""
        shard = int(shard)
        if shard < 0 or shard >= self.n_shards or not self._alive[shard]:
            return
        self._alive[shard] = False
        self._dead_held[shard] = [
            g for g in range(len(self._replicas))
            if (self._replicas[g] == shard).any()]
        if planned:
            self.elastic["removed"] += 1
        else:
            self.failover["deaths"] += 1
        if self._replicas.shape[1] < 2 and not planned:
            # replication=1 keeps the pre-replication contract: an
            # unplanned death is surfaced, not silently healed — the
            # dead shard's groups are lost and reads of them raise.
            # (A *planned* drain still heals: the host region has the
            # bytes and the operator asked for the move.)
            for row in self._replicas:
                if (row == shard).any():
                    row[row == shard] = -1
                    self.failover["lost_groups"] += 1
            self._recompute_serving()
            return
        costs = np.asarray(self._shard_costs(), np.float64)
        loads = np.zeros(self.n_shards, np.float64)
        for row in self._replicas:
            for s in row:
                if s >= 0 and self._alive[s]:
                    loads[int(s)] += 1.0
        fp = self._group_footprint_bytes()
        for g in range(len(self._replicas)):
            row = self._replicas[g]
            cols = np.nonzero(row == shard)[0]
            if not len(cols):
                continue
            placed = False
            while not placed:
                have = {int(s) for s in row if s >= 0 and self._alive[s]}
                cand = [int(s) for s in _shard_rank(costs, loads)
                        if self._alive[s] and int(s) not in have]
                if not cand:
                    break
                dst = cand[0]
                try:
                    self._stage_group(dst, g)
                except PoolUnavailableError:
                    self._on_shard_down(dst)
                    continue
                row[cols[0]] = dst
                loads[dst] += 1.0
                if planned:
                    self.elastic["moved_groups"] += 1
                    self.elastic["bytes"] += fp
                else:
                    self.failover["rereplicated_groups"] += 1
                    self.failover["rereplicate_bytes"] += fp
                child = self.children[dst]
                dt = (child.model_dt(fp, 1.0, 1.0)
                      if hasattr(child, "model_dt") else 0.0)
                if dt:
                    self.sim_s["failover"] = (
                        self.sim_s.get("failover", 0.0) + dt)
                placed = True
            if not placed:
                row[cols] = -1
                if not any(int(s) >= 0 and self._alive[int(s)]
                           for s in row):
                    self.failover["lost_groups"] += 1
            # a shard appears at most once per row, but scrub defensively
            row[row == shard] = -1
        self._recompute_serving()

    # ------------------------------------------------------------ elastic

    def add_shard(self, child_factory: Callable[[Store], MemoryPool]) -> int:
        """Scale the fleet out by one node at runtime.

        The new child stages the shared region (its factory does — same
        contract as construction time), then only the groups the
        placement policy would newly put on it migrate there
        (incremental rebalance, not a full reshuffle): each such group's
        *serving* replica moves to the new node; its other replicas stay
        put, so the replication factor is preserved.  Returns the new
        shard's index."""
        new = self.n_shards
        child = child_factory(self.store)
        child.shard_id = new
        if self.store.qvec_buf is not None:
            child._stage_quant()
        self.children.append(child)
        self._alive = np.append(self._alive, True)
        self.elastic["added"] += 1
        # start the new node empty-compacted: the groups the placement
        # moves below are adopted one by one (1/N staging from day one)
        self._apply_staging(only=new)
        desired = np.asarray(
            self.placement.place(self.spec.n_groups, self.n_shards,
                                 group_sizes=self._group_rows(),
                                 shard_costs=self._shard_costs()), np.int64)
        fp = self._group_footprint_bytes()
        for g in np.nonzero(desired == new)[0]:
            g = int(g)
            row = self._replicas[g]
            if (row == new).any():
                continue
            cur = int(self._serve[g])
            cols = np.nonzero(row == cur)[0] if cur >= 0 else np.zeros(0)
            col = int(cols[0]) if len(cols) else 0
            try:
                self._stage_group(new, g)
            except PoolUnavailableError:
                self._on_shard_down(new)
                break
            row[col] = new
            self.elastic["moved_groups"] += 1
            self.elastic["bytes"] += fp
        self._recompute_serving()
        return new

    def remove_shard(self, shard: int) -> None:
        """Planned drain of one node: its groups re-replicate onto
        survivors through the same path a failure takes (minus the
        failure), then the node leaves the serving set.  The child
        object stays in ``children`` so shard indices remain stable;
        any transport it holds is closed."""
        self._on_shard_down(int(shard), planned=True)
        child = self.children[int(shard)]
        if hasattr(child, "close"):
            child.close()

    def recover_shard(self, shard: int,
                      child_factory: Callable[[Store], MemoryPool]) -> None:
        """Rejoin a restarted memory node in place — the durable path.

        The new child recovered its region from its own data-dir (WAL
        replay), so unlike ``_on_shard_down`` healing NOTHING is
        re-staged from the host region: the factory connects (a durable
        ``RemotePool`` uses ``attach="auto"`` and skips the upload when
        the server's recovered fingerprint matches the mirror), the old
        transport is closed, and any group slots the death left empty
        are handed back to the recovered shard.  With ``replication=1``
        this is what turns a "lost" group back into a served one.
        """
        shard = int(shard)
        assert 0 <= shard < self.n_shards, shard
        old = self.children[shard]
        if hasattr(old, "close"):
            old.close()
        child = child_factory(self.store)
        child.shard_id = shard
        if (self.store.qvec_buf is not None
                and getattr(child, "attached_via", "upload") != "recovered"
                and hasattr(child, "_stage_quant")):
            child._stage_quant()     # full re-upload path needs the mirror
        self.children[shard] = child
        was_dead = not self._alive[shard]
        self._alive[shard] = True
        self.failover["recovered_shards"] += 1
        if was_dead:
            restored = 0
            for g in self._dead_held.pop(shard, []):
                row = self._replicas[g]
                if (row == shard).any():
                    continue
                free = np.nonzero(row < 0)[0]
                if not len(free):
                    continue          # fully re-replicated elsewhere
                if not any(int(s) >= 0 and self._alive[int(s)]
                           for s in row):
                    # the group had lost every copy — it is back now
                    self.failover["lost_groups"] = max(
                        0, self.failover["lost_groups"] - 1)
                row[free[0]] = shard
                restored += 1
            self.failover["recovered_groups"] += restored
        self._recompute_serving()
        self._apply_staging(only=shard)

    # ------------------------------------------------------------ migration

    def _note_span_access(self, pids) -> None:
        due = False
        for p in np.asarray(pids).reshape(-1):
            due = self.placement.note_access(int(p) // 2) or due
        if due:
            self._rebalance()

    def _rebalance(self) -> None:
        # group_sizes deliberately omitted: computing live rows walks
        # every partition on the host, and no migrating policy reads
        # them — this runs inside the span-read hot path
        if (self._serve < 0).any():
            return                    # degraded: heal first, then tune
        moves = self.placement.plan_moves(self._serve.copy(),
                                          shard_costs=self._shard_costs())
        for g, src, dst in moves:
            self._migrate(int(g), int(src), int(dst))

    def _migrate(self, group: int, src: int, dst: int) -> None:
        """Move one group's *serving replica* shard-to-shard: re-stage
        its blocks on the destination from the host region (source of
        truth), flip the serving entry, and account the background copy
        separately from verb traffic (it is not charged to any request
        ledger).  When the destination already holds a replica the
        migration is a pure serving switch — no bytes move."""
        spec = self.spec
        if src == dst or self._serve[group] != src:
            return
        if dst < 0 or dst >= self.n_shards or not self._alive[dst]:
            return
        row = self._replicas[group]
        if (row == dst).any():
            self._serve[group] = dst
            self.migration["n"] += 1
            return
        try:
            self._stage_group(dst, group)
        except PoolUnavailableError:
            self._on_shard_down(dst)
            return
        cols = np.nonzero(row == src)[0]
        row[int(cols[0]) if len(cols) else 0] = dst
        self._serve[group] = dst
        nb = self._block_copy_bytes(spec.group_blocks)
        dts = [c.model_dt(nb, 1.0, 1.0) if hasattr(c, "model_dt") else 0.0
               for c in (self.children[src], self.children[dst])]
        dt = fanout_dt(dts, True)   # src READ streams into the dst WRITE
        self.migration["n"] += 1
        self.migration["bytes"] += nb
        self.migration["sim_s"] += dt
        if dt:
            self.sim_s["migrate"] = self.sim_s.get("migrate", 0.0) + dt

    # ------------------------------------------------------------ stats

    def merged_hist(self):
        """Fleet-wide per-(verb, shard) latency view: every child's
        histogram (keyed by the ``shard_id`` set at construction) merged
        with the parent's own — the input the straggler detector reads."""
        from repro.obs.hist import VerbShardHist
        m = VerbShardHist()
        own = getattr(self, "_hist", None)
        if own is not None:
            m.merge(own)
        for c in self.children:
            ch = getattr(c, "_hist", None)
            if ch is not None:
                m.merge(ch)
        return m

    def check_stragglers(self) -> dict:
        """Run the straggler detector over :meth:`merged_hist` and act.

        A flagged shard's serving cost is penalized by its observed tail
        excess (seconds at the detector's quantile), and the serving map
        is recomputed — with ``replication >= 2`` the flagged shard's
        groups move to a healthy replica (counted in
        ``straggler_stats``); a recovered shard loses its penalty the
        same way.  Runs automatically every ``straggler_check_every``
        charged span reads when configured, or manually.  Returns the
        detector report (also surfaced in ``snapshot()["stragglers"]``).
        """
        self.straggler_stats["checks"] += 1
        report = self.straggler.verdicts(self.merged_hist())
        penalty = {int(s): float(i["excess_s"])
                   for s, i in report["flagged"].items()}
        self.straggler_stats["flagged_now"] = len(penalty)
        if penalty != self._straggler_penalty:
            old = self._serve.copy()
            self._straggler_penalty = penalty
            self._recompute_serving()
            moved = int((old != self._serve).sum())
            if moved:
                self.straggler_stats["reroutes"] += 1
                self.straggler_stats["moved_groups"] += moved
        self._last_straggler_report = report
        return report

    def harvest_trace(self) -> int:
        """Drain server-side trace spans from every live remote child
        (children without the hook — local/sim shards — contribute 0).
        A child dying mid-harvest is skipped and counted
        (``trace_harvest_failures``): observability must never take down
        the pool it is observing."""
        n = 0
        for s, c in enumerate(self.children):
            if not self._alive[s] or not hasattr(c, "harvest_trace"):
                continue
            try:
                n += c.harvest_trace()
            except PoolUnavailableError:
                self.trace_harvest_failures += 1
                continue
        return n

    @property
    def sim_total_s(self) -> float:
        """Modeled wire seconds on the parent's critical path."""
        return sum(self.sim_s.values())

    def snapshot(self) -> dict:
        """See ``MemoryPool.snapshot``; adds placement/replication state,
        per-shard child snapshots (dead shards report ``kind: down``),
        and the migration/replication_io/failover/elastic counters."""
        out = super().snapshot()
        out["n_shards"] = self.n_shards
        out["parallel"] = self.parallel
        out["placement"] = self.placement.name
        out["replication"] = int(self._replicas.shape[1])
        out["alive"] = self._alive.tolist()
        serve = self._serve[self._serve >= 0]
        out["groups_by_shard"] = np.bincount(
            serve, minlength=self.n_shards).tolist()
        reps = self._replicas[self._replicas >= 0]
        out["replicas_by_shard"] = np.bincount(
            reps, minlength=self.n_shards).tolist()
        out["migration"] = dict(self.migration)
        out["replication_io"] = dict(self.replication_io)
        out["failover"] = dict(self.failover)
        out["elastic"] = dict(self.elastic)
        out["trace_harvest_failures"] = self.trace_harvest_failures
        rep = self._last_straggler_report or {}
        out["stragglers"] = dict(
            self.straggler_stats,
            flagged={str(s): dict(i)
                     for s, i in rep.get("flagged", {}).items()},
            penalty_s={str(s): p
                       for s, p in self._straggler_penalty.items()})
        mh = self.merged_hist()
        if len(mh):
            out["hist"] = mh.to_dict()
        shards = []
        for s, c in enumerate(self.children):
            try:
                shards.append(c.snapshot())
            except Exception:
                # a dead node must never break stats reporting
                shards.append({"kind": "down", "shard": s})
        out["shards"] = shards
        if self.sim_s or any("sim_total_s" in s for s in out["shards"]):
            out["sim_s"] = dict(self.sim_s)
            out["sim_total_s"] = self.sim_total_s
        stg = [s.get("staging") for s in out["shards"]]
        if any(stg):
            # per-node device staging: the 1/N footprint story in one place
            out["staging"] = {
                "device_bytes_by_shard": [(t or {}).get("device_bytes", 0)
                                          for t in stg],
                "blocks_staged_by_shard": [(t or {}).get("blocks_staged", 0)
                                           for t in stg],
                "restaged_blocks": sum((t or {}).get("restaged_blocks", 0)
                                       for t in stg)}
        wired = [s["wire"] for s in out["shards"] if "wire" in s]
        if wired:
            # remote children: measured wire traffic summed over nodes
            out["wire_total"] = {
                k: sum(w[k] for w in wired)
                for k in ("frames_tx", "frames_rx", "bytes_tx", "bytes_rx")}
        return out
