"""ShardedPool — the region split across N memory nodes.

One memory node cannot hold a production-scale region, and §3.3's
doorbell batching only pays off at scale when descriptor batches are
formed *per destination node*.  ``ShardedPool`` implements the full
``MemoryPool`` protocol over N child pools (any mix of ``LocalPool`` /
``SimulatedRDMAPool``, including heterogeneous fabrics per shard to
model stragglers):

* **Group-granular placement** — the unit of ownership is the layout
  *group* (two partner sub-HNSWs + their shared overflow, §3.2), so a
  fetch span never straddles shards and every doorbell descriptor names
  blocks on exactly one node.  A pluggable ``PlacementPolicy``
  (``pool/placement.py``) owns the group -> shard map; the
  frequency-aware policy migrates hot groups toward the fastest /
  least-loaded shard at runtime (``refresh_blocks`` re-stages the
  arriving group on the destination node; results are bit-identical
  before and after a migration).
* **Per-shard doorbell fan-out** — ``read_spans`` / ``read_rows`` /
  ``read_quant_rows`` / ``post_*`` split each descriptor batch by
  owning shard and charge each slice on that shard's own fabric; the
  caller's ledger sees summed bytes/descriptors and ``trips = max``
  over shards when ``parallel=True`` (nodes answer their batches
  concurrently — the critical path is the slowest slice) or the sum in
  serial mode.  With one shard this reduces exactly to the child's own
  accounting.
* **Write routing** — ``append``/``repack`` go to the owner shard,
  which keeps its device twin (and the quantized mirror / flat-quant
  row index) coherent; the shared host region stays the single source
  of truth, so a rebuild (``adopt``) or migration can always re-stage
  any node from it.

Simulation note: the children share the serialized host region (this
container has one address space), and each child stages a full device
copy of it while *serving only the groups it owns* — so device memory
scales with ``n_shards`` here, a simulation convenience (real
transports would hold just their slice; block-compacted per-shard
staging is a ROADMAP item).  What the model measures — per-destination
verb counts, wire bytes, and modeled time — is exactly what a
multi-node deployment would see over real transports.
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import layout as LA
from repro.core.cost_model import NetLedger
from repro.core.layout import Store
from repro.pool.placement import PlacementPolicy, make_placement
from repro.pool.protocol import MemoryPool, _fresh_totals
from repro.pool.sim_rdma import fanout_dt


class ShardedPool(MemoryPool):

    kind = "sharded"

    def __init__(self, store: Store,
                 child_factories: Sequence[Callable[[Store], MemoryPool]],
                 *, placement="round_robin", parallel: bool = True):
        assert len(child_factories) >= 1, "need at least one shard"
        self.store = store
        self.children = [f(store) for f in child_factories]
        self.placement: PlacementPolicy = make_placement(placement)
        self.parallel = parallel
        self.verbs: Counter = Counter()
        self.totals = _fresh_totals()
        self.sim_s: dict[str, float] = {}
        self.migration = {"n": 0, "bytes": 0.0, "sim_s": 0.0}
        self._reset_placement()
        self._stage_meta()

    # ------------------------------------------------------------ ownership

    @property
    def n_shards(self) -> int:
        return len(self.children)

    def owner_of_group(self, group: int) -> int:
        return int(self._owner[group])

    def owner_of_pid(self, pid: int) -> int:
        """Destination shard of one partition's fetch span (a partition
        lives where its group lives) — also the shard-aware doorbell
        key the round scheduler groups descriptors by."""
        return int(self._owner[int(pid) // 2])

    def _owners_of_pids(self, pids) -> np.ndarray:
        return self._owner[np.asarray(pids, np.int64) // 2]

    def _owners_of_rows(self, rows) -> np.ndarray:
        """Owning shard per region row address (-1 rows -> -1)."""
        rows = np.asarray(rows, np.int64)
        grp = (rows // self.spec.slot_vecs) // self.spec.group_blocks
        own = self._owner[np.clip(grp, 0, len(self._owner) - 1)]
        return np.where(rows >= 0, own, -1)

    def _group_rows(self) -> np.ndarray:
        """Live rows per group (base + overflow) — the size signal for
        size-balanced placement."""
        spec, mt = self.spec, self.store.meta_table
        rows = np.zeros(spec.n_groups, np.int64)
        for pid in range(spec.n_partitions):
            rows[pid // 2] += int(self.store.n_base[pid])
        first = 2 * np.arange(spec.n_groups)
        rows += mt[first, LA.MT_OV_A].astype(np.int64)
        rows += mt[first, LA.MT_OV_B].astype(np.int64)
        return rows

    def _shard_costs(self) -> list[float]:
        """Modeled seconds per span read, per shard (0 = in-process) —
        the speed signal the frequency-aware policy migrates toward."""
        pb = float(self.spec.partition_bytes())
        return [c.model_dt(pb, 1.0, 1.0) if hasattr(c, "model_dt") else 0.0
                for c in self.children]

    def _reset_placement(self) -> None:
        self._owner = np.asarray(
            self.placement.place(self.spec.n_groups, self.n_shards,
                                 group_sizes=self._group_rows(),
                                 shard_costs=self._shard_costs()), np.int64)

    # ------------------------------------------------------------ charging

    def _child_sim(self, child) -> float:
        return getattr(child, "sim_total_s", 0.0)

    def _scratch(self, shard: int, ledger: NetLedger) -> NetLedger:
        """Per-destination ledger slice, priced on that shard's own
        fabric (falling back to the caller's for in-process children)."""
        fabric = getattr(self.children[shard], "fabric", ledger.fabric)
        return NetLedger(fabric)

    def _charged_call(self, shard: int, ledger: NetLedger, fn):
        """Run one child verb under a scratch ledger; returns the verb
        result and its charge slice (bytes, descriptors, trips, sim_dt)
        — the single place the per-destination bookkeeping lives."""
        child = self.children[shard]
        scratch = self._scratch(shard, ledger)
        t0 = self._child_sim(child)
        res = fn(child, scratch)
        return res, (scratch.bytes, scratch.descriptors,
                     scratch.round_trips, self._child_sim(child) - t0)

    def _charge_fanout(self, verb: str, ledger: Optional[NetLedger],
                       slices: list[tuple]) -> None:
        """Fold per-shard slices [(bytes, descriptors, trips, sim_dt)]
        into the caller's ledger and the pool totals: bytes and
        descriptors sum; trips (and modeled time) reduce by max when the
        shards answer in parallel, by sum in serial mode."""
        if ledger is None or not slices:
            return
        nb = float(sum(s[0] for s in slices))
        nd = float(sum(s[1] for s in slices))
        trips = fanout_dt([s[2] for s in slices], self.parallel)
        dt = fanout_dt([s[3] for s in slices], self.parallel)
        ledger.round_trips += trips
        ledger.descriptors += nd
        ledger.bytes += nb
        ledger.events += 1
        self.totals["round_trips"] += trips
        self.totals["descriptors"] += nd
        self.totals["bytes"] += nb
        if dt:
            self.sim_s[verb] = self.sim_s.get(verb, 0.0) + dt

    # ------------------------------------------------------------ meta

    def _stage_meta(self) -> None:
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False

    # read_meta: the shared MemoryPool implementation (serves the
    # parent's own cached table — children are never consulted)

    def adopt(self, store: Store) -> None:
        self.store = store
        for c in self.children:
            c.adopt(store)
        self._reset_placement()
        self._stage_meta()

    def attach_quant(self, group: int) -> None:
        LA.attach_quant_mirror(self.store, group)
        for c in self.children:
            c._stage_quant()

    # ------------------------------------------------------------ reads

    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        pids = np.asarray(pids).reshape(-1)
        verb = "read_spans_quant" if quant else "read_spans"
        self.verbs[verb] += len(pids)
        owners = self._owners_of_pids(pids)
        m = len(pids)
        parts, slices = [], []
        for s, child in enumerate(self.children):
            idx = np.nonzero(owners == s)[0]
            if not len(idx):
                continue
            if ledger is None:
                res = child.read_spans(pids[idx], ledger=None,
                                       doorbell=doorbell, quant=quant,
                                       quant_graph=quant_graph)
            else:
                res, sl = self._charged_call(
                    s, ledger,
                    lambda c, l: c.read_spans(pids[idx], ledger=l,
                                              doorbell=doorbell,
                                              quant=quant,
                                              quant_graph=quant_graph))
                slices.append(sl)
            parts.append((idx, res))
        self._charge_fanout(verb, ledger, slices)
        outs = None
        for idx, res in parts:
            if outs is None:
                outs = [jnp.zeros((m,) + r.shape[1:], r.dtype) for r in res]
            di = jnp.asarray(idx, jnp.int32)
            outs = [o.at[di].set(r) for o, r in zip(outs, res)]
        if ledger is not None:        # heat accrues on charged traffic
            self._note_span_access(pids)
        return tuple(outs)

    def _masked_fanout(self, rows, gather):
        """Row-granular fan-out: each shard gathers the full tensor with
        non-owned lanes masked to -1, and the owner's lanes are selected
        back — dead (-1) lanes keep gather-row-0 placeholders exactly
        like a single pool, masked by the caller."""
        rows_h = np.asarray(rows)
        owners = self._owners_of_rows(rows_h)
        out = None
        for s in range(self.n_shards):
            mask = owners == s
            if not mask.any():
                continue
            sub = jnp.asarray(np.where(mask, rows_h, -1).astype(np.int32))
            res = gather(self.children[s], sub)
            if not isinstance(res, tuple):
                res = (res,)
            mdev = jnp.asarray(mask)
            if out is None:
                out = list(res)
            else:
                out = [jnp.where(mdev.reshape(mdev.shape + (1,) * (r.ndim - mdev.ndim)), r, o)
                       for o, r in zip(out, res)]
        if out is None:               # every lane dead: any child serves
            res = gather(self.children[0], jnp.asarray(
                np.asarray(rows_h, np.int64).astype(np.int32)))
            return res
        return out[0] if len(out) == 1 else tuple(out)

    def read_rows(self, rows):
        self.verbs["read_rows"] += 1
        return self._masked_fanout(rows, lambda c, r: c.read_rows(r))

    def read_quant_rows(self, rows):
        self.verbs["read_quant_rows"] += 1
        return self._masked_fanout(rows,
                                   lambda c, r: c.read_quant_rows(r))

    # ------------------------------------------------- accounting posts

    def post_span_reads(self, n: int, *, ledger: NetLedger,
                        doorbell: int = 1, quant: bool = False,
                        quant_graph: bool = True, pids=None) -> None:
        if pids is None:
            # no destination info: price on the caller's fabric, like a
            # single-node pool (callers that know the spans pass pids)
            return super().post_span_reads(n, ledger=ledger,
                                           doorbell=doorbell, quant=quant,
                                           quant_graph=quant_graph)
        self.verbs["post_span_reads"] += n
        pids = np.asarray(pids).reshape(-1)
        owners = self._owners_of_pids(pids)
        slices = []
        for s in range(self.n_shards):
            k = int((owners == s).sum())
            if not k:
                continue
            _, sl = self._charged_call(
                s, ledger,
                lambda c, l: c.post_span_reads(k, ledger=l,
                                               doorbell=doorbell,
                                               quant=quant,
                                               quant_graph=quant_graph))
            slices.append(sl)
        self._charge_fanout("post_span_reads", ledger, slices)
        self._note_span_access(pids)

    def post_row_reads(self, groups, *, ledger: NetLedger,
                       doorbell: int = 1) -> None:
        groups = list(groups)
        self.verbs["post_row_reads"] += len(groups)
        by: dict[int, list] = {}
        for pid, cnt in groups:
            s = self.owner_of_pid(pid) if pid >= 0 else 0
            by.setdefault(s, []).append((pid, cnt))
        slices = []
        for s, sub in sorted(by.items()):
            _, sl = self._charged_call(
                s, ledger,
                lambda c, l: c.post_row_reads(sub, ledger=l,
                                              doorbell=doorbell))
            slices.append(sl)
        self._charge_fanout("post_row_reads", ledger, slices)

    # ------------------------------------------------------------ writes

    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        s = self.owner_of_pid(int(pid))
        if ledger is None:
            slot, sl = self.children[s].append(vec, int(gid), int(pid),
                                               ledger=None), None
        else:
            slot, sl = self._charged_call(
                s, ledger,
                lambda c, l: c.append(vec, int(gid), int(pid), ledger=l))
        if slot < 0:
            return slot
        self.verbs["append"] += 1
        self._mt_dirty = True
        if sl is not None:
            self._charge_fanout("append", ledger, [sl])
        return slot

    def repack(self, group: int, data_lookup) -> bool:
        self.verbs["repack"] += 1
        ok = self.children[self.owner_of_group(int(group))].repack(
            int(group), data_lookup)
        if ok:
            self._mt_dirty = True
        return ok

    # ------------------------------------------------------------ migration

    def _note_span_access(self, pids) -> None:
        due = False
        for p in np.asarray(pids).reshape(-1):
            due = self.placement.note_access(int(p) // 2) or due
        if due:
            self._rebalance()

    def _rebalance(self) -> None:
        # group_sizes deliberately omitted: computing live rows walks
        # every partition on the host, and no migrating policy reads
        # them — this runs inside the span-read hot path
        moves = self.placement.plan_moves(self._owner,
                                          shard_costs=self._shard_costs())
        for g, src, dst in moves:
            self._migrate(int(g), int(src), int(dst))

    def _migrate(self, group: int, src: int, dst: int) -> None:
        """Move one group shard-to-shard: re-stage its blocks on the
        destination from the host region (source of truth), flip the
        owner, and account the background copy separately from verb
        traffic (it is not charged to any request ledger)."""
        spec = self.spec
        if src == dst or self._owner[group] != src:
            return
        blocks = np.arange(group * spec.group_blocks,
                           (group + 1) * spec.group_blocks)
        self.children[dst].refresh_blocks(blocks)
        self._owner[group] = dst
        nb = float(spec.group_blocks * spec.block_bytes())
        if self.store.qvec_buf is not None:
            nb += float(spec.group_blocks
                        * (spec.vblk + spec.n_qgroups * 4))
        dts = [c.model_dt(nb, 1.0, 1.0) if hasattr(c, "model_dt") else 0.0
               for c in (self.children[src], self.children[dst])]
        dt = fanout_dt(dts, True)   # src READ streams into the dst WRITE
        self.migration["n"] += 1
        self.migration["bytes"] += nb
        self.migration["sim_s"] += dt
        if dt:
            self.sim_s["migrate"] = self.sim_s.get("migrate", 0.0) + dt

    # ------------------------------------------------------------ stats

    @property
    def sim_total_s(self) -> float:
        return sum(self.sim_s.values())

    def snapshot(self) -> dict:
        out = super().snapshot()
        out["n_shards"] = self.n_shards
        out["parallel"] = self.parallel
        out["placement"] = self.placement.name
        out["groups_by_shard"] = np.bincount(
            self._owner, minlength=self.n_shards).tolist()
        out["migration"] = dict(self.migration)
        out["shards"] = [c.snapshot() for c in self.children]
        if self.sim_s or any("sim_total_s" in s for s in out["shards"]):
            out["sim_s"] = dict(self.sim_s)
            out["sim_total_s"] = self.sim_total_s
        wired = [s["wire"] for s in out["shards"] if "wire" in s]
        if wired:
            # remote children: measured wire traffic summed over nodes
            out["wire_total"] = {
                k: sum(w[k] for w in wired)
                for k in ("frames_tx", "frames_rx", "bytes_tx", "bytes_rx")}
        return out
