"""In-process memory pool: the serialized region as device arrays.

``LocalPool`` is the transport the monolithic engine always implicitly
was — span reads are device gathers from the registered region, writes
are host-staging plus a device scatter twin — now behind the
``MemoryPool`` verbs so the compute side can't tell it apart from a real
remote.  Bit-identical to the pre-pool engine by construction: the verb
bodies are the exact gather/scatter sequences the engine used inline.

1/N staging: a sharded child that serves only some partition groups can
``restrict_staging(groups)`` to a block-compacted device region holding
just the owned groups' blocks.  Reads translate region block/row
addresses through a block->staged-slot indirection
(``layout.block_slot_map``) — host-side for span block ids, on device
for row gathers (dead ``-1`` lanes stay dead) — so verb results are
bit-identical to the fully staged pool while device bytes drop to
~1/N.  ``refresh_blocks`` adopts an arriving group at group granularity
(stage once from the host, append to the compacted tail) and scatters
only the blocks that actually moved; ``snapshot()["staging"]`` reports
the compaction and re-stage tallies.
"""
from __future__ import annotations

from collections import Counter
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import device_store as DS
from repro.core import layout as LA
from repro.core.cost_model import NetLedger
from repro.core.layout import Store
from repro.core.scheduler import doorbell_chunks
from repro.pool.protocol import MemoryPool, _fresh_totals, span_wire_bytes


class LocalPool(MemoryPool):
    """In-process transport: verbs are device gathers/scatters on the
    staged region; charges follow the shared ``MemoryPool`` rule."""

    kind = "local"

    def __init__(self, store: Store, *, use_gather_kernel: bool = False,
                 owned_groups=None):
        self.store = store
        self.use_gather_kernel = use_gather_kernel
        self.verbs: Counter = Counter()
        self.totals = _fresh_totals()
        self._owned: Optional[set] = (None if owned_groups is None
                                      else {int(g) for g in owned_groups})
        self._stage_all()

    # ------------------------------------------------------------ staging

    def restrict_staging(self, groups) -> None:
        """Compact the device region to only ``groups``' blocks (the 1/N
        staging a sharded child uses once placement is known).  Pass
        ``None`` to return to full staging."""
        self._owned = None if groups is None else {int(g) for g in groups}
        self._stage_all()

    def _stage_all(self) -> None:
        """(Re-)register the region: host buffers -> device arrays.

        Full staging when no owned set is declared; otherwise only the
        owned groups' blocks go to the device, block-compacted, with the
        region->staged indirection rebuilt alongside."""
        st, spec = self.store, self.store.spec
        if self._owned is None:
            self._staged_ids = None
            self._block_slot = None
            self._bs_dev = None
            self._g_dev = jnp.asarray(st.graph_buf)
            self._v_dev = jnp.asarray(st.vec_buf)
            n_staged = spec.n_blocks
        else:
            self._staged_ids = LA.owned_block_ids(spec, self._owned)
            self._block_slot = LA.block_slot_map(spec, self._staged_ids)
            self._bs_dev = jnp.asarray(self._block_slot, jnp.int32)
            self._g_dev = jnp.asarray(st.graph_buf[self._staged_ids])
            self._v_dev = jnp.asarray(st.vec_buf[self._staged_ids])
            n_staged = len(self._staged_ids)
        self._mt_dev = jnp.asarray(st.meta_table)
        self._mt_dirty = False
        if st.qvec_buf is not None:
            self._stage_quant()
        else:
            self._qv_dev = self._qs_dev = None
        self.staging = {"compacted": self._owned is not None,
                        "blocks_total": int(spec.n_blocks),
                        "blocks_staged": int(n_staged),
                        "restaged_blocks": 0,
                        "device_bytes": 0}
        self._count_device_bytes()

    def _count_device_bytes(self) -> None:
        b = self._g_dev.nbytes + self._v_dev.nbytes + self._mt_dev.nbytes
        if self._qv_dev is not None:
            b += self._qv_dev.nbytes + self._qs_dev.nbytes
        self.staging["device_bytes"] = int(b)

    def adopt(self, store: Store) -> None:
        """See ``MemoryPool.adopt``."""
        self.store = store
        self._stage_all()

    def attach_quant(self, group: int) -> None:
        """See ``MemoryPool.attach_quant``."""
        LA.attach_quant_mirror(self.store, group)
        self._stage_quant()
        self._count_device_bytes()

    def _stage_quant(self) -> None:
        """(Re-)stage the quantized mirror (already attached to the host
        store) — split out so a sharded parent can attach the mirror
        once and have every child stage it.  Compacted staging stages
        only the owned blocks' codes/scales, same indirection."""
        ids = self._staged_ids
        if ids is None:
            self._qv_dev = jnp.asarray(self.store.qvec_buf)
            self._qs_dev = jnp.asarray(self.store.qscale_buf)
        else:
            self._qv_dev = jnp.asarray(self.store.qvec_buf[ids])
            self._qs_dev = jnp.asarray(self.store.qscale_buf[ids])
        if hasattr(self, "staging"):   # sharded parents call this directly
            self._count_device_bytes()

    def refresh_blocks(self, block_ids) -> None:
        """Re-stage specific blocks from the host region (group
        migration landing on this pool: the host bytes are the source of
        truth; this node's device copy of the arriving group is stale).

        Under compacted staging an arriving group not yet owned is
        adopted at group granularity — its full block range is staged
        once from the host onto the compacted tail — and only the blocks
        that were already resident are scattered; either way just the
        moved group's blocks travel, never a full re-stage."""
        ids = np.asarray(block_ids, np.int64)
        if len(ids) == 0:
            return
        if self._owned is None:
            dev = jnp.asarray(ids, jnp.int32)
            self._scatter_blocks(ids, dev)
            self.staging["restaged_blocks"] += int(len(ids))
            return
        spec = self.spec
        new_groups = sorted({int(g) for g in ids // spec.group_blocks}
                            - self._owned)
        for g in new_groups:
            self._adopt_group(g)
        pre = (ids[~np.isin(ids // spec.group_blocks, new_groups)]
               if new_groups else ids)
        if len(pre):
            slots = self._block_slot[pre]
            assert (slots >= 0).all(), "refresh of unstaged block"
            self._scatter_blocks(pre, jnp.asarray(slots, jnp.int32))
        self.staging["restaged_blocks"] += (
            int(len(pre)) + len(new_groups) * spec.group_blocks)
        self.staging["blocks_staged"] = int(len(self._staged_ids))
        self._count_device_bytes()

    def _scatter_blocks(self, host_ids: np.ndarray, dev_ids) -> None:
        st = self.store
        self._g_dev = self._g_dev.at[dev_ids].set(
            jnp.asarray(st.graph_buf[host_ids]))
        self._v_dev = self._v_dev.at[dev_ids].set(
            jnp.asarray(st.vec_buf[host_ids]))
        if self._qv_dev is not None:
            self._qv_dev = self._qv_dev.at[dev_ids].set(
                jnp.asarray(st.qvec_buf[host_ids]))
            self._qs_dev = self._qs_dev.at[dev_ids].set(
                jnp.asarray(st.qscale_buf[host_ids]))

    def _adopt_group(self, group: int) -> None:
        """Stage one newly owned group onto the compacted device tail."""
        st, spec = self.store, self.spec
        gids = np.arange(group * spec.group_blocks,
                         (group + 1) * spec.group_blocks, dtype=np.int64)
        base = len(self._staged_ids)
        self._staged_ids = np.concatenate([self._staged_ids, gids])
        self._block_slot[gids] = base + np.arange(spec.group_blocks,
                                                  dtype=np.int32)
        self._bs_dev = jnp.asarray(self._block_slot, jnp.int32)
        self._g_dev = jnp.concatenate(
            [self._g_dev, jnp.asarray(st.graph_buf[gids])])
        self._v_dev = jnp.concatenate(
            [self._v_dev, jnp.asarray(st.vec_buf[gids])])
        if self._qv_dev is not None:
            self._qv_dev = jnp.concatenate(
                [self._qv_dev, jnp.asarray(st.qvec_buf[gids])])
            self._qs_dev = jnp.concatenate(
                [self._qs_dev, jnp.asarray(st.qscale_buf[gids])])
        self._owned.add(int(group))

    # ------------------------------------------------------------ reads
    # (read_meta, the charge rule, and the post_* accounting verbs are
    # the shared MemoryPool implementations — one copy for every
    # transport so ledger parity can never drift)

    def _gather_blocks(self, buf, ids):
        if self.use_gather_kernel:
            from repro.kernels.gather_blocks import ops as GO
            return GO.gather_blocks(buf, ids)
        return jnp.take(buf, ids, axis=0)

    def _staged_block_ids(self, block_ids: np.ndarray) -> np.ndarray:
        """Region block ids -> device rows (identity when fully staged)."""
        if self._owned is None:
            return block_ids
        slots = self._block_slot[block_ids]
        assert (slots >= 0).all(), "span read outside the staged groups"
        return slots

    def _staged_rows(self, rows):
        """Region row addresses -> compacted device rows, ON DEVICE.

        Rows address ``vec_buf.reshape(-1, dim)``; under compaction the
        owning block is remapped through the staged-slot table and the
        in-block offset is kept.  Dead ``-1`` lanes and rows of unstaged
        blocks stay ``-1`` (callers mask them; an unstaged LIVE row
        would be a placement bug and shows up as a masked lane, exactly
        like a dead candidate)."""
        if self._owned is None:
            return rows
        sv = self.spec.slot_vecs
        r = jnp.asarray(rows)
        safe = jnp.maximum(r, 0)
        slot = jnp.take(self._bs_dev, safe // sv, axis=0)
        tr = slot * sv + safe % sv
        return jnp.where((r < 0) | (slot < 0), -1, tr)

    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """See ``MemoryPool.read_spans``; charges
        ``span_wire_bytes(spec, quant=...)`` per span, ``doorbell``
        descriptors per round trip."""
        spec = self.spec
        pids = np.asarray(pids).reshape(-1)
        self.verbs["read_spans_quant" if quant else "read_spans"] += len(pids)
        per_bytes, per_desc = span_wire_bytes(spec, quant=quant,
                                              quant_graph=quant_graph)
        if ledger is not None:
            for db in doorbell_chunks(pids, doorbell):
                self._charge("read_spans_quant" if quant else "read_spans",
                             ledger, len(db) * per_bytes,
                             per_desc * len(db))
        block_ids = np.stack([self.store.span_block_ids(int(p))
                              for p in pids])
        block_ids = self._staged_block_ids(block_ids)
        ids = jnp.asarray(block_ids.reshape(-1), jnp.int32)
        m = block_ids.shape[0]
        g = self._gather_blocks(self._g_dev, ids).reshape(m, -1, spec.gblk)
        if not quant:
            v = self._gather_blocks(self._v_dev, ids).reshape(m, -1,
                                                              spec.vblk)
            return g, v
        qv = self._gather_blocks(self._qv_dev, ids).reshape(m, -1, spec.vblk)
        qs = self._gather_blocks(self._qs_dev, ids).reshape(
            m, -1, spec.n_qgroups)
        return g, qv, qs

    def read_rows(self, rows):
        """See ``MemoryPool.read_rows``; charged via ``post_row_reads``."""
        self.verbs["read_rows"] += 1
        return DS.gather_rows(self._v_dev, self._staged_rows(rows),
                              dim=self.spec.dim)

    def read_quant_rows(self, rows):
        """See ``MemoryPool.read_quant_rows``; charged via
        ``post_row_reads`` (quant rows are priced by the caller)."""
        self.verbs["read_quant_rows"] += 1
        return DS.gather_quant_rows(self._qv_dev, self._qs_dev,
                                    self._staged_rows(rows),
                                    dim=self.spec.dim,
                                    group=self.spec.quant_group)

    # ------------------------------------------------------------ writes

    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """See ``MemoryPool.append``; charges vector + 8 B id, plus
        codes + codebook scales when the quantized mirror is attached."""
        spec = self.spec
        vec = np.asarray(vec, np.float32)
        slot = LA.insert_vector(self.store, vec, int(gid), int(pid))
        if slot < 0:
            return slot
        group = int(self.store.meta_table[pid, LA.MT_GROUP])
        co = LA.overflow_write_coords(spec, group, slot)
        vb, gb = co["vec_block"], co["gid_block"]
        if self._owned is not None:
            vb, gb = int(self._block_slot[vb]), int(self._block_slot[gb])
            assert vb >= 0 and gb >= 0, "append to an unstaged group"
        self._g_dev, self._v_dev = DS.overflow_append(
            spec, self._g_dev, self._v_dev, jnp.asarray(vec),
            jnp.int32(gid), vb, co["vec_off"], gb, co["gid_off"])
        wire = spec.dim * 4 + 8
        if self.store.qvec_buf is not None:
            # quantized-mirror twin: re-quantize the touched block on the
            # host, scatter codes + codebook scales on device, and pay
            # the extra one-sided WRITE on the wire
            LA.refresh_quant_blocks(self.store, [co["vec_block"]])
            self._qv_dev, self._qs_dev = DS.overflow_append_quant(
                spec, self._qv_dev, self._qs_dev, jnp.asarray(vec),
                vb, co["vec_off"])
            wire += spec.dim + (spec.dim // spec.quant_group) * 4
        self.verbs["append"] += 1
        self._charge_write("append", ledger, wire)
        self._mt_dirty = True      # overflow counters moved
        self._notify_mutation("append", group=group, pid=int(pid),
                              slot=int(slot))
        return slot

    def repack(self, group: int, data_lookup) -> bool:
        """See ``MemoryPool.repack``; in-process, so nothing is charged
        (the offline repack is not on the query wire)."""
        self.verbs["repack"] += 1
        ok = LA.repack_group(self.store, group, data_lookup)
        if ok:
            LA.refresh_quant_group(self.store, group)
            self._stage_all()      # re-register the rewritten region
            self._notify_mutation("repack", group=int(group))
        return ok

    # ------------------------------------------------------------ stats

    def snapshot(self) -> dict:
        """See ``MemoryPool.snapshot``; adds the device-staging tallies
        (compaction, staged block count, device bytes, re-stages)."""
        out = super().snapshot()
        out["staging"] = dict(self.staging)
        return out
