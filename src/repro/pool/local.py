"""In-process memory pool: the serialized region as device arrays.

``LocalPool`` is the transport the monolithic engine always implicitly
was — span reads are device gathers from the registered region, writes
are host-staging plus a device scatter twin — now behind the
``MemoryPool`` verbs so the compute side can't tell it apart from a real
remote.  Bit-identical to the pre-pool engine by construction: the verb
bodies are the exact gather/scatter sequences the engine used inline.
"""
from __future__ import annotations

from collections import Counter
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import device_store as DS
from repro.core import layout as LA
from repro.core.cost_model import NetLedger
from repro.core.layout import Store
from repro.core.scheduler import doorbell_chunks
from repro.pool.protocol import MemoryPool, _fresh_totals, span_wire_bytes


class LocalPool(MemoryPool):
    """In-process transport: verbs are device gathers/scatters on the
    staged region; charges follow the shared ``MemoryPool`` rule."""

    kind = "local"

    def __init__(self, store: Store, *, use_gather_kernel: bool = False):
        self.store = store
        self.use_gather_kernel = use_gather_kernel
        self.verbs: Counter = Counter()
        self.totals = _fresh_totals()
        self._stage_all()

    # ------------------------------------------------------------ staging

    def _stage_all(self) -> None:
        """(Re-)register the region: host buffers -> device arrays."""
        self._g_dev = jnp.asarray(self.store.graph_buf)
        self._v_dev = jnp.asarray(self.store.vec_buf)
        self._mt_dev = jnp.asarray(self.store.meta_table)
        self._mt_dirty = False
        if self.store.qvec_buf is not None:
            self._qv_dev = jnp.asarray(self.store.qvec_buf)
            self._qs_dev = jnp.asarray(self.store.qscale_buf)
        else:
            self._qv_dev = self._qs_dev = None

    def adopt(self, store: Store) -> None:
        """See ``MemoryPool.adopt``."""
        self.store = store
        self._stage_all()

    def attach_quant(self, group: int) -> None:
        """See ``MemoryPool.attach_quant``."""
        LA.attach_quant_mirror(self.store, group)
        self._stage_quant()

    def _stage_quant(self) -> None:
        """(Re-)stage the quantized mirror (already attached to the host
        store) — split out so a sharded parent can attach the mirror
        once and have every child stage it."""
        self._qv_dev = jnp.asarray(self.store.qvec_buf)
        self._qs_dev = jnp.asarray(self.store.qscale_buf)

    def refresh_blocks(self, block_ids) -> None:
        """Re-stage specific blocks from the host region (group
        migration landing on this pool: the host bytes are the source of
        truth; this node's device copy of the arriving group is stale)."""
        ids = np.asarray(block_ids, np.int64)
        dev = jnp.asarray(ids, jnp.int32)
        self._g_dev = self._g_dev.at[dev].set(
            jnp.asarray(self.store.graph_buf[ids]))
        self._v_dev = self._v_dev.at[dev].set(
            jnp.asarray(self.store.vec_buf[ids]))
        if self._qv_dev is not None:
            self._qv_dev = self._qv_dev.at[dev].set(
                jnp.asarray(self.store.qvec_buf[ids]))
            self._qs_dev = self._qs_dev.at[dev].set(
                jnp.asarray(self.store.qscale_buf[ids]))

    # ------------------------------------------------------------ reads
    # (read_meta, the charge rule, and the post_* accounting verbs are
    # the shared MemoryPool implementations — one copy for every
    # transport so ledger parity can never drift)

    def _gather_blocks(self, buf, ids):
        if self.use_gather_kernel:
            from repro.kernels.gather_blocks import ops as GO
            return GO.gather_blocks(buf, ids)
        return jnp.take(buf, ids, axis=0)

    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """See ``MemoryPool.read_spans``; charges
        ``span_wire_bytes(spec, quant=...)`` per span, ``doorbell``
        descriptors per round trip."""
        spec = self.spec
        pids = np.asarray(pids).reshape(-1)
        self.verbs["read_spans_quant" if quant else "read_spans"] += len(pids)
        per_bytes, per_desc = span_wire_bytes(spec, quant=quant,
                                              quant_graph=quant_graph)
        if ledger is not None:
            for db in doorbell_chunks(pids, doorbell):
                self._charge("read_spans_quant" if quant else "read_spans",
                             ledger, len(db) * per_bytes,
                             per_desc * len(db))
        block_ids = np.stack([self.store.span_block_ids(int(p))
                              for p in pids])
        ids = jnp.asarray(block_ids.reshape(-1), jnp.int32)
        m = block_ids.shape[0]
        g = self._gather_blocks(self._g_dev, ids).reshape(m, -1, spec.gblk)
        if not quant:
            v = self._gather_blocks(self._v_dev, ids).reshape(m, -1,
                                                              spec.vblk)
            return g, v
        qv = self._gather_blocks(self._qv_dev, ids).reshape(m, -1, spec.vblk)
        qs = self._gather_blocks(self._qs_dev, ids).reshape(
            m, -1, spec.n_qgroups)
        return g, qv, qs

    def read_rows(self, rows):
        """See ``MemoryPool.read_rows``; charged via ``post_row_reads``."""
        self.verbs["read_rows"] += 1
        return DS.gather_rows(self._v_dev, rows, dim=self.spec.dim)

    def read_quant_rows(self, rows):
        """See ``MemoryPool.read_quant_rows``; charged via
        ``post_row_reads`` (quant rows are priced by the caller)."""
        self.verbs["read_quant_rows"] += 1
        return DS.gather_quant_rows(self._qv_dev, self._qs_dev, rows,
                                    dim=self.spec.dim,
                                    group=self.spec.quant_group)

    # ------------------------------------------------------------ writes

    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """See ``MemoryPool.append``; charges vector + 8 B id, plus
        codes + codebook scales when the quantized mirror is attached."""
        spec = self.spec
        vec = np.asarray(vec, np.float32)
        slot = LA.insert_vector(self.store, vec, int(gid), int(pid))
        if slot < 0:
            return slot
        group = int(self.store.meta_table[pid, LA.MT_GROUP])
        co = LA.overflow_write_coords(spec, group, slot)
        self._g_dev, self._v_dev = DS.overflow_append(
            spec, self._g_dev, self._v_dev, jnp.asarray(vec),
            jnp.int32(gid), co["vec_block"], co["vec_off"],
            co["gid_block"], co["gid_off"])
        wire = spec.dim * 4 + 8
        if self.store.qvec_buf is not None:
            # quantized-mirror twin: re-quantize the touched block on the
            # host, scatter codes + codebook scales on device, and pay
            # the extra one-sided WRITE on the wire
            LA.refresh_quant_blocks(self.store, [co["vec_block"]])
            self._qv_dev, self._qs_dev = DS.overflow_append_quant(
                spec, self._qv_dev, self._qs_dev, jnp.asarray(vec),
                co["vec_block"], co["vec_off"])
            wire += spec.dim + (spec.dim // spec.quant_group) * 4
        self.verbs["append"] += 1
        self._charge_write("append", ledger, wire)
        self._mt_dirty = True      # overflow counters moved
        self._notify_mutation("append", group=group, pid=int(pid),
                              slot=int(slot))
        return slot

    def repack(self, group: int, data_lookup) -> bool:
        """See ``MemoryPool.repack``; in-process, so nothing is charged
        (the offline repack is not on the query wire)."""
        self.verbs["repack"] += 1
        ok = LA.repack_group(self.store, group, data_lookup)
        if ok:
            LA.refresh_quant_group(self.store, group)
            self._stage_all()      # re-register the rewritten region
            self._notify_mutation("repack", group=int(group))
        return ok
