"""The compute/memory boundary — the paper's disaggregation, as an API.

d-HNSW's architecture is a *compute pool* that plans greedy search and a
*memory pool* reached over one-sided RDMA verbs.  Everything a compute
node may do to the memory pool is one of the verbs below; everything
else (representative meta-HNSW, resident-partition caches, round
scheduling, Pallas serve kernels) lives on the compute side
(``pool/compute.py ComputeClient``) and talks *only* through this
protocol.  That narrow waist is what makes transports swappable:

* ``LocalPool``          — in-process device arrays; bit-identical to
                           the pre-pool monolithic engine.
* ``SimulatedRDMAPool``  — same data path plus a per-verb latency /
                           bandwidth model (a simulated NIC clock), so
                           benchmark numbers reflect round trips and
                           wire time, not just event counts.
* ``ShardedPool``        — the region split group-granularly across N
                           child pools (``pool/sharded.py``): doorbell
                           batches fan out per destination shard, and a
                           pluggable placement policy may migrate hot
                           groups between nodes at runtime.

Verb accounting: data verbs take an optional ``NetLedger`` and charge it
in doorbell batches exactly the way the schemes demand — ``doorbell=1``
is the no-doorbell scheme (every span/row group its own round trip),
``doorbell=n`` groups n descriptors per trip, and the ``post_*`` verbs
charge without moving data (the naive scheme reads the same span once
per demanding query; simulation dedups the movement but must not dedup
the charge).  Passing ``ledger=None`` moves data without charging (used
only when the same verb was already posted).  Pools also keep their own
running totals (``totals``) and per-verb invocation counts (``verbs``)
— the conformance suite asserts these agree with the ledgers.
"""
from __future__ import annotations

import abc
import math
from typing import Optional

import numpy as np

from repro.core.cost_model import NetLedger
from repro.core.layout import LayoutSpec, Store
from repro.core.scheduler import doorbell_chunks
from repro.obs.trace import TRACER


class PoolUnavailableError(ConnectionError):
    """A memory node cannot be reached (dead, unreachable, or timed out).

    Raised by transports instead of hanging on a vanished node.  Callers
    that hold replicas (``ShardedPool`` with ``replication >= 2``) catch
    it, mark the shard dead, and transparently retry on a surviving
    replica; everyone else surfaces it — a single-replica pool has
    nothing to fail over to.  Defined here (not in ``repro.net``) so the
    failover tier never has to import the transport it is recovering
    from.
    """


class MemoryPool(abc.ABC):
    """Abstract memory-pool transport.

    Concrete pools own the serialized region (``Store`` host staging +
    whatever device/remote representation the transport uses) and
    implement the verbs.  ``spec`` is always ``store.spec`` — a frozen
    ``LayoutSpec`` safe to close jitted functions over.

    The *charge math* (ledger + pool totals + the trips-per-doorbell
    rule) and the pure-accounting ``post_*`` verbs live HERE, shared by
    every transport — the conformance suite's exact-ledger-parity gate
    depends on there being exactly one copy of it.  Transports that
    model or measure a wire hook ``_transport``.
    """

    kind: str = "abstract"
    store: Store

    # ------------------------------------------------------------ meta

    @property
    def spec(self) -> LayoutSpec:
        """The region's frozen ``LayoutSpec`` (= ``store.spec``)."""
        return self.store.spec

    def read_meta(self):
        """Device copy of the global metadata table (per-partition
        offsets/counters).  Compute instances cache it — the paper's
        'global metadata block' — so this verb is never charged; it is
        restaged lazily after writes move the host counters.  Concrete
        pools initialize ``_mt_dev``/``_mt_dirty`` at staging time."""
        import jax.numpy as jnp
        self.verbs["read_meta"] += 1
        if self._mt_dirty:
            self._mt_dev = jnp.asarray(self.store.meta_table)
            self._mt_dirty = False
        return self._mt_dev

    @abc.abstractmethod
    def adopt(self, store: Store) -> None:
        """Re-register a rebuilt region (the offline full re-pack)."""

    @abc.abstractmethod
    def attach_quant(self, group: int) -> None:
        """Attach (or rebuild) the int8 + codebook mirror of the region
        and stage it for quantized span reads."""

    # ------------------------------------------------------------ reads

    @abc.abstractmethod
    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """Doorbell-batched span READ: one descriptor per partition span
        (two for quantized spans — data + appended codebook).  Returns
        device blocks ``(g, v)`` with shape (m, fetch_blocks, ·), or
        ``(g, qv, qs)`` when ``quant``.  Charges ``ledger`` one round
        trip per ``doorbell`` spans."""

    @abc.abstractmethod
    def read_rows(self, rows):
        """Row-granular READ: gather exact f32 vector rows by region row
        address (-1 lanes are placeholders, masked by the caller).
        Accounting is posted separately via ``post_row_reads`` because
        residency (which rows are free) is compute-side policy."""

    @abc.abstractmethod
    def read_quant_rows(self, rows):
        """Row-granular READ from the quantized mirror: (codes, scales)
        for the dense-resident flat-scan path."""

    # ------------------------------------------------------------ charging

    def _transport(self, verb: str, n_bytes, descriptors, trips):
        """Transport hook, called once per charge with the slice it
        carried.  Default: bytes move over nothing (returns None).
        Transports that model a wire return the slice's observed
        seconds, which ``_charge`` records into the per-(verb, shard)
        latency histogram (:meth:`hist`).  Each argument may be a scalar
        (one destination) or a per-destination sequence (a sharded
        fan-out); see ``SimulatedRDMAPool``."""
        return None

    @property
    def hist(self):
        """Lazy per-(verb, shard) latency histogram view.

        ``shard_id`` (set by ``ShardedPool`` on its children; defaults
        to 0) keys the shard dimension; a transport contributes by
        returning observed seconds from ``_transport`` or by calling
        :meth:`_observe` directly (the remote CQ-poll path)."""
        h = getattr(self, "_hist", None)
        if h is None:
            from repro.obs.hist import VerbShardHist
            h = self._hist = VerbShardHist()
        return h

    def _observe(self, verb: str, seconds: float) -> None:
        """Record one observed-latency sample for ``verb`` on this pool's
        shard into :meth:`hist`."""
        self.hist.record(verb, getattr(self, "shard_id", 0), seconds)

    def _charge(self, verb: str, ledger: Optional[NetLedger],
                n_bytes: float, descriptors: int) -> None:
        """THE charge rule: ledger + pool running totals + the
        trips = ceil(descriptors / max_doorbell) split, identically on
        every transport."""
        if ledger is None:
            return
        ledger.read(n_bytes, descriptors=descriptors)
        trips = math.ceil(descriptors / ledger.fabric.max_doorbell)
        self.totals["round_trips"] += trips
        self.totals["descriptors"] += descriptors
        self.totals["bytes"] += n_bytes
        dt = self._transport(verb, n_bytes, descriptors, trips)
        if dt is not None:
            self._observe(verb, float(dt))
        if TRACER.enabled:
            TRACER.event("pool." + verb, tier="pool", kind=self.kind,
                         bytes=float(n_bytes), descs=int(descriptors),
                         trips=int(trips))

    def _charge_write(self, verb: str, ledger: Optional[NetLedger],
                      n_bytes: float) -> None:
        """The write-side twin of ``_charge``: one descriptor, one trip,
        shared by every transport's ``append`` so writes hit the same
        ledger/totals/transport/trace path as reads."""
        if ledger is None:
            return
        ledger.write(n_bytes, descriptors=1)
        self.totals["round_trips"] += 1
        self.totals["descriptors"] += 1
        self.totals["bytes"] += n_bytes
        dt = self._transport(verb, n_bytes, 1, 1)
        if dt is not None:
            self._observe(verb, float(dt))
        if TRACER.enabled:
            TRACER.event("pool." + verb, tier="pool", kind=self.kind,
                         bytes=float(n_bytes), descs=1, trips=1)

    # ------------------------------------------------- accounting posts

    def post_span_reads(self, n: int, *, ledger: NetLedger,
                        doorbell: int = 1, quant: bool = False,
                        quant_graph: bool = True, pids=None) -> None:
        """Charge ``n`` span READs without moving data (naive scheme:
        every (query, partition) demand is its own read; the flat
        resident sweep: spans already moved by a data verb).  ``pids``
        optionally names the spans so a sharded pool can attribute each
        charge to its destination node; single-node pools ignore it."""
        self.verbs["post_span_reads"] += n
        per_bytes, per_desc = span_wire_bytes(self.spec, quant=quant,
                                              quant_graph=quant_graph)
        for db in doorbell_chunks(np.arange(n), doorbell):
            self._charge("post_span_reads", ledger, len(db) * per_bytes,
                         per_desc * len(db))

    def post_row_reads(self, groups, *, ledger: NetLedger,
                       doorbell: int = 1) -> None:
        """Charge row-granular READs.  ``groups`` is [(pid, n_rows)];
        each group is one descriptor batch member, grouped ``doorbell``
        groups per round trip."""
        row_b = self.spec.row_bytes()
        groups = list(groups)
        self.verbs["post_row_reads"] += len(groups)
        for chunk in doorbell_chunks(groups, doorbell):
            cnt = sum(c for _, c in chunk)
            self._charge("post_row_reads", ledger, cnt * row_b, cnt)

    # ------------------------------------------------------------ mutation

    def register_mutation_hook(self, fn) -> None:
        """Subscribe ``fn(verb, **info)`` to state-mutating verbs.

        Transports call :meth:`_notify_mutation` after an ``append`` or
        ``repack`` lands; the ingest compactor uses this to track dirty
        groups without polling, and tests use it to observe write flow.
        Hooks run synchronously on the mutating thread and must be
        cheap; a hook must never call back into the pool.
        """
        if not hasattr(self, "_mutation_hooks"):
            self._mutation_hooks = []
        self._mutation_hooks.append(fn)

    def _notify_mutation(self, verb: str, **info) -> None:
        """Fan a landed mutation out to the registered hooks."""
        for fn in getattr(self, "_mutation_hooks", ()):
            fn(verb, **info)

    # ------------------------------------------------------------ writes

    @abc.abstractmethod
    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """One-sided WRITE: stage one vector into ``pid``'s shared
        overflow region — host layout, device twin, and (when attached)
        the quantized-mirror twin, atomically.  Returns the slot index
        or -1 when the group's region is full (caller must repack).
        Charges the wire bytes of the write (vector + id, plus codes +
        codebook scales when the mirror is attached)."""

    @abc.abstractmethod
    def repack(self, group: int, data_lookup) -> bool:
        """Offline re-pack of one group (paper §3.2): fold both
        partners' overflow into rebuilt sub-HNSWs, refresh the quantized
        mirror, re-register the touched region.  Returns False when a
        merged partition no longer fits (caller must full-rebuild)."""

    # ------------------------------------------------------------ stats

    def snapshot(self) -> dict:
        """Verb counts + charged totals (+ transport-specific extras)."""
        out = {"kind": self.kind, "verbs": dict(self.verbs),
               "totals": dict(self.totals)}
        h = getattr(self, "_hist", None)
        if h is not None and len(h):
            out["hist"] = h.to_dict()
        return out


def _fresh_totals() -> dict:
    return {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}


def span_wire_bytes(spec: LayoutSpec, *, quant: bool,
                    quant_graph: bool = True) -> tuple[int, int]:
    """(bytes, descriptors) of ONE span read under the given precision —
    the single pricing rule every pool and every scheme shares."""
    if quant:
        return spec.quant_partition_bytes(include_graph=quant_graph), 2
    return spec.partition_bytes(), 1
