"""The compute/memory boundary — the paper's disaggregation, as an API.

d-HNSW's architecture is a *compute pool* that plans greedy search and a
*memory pool* reached over one-sided RDMA verbs.  Everything a compute
node may do to the memory pool is one of the verbs below; everything
else (representative meta-HNSW, resident-partition caches, round
scheduling, Pallas serve kernels) lives on the compute side
(``pool/compute.py ComputeClient``) and talks *only* through this
protocol.  That narrow waist is what makes transports swappable:

* ``LocalPool``          — in-process device arrays; bit-identical to
                           the pre-pool monolithic engine.
* ``SimulatedRDMAPool``  — same data path plus a per-verb latency /
                           bandwidth model (a simulated NIC clock), so
                           benchmark numbers reflect round trips and
                           wire time, not just event counts.
* ``ShardedPool``        — the region split group-granularly across N
                           child pools (``pool/sharded.py``): doorbell
                           batches fan out per destination shard, and a
                           pluggable placement policy may migrate hot
                           groups between nodes at runtime.

Verb accounting: data verbs take an optional ``NetLedger`` and charge it
in doorbell batches exactly the way the schemes demand — ``doorbell=1``
is the no-doorbell scheme (every span/row group its own round trip),
``doorbell=n`` groups n descriptors per trip, and the ``post_*`` verbs
charge without moving data (the naive scheme reads the same span once
per demanding query; simulation dedups the movement but must not dedup
the charge).  Passing ``ledger=None`` moves data without charging (used
only when the same verb was already posted).  Pools also keep their own
running totals (``totals``) and per-verb invocation counts (``verbs``)
— the conformance suite asserts these agree with the ledgers.
"""
from __future__ import annotations

import abc
from typing import Optional

from repro.core.cost_model import NetLedger
from repro.core.layout import LayoutSpec, Store


class MemoryPool(abc.ABC):
    """Abstract memory-pool transport.

    Concrete pools own the serialized region (``Store`` host staging +
    whatever device/remote representation the transport uses) and
    implement the verbs.  ``spec`` is always ``store.spec`` — a frozen
    ``LayoutSpec`` safe to close jitted functions over.
    """

    kind: str = "abstract"
    store: Store

    # ------------------------------------------------------------ meta

    @property
    def spec(self) -> LayoutSpec:
        return self.store.spec

    @abc.abstractmethod
    def read_meta(self):
        """Device copy of the global metadata table (per-partition
        offsets/counters).  Compute instances cache it — the paper's
        'global metadata block' — so this verb is never charged; it is
        restaged lazily after writes move the host counters."""

    @abc.abstractmethod
    def adopt(self, store: Store) -> None:
        """Re-register a rebuilt region (the offline full re-pack)."""

    @abc.abstractmethod
    def attach_quant(self, group: int) -> None:
        """Attach (or rebuild) the int8 + codebook mirror of the region
        and stage it for quantized span reads."""

    # ------------------------------------------------------------ reads

    @abc.abstractmethod
    def read_spans(self, pids, *, ledger: Optional[NetLedger],
                   doorbell: int = 1, quant: bool = False,
                   quant_graph: bool = True):
        """Doorbell-batched span READ: one descriptor per partition span
        (two for quantized spans — data + appended codebook).  Returns
        device blocks ``(g, v)`` with shape (m, fetch_blocks, ·), or
        ``(g, qv, qs)`` when ``quant``.  Charges ``ledger`` one round
        trip per ``doorbell`` spans."""

    @abc.abstractmethod
    def read_rows(self, rows):
        """Row-granular READ: gather exact f32 vector rows by region row
        address (-1 lanes are placeholders, masked by the caller).
        Accounting is posted separately via ``post_row_reads`` because
        residency (which rows are free) is compute-side policy."""

    @abc.abstractmethod
    def read_quant_rows(self, rows):
        """Row-granular READ from the quantized mirror: (codes, scales)
        for the dense-resident flat-scan path."""

    # ------------------------------------------------- accounting posts

    @abc.abstractmethod
    def post_span_reads(self, n: int, *, ledger: NetLedger,
                        doorbell: int = 1, quant: bool = False,
                        quant_graph: bool = True, pids=None) -> None:
        """Charge ``n`` span READs without moving data (naive scheme:
        every (query, partition) demand is its own read; the flat
        resident sweep: spans already moved by a data verb).  ``pids``
        optionally names the spans so a sharded pool can attribute each
        charge to its destination node; single-node pools ignore it."""

    @abc.abstractmethod
    def post_row_reads(self, groups, *, ledger: NetLedger,
                       doorbell: int = 1) -> None:
        """Charge row-granular READs.  ``groups`` is [(pid, n_rows)];
        each group is one descriptor batch member, grouped ``doorbell``
        groups per round trip."""

    # ------------------------------------------------------------ writes

    @abc.abstractmethod
    def append(self, vec, gid: int, pid: int, *,
               ledger: Optional[NetLedger]) -> int:
        """One-sided WRITE: stage one vector into ``pid``'s shared
        overflow region — host layout, device twin, and (when attached)
        the quantized-mirror twin, atomically.  Returns the slot index
        or -1 when the group's region is full (caller must repack).
        Charges the wire bytes of the write (vector + id, plus codes +
        codebook scales when the mirror is attached)."""

    @abc.abstractmethod
    def repack(self, group: int, data_lookup) -> bool:
        """Offline re-pack of one group (paper §3.2): fold both
        partners' overflow into rebuilt sub-HNSWs, refresh the quantized
        mirror, re-register the touched region.  Returns False when a
        merged partition no longer fits (caller must full-rebuild)."""

    # ------------------------------------------------------------ stats

    def snapshot(self) -> dict:
        """Verb counts + charged totals (+ transport-specific extras)."""
        return {"kind": self.kind, "verbs": dict(self.verbs),
                "totals": dict(self.totals)}


def _fresh_totals() -> dict:
    return {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}


def span_wire_bytes(spec: LayoutSpec, *, quant: bool,
                    quant_graph: bool = True) -> tuple[int, int]:
    """(bytes, descriptors) of ONE span read under the given precision —
    the single pricing rule every pool and every scheme shares."""
    if quant:
        return spec.quant_partition_bytes(include_graph=quant_graph), 2
    return spec.partition_bytes(), 1
