"""Elastic scaling: reshard live state onto a different mesh.

Down-scale (lost a pod / shrank the fleet) and up-scale (capacity came
back) are the same operation: build the new mesh, resolve the same
*logical* specs against it, and ``device_put`` every leaf to its new
sharding.  Works for params/opt state (train) and for the d-HNSW
sharded store (serve) — the store's block-contiguous owner mapping means
a rescale moves whole block ranges, and ``plan_store_migration`` lists
exactly which block spans each owner sends where.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_tree(tree: Any, new_shardings: Any) -> Any:
    """Move every leaf to the new mesh/sharding (cross-mesh device_put)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, new_shardings)


def rescale_train_state(params, opt_state, defs, new_mesh: Mesh):
    """Re-resolve the params' logical specs on ``new_mesh`` and move."""
    from repro.models.params import param_shardings
    from repro.train.adamw import AdamWState
    p_sh = param_shardings(defs, new_mesh)
    opt_sh = AdamWState(NamedSharding(new_mesh, P()), p_sh, p_sh)
    return reshard_tree(params, p_sh), reshard_tree(opt_state, opt_sh)


def plan_store_migration(n_blocks: int, old_tp: int, new_tp: int):
    """Block moves for rescaling the d-HNSW memory pool owner count.

    Returns [(src_owner, dst_owner, first_block, n)] — contiguous spans
    only (the layout guarantee).  Total moved bytes is the rescale cost.
    """
    old_per = -(-n_blocks // old_tp)
    new_per = -(-n_blocks // new_tp)
    moves = []
    b = 0
    while b < n_blocks:
        src = min(b // old_per, old_tp - 1)
        dst = min(b // new_per, new_tp - 1)
        # span until either owner boundary changes
        nxt = min((b // old_per + 1) * old_per,
                  (b // new_per + 1) * new_per, n_blocks)
        if src != dst:
            moves.append((src, dst, b, nxt - b))
        b = nxt
    return moves
