"""Fault tolerance: heartbeats, straggler detection, checkpoint-restart.

At 1000+ nodes the design assumptions are: (i) *some* worker is always
slow or dead, (ii) restart must resume from the last committed step with
no torn state, (iii) the d-HNSW partition->owner map must re-balance
away from sick memory owners without a full re-shard.

``HeartbeatMonitor`` tracks per-worker beat times and per-step
durations; stragglers are flagged by an EWMA z-score on step time (the
standard straggler test — robust to the global speed drifting).
``run_with_restarts`` is the supervision loop: it executes a step
function, checkpoints every ``ckpt_every`` steps (atomic, see
train/checkpoint.py), and on failure restores the last commit and
continues — fault injection in tests exercises exactly this path.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.train import checkpoint as CKPT


@dataclass
class WorkerStats:
    last_beat: float = 0.0
    ewma: float = 0.0       # step-time EWMA
    ewvar: float = 0.0      # EWMA of squared deviation
    n: int = 0


class HeartbeatMonitor:
    """Detects dead workers (beat timeout) and stragglers (z-score)."""

    def __init__(self, n_workers: int, *, timeout_s: float = 10.0,
                 alpha: float = 0.2, z_thresh: float = 3.0):
        self.workers = {i: WorkerStats() for i in range(n_workers)}
        self.timeout_s = timeout_s
        self.alpha = alpha
        self.z_thresh = z_thresh

    def beat(self, worker: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        w = self.workers[worker]
        w.last_beat = time.monotonic() if now is None else now
        if w.n == 0:
            w.ewma = step_time_s
        else:
            d = step_time_s - w.ewma
            w.ewma += self.alpha * d
            w.ewvar = (1 - self.alpha) * (w.ewvar + self.alpha * d * d)
        w.n += 1

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [i for i, w in self.workers.items()
                if w.n > 0 and now - w.last_beat > self.timeout_s]

    def stragglers(self) -> list[int]:
        """Workers whose EWMA step time is a z_thresh outlier vs the fleet."""
        live = [w.ewma for w in self.workers.values() if w.n >= 3]
        if len(live) < 3:
            return []
        mean = sum(live) / len(live)
        var = sum((x - mean) ** 2 for x in live) / len(live)
        sd = math.sqrt(var) + 1e-9
        return [i for i, w in self.workers.items()
                if w.n >= 3 and (w.ewma - mean) / sd > self.z_thresh]


def rebalance_partitions(owners, sick: set[int], n_owners: int):
    """Reassign d-HNSW partitions owned by sick memory instances to the
    least-loaded healthy ones.  The paper's layout makes each migration a
    contiguous copy of one group span.  Returns (new_owners, moves)."""
    import numpy as np
    owners = np.asarray(owners).copy()
    healthy = [o for o in range(n_owners) if o not in sick]
    if not healthy:
        raise RuntimeError("no healthy memory instances left")
    load = {o: int((owners == o).sum()) for o in healthy}
    moves = []
    for pid in np.nonzero(np.isin(owners, list(sick)))[0]:
        tgt = min(load, key=load.get)
        moves.append((int(pid), int(owners[pid]), tgt))
        owners[pid] = tgt
        load[tgt] += 1
    return owners, moves


@dataclass
class RestartReport:
    steps_done: int
    n_failures: int
    n_restores: int
    history: list = field(default_factory=list)


def run_with_restarts(step_fn: Callable[[Any, int], Any], state: Any,
                      n_steps: int, *, ckpt_dir: str, ckpt_every: int = 10,
                      shardings: Any = None,
                      max_failures: int = 10) -> tuple[Any, RestartReport]:
    """Supervised training loop: step, checkpoint, restore-on-failure.

    ``step_fn(state, step) -> state`` may raise (fault injection or real
    device loss).  On failure we restore the last committed checkpoint
    and resume from its step.  This is the single-controller analogue of
    a multi-controller restart: in a real pod deployment each host runs
    this loop and the failed host's work is recovered from the shared
    checkpoint directory.
    """
    report = RestartReport(0, 0, 0)
    step = 0
    CKPT.save(ckpt_dir, step, state)
    failures = 0
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            report.steps_done = step
            if step % ckpt_every == 0 or step == n_steps:
                CKPT.save(ckpt_dir, step, state)
                report.history.append(("ckpt", step))
        except Exception as e:  # noqa: BLE001 — supervision boundary
            failures += 1
            report.n_failures = failures
            if failures > max_failures:
                raise
            state, step = CKPT.restore(ckpt_dir, state, shardings=shardings)
            report.n_restores += 1
            report.history.append(("restore", step, repr(e)[:60]))
    return state, report
