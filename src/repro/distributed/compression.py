"""Gradient compression for the data-parallel all-reduce.

int8 quantization with **error feedback** (residual carry): each step
quantizes ``g + e`` per-leaf with a shared absmax scale, all-reduces the
int8 payload (accumulated in int32 to avoid overflow), dequantizes, and
stores the quantization error back into ``e``.  Error feedback makes the
compressed SGD trajectory converge like the uncompressed one (the noise
telescopes); wire bytes for the grad reduction drop 4x.

Implemented as an explicit ``shard_map`` reduction over the batch axes
so the HLO really carries int8 (an implicit GSPMD all-reduce would stay
f32).  ``compressed_grad_reduce`` is dropped into the train step between
grad computation and the optimizer.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P


class ErrorState(NamedTuple):
    residual: Any  # pytree like grads (f32)


def init_error_state(grads_like) -> ErrorState:
    return ErrorState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32 -> (int8 payload, per-leaf scale)."""
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g, e):
    """(grad, residual) -> (int8, scale, new_residual_fn input)."""
    target = g.astype(jnp.float32) + e
    q, scale = quantize(target)
    return q, scale, target


def compressed_grad_reduce(grads, err: ErrorState, mesh: Mesh,
                           batch_axes=("data",)):
    """All-reduce (mean) int8-compressed grads over ``batch_axes``.

    grads enter as per-device *local* grads inside shard_map (callers
    wrap this; see make_compressed_train_step) and leave dequantized,
    averaged, with updated error state.
    """
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def leaf(g, e):
        target = g.astype(jnp.float32) + e
        # SHARED scale (pmax over replicas): the int8 payloads then share
        # one codebook, so the int32 psum dequantizes exactly — a
        # per-replica scale would corrupt the sum
        absmax = lax.pmax(jnp.max(jnp.abs(target)), axes)
        scale = jnp.maximum(absmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        acc = lax.psum(q.astype(jnp.int32), axes)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        g_hat = acc.astype(jnp.float32) * scale / n
        new_e = target - q.astype(jnp.float32) * scale  # local quant error
        return g_hat, new_e

    out = jax.tree.map(leaf, grads, err.residual)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, ErrorState(new_e)


def wire_bytes_saved(grads) -> dict:
    """Accounting helper: f32 vs int8(+scale) all-reduce payload."""
    n = sum(int(g.size) for g in jax.tree.leaves(grads))
    return {"f32_bytes": 4 * n, "int8_bytes": n + 4,
            "ratio": 4 * n / (n + 4)}
