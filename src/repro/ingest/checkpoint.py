"""Atomic region checkpoints + the per-server durability orchestrator.

A checkpoint is one self-checking file holding the full serialized
region — literally the wire ``ATTACH`` payload (``net/wire.enc_attach``)
with a small header — committed with the write-temp-fsync-rename idiom
(the same discipline as ``train/checkpoint.py``), so a reader sees
either the old checkpoint or the new one, never a torn file.

``Durability`` glues checkpointing to the WAL for a ``PoolServer``
running with ``--data-dir``:

* every mutating verb is logged (``log``) before the server acks;
* ``maybe_checkpoint`` snapshots the region every ``checkpoint_every``
  logged records and *rotates* the WAL — the new log file is named by
  the total records already folded into the checkpoint, so a crash
  between the checkpoint rename and the rotation can never replay a
  record twice (the stale log's name no longer matches);
* ``recover`` loads the checkpoint (if any) and returns the committed
  WAL tail for the caller to replay through its verb handlers.

Data-dir layout::

    <data_dir>/checkpoint.bin     the region snapshot (atomic)
    <data_dir>/wal.<applied>.log  mutations since that snapshot
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from typing import List, Optional, Tuple

from repro.ingest.wal import WalRecord, WriteAheadLog, read_wal
from repro.obs.trace import TRACER

MAGIC = b"dHCK"
VERSION = 1
_HDR = struct.Struct("<4sHHQIQ")   # magic, version, flags, applied, crc, len

CKPT_FILE = "checkpoint.bin"


def _wal_path(data_dir: str, applied: int) -> str:
    return os.path.join(data_dir, f"wal.{applied:012d}.log")


def save_checkpoint(data_dir: str, store, *, applied: int = 0) -> int:
    """Atomically snapshot ``store`` into ``<data_dir>/checkpoint.bin``.

    ``applied`` is the total mutation count folded into this snapshot
    (the WAL rotation key).  Returns bytes written.
    """
    from repro.net import wire as W
    payload, flags = W.enc_attach(store)
    hdr = _HDR.pack(MAGIC, VERSION, flags, applied, zlib.crc32(payload),
                    len(payload))
    path = os.path.join(data_dir, CKPT_FILE)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(hdr)
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    dirfd = os.open(data_dir, os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)
    return len(hdr) + len(payload)


def load_checkpoint(data_dir: str):
    """Load a checkpoint -> ``(store, applied)``, or ``None`` if absent.

    Raises ``IOError`` on a corrupt file (bad magic, version, or CRC) —
    corruption must be surfaced, not silently served.
    """
    from repro.net import wire as W
    path = os.path.join(data_dir, CKPT_FILE)
    try:
        with open(path, "rb") as f:
            buf = f.read()
    except FileNotFoundError:
        return None
    if len(buf) < _HDR.size:
        raise IOError(f"checkpoint {path}: truncated header")
    magic, version, flags, applied, crc, plen = _HDR.unpack_from(buf)
    if magic != MAGIC or version != VERSION:
        raise IOError(f"checkpoint {path}: bad magic/version")
    payload = buf[_HDR.size:]
    if len(payload) != plen or zlib.crc32(payload) != crc:
        raise IOError(f"checkpoint {path}: checksum mismatch")
    return W.dec_attach(payload, flags), applied


class Durability:
    """WAL + checkpoint lifecycle for one pool server's region."""

    def __init__(self, data_dir: str, *, checkpoint_every: int = 256,
                 fsync: bool = False):
        os.makedirs(data_dir, exist_ok=True)
        self.data_dir = data_dir
        self.checkpoint_every = checkpoint_every
        self.fsync = fsync
        self.replaying = False       # suppress log() during replay
        self.applied = 0             # total mutations (ckpt + WAL)
        self._ckpt_base = 0          # mutations folded into the ckpt
        self.n_checkpoints = 0
        self.checkpoint_bytes = 0
        self.replayed_records = 0
        self.torn_bytes = 0
        self.recovered = False
        self._wal: Optional[WriteAheadLog] = None

    # ------------------------------------------------------------ recovery

    def recover(self) -> Tuple[Optional[object], List[WalRecord]]:
        """Load the checkpoint + committed WAL tail -> (store, tail).

        The caller replays ``tail`` through its verb handlers (wrapped
        in :meth:`replay_guard` so replay is never re-logged), then
        normally calls :meth:`checkpoint` to fold the tail in.  Stale
        WAL files from an interrupted rotation are deleted here.
        """
        store = None
        ck = load_checkpoint(self.data_dir)
        if ck is not None:
            store, self._ckpt_base = ck
            self.recovered = True
        tail_path = _wal_path(self.data_dir, self._ckpt_base)
        records, torn = read_wal(tail_path)
        self.torn_bytes = torn
        self.replayed_records = len(records)
        if records:
            self.recovered = True
        for name in os.listdir(self.data_dir):
            p = os.path.join(self.data_dir, name)
            if name.startswith("wal.") and p != tail_path:
                os.remove(p)        # pre-checkpoint log: already folded in
        self.applied = self._ckpt_base + len(records)
        self._wal = WriteAheadLog(tail_path, fsync=self.fsync)
        return store, records

    def replay_guard(self):
        """Context manager marking handler dispatch as replay (no log)."""
        dur = self

        class _Guard:
            def __enter__(self):
                dur.replaying = True
                return dur

            def __exit__(self, *exc):
                dur.replaying = False
                return False

        return _Guard()

    # ------------------------------------------------------------ logging

    def log(self, op: int, flags: int, payload: bytes) -> None:
        """Append one mutating verb to the WAL (no-op during replay)."""
        if self.replaying:
            return
        if self._wal is None:
            self._wal = WriteAheadLog(
                _wal_path(self.data_dir, self._ckpt_base), fsync=self.fsync)
        self._wal.append(op, flags, payload)
        self.applied += 1

    def pending(self) -> int:
        """Mutations logged since the last checkpoint."""
        return self.applied - self._ckpt_base

    def maybe_checkpoint(self, store) -> bool:
        """Checkpoint when the cadence says so; returns True if it did."""
        if self.checkpoint_every <= 0 or store is None:
            return False
        if self.pending() < self.checkpoint_every:
            return False
        self.checkpoint(store)
        return True

    def checkpoint(self, store) -> int:
        """Snapshot the region now and rotate the WAL.  Returns bytes."""
        t0 = time.perf_counter()
        n = save_checkpoint(self.data_dir, store, applied=self.applied)
        old = self._wal
        self._ckpt_base = self.applied
        self._wal = WriteAheadLog(_wal_path(self.data_dir, self._ckpt_base),
                                  fsync=self.fsync)
        if old is not None:
            old.close()
            if old.path != self._wal.path and os.path.exists(old.path):
                os.remove(old.path)
        self.n_checkpoints += 1
        self.checkpoint_bytes += n
        if TRACER.enabled:
            TRACER.add("ingest.checkpoint", "ingest", t0,
                       time.perf_counter() - t0, bytes=n,
                       applied=self.applied)
        return n

    # ------------------------------------------------------------ stats

    def stats(self) -> dict:
        """Durability counters for the STATS verb / Prometheus export."""
        return {
            "applied": self.applied,
            "wal_records": 0 if self._wal is None else self._wal.records,
            "wal_bytes": 0 if self._wal is None else self._wal.bytes,
            "checkpoints": self.n_checkpoints,
            "checkpoint_bytes": self.checkpoint_bytes,
            "replayed_records": self.replayed_records,
            "torn_bytes": self.torn_bytes,
            "recovered": self.recovered,
        }

    def close(self) -> None:
        """Release the WAL handle (server shutdown)."""
        if self._wal is not None:
            self._wal.close()
