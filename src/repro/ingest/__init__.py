"""Streaming ingestion + durability for the memory pool.

The missing third leg of the disaggregated system: everything before
this subsystem held the region only in volatile memory (a dead
``PoolServer`` lost its bytes, and PR 6's failover re-replicated them
from the *host* region — a crutch), and the region itself had to be
built fully in builder RAM before one big ATTACH.  ``repro.ingest``
fixes both:

* ``wal.py``        — a length-prefixed, CRC-checked write-ahead log of
                      the state-mutating verbs; records reuse the wire
                      codecs verbatim, so replay is just re-dispatch.
* ``checkpoint.py`` — atomic region snapshots (write-temp-fsync-rename)
                      plus ``Durability``, the per-server orchestrator
                      a ``PoolServer --data-dir`` runs: log every
                      mutation before acking, checkpoint on a cadence,
                      recover checkpoint + WAL tail on restart.
* ``loader.py``     — out-of-core bulk loading: stream vectors in
                      bounded-memory chunks (parse -> validate ->
                      retry/error-queue), spill to disk, and serialize
                      the region group-by-group so peak builder RSS is
                      O(chunk), not O(dataset) — bit-identical to an
                      in-memory build.
* ``compactor.py``  — a background compaction daemon that watches
                      per-group overflow ratios and issues ``repack``
                      verbs off the serve path under a rate budget.

Observability: spans ``ingest.wal_append`` / ``ingest.checkpoint`` /
``ingest.replay`` / ``ingest.compact`` plus Prometheus counters via
``repro.obs.metrics`` (the pool-server exporter renders the durability
counters, the compactor renders its own).
"""
from repro.ingest.checkpoint import (Durability, load_checkpoint,
                                     save_checkpoint)
from repro.ingest.compactor import CompactionPolicy, Compactor
from repro.ingest.loader import BulkLoader, LoadReport, chunked_source
from repro.ingest.wal import (WalRecord, WriteAheadLog, encode_record,
                              iter_records, read_wal)

__all__ = ["WriteAheadLog", "WalRecord", "encode_record", "iter_records",
           "read_wal", "save_checkpoint", "load_checkpoint", "Durability",
           "BulkLoader", "LoadReport", "chunked_source", "Compactor",
           "CompactionPolicy"]
