"""Write-ahead log for pool-server mutations.

Every state-mutating verb a ``PoolServer`` acks (``attach``,
``attach_quant``, ``append``, ``write_blocks``) is first appended here
as one record.  A record carries the verb's *wire encoding* verbatim —
``(op, flags, payload)`` exactly as it arrived in the frame — so replay
is re-dispatch through the same handler table, and the WAL needs no
codec of its own beyond framing:

    record := u32 body_len | u32 crc32(body) | body
    body   := u8 op | u16 flags | payload bytes

Torn-tail semantics: a crash mid-append leaves a short or CRC-broken
final record; ``iter_records`` stops cleanly at the first bad record and
reports how many trailing bytes it abandoned, so recovery replays every
fully-committed mutation and nothing else.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from dataclasses import dataclass
from typing import Iterator, List, Tuple

from repro.obs.trace import TRACER

_HDR = struct.Struct("<II")     # body_len, crc32(body)
_BODY = struct.Struct("<BH")    # op, flags

#: Upper bound on one record body (64 MiB) — a corrupt length prefix
#: must not allocate unbounded memory during replay.
MAX_BODY = 64 << 20


@dataclass(frozen=True)
class WalRecord:
    """One replayable mutation: the verb's wire triple."""

    op: int
    flags: int
    payload: bytes


def encode_record(op: int, flags: int, payload: bytes) -> bytes:
    """Frame one mutation as a self-checking WAL record."""
    if not 0 <= op <= 0xFF:
        raise ValueError(f"op {op} out of u8 range")
    if not 0 <= flags <= 0xFFFF:
        raise ValueError(f"flags {flags} out of u16 range")
    body = _BODY.pack(op, flags) + bytes(payload)
    return _HDR.pack(len(body), zlib.crc32(body)) + body


def iter_records(buf: bytes) -> Iterator[WalRecord]:
    """Yield committed records from a log image, stopping cleanly at a
    torn tail (short header, short body, oversized length, or CRC
    mismatch — all treated as end-of-log, never an exception)."""
    off = 0
    n = len(buf)
    while off + _HDR.size <= n:
        body_len, crc = _HDR.unpack_from(buf, off)
        if body_len < _BODY.size or body_len > MAX_BODY:
            return
        end = off + _HDR.size + body_len
        if end > n:
            return
        body = buf[off + _HDR.size:end]
        if zlib.crc32(body) != crc:
            return
        op, flags = _BODY.unpack_from(body)
        yield WalRecord(op, flags, body[_BODY.size:])
        off = end


def read_wal(path: str) -> Tuple[List[WalRecord], int]:
    """Read a log file -> (committed records, torn tail bytes dropped).

    A missing file reads as an empty log (fresh server).
    """
    try:
        with open(path, "rb") as f:
            buf = f.read()
    except FileNotFoundError:
        return [], 0
    records = list(iter_records(buf))
    consumed = sum(_HDR.size + _BODY.size + len(r.payload) for r in records)
    return records, len(buf) - consumed


class WriteAheadLog:
    """Append-only mutation log with durable-before-ack semantics.

    ``fsync=True`` makes every append an fsync (crash-safe against power
    loss); the default flushes to the OS (crash-safe against process
    death — the kill -9 case the tests exercise) without paying a disk
    sync per verb.
    """

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        self._f = open(path, "ab")
        self.records = 0           # appended this session
        self.bytes = self._f.tell()

    def append(self, op: int, flags: int, payload: bytes) -> int:
        """Durably append one mutation; returns the session record index.

        Emits an ``ingest.wal_append`` trace event when tracing is on.
        """
        rec = encode_record(op, flags, payload)
        t0 = time.perf_counter()
        self._f.write(rec)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.records += 1
        self.bytes += len(rec)
        if TRACER.enabled:
            TRACER.add("ingest.wal_append", "ingest", t0,
                       time.perf_counter() - t0, op=int(op),
                       bytes=len(rec))
        return self.records - 1

    def truncate(self) -> None:
        """Reset the log (a checkpoint just made its records redundant)."""
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        self._f = open(self.path, "ab")
        self.bytes = 0

    def close(self) -> None:
        """Flush and release the log file handle."""
        try:
            self._f.flush()
            self._f.close()
        except ValueError:          # already closed
            pass
