"""Background compaction: fold overflow back into the layout off-path.

Appends land in a group's shared overflow strip; searches then pay an
extra overflow read per touched group until someone calls ``repack``.
The serve path deliberately never does (PR 3 moved repack off the hot
path) — the :class:`Compactor` is the *someone*: it watches per-group
overflow occupancy straight from the pool's ``meta_table`` mirror,
picks the worst offenders, and issues ``repack`` verbs under a rate
budget so compaction cost never bursts into serving latency.

The trigger is event-driven, not poll-only: the pool's mutation hook
(``MemoryPool.register_mutation_hook``) marks groups dirty as appends
happen, so a ``tick`` inspects only groups that actually changed.
``tick()`` is synchronous (tests drive it deterministically);
``start()`` runs the same tick on a daemon thread for real deployments.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Set

import numpy as np

from repro.core.layout import MT_OV_A, MT_OV_B
from repro.obs.trace import TRACER


@dataclass
class CompactionPolicy:
    """Knobs for when and how fast the daemon compacts.

    ``threshold`` is the overflow-strip occupancy (used / ov_cap) above
    which a group is eligible; ``max_repacks_per_tick`` is the rate
    budget; ``interval_s`` paces the background thread.
    """

    threshold: float = 0.5
    max_repacks_per_tick: int = 2
    interval_s: float = 0.25


class Compactor:
    """Watch overflow ratios and repack the worst groups off-path.

    ``data_lookup(gids) -> vectors`` resolves global ids to raw vectors
    during repack (the engine wires its own ``_lookup``);
    ``on_compacted(group)`` lets the owner invalidate caches for the
    rewritten group.
    """

    def __init__(self, pool, data_lookup: Callable,
                 policy: Optional[CompactionPolicy] = None,
                 on_compacted: Optional[Callable[[int], None]] = None):
        self.pool = pool
        self.data_lookup = data_lookup
        self.policy = policy or CompactionPolicy()
        self.on_compacted = on_compacted
        self.dirty: Set[int] = set()
        self.groups_compacted = 0
        self.ticks = 0
        self.skipped_budget = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._scanned_once = False
        pool.register_mutation_hook(self._on_mutation)

    # ------------------------------------------------------------ events

    def _on_mutation(self, verb: str, **info) -> None:
        if verb == "append" and "group" in info:
            self.dirty.add(int(info["group"]))

    # ------------------------------------------------------------ policy

    def overflow_ratios(self) -> Dict[int, float]:
        """Per-group overflow occupancy (used / ov_cap) from meta."""
        spec = self.pool.store.spec
        mt = np.asarray(self.pool.read_meta())
        out: Dict[int, float] = {}
        for g in range(spec.n_groups):
            row = mt[2 * g]
            used = int(row[MT_OV_A]) + int(row[MT_OV_B])
            out[g] = used / max(spec.ov_cap, 1)
        return out

    def _candidates(self) -> Dict[int, float]:
        ratios = self.overflow_ratios()
        if self._scanned_once:
            ratios = {g: r for g, r in ratios.items() if g in self.dirty}
        self._scanned_once = True
        return {g: r for g, r in ratios.items()
                if r >= self.policy.threshold}

    # ------------------------------------------------------------ ticking

    def tick(self) -> int:
        """One compaction round: repack up to the budget, worst-first.

        Returns how many groups were repacked.  Deterministic — the
        tests call this directly instead of racing the thread.
        """
        self.ticks += 1
        cands = sorted(self._candidates().items(),
                       key=lambda kv: -kv[1])
        if len(cands) > self.policy.max_repacks_per_tick:
            self.skipped_budget += (len(cands)
                                    - self.policy.max_repacks_per_tick)
            cands = cands[:self.policy.max_repacks_per_tick]
        done = 0
        for group, ratio in cands:
            t0 = time.perf_counter()
            changed = self.pool.repack(group, self.data_lookup)
            if TRACER.enabled:
                TRACER.add("ingest.compact", "ingest", t0,
                           time.perf_counter() - t0, group=int(group),
                           ratio=float(ratio), changed=bool(changed))
            self.dirty.discard(group)
            if changed:
                done += 1
                self.groups_compacted += 1
                if self.on_compacted is not None:
                    self.on_compacted(group)
        return done

    # ------------------------------------------------------------ daemon

    def start(self) -> "Compactor":
        """Run ticks on a daemon thread every ``interval_s``."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.policy.interval_s):
                self.tick()

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="repro-compactor")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the background thread (idempotent)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    # ------------------------------------------------------------ stats

    def stats(self) -> dict:
        """Counters for the Prometheus exporter."""
        return {
            "ticks": self.ticks,
            "groups_compacted": self.groups_compacted,
            "skipped_budget": self.skipped_budget,
            "dirty_groups": len(self.dirty),
        }
