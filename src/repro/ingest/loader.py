"""Out-of-core bulk loading: build the region with O(chunk) builder RAM.

The in-memory build (``ComputeClient.build``) holds the whole dataset
while it samples representatives, assigns every vector, and serializes
every partition.  ``BulkLoader`` produces a **bit-identical** meta +
region from a stream of bounded chunks instead:

* **pass 1 (parse -> validate -> spill)**: each chunk is parsed to
  float32, validated (rank/width/finiteness), and appended to a disk
  spill file; chunks that fail land in a retryable error queue
  (``error_queue`` / :meth:`retry_failed`) instead of aborting the load.
* **pass 2 (finalize)**: representative ids need only ``n`` (the
  sampling is by index — ``meta.rep_sample_ids``), so the rep rows are
  gathered from the spill; assignment is per-row nearest-rep and
  streams chunk-by-chunk; partitions are then serialized one at a time
  from spill gathers (``layout.plan_spec`` guarantees the identical
  region geometry the in-memory build would plan).

The builder working set — one chunk, the rep rows, one chunk's distance
matrix, one partition's staging gather — is tracked by the loader's own
accounting (``LoadReport.peak_builder_bytes``); the region itself is
the *memory pool's* state, not the builder's, and can be shipped
group-by-group to a live pool through the existing ``refresh_blocks``
verb (``finalize(into_pool=...)``).
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import layout as LA
from repro.core import meta as ME
from repro.core.hnsw import HNSWParams, brute_force_knn
from repro.obs.trace import TRACER


def chunked_source(data: np.ndarray, chunk_rows: int) -> Iterator[np.ndarray]:
    """Yield ``data`` in row chunks of at most ``chunk_rows``."""
    for s in range(0, len(data), chunk_rows):
        yield data[s:s + chunk_rows]


@dataclass
class FailedChunk:
    """One rejected source chunk, kept for a later retry."""

    index: int          # arrival index of the chunk
    reason: str
    chunk: object       # the raw object as received
    retries: int = 0


@dataclass
class LoadReport:
    """What a bulk load did, with the builder-memory accounting."""

    rows: int = 0
    dim: int = 0
    chunks_total: int = 0
    chunks_ok: int = 0
    chunks_failed: int = 0
    chunks_retried: int = 0
    chunk_rows: int = 0
    chunk_bytes: int = 0            # the configured budget, in bytes
    dataset_bytes: int = 0
    peak_builder_bytes: int = 0     # max simultaneous builder buffers
    verbs_issued: int = 0           # refresh_blocks verbs shipped
    groups_shipped: int = 0
    spill_path: str = ""
    failures: List[Tuple[int, str]] = field(default_factory=list)


class BulkLoader:
    """Streaming two-pass builder for the d-HNSW region.

    Parameters mirror the engine's build knobs (``n_rep``, ``seed``,
    ``meta_levels``, ``sub_params``) so ``finalize()`` reproduces
    ``build_meta`` + ``build_store`` exactly; ``chunk_rows`` is the
    bounded-memory budget.
    """

    def __init__(self, *, n_rep: int, chunk_rows: int, seed: int = 0,
                 meta_levels: int = 3,
                 sub_params: Optional[HNSWParams] = None,
                 ov_cap: int = 0, slot_vecs: int = 64,
                 np_max: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 quant_group: int = 0):
        assert chunk_rows > 0, chunk_rows
        self.n_rep = n_rep
        self.quant_group = int(quant_group)
        self.chunk_rows = chunk_rows
        self.seed = seed
        self.meta_levels = meta_levels
        self.sub_params = sub_params
        self.ov_cap = ov_cap
        self.slot_vecs = slot_vecs
        self.np_max = np_max
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="repro_ingest_")
        os.makedirs(self.spill_dir, exist_ok=True)
        self.spill_path = os.path.join(self.spill_dir, "spill.f32")
        self._spill = open(self.spill_path, "wb")
        self.dim: Optional[int] = None
        self.rows = 0
        self.error_queue: List[FailedChunk] = []
        self.report = LoadReport(chunk_rows=chunk_rows,
                                 spill_path=self.spill_path)
        self._resident: dict = {}
        self._chunk_idx = 0

    # ------------------------------------------------- memory accounting

    def _hold(self, name: str, nbytes: int) -> None:
        self._resident[name] = int(nbytes)
        total = sum(self._resident.values())
        if total > self.report.peak_builder_bytes:
            self.report.peak_builder_bytes = total

    def _drop(self, name: str) -> None:
        self._resident.pop(name, None)

    # ------------------------------------------------------------ pass 1

    def _parse(self, chunk) -> np.ndarray:
        arr = np.asarray(chunk, np.float32)
        if arr.ndim != 2:
            raise ValueError(f"chunk must be 2-D, got shape {arr.shape}")
        return arr

    def _validate(self, arr: np.ndarray) -> None:
        if self.dim is not None and arr.shape[1] != self.dim:
            raise ValueError(f"dim {arr.shape[1]} != {self.dim}")
        if not np.isfinite(arr).all():
            raise ValueError("non-finite values in chunk")

    def _accept(self, arr: np.ndarray) -> None:
        if self.dim is None:
            self.dim = int(arr.shape[1])
            self.report.dim = self.dim
            self.report.chunk_bytes = self.chunk_rows * self.dim * 4
        self._hold("chunk", arr.nbytes)
        self._spill.write(np.ascontiguousarray(arr).tobytes())
        self.rows += int(arr.shape[0])
        self._drop("chunk")

    def add_chunks(self, source: Iterable) -> "BulkLoader":
        """Pass 1: parse -> validate -> spill each chunk; failures go to
        the error queue instead of aborting."""
        for chunk in source:
            idx = self._chunk_idx
            self._chunk_idx += 1
            self.report.chunks_total += 1
            try:
                arr = self._parse(chunk)
                self._validate(arr)
            except (ValueError, TypeError) as e:
                self.error_queue.append(FailedChunk(idx, str(e), chunk))
                self.report.chunks_failed += 1
                self.report.failures.append((idx, str(e)))
                continue
            self._accept(arr)
            self.report.chunks_ok += 1
        return self

    def retry_failed(self, fix: Optional[Callable] = None) -> int:
        """Re-run parse/validate on the error queue (after an optional
        ``fix`` transform); returns how many chunks were recovered."""
        recovered = 0
        still: List[FailedChunk] = []
        for fc in self.error_queue:
            fc.retries += 1
            try:
                arr = self._parse(fix(fc.chunk) if fix else fc.chunk)
                self._validate(arr)
            except (ValueError, TypeError) as e:
                fc.reason = str(e)
                still.append(fc)
                continue
            self._accept(arr)
            recovered += 1
            self.report.chunks_ok += 1
            self.report.chunks_failed -= 1
            self.report.chunks_retried += 1
        self.error_queue = still
        return recovered

    # ------------------------------------------------------------ pass 2

    def data_view(self) -> np.ndarray:
        """Read-only disk-backed view of every accepted row (the
        engine's repack ``data_lookup`` reads through this, so holding
        it does not count against builder RAM)."""
        assert self.dim is not None, "no chunks accepted yet"
        self._spill.flush()
        return np.memmap(self.spill_path, np.float32, mode="r",
                         shape=(self.rows, self.dim))

    def _assign(self, reps: np.ndarray, data: np.ndarray) -> np.ndarray:
        """Exact nearest-rep assignment, streamed chunk-by-chunk.

        Per-row results are independent, so chunking reproduces the
        in-memory ``build_meta`` assignment bit-for-bit.
        """
        out = np.empty(self.rows, np.int32)
        for s in range(0, self.rows, self.chunk_rows):
            sl = data[s:s + self.chunk_rows]
            self._hold("assign_chunk",
                       sl.shape[0] * self.dim * 4
                       + sl.shape[0] * len(reps) * 4)
            _, nn = brute_force_knn(reps, np.asarray(sl), 1)
            out[s:s + self.chunk_rows] = nn[:, 0].astype(np.int32)
            self._drop("assign_chunk")
        return out

    def finalize(self, into_pool=None):
        """Pass 2: build meta + serialize the region from the spill.

        Returns ``(meta, store, report)``.  With ``into_pool`` set, each
        finished group is shipped immediately through the pool's
        ``refresh_blocks`` verb (the server-side region fills while the
        builder still holds only O(chunk)).
        """
        if self.error_queue:
            # two-stage contract: the caller decides — retry or accept
            # the loss; finalize proceeds over the accepted rows only
            pass
        assert self.rows > 0, "nothing to finalize"
        self._spill.flush()
        os.fsync(self._spill.fileno())
        data = self.data_view()
        self.report.rows = self.rows
        self.report.dataset_bytes = self.rows * self.dim * 4

        with TRACER.span("ingest.meta_stream", tier="ingest",
                         rows=int(self.rows)):
            rep_ids = ME.rep_sample_ids(self.rows, self.n_rep,
                                        seed=self.seed)
            reps = np.array(data[rep_ids], np.float32)
            self._hold("reps", reps.nbytes)
            assignments = self._assign(reps, data)
            meta = ME.build_meta_from_parts(reps, rep_ids, assignments,
                                            seed=self.seed,
                                            meta_levels=self.meta_levels)

        p = self.sub_params or HNSWParams(M=8, M0=16, ef_construction=80)
        spec, parts = LA.plan_spec(meta, self.dim, deg=p.M0,
                                   ov_cap=self.ov_cap,
                                   slot_vecs=self.slot_vecs,
                                   np_max=self.np_max)
        store = LA.empty_store(spec)
        group_blocks = spec.group_blocks
        for pid in range(meta.n_partitions):
            ids = LA.partition_member_ids(meta, parts, pid, spec.np_max)
            self._hold("stage", ids.size * self.dim * 4)
            LA.serialize_partition(store, pid, ids,
                                   np.asarray(data[ids], np.float32), 0, p)
            self._drop("stage")
            group_done = pid % 2 == 1 or pid == meta.n_partitions - 1
            if into_pool is not None and group_done:
                group = pid // 2
                into_pool.refresh_blocks(
                    np.arange(group * group_blocks,
                              (group + 1) * group_blocks))
                self.report.verbs_issued += 1
                self.report.groups_shipped += 1
        self._drop("reps")
        if self.quant_group:
            self._quantize_region(store)
        return meta, store, self.report

    def _quantize_region(self, store) -> None:
        """Second finalize sweep: build the int8 mirror chunk-by-chunk.

        The codec is per-row independent (``quant.codec``), so
        quantizing ``~chunk_rows`` worth of blocks at a time is
        bit-identical to ``layout.attach_quant_mirror``'s whole-buffer
        shot while the builder holds only O(chunk) working set (the
        mirror itself is region state, like the buffers it mirrors)."""
        import dataclasses as DC
        spec = store.spec
        if spec.dim % self.quant_group:
            raise ValueError(f"quant group {self.quant_group} must divide "
                             f"dim {spec.dim}")
        if spec.quant_group != self.quant_group:
            store.spec = spec = DC.replace(spec,
                                           quant_group=self.quant_group)
        store.qvec_buf = np.zeros((spec.n_blocks, spec.vblk), np.int8)
        store.qscale_buf = np.zeros((spec.n_blocks, spec.n_qgroups),
                                    np.float32)
        blk_chunk = max(1, self.chunk_rows // spec.slot_vecs)
        with TRACER.span("ingest.quant_stream", tier="ingest",
                         blocks=int(spec.n_blocks)):
            for s in range(0, spec.n_blocks, blk_chunk):
                ids = np.arange(s, min(s + blk_chunk, spec.n_blocks))
                # f32 source slice + codes + scales, live at once
                self._hold("quant_chunk",
                           len(ids) * (spec.vblk * 5 + spec.n_qgroups * 4))
                LA.refresh_quant_blocks(store, ids)
                self._drop("quant_chunk")

    def close(self) -> None:
        """Close the spill file handle (the memmap view stays valid)."""
        try:
            self._spill.close()
        except ValueError:
            pass
