"""Pure-jnp oracle for the fused distance+top-k kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def distance_topk_ref(queries, database, k: int, n_valid=None):
    """Exact squared-L2 top-k.

    queries: (B, D); database: (N, D) -> (dists (B, k), ids (B, k)),
    ascending.  ``n_valid`` masks padded database rows.
    """
    q = queries.astype(jnp.float32)
    x = database.astype(jnp.float32)
    d = (jnp.sum(q * q, -1)[:, None] - 2.0 * q @ x.T
         + jnp.sum(x * x, -1)[None, :])
    if n_valid is not None:
        d = jnp.where(jnp.arange(x.shape[0])[None, :] < n_valid, d, jnp.inf)
    nd, ni = lax.top_k(-d, k)
    return -nd, ni
