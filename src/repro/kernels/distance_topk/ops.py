"""jit'd public wrapper for the fused distance+top-k kernel.

Pads inputs to block multiples, dispatches to the Pallas kernel
(interpret=True on CPU — this container — compiled BlockSpecs on TPU),
and restores inf/-1 padding semantics.  ``use_ref=True`` forces the
pure-jnp oracle (useful to A/B in benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.kernel import MASKED, distance_topk_pallas
from repro.kernels.distance_topk.ref import distance_topk_ref


def _pad_to(x, mult, axis, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "use_ref"))
def distance_topk(queries, database, k: int, n_valid=None, *,
                  block_q: int = 128, block_n: int = 256,
                  interpret: bool | None = None, use_ref: bool = False):
    """Top-k nearest database rows per query (squared L2, ascending).

    queries (B, D), database (N, D) -> (dists (B, k), ids (B, k)).
    ``n_valid`` masks padded/unused database rows (defaults to N).
    """
    if n_valid is None:
        n_valid = queries.shape[0] * 0 + database.shape[0]
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape(())
    if use_ref:
        return distance_topk_ref(queries, database, k, n_valid)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, D = queries.shape
    qp = _pad_to(queries.astype(jnp.float32), block_q, 0)
    xp = _pad_to(database.astype(jnp.float32), block_n, 0)
    d, i = distance_topk_pallas(qp, xp, n_valid, k=k, block_q=block_q,
                                block_n=block_n, interpret=interpret)
    d, i = d[:B], i[:B]
    bad = d >= MASKED * 0.99
    return jnp.where(bad, jnp.inf, d), jnp.where(bad, -1, i)
