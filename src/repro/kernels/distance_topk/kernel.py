"""Fused batched L2-distance + top-k Pallas TPU kernel.

The sub-HNSW compute hot-spot restructured for the MXU: distances are a
tiled matmul (||q||^2 + ||x||^2 - 2 q.x^T, arithmetic intensity ~2D flops
per 4-byte candidate), and a running per-query top-k lives in VMEM
scratch so only k values/ids per query ever leave the kernel — never the
(B, N) distance matrix (HBM traffic drops from O(B*N) to O(B*k)).

Grid: (nq, nn), database-tile axis innermost.  Per (q-tile, x-tile):
  1. dist tile (BQ, BN) via one MXU matmul + row/col norms;
  2. merge into the running (BQ, k) scratch by k rounds of masked
     argmin extraction (k is small and static — unrolled; VPU work).

Block shapes: BQ x D and BN x D with D <= 1024 -> worst-case VMEM
footprint  q(128x1024x4) + x(256x1024x4) + dist(128x256x4) + scratch
~= 1.7 MB, comfortably inside the ~16 MB v5e VMEM budget; matmul dims
(BQ, D, BN) are all multiples of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASKED = 3.4e38  # "worse than any real distance" sentinel (argmin-safe python float)


def _merge_topk_scratch(best_d, best_i, tile_d, tile_i, k: int):
    """Merge a (BQ, BN) candidate tile into the (BQ, k) running best.

    k unrolled rounds: pick the tile argmin per row, insert if better
    than the current worst, mask it out, repeat.  All VPU-friendly
    (iota/compare/select), no sorts.
    """
    bq = best_d.shape[0]
    cand_d = jnp.concatenate([best_d, tile_d], axis=1)   # (BQ, k+BN)
    cand_i = jnp.concatenate([best_i, tile_i], axis=1)
    width = cand_d.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)
    out_d = []
    out_i = []
    for _ in range(k):
        pos = jnp.argmin(cand_d, axis=1)                 # (BQ,)
        sel = col == pos[:, None]
        out_d.append(jnp.min(cand_d, axis=1))
        out_i.append(jnp.sum(jnp.where(sel, cand_i, 0), axis=1))
        cand_d = jnp.where(sel, MASKED, cand_d)
    return (jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32))


def _kernel(n_valid_ref, q_ref, x_ref, d_out_ref, i_out_ref,
            best_d, best_i, *, k: int, block_n: int):
    nn = pl.num_programs(1)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d, MASKED)
        best_i[...] = jnp.full_like(best_i, -1)

    q = q_ref[...].astype(jnp.float32)                   # (BQ, D)
    x = x_ref[...].astype(jnp.float32)                   # (BN, D)
    q2 = jnp.sum(q * q, axis=1, keepdims=True)           # (BQ, 1)
    x2 = jnp.sum(x * x, axis=1)[None, :]                 # (1, BN)
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dist = q2 + x2 - 2.0 * dots                          # (BQ, BN)

    base = j * block_n
    gids = base + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(gids < n_valid_ref[0], dist, MASKED)

    best_d[...], best_i[...] = _merge_topk_scratch(
        best_d[...], best_i[...], dist, gids, k)

    @pl.when(j == nn - 1)
    def _flush():
        d_out_ref[...] = best_d[...]
        i_out_ref[...] = best_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret"))
def distance_topk_pallas(queries, database, n_valid, *, k: int,
                         block_q: int = 128, block_n: int = 256,
                         interpret: bool = False):
    """queries (B, D) f32, database (N, D) f32, n_valid () i32.

    B % block_q == 0 and N % block_n == 0 (ops.py pads).  Returns
    ascending (dists (B, k), ids (B, k)); padded rows masked via n_valid.
    """
    bq, d = queries.shape
    n, _ = database.shape
    assert bq % block_q == 0 and n % block_n == 0, (bq, n)
    grid = (bq // block_q, n // block_n)

    kern = functools.partial(_kernel, k=k, block_n=block_n)
    d_out, i_out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_q, d), lambda i, j, nv: (i, 0)),
                pl.BlockSpec((block_n, d), lambda i, j, nv: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_q, k), lambda i, j, nv: (i, 0)),
                pl.BlockSpec((block_q, k), lambda i, j, nv: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q, k), jnp.float32),
                pltpu.VMEM((block_q, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((bq, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(n_valid.reshape(1), queries, database)
    return d_out, i_out
