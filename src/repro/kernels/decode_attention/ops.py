"""jit'd public wrapper for GQA flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_s", "interpret",
                                             "use_ref"))
def decode_attention(q, k, v, pos, *, block_s: int = 256,
                     interpret: bool | None = None, use_ref: bool = False):
    """One-token GQA attention against a KV cache.

    q (B, H, hd); k/v (B, S, K, hd); pos (B,) -> (B, H, hd).
    Pads S to a block multiple (masked via pos).
    """
    if use_ref:
        return decode_attention_ref(q, k, v, pos)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    pad = (-S) % block_s
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    out = decode_attention_pallas(q.reshape(B, K, G, hd), k, v,
                                  jnp.asarray(pos, jnp.int32),
                                  block_s=block_s, interpret=interpret)
    return out.reshape(B, H, hd)
