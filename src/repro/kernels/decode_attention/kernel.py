"""GQA flash-decode attention Pallas TPU kernel.

The serving substrate's hot spot: one new query token per sequence
against a long KV cache — strictly memory-bound (arithmetic intensity
~2 flops/byte of KV).  The kernel streams the cache through VMEM in
blocks with online-softmax accumulation, so HBM traffic is exactly one
pass over K and V; the (tiny) q tile stays resident.

Grid: (B, S/block_s), cache-block axis innermost; scratch carries the
running (max, sum, acc) across cache blocks.  Per-step VMEM:
q (K*G, hd) + k/v blocks (block_s, K, hd) x2 + acc — with block_s=256,
K<=32, hd<=128: ~9 MB worst case, v5e-safe; hd and block_s stay
multiples of 128/8 for lane alignment.

``pos`` (valid cache length per sequence) is scalar-prefetched: the
grid's block masks are computed from it before the body runs, and whole
blocks past ``pos`` skip their flash update entirely (the same trick
flash-decode uses to avoid streaming dead cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, block_s: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]

    @pl.when(j * block_s < pos)          # skip fully-masked cache blocks
    def _update():
        q = q_ref[0].astype(jnp.float32)             # (K, G, hd)
        k = k_ref[0].astype(jnp.float32)             # (BS, K, hd)
        v = v_ref[0].astype(jnp.float32)             # (BS, K, hd)
        # s[k, g, s] = q[k, g, :] . k[s, k, :]  — batched over kv heads
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale   # (K, G, BS)
        kpos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos < pos, s, NEG)

        m_prev = m_scr[...]                          # (K, G)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])            # (K, G, BS)
        corr = jnp.exp(m_prev - m_new)               # (K, G)
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        # acc[k, g, h] += p[k, g, s] v[s, k, h]
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)      # (K, G, hd)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k, v, pos, *, block_s: int = 256,
                            interpret: bool = False):
    """q (B, K, G, hd); k/v (B, S, K, hd); pos (B,) i32 -> (B, K, G, hd)."""
    B, K, G, hd = q.shape
    S = k.shape[1]
    assert S % block_s == 0, (S, block_s)
    grid = (B, S // block_s)
    kern = functools.partial(_kernel, block_s=block_s, scale=hd ** -0.5)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, K, G, hd), lambda b, j, pos: (b, 0, 0, 0)),
                pl.BlockSpec((1, block_s, K, hd), lambda b, j, pos: (b, j, 0, 0)),
                pl.BlockSpec((1, block_s, K, hd), lambda b, j, pos: (b, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, K, G, hd), lambda b, j, pos: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((K, G), jnp.float32),
                pltpu.VMEM((K, G), jnp.float32),
                pltpu.VMEM((K, G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(pos, q, k, v)
