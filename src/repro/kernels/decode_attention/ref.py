"""Pure-jnp oracle for GQA flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos):
    """One-token GQA attention against a KV cache.

    q (B, H, hd); k/v (B, S, K, hd); pos (B,) = number of valid cache
    entries per sequence (attend to cache[:pos]).  H = K * G.
    Returns (B, H, hd) f32.
    """
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, K, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, kf) * (hd ** -0.5)
    mask = jnp.arange(S)[None, :] < pos[:, None]          # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", p, vf)
    return out.reshape(B, H, hd)
