"""jit'd public wrapper for the fused int8 dequant+distance+top-k kernel.

Pads inputs to block multiples, dispatches to the Pallas kernel
(interpret=True on CPU — this container — compiled BlockSpecs on TPU),
and restores inf/-1 padding semantics.  ``use_ref=True`` forces the
pure-jnp oracle (benchmarks A/B against it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.kernel import MASKED
from repro.kernels.distance_topk.ops import _pad_to
from repro.kernels.quant_topk.kernel import quant_topk_pallas
from repro.kernels.quant_topk.ref import quant_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "group", "block_q",
                                             "block_n", "interpret",
                                             "use_ref"))
def quant_topk(queries, codes, scales, k: int, group: int, n_valid=None, *,
               block_q: int = 128, block_n: int = 256,
               interpret: bool | None = None, use_ref: bool = False):
    """Top-k nearest database rows per query over an int8-quantized
    database (squared L2 on the dequantized values, ascending).

    queries (B, D) f32, codes (N, D) int8, scales (N, D // group) f32
    -> (dists (B, k), ids (B, k)).  ``n_valid`` masks padded rows.
    """
    if n_valid is None:
        n_valid = codes.shape[0]
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape(())
    if use_ref:
        return quant_topk_ref(queries, codes, scales, k, group, n_valid)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, D = queries.shape
    qp = _pad_to(queries.astype(jnp.float32), block_q, 0)
    cp = _pad_to(codes.astype(jnp.int8), block_n, 0)
    sp = _pad_to(scales.astype(jnp.float32), block_n, 0)
    d, i = quant_topk_pallas(qp, cp, sp, n_valid, k=k, group=group,
                             block_q=block_q, block_n=block_n,
                             interpret=interpret)
    d, i = d[:B], i[:B]
    bad = d >= MASKED * 0.99
    return jnp.where(bad, jnp.inf, d), jnp.where(bad, -1, i)
