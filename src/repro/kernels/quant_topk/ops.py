"""jit'd public wrapper for the fused int8 dequant+distance+top-k kernel.

Pads inputs to block multiples, dispatches to the Pallas kernel
(interpret=True on CPU — this container — compiled BlockSpecs on TPU),
and restores inf/-1 padding semantics.  ``use_ref=True`` forces the
pure-jnp oracle (benchmarks A/B against it).

When the global tracer is enabled every call is wrapped in a
``kernel.quant_topk`` span (attrs: impl=pallas|ref, B/N/D/k) that blocks
on the result so the span duration is real device time, not dispatch
time.  The traced block happens OUTSIDE the jitted function — a span
recorder cannot live inside a traced/jitted body — and the numerical
results are identical either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.kernel import MASKED
from repro.kernels.distance_topk.ops import _pad_to
from repro.kernels.quant_topk.kernel import quant_topk_pallas
from repro.kernels.quant_topk.ref import quant_topk_ref
from repro.obs.trace import TRACER


@functools.partial(jax.jit, static_argnames=("k", "group", "block_q",
                                             "block_n", "interpret",
                                             "use_ref"))
def _quant_topk_jit(queries, codes, scales, k: int, group: int, n_valid, *,
                    block_q: int, block_n: int, interpret, use_ref: bool):
    """The jitted kernel body (see ``quant_topk`` for the contract)."""
    if n_valid is None:
        n_valid = codes.shape[0]
    n_valid = jnp.asarray(n_valid, jnp.int32).reshape(())
    if use_ref:
        return quant_topk_ref(queries, codes, scales, k, group, n_valid)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, D = queries.shape
    qp = _pad_to(queries.astype(jnp.float32), block_q, 0)
    cp = _pad_to(codes.astype(jnp.int8), block_n, 0)
    sp = _pad_to(scales.astype(jnp.float32), block_n, 0)
    d, i = quant_topk_pallas(qp, cp, sp, n_valid, k=k, group=group,
                             block_q=block_q, block_n=block_n,
                             interpret=interpret)
    d, i = d[:B], i[:B]
    bad = d >= MASKED * 0.99
    return jnp.where(bad, jnp.inf, d), jnp.where(bad, -1, i)


def auto_use_ref() -> bool:
    """Whether ``quant_kernel="auto"`` should take the jnp ref path.

    On backends where the Pallas kernel would run under ``interpret=True``
    (CPU — this container) the interpreter is ~an order of magnitude
    slower than the jnp oracle, so "auto" routes to the ref impl there
    and reserves Pallas for real accelerators.
    """
    return jax.default_backend() == "cpu"


def quant_topk(queries, codes, scales, k: int, group: int, n_valid=None, *,
               block_q: int = 128, block_n: int = 256,
               interpret: bool | None = None, use_ref: bool = False):
    """Top-k nearest database rows per query over an int8-quantized
    database (squared L2 on the dequantized values, ascending).

    queries (B, D) f32, codes (N, D) int8, scales (N, D // group) f32
    -> (dists (B, k), ids (B, k)).  ``n_valid`` masks padded rows.
    """
    if not TRACER.enabled:
        return _quant_topk_jit(queries, codes, scales, k, group, n_valid,
                               block_q=block_q, block_n=block_n,
                               interpret=interpret, use_ref=use_ref)
    with TRACER.span("kernel.quant_topk", tier="kernel",
                     impl="ref" if use_ref else "pallas",
                     B=int(queries.shape[0]), N=int(codes.shape[0]),
                     D=int(codes.shape[1]), k=int(k)):
        out = _quant_topk_jit(queries, codes, scales, k, group, n_valid,
                              block_q=block_q, block_n=block_n,
                              interpret=interpret, use_ref=use_ref)
        return jax.block_until_ready(out)
