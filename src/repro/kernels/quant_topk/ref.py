"""Pure-jnp oracle for the fused int8 dequant+distance+top-k kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def dequantize_ref(codes, scales, group: int):
    """codes (N, D) int8, scales (N, D // group) f32 -> (N, D) f32."""
    n, d = codes.shape
    x = codes.astype(jnp.float32).reshape(n, d // group, group)
    return (x * scales[:, :, None]).reshape(n, d)


def quant_topk_ref(queries, codes, scales, k: int, group: int,
                   n_valid=None):
    """Exact squared-L2 top-k over the dequantized database.

    queries (B, D) f32; codes (N, D) int8; scales (N, D // group) f32
    -> (dists (B, k), ids (B, k)), ascending.  ``n_valid`` masks padded
    database rows.
    """
    q = queries.astype(jnp.float32)
    x = dequantize_ref(codes, scales, group)
    d = (jnp.sum(q * q, -1)[:, None] - 2.0 * q @ x.T
         + jnp.sum(x * x, -1)[None, :])
    if n_valid is not None:
        d = jnp.where(jnp.arange(x.shape[0])[None, :] < n_valid, d, jnp.inf)
    nd, ni = lax.top_k(-d, k)
    return -nd, ni
