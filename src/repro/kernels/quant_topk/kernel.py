"""Fused int8-dequant + L2-distance + top-k Pallas TPU kernel.

The quantized-tier twin of ``kernels/distance_topk``: the database tile
arrives as int8 codes plus per-group f32 codebook scales (the wire/HBM
format of the quantized resident tier — 4x less vector traffic than
f32), is dequantized in VMEM right before the MXU matmul, and the same
running top-k scratch keeps HBM output at O(B*k).

Per (q-tile, x-tile):
  1. dequant: x = codes.f32 * scales broadcast over each group (VPU);
  2. dist tile (BQ, BN) via one MXU matmul + row/col norms;
  3. merge into the (BQ, k) running best (k unrolled argmin rounds).

VMEM: the int8 tile (BN, D) costs a quarter of its f32 twin; the
dequantized tile is transient.  Worst case with BQ=128, BN=256, D<=1024:
q 512 KB + codes 256 KB + scales 32 KB + dequant 1 MB + dist 128 KB
~= 1.9 MB, inside the ~16 MB v5e budget; matmul dims stay multiples of
the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.distance_topk.kernel import MASKED, _merge_topk_scratch


def _kernel(n_valid_ref, q_ref, x_ref, s_ref, d_out_ref, i_out_ref,
            best_d, best_i, *, k: int, block_n: int, group: int):
    nn = pl.num_programs(1)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d, MASKED)
        best_i[...] = jnp.full_like(best_i, -1)

    q = q_ref[...].astype(jnp.float32)                   # (BQ, D)
    codes = x_ref[...].astype(jnp.float32)               # (BN, D) int8 -> f32
    scales = s_ref[...]                                  # (BN, D // group)
    bn, d = codes.shape
    # dequantize: broadcast each group scale over its `group` lanes
    x = (codes.reshape(bn, d // group, group)
         * scales[:, :, None]).reshape(bn, d)

    q2 = jnp.sum(q * q, axis=1, keepdims=True)           # (BQ, 1)
    x2 = jnp.sum(x * x, axis=1)[None, :]                 # (1, BN)
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dist = q2 + x2 - 2.0 * dots                          # (BQ, BN)

    base = j * block_n
    gids = base + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(gids < n_valid_ref[0], dist, MASKED)

    best_d[...], best_i[...] = _merge_topk_scratch(
        best_d[...], best_i[...], dist, gids, k)

    @pl.when(j == nn - 1)
    def _flush():
        d_out_ref[...] = best_d[...]
        i_out_ref[...] = best_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "group", "block_q", "block_n",
                                    "interpret"))
def quant_topk_pallas(queries, codes, scales, n_valid, *, k: int,
                      group: int, block_q: int = 128, block_n: int = 256,
                      interpret: bool = False):
    """queries (B, D) f32, codes (N, D) int8, scales (N, D // group) f32,
    n_valid () i32.  B % block_q == 0 and N % block_n == 0 (ops.py pads).
    Returns ascending (dists (B, k), ids (B, k)); rows past n_valid are
    masked to inf/-1.
    """
    bq, d = queries.shape
    n, _ = codes.shape
    assert bq % block_q == 0 and n % block_n == 0, (bq, n)
    assert d % group == 0, (d, group)
    grid = (bq // block_q, n // block_n)

    kern = functools.partial(_kernel, k=k, block_n=block_n, group=group)
    d_out, i_out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_q, d), lambda i, j, nv: (i, 0)),
                pl.BlockSpec((block_n, d), lambda i, j, nv: (j, 0)),
                pl.BlockSpec((block_n, d // group), lambda i, j, nv: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_q, k), lambda i, j, nv: (i, 0)),
                pl.BlockSpec((block_q, k), lambda i, j, nv: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q, k), jnp.float32),
                pltpu.VMEM((block_q, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((bq, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(n_valid.reshape(1), queries, codes, scales)
    return d_out, i_out
