"""Pure-jnp oracle for the doorbell block gather."""
from __future__ import annotations

import jax.numpy as jnp


def gather_blocks_ref(buf, block_ids):
    """buf (n_blocks, blk); block_ids (m,) i32 -> (m, blk)."""
    return jnp.take(buf, block_ids, axis=0)
