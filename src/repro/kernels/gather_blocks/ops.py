"""jit'd public wrapper for the doorbell block gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_blocks.kernel import gather_blocks_pallas
from repro.kernels.gather_blocks.ref import gather_blocks_ref


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref"))
def gather_blocks(buf, block_ids, *, interpret: bool | None = None,
                  use_ref: bool = False):
    """One doorbell batch: fetch ``block_ids`` rows of ``buf`` in a single
    launch.  buf (n_blocks, blk); block_ids (m,) -> (m, blk)."""
    block_ids = jnp.asarray(block_ids, jnp.int32)
    if use_ref:
        return gather_blocks_ref(buf, block_ids)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return gather_blocks_pallas(buf, block_ids, interpret=interpret)
