"""Doorbell block-gather Pallas TPU kernel — the RDMA doorbell primitive.

The paper's doorbell batching posts one RDMA work request whose
descriptor list names m discontiguous remote regions; the NIC resolves
them with multiple PCIe transactions inside ONE network round trip.  The
TPU-native analogue: ONE ``pallas_call`` whose scalar-prefetched index
vector drives the input BlockSpec ``index_map``, so the same launch DMAs
m discontiguous HBM blocks into one contiguous destination.  Each grid
step's block address is known from the prefetched scalars before the
body runs — Mosaic double-buffers the HBM->VMEM streams exactly like the
NIC pipelines its PCIe reads.

Grid: (m,).  VMEM per step: 2 x blk x 4 B (in + out block), so blk up to
~256 KB keeps the double-buffered footprint well inside v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, buf_ref, out_ref):
    out_ref[...] = buf_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks_pallas(buf, block_ids, *, interpret: bool = False):
    """buf (n_blocks, blk); block_ids (m,) i32 -> (m, blk).

    One launch = one doorbell batch: m descriptors, m HBM block reads,
    contiguous output (the compute-pool staging buffer).
    """
    m = block_ids.shape[0]
    blk = buf.shape[1]
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m,),
            in_specs=[
                # the descriptor list: block i of the output reads remote
                # block ids[i] — data-dependent index_map via prefetch
                pl.BlockSpec((1, blk), lambda i, ids: (ids[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, blk), lambda i, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, blk), buf.dtype),
        interpret=interpret,
    )(block_ids, buf)
