"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # shared-expert / dense d_ff
    vocab_size=202_048,
    rope_theta=500_000.0,
    n_experts=16,
    moe_top_k=1,
    expert_d_ff=8192,
    shared_expert=True,
)
