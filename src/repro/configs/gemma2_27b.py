"""gemma2-27b — dense, local/global alternating attention, logit softcap. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,  # gemma2 uses explicit head_dim (32*128 != d_model)
    d_ff=36_864,
    vocab_size=256_000,
    rope_theta=10_000.0,
    local_global_pattern=True,
    local_window=4096,
    logit_softcap=30.0,
    attn_softcap=50.0,
)
