"""Config system: model configs, input shapes, and the arch registry.

Every assigned architecture gets one file in this package exporting
``CONFIG``.  ``registry.get_config(arch_id)`` resolves them.  Shapes are
global (paper brief): each LM arch is paired with the four LM shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.  Only fields a family uses are read."""

    name: str
    family: str  # dense | ssm | moe | hybrid | encdec | vlm

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention details
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    logit_softcap: float = 0.0  # final logits soft-capping (gemma2: 30)
    attn_softcap: float = 0.0  # attention-score soft-capping (gemma2: 50)
    local_window: int = 0  # sliding-window size for local layers
    local_global_pattern: bool = False  # gemma2: alternate local/global
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    expert_d_ff: int = 0  # per-expert hidden size (qwen3-moe: 768)
    shared_expert: bool = False  # llama4: one always-on shared expert
    capacity_factor: float = 1.25

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (zamba2): one shared attention block applied every k mamba blocks
    attn_every: int = 0

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0  # precomputed frame-embedding length (conv frontend stub)

    # VLM (pixtral): number of stubbed patch-embedding tokens at prefill
    n_patches: int = 0

    # training
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"  # master params

    def kv_dim(self) -> int:
        return self.n_kv_heads * self.the_head_dim()

    def the_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k?  Pure SSM / hybrid only (brief)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for 6ND model flops) ----
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.the_head_dim()
        n = 0
        # embeddings (+ untied unembed)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        per_layer_mlp = 3 * d * self.d_ff if self.d_ff else 0
        if self.family == "ssm":
            n += self.n_layers * self._ssm_layer_params()
        elif self.family == "hybrid":
            n_attn_uses = self.n_layers // max(self.attn_every, 1)
            n += self.n_layers * self._ssm_layer_params()
            # one SHARED attention block (weights tied across uses)
            n += per_layer_attn + per_layer_mlp
            del n_attn_uses
        elif self.family in ("moe",):
            e = self.moe_top_k if active_only else self.n_experts
            per_moe = 3 * d * self.expert_d_ff * e
            if self.shared_expert:
                per_moe += 3 * d * self.d_ff
            n += self.n_layers * (per_layer_attn + per_moe + d * self.n_experts)
        elif self.family == "encdec":
            n += (self.n_enc_layers + self.n_layers) * (per_layer_attn + per_layer_mlp)
            n += self.n_layers * per_layer_attn  # cross-attention
        else:  # dense / vlm
            n += self.n_layers * (per_layer_attn + per_layer_mlp)
        return n

    def _ssm_layer_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        nh = d_in // self.ssm_head_dim
        g, s = self.ssm_groups, self.ssm_state
        n = d * (2 * d_in + 2 * g * s + nh)  # in_proj (z, x, B, C, dt)
        n += d_in * self.ssm_conv  # depthwise conv
        n += nh * 2  # A_log, D
        n += d_in * d  # out_proj
        if self.d_ff:
            n += 3 * d * self.d_ff
        return n


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the brief's skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "full-attention arch: 500k ctx needs sub-quadratic mixing (skip per brief)"
    return True, ""
