"""pixtral-12b — VLM: pixtral-ViT frontend (stubbed) + mistral-nemo backbone. [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    rope_theta=1_000_000.0,
    n_patches=256,  # stubbed patch-embedding tokens prepended at train/prefill
)
