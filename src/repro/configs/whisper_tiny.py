"""whisper-tiny — encoder-decoder audio backbone; conv frontend stubbed. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    enc_seq=1500,  # precomputed log-mel frame embeddings (stub per brief)
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
)
