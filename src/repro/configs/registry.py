"""Arch registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, InputShape, ModelConfig, shape_applicable

ARCH_IDS = [
    "mamba2-370m",
    "phi3-mini-3.8b",
    "gemma2-27b",
    "codeqwen1.5-7b",
    "qwen3-8b",
    "llama4-scout-17b-a16e",
    "qwen3-moe-30b-a3b",
    "whisper-tiny",
    "zamba2-2.7b",
    "pixtral-12b",
]

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "gemma2-27b": "gemma2_27b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen3-8b": "qwen3_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-2.7b": "zamba2_2_7b",
    "pixtral-12b": "pixtral_12b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> InputShape:
    return SHAPES[shape_id]


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config: small width/depth, tiny vocab — runs a
    real forward/train step on one CPU device."""
    cfg = get_config(arch_id)
    kw = dict(
        n_layers=2,
        d_model=64,
        vocab_size=256,
        norm_eps=cfg.norm_eps,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2, head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.family in ("moe",):
        kw.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2), expert_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, n_layers=4)
    if cfg.family == "hybrid":
        kw.update(attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq=16)
    if cfg.family == "vlm":
        kw.update(n_patches=4)
    if cfg.local_global_pattern:
        kw.update(local_window=32)
    return cfg.replace(**kw)


def all_cells():
    """Every (arch, shape) pair with its applicability verdict."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            ok, why = shape_applicable(cfg, SHAPES[s])
            out.append((a, s, ok, why))
    return out
