"""Distribution: sharded store fetch, elastic rescale, compression.

Multi-device cases run in a subprocess with fake host devices so the
main test process keeps seeing exactly one device (brief requirement).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (ErrorState, dequantize,
                                           init_error_state, quantize)
from repro.pool.placement import plan_store_migration, rebalance_partitions


def _run_sub(code: str):
    # JAX_PLATFORMS=cpu is load-bearing: without it, boxes with a libtpu
    # install spin for minutes retrying TPU metadata fetches before the
    # fake host devices ever come up
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_sharded_store_fetch_multidevice():
    out = _run_sub("""
        import numpy as np, jax
        from repro.data.synthetic import sift_like
        from repro.core import build_meta, build_store
        from repro.core.distributed import ShardedStore
        ds = sift_like(n=1500, n_queries=4, seed=1)
        meta = build_meta(ds.data, 12, seed=0)
        store = build_store(ds.data, meta)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ss = ShardedStore(store, mesh)
        ids = np.concatenate([store.span_block_ids(3),
                              store.span_block_ids(8)])
        g, v = ss.fetch(ids)
        assert np.array_equal(np.asarray(g), store.graph_buf[ids])
        assert np.allclose(np.asarray(v), store.vec_buf[ids])
        print("FETCH_OK")
    """)
    assert "FETCH_OK" in out


def test_elastic_reshard_multidevice():
    """Train state moves 4-way -> 2-way mesh with values intact."""
    out = _run_sub("""
        import numpy as np, jax
        from repro.configs.registry import smoke_config
        from repro.train.checkpoint import rescale_train_state
        from repro.models import model as M
        from repro.models.params import init_params, param_shardings
        from repro.train import adamw
        cfg = smoke_config("qwen3-8b")
        defs = M.param_defs(cfg)
        mesh1 = jax.make_mesh((2, 4), ("data", "model"))
        params = init_params(defs, jax.random.key(0))
        params = jax.device_put(params, param_shardings(defs, mesh1))
        opt = adamw.init(params)
        before = np.asarray(jax.tree.leaves(params)[0])
        mesh2 = jax.make_mesh((4, 2), ("data", "model"))
        p2, o2 = rescale_train_state(params, opt, defs, mesh2)
        after = np.asarray(jax.tree.leaves(p2)[0])
        assert np.array_equal(before, after)
        shard = jax.tree.leaves(p2)[0].sharding
        assert shard.mesh.shape["model"] == 2
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out


def test_compressed_allreduce_multidevice():
    """int8 psum (shard_map) mean-grad close to f32; error feedback sound."""
    out = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core.distributed import shard_map_compat
        from repro.distributed.compression import (compressed_grad_reduce,
                                                   init_error_state)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        local = rng.standard_normal((8, 64, 32)).astype(np.float32)
        grads = {"w": jax.device_put(local, NamedSharding(mesh, P("data")))}
        err = init_error_state({"w": jnp.zeros((64, 32))})

        def red(g, e):
            out, new = compressed_grad_reduce({"w": g[0]}, e, mesh)
            return out["w"], new
        f = jax.jit(shard_map_compat(red, mesh=mesh,
                    in_specs=(P("data"), P()), out_specs=P()))
        ghat, _ = f(grads["w"], err)
        # mean over replicas
        want = local.mean(0)
        got = np.asarray(ghat)
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.05, rel
        print("COMPRESS_OK", rel)
    """)
    assert "COMPRESS_OK" in out


def test_quantize_error_feedback_converges():
    """Residual-carry: the ACCUMULATED dequantized signal tracks the
    accumulated true signal (the EF telescoping property)."""
    rng = np.random.default_rng(0)
    e = np.zeros(64, np.float32)
    acc_true = np.zeros(64)
    acc_hat = np.zeros(64)
    for step in range(50):
        g = rng.standard_normal(64).astype(np.float32)
        acc_true += g
        q, s = quantize(jnp.asarray(g + e))
        ghat = np.asarray(dequantize(q, s))
        e = (g + e) - ghat
        acc_hat += ghat
    # error feedback keeps the accumulated drift bounded by one step's quanta
    drift = np.abs(acc_true - acc_hat).max()
    assert drift < 0.2, drift


def test_plan_store_migration_contiguous():
    moves = plan_store_migration(n_blocks=100, old_tp=4, new_tp=5)
    covered = np.zeros(100, bool)
    for src, dst, b, n in moves:
        assert src != dst
        assert n > 0
        covered[b:b + n] = True
    # after migration every block's owner matches the new mapping
    new_per = -(-100 // 5)
    for b in range(100):
        old_owner = min(b // 25, 3)
        new_owner = min(b // new_per, 4)
        if old_owner != new_owner:
            assert covered[b], b


def test_rebalance_partitions_moves_off_sick_owner():
    owners = np.array([0, 0, 1, 1, 2, 2, 3, 3])
    new, moves = rebalance_partitions(owners, sick={1}, n_owners=4)
    assert not np.isin(new, [1]).any()
    assert len(moves) == 2
    # healthy owners' loads stay balanced within 1
    import collections
    load = collections.Counter(new.tolist())
    assert max(load.values()) - min(load.values()) <= 1
