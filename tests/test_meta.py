"""Meta-HNSW (representative index) — paper §3.1 properties."""
import numpy as np

from repro.core.hnsw import brute_force_knn
from repro.core.meta import balance_stats, build_meta


def test_meta_structure(sift_small):
    meta = build_meta(sift_small.data, 64, seed=0)
    assert meta.n_partitions == 64
    assert meta.graph.n_levels == 3            # paper: three-layer meta-HNSW
    assert meta.graph.entry == 0               # fixed entry point in L2
    assert meta.assignments.shape == (sift_small.data.shape[0],)
    assert meta.assignments.min() >= 0 and meta.assignments.max() < 64


def test_meta_is_lightweight(sift_small):
    """Paper: 0.373 MB for SIFT1M@500 reps.  Scaled: tiny vs the data."""
    meta = build_meta(sift_small.data, 64, seed=0)
    assert meta.size_bytes() < 0.05 * sift_small.data.nbytes


def test_assignment_is_nearest_rep(sift_small):
    meta = build_meta(sift_small.data, 32, seed=1)
    _, nn = brute_force_knn(meta.reps, sift_small.data[:200], 1)
    assert np.array_equal(meta.assignments[:200], nn[:, 0].astype(np.int32))


def test_partition_lists_partition_everything(sift_small):
    meta = build_meta(sift_small.data, 32, seed=1)
    lists = meta.partition_lists()
    allids = np.sort(np.concatenate(lists))
    assert np.array_equal(allids, np.arange(sift_small.data.shape[0]))
    stats = balance_stats(meta)
    assert stats["empty"] <= 2  # uniform sampling rarely leaves empties


def test_meta_route_matches_exact_topb(sift_small):
    import jax.numpy as jnp
    from repro.core.search import meta_route
    meta = build_meta(sift_small.data, 32, seed=1)
    q = sift_small.queries[:32]
    pids, _ = meta_route(jnp.asarray(meta.graph.vectors),
                         jnp.asarray(meta.graph.adjacency),
                         jnp.asarray(q), meta.graph.entry, b=4,
                         n_levels=meta.graph.n_levels)
    _, exact = brute_force_knn(meta.reps, q, 4)
    overlap = np.mean([len(set(np.asarray(pids)[i].tolist())
                           & set(exact[i].tolist())) / 4
                       for i in range(len(q))])
    assert overlap >= 0.95, overlap
