"""repro.net — the real TCP transport behind the MemoryPool verbs.

Three layers of coverage:

* **wire format** — encode/decode round-trips for every verb frame
  (deterministic edge cases always; randomized property tests when
  ``hypothesis`` is installed, skipping cleanly otherwise), including
  zero-descriptor batches, max-size span batches, and int8 payloads.
* **conformance** — the transport gate from ``tests/test_pool.py``
  applied to ``RemotePool``: against live loopback ``PoolServer``
  processes, search + insert must be bit-identical to ``LocalPool``
  across {naive, full} x {none, int8}, both single-node and as two
  ``ShardedPool`` children over two server processes; measured wire
  payload bytes must equal the ``NetLedger``'s modeled charge for span
  verbs, with trips == frames sent.
* **failure** — a killed server raises a clean ``PoolUnavailableError``
  at the next verb (bounded by the socket timeout) instead of hanging.

The module spawns its loopback servers once (module-scoped fixture) and
re-ATTACHes per engine build — one region per server at a time.
"""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI fast tier / bare containers
    HAVE_HYPOTHESIS = False

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, Fabric, NetLedger
from repro.core.hnsw import HNSWParams
from repro.core.layout import build_store
from repro.core.meta import build_meta
from repro.net import (PoolUnavailableError, RemotePool, parse_endpoint,
                       spawn_pool_servers)
from repro.net import wire as W
from repro.pool import LocalPool, ShardedPool
from repro.pool.placement import FrequencyAwarePlacement

CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3, fabric=RDMA_100G)


@pytest.fixture(scope="module")
def servers():
    with spawn_pool_servers(2) as endpoints:
        yield endpoints


@pytest.fixture(scope="module")
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:24]


def _tiny_store(data, ov_cap=0):
    meta = build_meta(data, 8, seed=0, meta_levels=2)
    return build_store(data, meta, ov_cap=ov_cap,
                       sub_params=HNSWParams(M=4, M0=8, ef_construction=40))


def _build(pool, data, **over):
    cfg = {**CFG, **over, "pool": pool}
    return DHNSWEngine(EngineConfig(**cfg)).build(data)


# ------------------------------------------------------------ wire format

def test_frame_header_roundtrip():
    buf = W.pack_frame(W.OP_READ_SPANS, b"abc", flags=W.FLAG_QUANT, seq=7)
    op, flags, seq, length = W.unpack_header(buf[:W.HEADER_BYTES])
    assert (op, flags, seq, length) == (W.OP_READ_SPANS, W.FLAG_QUANT, 7, 3)
    assert buf[W.HEADER_BYTES:] == b"abc"
    # empty payload
    op, flags, seq, length = W.unpack_header(
        W.pack_frame(W.OP_PING, b"", seq=0))
    assert length == 0
    with pytest.raises(W.WireError):
        W.unpack_header(b"XXXX" + bytes(W.HEADER_BYTES - 4))
    with pytest.raises(W.WireError):
        W.unpack_header(b"short")


def test_wire_attach_and_span_frames_roundtrip(pds):
    """Every buffer of the region survives encode -> decode, and span
    responses decode to exactly what was gathered — including the
    zero-descriptor batch and the max-size (every partition) batch."""
    data, _ = pds
    store = _tiny_store(data)
    payload, flags = W.enc_attach(store)
    back = W.dec_attach(payload, flags)
    assert np.array_equal(back.graph_buf, store.graph_buf)
    assert np.array_equal(back.vec_buf, store.vec_buf)
    assert np.array_equal(back.meta_table, store.meta_table)
    assert np.array_equal(back.n_base, store.n_base)
    assert back.spec == store.spec

    spec = store.spec
    for pids in (np.zeros(0, np.int64),                 # zero descriptors
                 np.arange(spec.n_partitions)):         # max-size batch
        assert np.array_equal(W.dec_pids(W.enc_pids(pids)), pids)
        m = len(pids)
        ids = (np.stack([store.span_block_ids(int(p)) for p in pids])
               if m else np.zeros((0, spec.fetch_blocks), np.int64))
        g = store.graph_buf[ids.reshape(-1)].reshape(m, spec.fetch_blocks,
                                                     spec.gblk)
        v = store.vec_buf[ids.reshape(-1)].reshape(m, spec.fetch_blocks,
                                                   spec.vblk)
        payload = W.enc_spans_resp(spec, quant=False, g=g, v=v)
        assert len(payload) == m * spec.partition_bytes()
        g2, v2 = W.dec_spans_resp(spec, payload, m=m, quant=False)
        assert np.array_equal(g, g2) and np.array_equal(v, v2)


def test_wire_quant_frames_roundtrip(pds):
    """int8 payloads: quant span responses in both layouts (full graph
    blocks vs compact gid tails), quant row responses, and the
    extract/rebuild tail pair restoring every id lane."""
    data, _ = pds
    store = _tiny_store(data)
    from repro.core.layout import attach_quant_mirror
    attach_quant_mirror(store, 32)
    spec = store.spec
    pids = np.array([0, 3, 6])
    m = len(pids)
    ids = np.stack([store.span_block_ids(int(p)) for p in pids])
    qv = store.qvec_buf[ids.reshape(-1)].reshape(m, spec.fetch_blocks, -1)
    qs = store.qscale_buf[ids.reshape(-1)].reshape(m, spec.fetch_blocks, -1)
    g = store.graph_buf[ids.reshape(-1)].reshape(m, spec.fetch_blocks, -1)
    sides = W.span_sides(store.meta_table, pids)

    payload = W.enc_spans_resp(spec, quant=True, graph=True, qv=qv, qs=qs,
                               g=g)
    assert len(payload) == m * spec.quant_partition_bytes(
        include_graph=True)
    qv2, qs2, g2 = W.dec_spans_resp(spec, payload, m=m, quant=True,
                                    graph=True)
    assert np.array_equal(qv, qv2) and np.array_equal(qs, qs2)
    assert np.array_equal(g, g2)

    tails = W.extract_gid_tails(spec, g, sides)
    payload = W.enc_spans_resp(spec, quant=True, graph=False, qv=qv, qs=qs,
                               tails=tails)
    assert len(payload) == m * spec.quant_partition_bytes(
        include_graph=False)
    qv2, qs2, t2 = W.dec_spans_resp(spec, payload, m=m, quant=True,
                                    graph=False)
    assert np.array_equal(tails, t2)
    rebuilt = W.rebuild_quant_gspans(spec, t2, sides)
    # every id lane restored exactly; non-id lanes are -1 by contract
    assert np.array_equal(W.extract_gid_tails(spec, rebuilt, sides), tails)

    rows = np.array([0, 5, 130], np.int64)
    codes = store.qvec_buf.reshape(-1, spec.dim)[rows]
    scales = store.qscale_buf.reshape(-1, spec.dim // 32)[rows]
    payload = W.enc_quant_rows_resp(codes, scales)
    c2, s2 = W.dec_quant_rows_resp(payload, len(rows), spec.dim, 32)
    assert np.array_equal(codes, c2) and np.array_equal(scales, s2)


def test_wire_append_and_write_blocks_roundtrip(pds):
    data, _ = pds
    store = _tiny_store(data)
    spec = store.spec
    vec = data[0] + 0.25
    payload, flags = W.enc_append(vec, 42, 3)
    v2, gid, pid, codes, scales = W.dec_append(payload, flags, spec.dim, 1)
    assert np.array_equal(np.asarray(vec, np.float32), v2)
    assert (gid, pid, codes, scales) == (42, 3, None, None)
    assert len(payload) == spec.dim * 4 + 8 + 8   # model bytes + address

    from repro.quant.codec import quantize_groups
    from repro.core.layout import attach_quant_mirror
    attach_quant_mirror(store, 32)
    codes, scales = quantize_groups(np.asarray(vec, np.float32), 32)
    payload, flags = W.enc_append(vec, 42, 3, codes, scales)
    assert flags & W.FLAG_QUANT
    v2, gid, pid, c2, s2 = W.dec_append(payload, flags, spec.dim, 32)
    assert np.array_equal(codes, c2) and np.array_equal(scales, s2)

    ids = np.arange(spec.group_blocks)            # one group's blocks
    payload, flags = W.enc_write_blocks(store, ids)
    upd = W.dec_write_blocks(payload, flags, store.spec)  # spec now quant
    assert np.array_equal(upd["ids"], ids)
    assert np.array_equal(upd["g"], store.graph_buf[ids])
    assert np.array_equal(upd["v"], store.vec_buf[ids])
    assert np.array_equal(upd["qv"], store.qvec_buf[ids])
    assert np.array_equal(upd["meta"], store.meta_table)

    meta_payload = W.enc_meta_resp(store)
    meta, n_base = W.dec_meta_resp(meta_payload, spec.n_partitions)
    assert np.array_equal(meta, store.meta_table)
    assert np.array_equal(n_base, store.n_base)


if HAVE_HYPOTHESIS:
    @given(n=st.integers(0, 200), seed=st.integers(0, 2**32 - 1),
           dim=st.sampled_from([8, 32, 128]))
    @settings(max_examples=50, deadline=None)
    def test_wire_row_frames_property(n, seed, dim):
        """Randomized round-trips: pid/row batches of any size (zero
        included) and f32/int8 row payloads reproduce exactly."""
        rng = np.random.default_rng(seed)
        rows = rng.integers(-1, 1 << 40, size=n, dtype=np.int64)
        assert np.array_equal(W.dec_rows(W.enc_rows(rows)), rows)
        vrows = rng.standard_normal((n, dim)).astype(np.float32)
        assert np.array_equal(
            W.dec_rows_resp(W.enc_rows_resp(vrows), n, dim), vrows)
        codes = rng.integers(-127, 128, size=(n, dim)).astype(np.int8)
        scales = rng.standard_normal((n, dim // 8)).astype(np.float32)
        c2, s2 = W.dec_quant_rows_resp(
            W.enc_quant_rows_resp(codes, scales), n, dim, 8)
        assert np.array_equal(codes, c2) and np.array_equal(scales, s2)

    @given(op=st.sampled_from(sorted(W.OP_NAMES)),
           flags=st.integers(0, 0xFFFF), seq=st.integers(0, 2**32 - 1),
           n=st.integers(0, 4096))
    @settings(max_examples=100, deadline=None)
    def test_wire_header_property(op, flags, seq, n):
        hdr = W.pack_frame(op, bytes(n), flags=flags,
                           seq=seq)[:W.HEADER_BYTES]
        assert W.unpack_header(hdr) == (op, flags, seq, n)


# ------------------------------------------------------------ conformance

@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("mode", ["naive", "full"])
def test_remote_bit_identical_search_insert(servers, pds, mode, quant):
    """The conformance gate: RemotePool — single server, and as two
    ShardedPool children over two server processes — returns bit-
    identical search/insert results AND identical NetLedger accounting
    vs LocalPool, while the measured span wire bytes equal the model."""
    data, queries = pds
    base = _build("local", data, mode=mode, quant=quant)
    d0, g0, st0 = base.search(queries, k=10)
    new = queries[:3] + 0.001
    gids0 = base.insert(new)
    d1, g1, _ = base.search(queries[:8], k=10)

    rem = _build("remote", data, mode=mode, quant=quant,
                 endpoints=(servers[0],))
    d, g, st = rem.search(queries, k=10)
    assert np.array_equal(d0, d) and np.array_equal(g0, g)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st["net"][key], key
    assert np.array_equal(gids0, rem.insert(new))
    d, g, _ = rem.search(queries[:8], k=10)
    assert np.array_equal(d1, d) and np.array_equal(g1, g)
    wvm = rem.pool.wire_vs_model()
    for verb in ("read_spans", "read_spans_quant"):
        if verb in wvm:
            assert wvm[verb]["measured"] == wvm[verb]["modeled"], wvm

    # two ShardedPool children over two loopback server processes
    sh = _build("remote", data, mode=mode, quant=quant,
                endpoints=tuple(servers))
    d, g, st = sh.search(queries, k=10)
    assert np.array_equal(d0, d) and np.array_equal(g0, g)
    assert st["pool"]["kind"] == "sharded"
    assert st["pool"]["n_shards"] == 2
    assert all(s["kind"] == "remote" for s in st["pool"]["shards"])
    assert np.array_equal(gids0, sh.insert(new))
    d, g, st = sh.search(queries[:8], k=10)
    assert np.array_equal(d1, d) and np.array_equal(g1, g)
    assert st["pool"]["wire_total"]["bytes_rx"] > 0


def test_remote_wire_matches_ledger_accounting(servers, pds):
    """Measured wire bytes == NetLedger accounting, verb by verb: span
    frames carry exactly the modeled span bytes (trips == frames sent),
    and a row fetch for the rows ``post_row_reads`` charged moves
    exactly the charged bytes."""
    data, _ = pds
    store = _tiny_store(data)
    pool = RemotePool(store, servers[0])
    led = NetLedger(RDMA_100G)

    pids = np.array([0, 2, 3, 5, 6])
    pool.read_spans(pids, ledger=led, doorbell=2)
    spans_frames = pool.wire["frames_by_verb"]["read_spans"]
    assert spans_frames == 3                     # ceil(5 / 2) batches
    assert led.round_trips == spans_frames       # trips == frames sent
    wvm = pool.wire_vs_model()["read_spans"]
    assert wvm["measured"] == wvm["modeled"] == led.bytes

    # rows: charge first (the accounting verb), then move the same rows
    groups = [(0, 2), (2, 3)]
    before = led.bytes
    pool.post_row_reads(groups, ledger=led, doorbell=8)
    charged = led.bytes - before
    rows = np.array([0, 1, 130, 131, 132], np.int64)   # 5 distinct rows
    pool.read_rows(rows)
    measured = pool.wire["payload_by_verb"]["read_rows"]
    assert measured == charged == len(rows) * store.spec.row_bytes()

    # quant spans, both layouts
    pool.attach_quant(32)
    for graph in (True, False):
        led_q = NetLedger(RDMA_100G)
        pool.read_spans(pids, ledger=led_q, doorbell=4, quant=True,
                        quant_graph=graph)
        assert led_q.bytes == len(pids) * store.spec.quant_partition_bytes(
            include_graph=graph)
    wvm = pool.wire_vs_model()["read_spans_quant"]
    assert wvm["measured"] == wvm["modeled"]

    # zero-descriptor batch: legal, free, frameless
    before = dict(pool.wire["frames_by_verb"])
    g, v = pool.read_spans(np.zeros(0, np.int64), ledger=led)
    assert g.shape[0] == 0
    assert pool.wire["frames_by_verb"] == before


def test_remote_append_repack_keep_regions_coherent(servers, pds):
    """Writes land on both sides: appends fill the shared overflow until
    repack, and after the client-side repack + block WRITE the server
    region equals the mirror bit for bit."""
    data, _ = pds
    extra = {}

    def lookup(gids):
        out = np.zeros((len(gids), data.shape[1]), np.float32)
        for i, g in enumerate(int(x) for x in gids):
            out[i] = data[g] if g < len(data) else extra[g]
        return out

    s_local, s_rem = _tiny_store(data, ov_cap=8), _tiny_store(data, ov_cap=8)
    lp = LocalPool(s_local)
    rp = RemotePool(s_rem, servers[0])
    lp.attach_quant(32)
    rp.attach_quant(32)
    gid = 50_000
    while True:
        vec = data[0] + 0.01 * (gid - 50_000 + 1)
        extra[gid] = vec
        sl = lp.append(vec, gid, 1, ledger=None)
        sr = rp.append(vec, gid, 1, ledger=None)
        assert sl == sr
        if sl < 0:
            break
        gid += 1
    assert lp.repack(0, lookup) == rp.repack(0, lookup) is True
    assert np.array_equal(s_local.graph_buf, s_rem.graph_buf)
    assert np.array_equal(s_local.vec_buf, s_rem.vec_buf)
    assert np.array_equal(s_local.meta_table, s_rem.meta_table)
    assert np.array_equal(s_local.qvec_buf, s_rem.qvec_buf)
    server_meta, _ = rp.server_meta()
    assert np.array_equal(server_meta, s_rem.meta_table)
    a = lp.read_spans(np.arange(4), ledger=None)
    b = rp.read_spans(np.arange(4), ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_remote_migration_restages_destination(servers, pds):
    """Freq placement over two remote children with unequal fabrics
    migrates hot groups; the destination server is re-staged over the
    wire (refresh_blocks) so results stay bit-identical, and appends
    after the move land on a coherent owner."""
    data, _ = pds
    slow = Fabric("slow", rtt_s=100e-6, bw_Bps=0.5e9, per_op_s=5e-6,
                  max_doorbell=32)
    s_local, s_rem = _tiny_store(data), _tiny_store(data)
    lp = LocalPool(s_local)
    fabrics = (RDMA_100G, slow)
    sp = ShardedPool(
        s_rem,
        [lambda st, ep=ep, f=f: RemotePool(st, ep, fabric=f)
         for ep, f in zip(servers, fabrics)],
        placement=FrequencyAwarePlacement(migrate_every=16, max_moves=2))
    led = NetLedger(RDMA_100G)
    hot = np.array([2 * g for g in range(4)
                    if sp.owner_of_group(g) == 1][:2])
    assert len(hot), "expected some groups on the slow shard"
    for _ in range(30):
        sp.read_spans(hot, ledger=led, doorbell=2)
    snap = sp.snapshot()
    assert snap["migration"]["n"] >= 1, "hot groups should migrate"
    migrated = [s for s in snap["shards"]
                if "migrate" in s["wire"]["payload_by_verb"]]
    assert migrated, "migration bytes should cross the wire"
    a = lp.read_spans(np.arange(8), ledger=None)
    b = sp.read_spans(np.arange(8), ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    vec = data[5] + 0.5
    assert lp.append(vec, 77_000, int(hot[0]), ledger=None) \
        == sp.append(vec, 77_000, int(hot[0]), ledger=None) >= 0
    a = lp.read_spans(np.arange(8), ledger=None)
    b = sp.read_spans(np.arange(8), ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- failure

def test_remote_verb_error_does_not_poison_connection(servers, pds):
    """A server-side verb error inside a pipelined doorbell batch is a
    RuntimeError for THAT call only: the remaining in-flight responses
    are drained, so the connection keeps serving (a healthy server must
    never start looking like a dead one)."""
    data, _ = pds
    s_local, s_rem = _tiny_store(data), _tiny_store(data)
    lp = LocalPool(s_local)
    pool = RemotePool(s_rem, servers[1])
    # pid 999 is out of range: the first frame errors server-side while
    # the second (valid) frame's response is already in flight
    with pytest.raises(RuntimeError, match="pool server error"):
        pool.read_spans(np.array([0, 999, 1, 2]), ledger=None, doorbell=2)
    a = lp.read_spans(np.arange(4), ledger=None)
    b = pool.read_spans(np.arange(4), ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_remote_server_kill_raises_clean_error(pds):
    """A vanished server is a PoolUnavailableError at the next verb —
    bounded by the socket timeout, never a hang — and connecting to a
    dead endpoint fails the same way."""
    data, _ = pds
    store = _tiny_store(data)
    with spawn_pool_servers(1) as eps:
        pool = RemotePool(store, eps[0], timeout_s=5.0)
        pool.read_spans(np.arange(2), ledger=None)
        endpoint = parse_endpoint(eps[0])
    # context exit terminated the server process
    t0 = time.time()
    with pytest.raises(PoolUnavailableError):
        pool.read_spans(np.arange(2), ledger=None)
    assert time.time() - t0 < 10.0, "should fail fast, not hang"
    # the connection is closed for good: the next verb fails too
    with pytest.raises(PoolUnavailableError):
        pool.read_rows(np.array([0, 1]))
    with pytest.raises(PoolUnavailableError):
        RemotePool(_tiny_store(data), endpoint, connect_timeout_s=2.0)


def test_replicated_remote_survives_kill9_mid_search(pds):
    """The ROADMAP chaos gate at test scale: two loopback PoolServers
    behind a replicated pool (replication=2); kill -9 one server and
    keep searching — no PoolUnavailableError surfaces, results stay
    bit-identical to LocalPool, the dead shard's groups re-replicate
    onto the survivor, and inserts keep landing on both regions."""
    data, queries = pds
    base = _build("local", data)
    with spawn_pool_servers(2, with_procs=True) as (eps, procs):
        eng = _build("remote", data, endpoints=tuple(eps), replication=2)
        d0, g0, _ = base.search(queries, k=10)
        d1, g1, st = eng.search(queries, k=10)
        assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
        assert st["pool"]["replication"] == 2

        procs[0].kill()                        # SIGKILL, no goodbye
        procs[0].wait(timeout=10)
        d2, g2, st = eng.search(queries, k=10)  # discovers the death
        assert np.array_equal(d0, d2) and np.array_equal(g0, g2)
        fo = st["pool"]["failover"]
        assert fo["deaths"] == 1
        assert fo["read_retries"] >= 1
        assert fo["lost_groups"] == 0
        assert st["pool"]["alive"] == [False, True]

        # writes after the death: both engines agree bit for bit
        new = queries[:2] + 0.001
        assert np.array_equal(base.insert(new), eng.insert(new))
        da, ga, _ = base.search(queries[:8], k=10)
        db, gb, _ = eng.search(queries[:8], k=10)
        assert np.array_equal(da, db) and np.array_equal(ga, gb)
