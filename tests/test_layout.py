"""RDMA-friendly layout — §3.2: round-trip, spans, overflow, repack.

Includes hypothesis property tests over the layout arithmetic (offsets
never overlap, every span is in-bounds, both partners cover the shared
overflow region).  Without ``hypothesis`` installed the property tests
skip cleanly (``pytest.importorskip``) and the rest of the module runs.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI fast tier / bare containers
    HAVE_HYPOTHESIS = False

from repro.core import layout as LA
from repro.core.layout import LayoutSpec, build_store
from repro.core.meta import build_meta


@pytest.fixture(scope="module")
def store_and_meta(sift_small):
    meta = build_meta(sift_small.data, 24, seed=2)
    store = build_store(sift_small.data, meta)
    return store, meta, sift_small.data


# ---------------------------------------------------------------- spec math

if HAVE_HYPOTHESIS:
    @given(dim=st.integers(4, 512), deg=st.integers(2, 64),
           np_max=st.integers(1, 3000), ov_cap=st.integers(4, 500),
           slot_vecs=st.integers(1, 128), n_parts=st.integers(1, 600))
    @settings(max_examples=200, deadline=None)
    def test_spec_arithmetic_invariants(dim, deg, np_max, ov_cap, slot_vecs,
                                        n_parts):
        spec = LayoutSpec(dim=dim, deg=deg, np_max=np_max, ov_cap=ov_cap,
                          slot_vecs=slot_vecs, n_partitions=n_parts)
        # capacities: the data span must hold the padded sub-HNSW, the ov
        # span the shared region, in BOTH buffers
        assert spec.data_blocks * spec.gblk >= spec.np_max * (spec.deg + 1)
        assert spec.data_blocks * spec.vblk >= spec.np_max * spec.dim
        assert spec.ov_blocks * spec.gblk >= spec.ov_cap
        assert spec.ov_blocks * spec.vblk >= spec.ov_cap * spec.dim
        assert spec.group_blocks == 2 * spec.data_blocks + spec.ov_blocks
        assert spec.n_blocks == spec.n_groups * spec.group_blocks
        # fetch spans of a group's two partitions: in-bounds, both contain
        # the shared overflow, data regions disjoint
        for pid in (0, 1):
            if pid >= n_parts:
                continue
            start = pid * spec.data_blocks  # side A: 0; side B: data_blocks
            end = start + spec.fetch_blocks
            assert end <= spec.group_blocks
        ov_lo, ov_hi = spec.data_blocks, spec.data_blocks + spec.ov_blocks
        a_span = range(0, spec.fetch_blocks)
        b_span = range(spec.data_blocks, spec.group_blocks)
        assert set(range(ov_lo, ov_hi)) <= set(a_span)
        assert set(range(ov_lo, ov_hi)) <= set(b_span)

    @given(group=st.integers(0, 50), slot=st.integers(0, 199),
           dim=st.integers(4, 256), slot_vecs=st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_overflow_coords_in_ov_region(group, slot, dim, slot_vecs):
        spec = LayoutSpec(dim=dim, deg=8, np_max=100, ov_cap=200,
                          slot_vecs=slot_vecs, n_partitions=200)
        co = LA.overflow_write_coords(spec, group, slot)
        lo = group * spec.group_blocks + spec.data_blocks
        hi = lo + spec.ov_blocks
        assert lo <= co["vec_block"] < hi
        assert lo <= co["gid_block"] < hi
        # vector writes never straddle a block boundary (vblk % dim == 0)
        assert co["vec_off"] + dim <= spec.vblk
else:
    def test_spec_arithmetic_invariants():
        pytest.importorskip("hypothesis")

    def test_overflow_coords_in_ov_region():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------- round-trip

def test_all_partitions_roundtrip(store_and_meta):
    import jax.numpy as jnp
    from repro.core import device_store as DS
    store, meta, data = store_and_meta
    spec = store.spec
    for pid in range(spec.n_partitions):
        ids = LA.partition_gids(store, pid)
        part = DS.decode_span(
            spec, jnp.asarray(store.graph_buf[store.span_block_ids(pid)]),
            jnp.asarray(store.vec_buf[store.span_block_ids(pid)]),
            jnp.asarray(store.meta_table[pid]))
        n = len(ids)
        assert np.array_equal(np.asarray(part.gids)[:n], ids)
        assert np.allclose(np.asarray(part.vectors)[:n], data[ids])
        assert int(np.asarray(part.valid).sum()) == n


def test_partitions_cover_dataset(store_and_meta):
    store, meta, data = store_and_meta
    allg = np.concatenate([LA.partition_gids(store, p)
                           for p in range(store.spec.n_partitions)])
    assert np.array_equal(np.sort(allg), np.arange(data.shape[0]))


def test_spans_disjoint_data_shared_overflow(store_and_meta):
    store, _, _ = store_and_meta
    spec = store.spec
    seen = {}
    for pid in range(spec.n_partitions):
        span = set(store.span_block_ids(pid).tolist())
        partner = pid ^ 1
        for q, qspan in seen.items():
            inter = span & qspan
            if q == partner:
                assert len(inter) == spec.ov_blocks  # exactly the shared ov
            else:
                assert not inter
        seen[pid] = span


# ---------------------------------------------------------------- insert

def test_insert_into_overflow_and_read_back(store_and_meta):
    import jax.numpy as jnp
    from repro.core import device_store as DS
    store, meta, data = store_and_meta
    spec = store.spec
    pid = 3
    vec = np.float32(np.arange(spec.dim)) / spec.dim
    slot = LA.insert_vector(store, vec, gid=999_999, pid=pid)
    assert slot >= 0
    assert 999_999 in LA.overflow_gids(store, pid).tolist()
    # one contiguous span fetch now returns the inserted vector too
    part = DS.decode_span(
        spec, jnp.asarray(store.graph_buf[store.span_block_ids(pid)]),
        jnp.asarray(store.vec_buf[store.span_block_ids(pid)]),
        jnp.asarray(store.meta_table[pid]))
    gids = np.asarray(part.gids)
    valid = np.asarray(part.valid)
    j = np.nonzero((gids == 999_999) & valid)[0]
    assert len(j) == 1
    assert np.allclose(np.asarray(part.vectors)[j[0]], vec)
    # the PARTNER's fetch must NOT claim this vector as its own
    partner = pid ^ 1
    ppart = DS.decode_span(
        spec, jnp.asarray(store.graph_buf[store.span_block_ids(partner)]),
        jnp.asarray(store.vec_buf[store.span_block_ids(partner)]),
        jnp.asarray(store.meta_table[partner]))
    pg = np.asarray(ppart.gids)
    pv = np.asarray(ppart.valid)
    assert not ((pg == 999_999) & pv).any()


def test_shared_overflow_fills_from_both_ends(sift_small):
    meta = build_meta(sift_small.data[:500], 8, seed=0)
    store = build_store(sift_small.data[:500], meta, ov_cap=6)
    a_pid, b_pid = 0, 1
    v = np.zeros(store.spec.dim, np.float32)
    assert LA.insert_vector(store, v, 10_001, a_pid) == 0
    assert LA.insert_vector(store, v, 10_002, b_pid) == 5
    assert LA.insert_vector(store, v, 10_003, a_pid) == 1
    # counters mirrored on both partners
    assert store.meta_table[a_pid, LA.MT_OV_A] == 2
    assert store.meta_table[b_pid, LA.MT_OV_A] == 2
    assert store.meta_table[a_pid, LA.MT_OV_B] == 1
    # fill it up -> -1 (repack needed)
    for g in range(3):
        LA.insert_vector(store, v, 20_000 + g, a_pid)
    assert LA.insert_vector(store, v, 30_000, a_pid) == -1


def test_repack_group_folds_overflow(sift_small):
    data = sift_small.data[:600]
    meta = build_meta(data, 8, seed=0)
    store = build_store(data, meta, ov_cap=8, np_max=200)
    pid = 2
    extra = {}
    for g in range(4):
        vec = data[g] + 0.01
        extra[1000 + g] = vec
        assert LA.insert_vector(store, vec, 1000 + g, pid) >= 0

    def lookup(gids):
        return np.stack([data[g] if g < 600 else extra[g] for g in gids])

    n_before = int(store.meta_table[pid, LA.MT_N_BASE])
    ok = LA.repack_group(store, int(store.meta_table[pid, LA.MT_GROUP]),
                         lookup)
    assert ok
    assert store.meta_table[pid, LA.MT_OV_A] == 0
    assert store.meta_table[pid, LA.MT_OV_B] == 0
    assert int(store.meta_table[pid, LA.MT_N_BASE]) == n_before + 4
    base = LA.partition_gids(store, pid).tolist()
    for g in extra:
        assert g in base
