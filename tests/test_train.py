"""Training substrate: convergence, checkpoint integrity, restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import smoke_config
from repro.data.synthetic import token_stream
from repro.models import model as M
from repro.models.params import init_params
from repro.train import adamw
from repro.train import checkpoint as CKPT
from repro.train.trainer import HeartbeatMonitor, fit, run_with_restarts

# long-running tier: excluded from CI fast job (-m 'not slow')
pytestmark = pytest.mark.slow

SHAPE = InputShape("tiny", 32, 4, "train")


def test_loss_decreases():
    cfg = smoke_config("qwen3-8b")
    # fixed repeating batch -> the model must fit it
    batch = next(token_stream(cfg.vocab_size, 4, 32, seed=0))
    rep = fit(cfg, SHAPE, iter(lambda: batch, None), 30, log_every=0)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    cfg = smoke_config("mamba2-370m")
    params = init_params(M.param_defs(cfg), jax.random.key(0))
    opt = adamw.init(params)
    CKPT.save(str(tmp_path), 7, (params, opt))
    (p2, o2), step = CKPT.restore(str(tmp_path), (params, opt))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_gc(tmp_path):
    tree = {"w": jnp.arange(10.0)}
    for s in (1, 2, 3, 4, 5):
        CKPT.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.arange(10.0)}
    d = CKPT.save(str(tmp_path), 1, tree)
    # flip a byte in the leaf file
    f = os.path.join(d, "arr_00000.npy")
    data = bytearray(open(f, "rb").read())
    data[-1] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(IOError):
        CKPT.restore(str(tmp_path), tree)


def test_run_with_restarts_recovers(tmp_path):
    """Fault injection: the supervised loop restores and finishes."""
    state = {"x": jnp.zeros(())}
    fail_at = {3, 7}

    def step_fn(s, step):
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError(f"injected failure at {step}")
        return {"x": s["x"] + 1.0}

    final, rep = run_with_restarts(step_fn, state, 10,
                                   ckpt_dir=str(tmp_path), ckpt_every=2)
    assert rep.steps_done == 10
    assert rep.n_restores == 2
    assert float(final["x"]) == 10.0


def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(8, z_thresh=2.5)
    for step in range(6):
        for w in range(8):
            t = 1.0 if w != 5 else 3.5   # worker 5 is slow
            mon.beat(w, t, now=float(step))
    assert mon.stragglers() == [5]
    # worker 3 stops beating -> dead after timeout
    for step in range(6, 9):
        for w in range(8):
            if w != 3:
                mon.beat(w, 1.0, now=float(step) * 5)
    assert 3 in mon.dead(now=100.0)


def test_perf_flags_numerics_equivalence():
    """§Perf flags (bf16 gathers + TP unembed + sharded CE) must not
    change the math — loss/grad-norm agree to bf16 tolerance."""
    import subprocess
    import sys
    import textwrap
    code = """
        import os, sys
        flags = sys.argv[1] == "on"
        if flags:
            os.environ["REPRO_LOSS_UNEMBED_TP"] = "1"
            os.environ["REPRO_CAST_PARAMS_ONCE"] = "1"
            os.environ["REPRO_SHARDED_CE"] = "1"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import smoke_config
        from repro.configs.base import InputShape
        from repro.models import model as M
        from repro.models.params import init_params
        from repro.train import adamw
        from repro.train.train_step import make_train_step
        cfg = smoke_config("qwen3-8b").replace(vocab_size=512)
        shape = InputShape("t", 1024, 8, "train")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        step, in_sh, out_sh, _ = make_train_step(cfg, shape, mesh)
        params = init_params(M.param_defs(cfg), jax.random.key(0))
        opt = adamw.init(params)
        rng = np.random.default_rng(0)
        batch = {k: jnp.asarray(rng.integers(0, 512, (8, 1024)), jnp.int32)
                 for k in ("tokens", "labels")}
        with mesh:
            _, _, m = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh)(params, opt, batch)
        print(float(m["loss"]))
    """
    losses = []
    for arg in ("off", "on"):
        # JAX_PLATFORMS=cpu is load-bearing: without it, boxes with a
        # libtpu install spin for minutes retrying TPU metadata fetches
        res = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code), arg],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": "/root",
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
                 "XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
        assert res.returncode == 0, res.stderr[-2000:]
        losses.append(float(res.stdout.strip().splitlines()[-1]))
    assert abs(losses[0] - losses[1]) < 1e-4, losses
