"""JAX fixed-shape search vs host HNSW semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hnsw import HNSW, HNSWParams, brute_force_knn, recall_at_k
from repro.core.search import (batched_beam_search, beam_search,
                               greedy_descent, merge_topk, scan_partition)


@pytest.fixture(scope="module")
def graph(rng=None):
    rng = np.random.default_rng(3)
    data = rng.standard_normal((1200, 24)).astype(np.float32)
    h = HNSW(24, HNSWParams(M=8, M0=16, ef_construction=64)).build(data)
    return h, h.export(), data


def test_jax_beam_matches_host_recall(graph):
    h, g, data = graph
    rng = np.random.default_rng(4)
    q = data[:40] + 0.01 * rng.standard_normal((40, 24)).astype(np.float32)
    _, gt = brute_force_knn(data, q, 10)
    d, i = batched_beam_search(jnp.asarray(g.vectors),
                               jnp.asarray(g.adjacency), jnp.asarray(q),
                               g.entry, ef=64, n_levels=g.n_levels)
    rec_jax = recall_at_k(np.asarray(i)[:, :10], gt)
    pred = np.array([[n for _, n in h.search(x, 10, 64)] for x in q])
    rec_host = recall_at_k(pred, gt)
    assert rec_jax >= rec_host - 0.05, (rec_jax, rec_host)
    assert rec_jax >= 0.85


def test_beam_results_sorted_and_deduped(graph):
    _, g, data = graph
    q = data[7] + 0.01
    d, i = beam_search(jnp.asarray(g.vectors), jnp.asarray(g.adjacency),
                       jnp.asarray(q), g.entry, ef=32, n_levels=g.n_levels)
    d, i = np.asarray(d), np.asarray(i)
    live = i >= 0
    assert (np.diff(d[live[: live.sum()]]) >= -1e-6).all()
    ids = i[live]
    assert len(set(ids.tolist())) == len(ids)


def test_greedy_descent_improves(graph):
    _, g, data = graph
    q = jnp.asarray(data[100] + 0.001)
    u, du = greedy_descent(jnp.asarray(g.vectors), jnp.asarray(g.adjacency),
                           q, g.entry, g.n_levels)
    d_entry = float(jnp.sum(jnp.square(jnp.asarray(g.vectors)[g.entry] - q)))
    assert float(du) <= d_entry + 1e-6


def test_scan_partition_exact(rng):
    v = rng.standard_normal((100, 8)).astype(np.float32)
    q = rng.standard_normal(8).astype(np.float32)
    d, i = scan_partition(jnp.asarray(v), jnp.asarray(q), 5, n_valid=60)
    full = np.sum((v[:60] - q) ** 2, 1)
    assert set(np.asarray(i).tolist()) == set(np.argsort(full)[:5].tolist())


def test_merge_topk(rng):
    da = jnp.asarray([[0.1, 0.5, jnp.inf]])
    ia = jnp.asarray([[3, 9, -1]])
    db = jnp.asarray([[0.2, 0.3, 0.9]])
    ib = jnp.asarray([[7, 8, 11]])
    d, i = merge_topk(da, ia, db, ib, 3)
    assert np.allclose(np.asarray(d)[0], [0.1, 0.2, 0.3])
    assert np.asarray(i)[0].tolist() == [3, 7, 8]
