"""DHNSWEngine end-to-end: recall, scheme equivalence, cache, insert."""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G


def test_recall_full_graph(built_engine, sift_small):
    d, g, st = built_engine.search(sift_small.queries, k=10)
    rec = recall_at_k(g, sift_small.gt_ids[:, :10])
    assert rec >= 0.75, rec
    # distances ascending, ids valid
    assert (np.diff(d, axis=1) >= -1e-5).all()
    live = g >= 0
    assert live[:, 0].all()


def test_scan_mode_at_least_graph_recall(sift_small):
    cfgs = dict(n_rep=32, b=4, ef=48, cache_frac=0.25, seed=3)
    g_eng = DHNSWEngine(EngineConfig(search_mode="graph", **cfgs)).build(
        sift_small.data)
    s_eng = DHNSWEngine(EngineConfig(search_mode="scan", **cfgs)).build(
        sift_small.data)
    _, gg, _ = g_eng.search(sift_small.queries, k=10)
    _, gs, _ = s_eng.search(sift_small.queries, k=10)
    rg = recall_at_k(gg, sift_small.gt_ids[:, :10])
    rs = recall_at_k(gs, sift_small.gt_ids[:, :10])
    # scan is exact within fetched partitions -> ceiling for this b
    assert rs >= rg - 1e-9, (rs, rg)


def test_modes_return_same_answers_different_cost(sift_small):
    """All three schemes differ ONLY in transfer strategy (paper §4)."""
    common = dict(search_mode="scan", n_rep=32, b=3, ef=48,
                  cache_frac=0.25, seed=3, fabric=RDMA_100G)
    res = {}
    for mode in ("naive", "no_doorbell", "full"):
        eng = DHNSWEngine(EngineConfig(mode=mode, **common)).build(
            sift_small.data)
        d, g, st = eng.search(sift_small.queries, k=10)
        res[mode] = (g, st)
    gn, gnd, gf = res["naive"][0], res["no_doorbell"][0], res["full"][0]
    assert np.array_equal(gn, gnd)
    assert np.array_equal(gn, gf)
    # round trips: naive >> no_doorbell >= full (paper Table 1)
    rt = {m: res[m][1]["net"]["round_trips"] for m in res}
    assert rt["naive"] > rt["no_doorbell"] >= rt["full"]
    lat = {m: res[m][1]["net"]["latency_s"] for m in res}
    assert lat["naive"] > lat["full"]


def test_recall_monotone_in_b(sift_small):
    recs = []
    for b in (1, 2, 6):
        eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=32, b=b,
                                       ef=48, cache_frac=0.3, seed=3)).build(
            sift_small.data)
        _, g, _ = eng.search(sift_small.queries, k=10)
        recs.append(recall_at_k(g, sift_small.gt_ids[:, :10]))
    assert recs[0] <= recs[1] <= recs[2] + 1e-9
    assert recs[-1] >= 0.85


def test_cache_persists_across_batches(built_engine, sift_small):
    q = sift_small.queries
    _, _, st1 = built_engine.search(q, k=10)
    _, _, st2 = built_engine.search(q, k=10)  # identical batch
    assert st2["n_fetches"] < max(st1["n_fetches"], 1) or \
        st2["cache_hits"] > 0


def test_insert_then_searchable(sift_small):
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=16, b=2,
                                   ef=32, cache_frac=0.4, seed=3)).build(
        sift_small.data[:2000])
    rng = np.random.default_rng(5)
    new = sift_small.data[2000:2010] + 0.001
    gids = eng.insert(new)
    assert len(gids) == 10
    # querying exactly the inserted vectors must find them
    d, g, _ = eng.search(new, k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids)])
    assert found >= 0.9, (found, g[:3], gids[:3])


def test_insert_overflow_triggers_repack(sift_small):
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=8, b=2,
                                   ef=32, cache_frac=0.5, seed=3))
    eng.build(sift_small.data[:1000])
    ov = eng.store.spec.ov_cap
    # target one partition with > ov_cap inserts: forces >= 1 repack
    base = sift_small.data[42]
    new = base[None, :] + 0.0005 * np.random.default_rng(0).standard_normal(
        (ov + 3, eng.store.spec.dim)).astype(np.float32)
    gids = eng.insert(new)
    d, g, _ = eng.search(new[:8], k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids[:8])])
    assert found >= 0.8, found


def test_round_trips_match_paper_shape(sift_small):
    """Naive rtpq ~= b (paper: 3.547 at b~4); full << 1 with batching."""
    common = dict(search_mode="scan", n_rep=32, ef=48, cache_frac=0.25,
                  seed=3, b=4)
    naive = DHNSWEngine(EngineConfig(mode="naive", **common)).build(
        sift_small.data)
    full = DHNSWEngine(EngineConfig(mode="full", doorbell=8, **common)).build(
        sift_small.data)
    _, _, stn = naive.search(sift_small.queries, k=10)
    _, _, stf = full.search(sift_small.queries, k=10)
    assert 3.0 <= stn["round_trips_per_query"] <= 4.01
    assert stf["round_trips_per_query"] < 0.25
