"""DHNSWEngine end-to-end: recall, scheme equivalence, cache, insert."""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G


def test_recall_full_graph(built_engine, sift_small):
    d, g, st = built_engine.search(sift_small.queries, k=10)
    rec = recall_at_k(g, sift_small.gt_ids[:, :10])
    assert rec >= 0.75, rec
    # distances ascending, ids valid
    assert (np.diff(d, axis=1) >= -1e-5).all()
    live = g >= 0
    assert live[:, 0].all()


def test_scan_mode_at_least_graph_recall(sift_small):
    cfgs = dict(n_rep=32, b=4, ef=48, cache_frac=0.25, seed=3)
    g_eng = DHNSWEngine(EngineConfig(search_mode="graph", **cfgs)).build(
        sift_small.data)
    s_eng = DHNSWEngine(EngineConfig(search_mode="scan", **cfgs)).build(
        sift_small.data)
    _, gg, _ = g_eng.search(sift_small.queries, k=10)
    _, gs, _ = s_eng.search(sift_small.queries, k=10)
    rg = recall_at_k(gg, sift_small.gt_ids[:, :10])
    rs = recall_at_k(gs, sift_small.gt_ids[:, :10])
    # scan is exact within fetched partitions -> ceiling for this b
    assert rs >= rg - 1e-9, (rs, rg)


def test_modes_return_same_answers_different_cost(sift_small):
    """All three schemes differ ONLY in transfer strategy (paper §4)."""
    common = dict(search_mode="scan", n_rep=32, b=3, ef=48,
                  cache_frac=0.25, seed=3, fabric=RDMA_100G)
    res = {}
    for mode in ("naive", "no_doorbell", "full"):
        eng = DHNSWEngine(EngineConfig(mode=mode, **common)).build(
            sift_small.data)
        d, g, st = eng.search(sift_small.queries, k=10)
        res[mode] = (g, st)
    gn, gnd, gf = res["naive"][0], res["no_doorbell"][0], res["full"][0]
    assert np.array_equal(gn, gnd)
    assert np.array_equal(gn, gf)
    # round trips: naive >> no_doorbell >= full (paper Table 1)
    rt = {m: res[m][1]["net"]["round_trips"] for m in res}
    assert rt["naive"] > rt["no_doorbell"] >= rt["full"]
    lat = {m: res[m][1]["net"]["latency_s"] for m in res}
    assert lat["naive"] > lat["full"]


def test_recall_monotone_in_b(sift_small):
    recs = []
    for b in (1, 2, 6):
        eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=32, b=b,
                                       ef=48, cache_frac=0.3, seed=3)).build(
            sift_small.data)
        _, g, _ = eng.search(sift_small.queries, k=10)
        recs.append(recall_at_k(g, sift_small.gt_ids[:, :10]))
    assert recs[0] <= recs[1] <= recs[2] + 1e-9
    assert recs[-1] >= 0.85


def test_cache_persists_across_batches(built_engine, sift_small):
    q = sift_small.queries
    _, _, st1 = built_engine.search(q, k=10)
    _, _, st2 = built_engine.search(q, k=10)  # identical batch
    assert st2["n_fetches"] < max(st1["n_fetches"], 1) or \
        st2["cache_hits"] > 0


def test_insert_then_searchable(sift_small):
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=16, b=2,
                                   ef=32, cache_frac=0.4, seed=3)).build(
        sift_small.data[:2000])
    rng = np.random.default_rng(5)
    new = sift_small.data[2000:2010] + 0.001
    gids = eng.insert(new)
    assert len(gids) == 10
    # querying exactly the inserted vectors must find them
    d, g, _ = eng.search(new, k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids)])
    assert found >= 0.9, (found, g[:3], gids[:3])


def test_insert_overflow_triggers_repack(sift_small):
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=8, b=2,
                                   ef=32, cache_frac=0.5, seed=3))
    eng.build(sift_small.data[:1000])
    ov = eng.store.spec.ov_cap
    # target one partition with > ov_cap inserts: forces >= 1 repack
    base = sift_small.data[42]
    new = base[None, :] + 0.0005 * np.random.default_rng(0).standard_normal(
        (ov + 3, eng.store.spec.dim)).astype(np.float32)
    gids = eng.insert(new)
    d, g, _ = eng.search(new[:8], k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids[:8])])
    assert found >= 0.8, found


def test_insert_right_after_repack_immediately_searchable(sift_small):
    """Regression (ROADMAP open item): the vector whose insert TRIGGERS
    a repack is re-inserted right after it — the old monolithic path
    wrote it to the host mirror only and left the device twin stale
    until the next repack/rebuild, so searching for it came back empty.
    Staged through the pool ``append`` verb (device + mirror twin) it
    must be exactly searchable immediately.

    The inserts target the SMALLEST partition so the repack *succeeds*
    (small + ov_cap fits np_max) — a failed repack falls back to a full
    rebuild, which always restaged the device and masked the bug."""
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=16, b=2,
                                   ef=32, cache_frac=0.5, seed=3))
    eng.build(sift_small.data[:1000])
    spec = eng.store.spec
    sizes = np.asarray(eng.store.n_base)
    pid = int(np.argmin(sizes))
    assert sizes[pid] + spec.ov_cap <= spec.np_max, "repack must fit"
    rep = sift_small.data[int(eng.meta.rep_ids[pid])]
    new = rep[None, :] + 0.0003 * np.random.default_rng(1).standard_normal(
        (spec.ov_cap + 1, spec.dim)).astype(np.float32)
    # the first ov_cap inserts fill the shared region; the last one
    # finds it full, repacks the group, and is re-inserted post-repack
    gids = eng.insert(new)
    d, g, _ = eng.search(new[-1:], k=3)
    assert int(gids[-1]) in g[0], (gids[-1], g[0])
    # scan mode is exact within the probed partition: the re-inserted
    # vector must be its own nearest neighbour at distance ~0
    assert d[0, 0] <= 1e-6, d[0]


def test_failed_repack_rebuild_keeps_gid_unique(sift_small):
    """Sibling regression: when the repack CANNOT fit (targeting the
    largest partition) the engine falls back to a full rebuild, which
    already folds the triggering vector into the rebuilt base — the old
    path then appended it to overflow anyway, so its gid appeared twice
    in the index and consumed two top-k slots."""
    eng = DHNSWEngine(EngineConfig(search_mode="scan", n_rep=16, b=2,
                                   ef=32, cache_frac=0.5, seed=3))
    eng.build(sift_small.data[:1000])
    spec = eng.store.spec
    pid = int(np.argmax(np.asarray(eng.store.n_base)))
    assert eng.store.n_base[pid] + spec.ov_cap > spec.np_max, \
        "repack must NOT fit for this scenario"
    rep = sift_small.data[int(eng.meta.rep_ids[pid])]
    new = rep[None, :] + 0.0003 * np.random.default_rng(2).standard_normal(
        (spec.ov_cap + 1, spec.dim)).astype(np.float32)
    gids = eng.insert(new)
    d, g, _ = eng.search(new[-1:], k=5)
    assert int(gids[-1]) in g[0]
    assert d[0, 0] <= 1e-6, d[0]
    live = g[0][g[0] >= 0]
    assert len(np.unique(live)) == len(live), g[0]   # no duplicate gid


def test_round_trips_match_paper_shape(sift_small):
    """Naive rtpq ~= b (paper: 3.547 at b~4); full << 1 with batching."""
    common = dict(search_mode="scan", n_rep=32, ef=48, cache_frac=0.25,
                  seed=3, b=4)
    naive = DHNSWEngine(EngineConfig(mode="naive", **common)).build(
        sift_small.data)
    full = DHNSWEngine(EngineConfig(mode="full", doorbell=8, **common)).build(
        sift_small.data)
    _, _, stn = naive.search(sift_small.queries, k=10)
    _, _, stf = full.search(sift_small.queries, k=10)
    assert 3.0 <= stn["round_trips_per_query"] <= 4.01
    assert stf["round_trips_per_query"] < 0.25
