"""Query-aware batched loading — §3.3 invariants (+hypothesis).

The property tests need ``hypothesis``; when it isn't installed they
skip cleanly (``pytest.importorskip``) and the deterministic invariant
tests still run.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI fast tier / bare containers
    HAVE_HYPOTHESIS = False

from repro.core.scheduler import (LRUCacheState, TieredCacheState,
                                  doorbell_chunks_sharded, naive_plan,
                                  plan_batch)


def _random_topb(rng, B, b, P):
    out = np.zeros((B, b), np.int64)
    for q in range(B):
        out[q] = rng.choice(P, size=b, replace=False)
    return out


def test_each_partition_loaded_at_most_once():
    """The paper's headline invariant: one load per partition per batch."""
    rng = np.random.default_rng(0)
    topb = _random_topb(rng, 50, 3, 40)
    plan = plan_batch(topb, LRUCacheState(8), doorbell=4)
    loads = plan.loads_per_partition()
    assert all(v == 1 for v in loads.values()), loads
    assert plan.n_fetches == len(plan.unique_pids)


def test_resident_partitions_not_fetched():
    rng = np.random.default_rng(1)
    cache = LRUCacheState(16)
    topb = _random_topb(rng, 30, 2, 20)
    p1 = plan_batch(topb, cache, doorbell=4)
    # same batch again: everything needed should be cache-hit or refetch
    p2 = plan_batch(topb, cache, doorbell=4)
    assert p2.n_fetches < p1.n_fetches  # warm cache saved transfers
    assert p2.n_cache_hits > 0


def test_every_query_served_for_every_needed_partition():
    rng = np.random.default_rng(2)
    topb = _random_topb(rng, 25, 3, 30)
    plan = plan_batch(topb, LRUCacheState(6), doorbell=4)
    served = set()
    for rnd in plan.rounds:
        for q, p in rnd.serve_pairs:
            served.add((int(q), int(p)))
    want = {(q, int(p)) for q in range(25) for p in topb[q]}
    assert served == want


def test_rounds_respect_cache_capacity():
    rng = np.random.default_rng(3)
    cap = 5
    topb = _random_topb(rng, 40, 4, 60)
    plan = plan_batch(topb, LRUCacheState(cap), doorbell=3)
    for rnd in plan.rounds:
        assert len(rnd.fetch_pids) <= cap
        assert len(set(rnd.fetch_slots.tolist())) == len(rnd.fetch_pids)
        for db in rnd.doorbells:
            assert len(db) <= 3


def test_sharded_doorbell_chunks_never_mix_destinations():
    """Descriptor batches are formed per destination shard: every chunk
    is single-owner, <= doorbell long, and the union is the input."""
    items = np.arange(17, dtype=np.int64)
    owner = lambda p: p % 3                               # noqa: E731
    chunks = doorbell_chunks_sharded(items, 4, owner)
    seen = []
    for db in chunks:
        assert len(db) <= 4
        assert len({owner(int(x)) for x in db}) == 1
        seen.extend(int(x) for x in db)
    assert sorted(seen) == items.tolist()
    # owner_of=None degrades to plain sequential chunking
    plain = doorbell_chunks_sharded(items, 4, None)
    assert [len(c) for c in plain] == [4, 4, 4, 4, 1]
    # plan_batch threads the owner through to each round's doorbells
    rng = np.random.default_rng(9)
    plan = plan_batch(_random_topb(rng, 30, 4, 50), LRUCacheState(6),
                      doorbell=4, owner_of=owner)
    for rnd in plan.rounds:
        for db in rnd.doorbells:
            assert len({owner(int(x)) for x in db}) == 1


def test_naive_plan_counts_all_pairs():
    rng = np.random.default_rng(4)
    topb = _random_topb(rng, 10, 3, 50)
    raw = naive_plan(topb)
    assert len(raw) == 30  # no dedup across queries (only within)


def test_serve_ranks_unique_per_query_per_round():
    """The merge lanes the device scatter relies on: within a round, a
    query's pairs occupy ranks 0..m-1 exactly once each."""
    rng = np.random.default_rng(6)
    topb = _random_topb(rng, 30, 4, 25)
    plan = plan_batch(topb, LRUCacheState(6), doorbell=4)
    for rnd in plan.rounds:
        assert len(rnd.pair_ranks) == len(rnd.serve_pairs)
        per_q = {}
        for (q, _), r in zip(rnd.serve_pairs, rnd.pair_ranks):
            per_q.setdefault(int(q), []).append(int(r))
        for ranks in per_q.values():
            assert sorted(ranks) == list(range(len(ranks)))
        assert rnd.n_lanes == max((len(v) for v in per_q.values()),
                                  default=1)
        # padded batch-major view round-trips
        n = len(rnd.serve_pairs)
        qi, pids, slots, ranks, valid = rnd.serve_tensors(n + 3, 30)
        assert valid[:n].all() and not valid[n:].any()
        assert (qi[n:] == 30).all()
        assert np.array_equal(qi[:n], rnd.serve_pairs[:, 0])
        assert np.array_equal(pids[:n], rnd.serve_pairs[:, 1])
        assert np.array_equal(slots[:n], rnd.pair_slots)


if HAVE_HYPOTHESIS:
    @given(B=st.integers(1, 40), b=st.integers(1, 5), P=st.integers(5, 64),
           cap=st.integers(2, 20), doorbell=st.integers(1, 8),
           seed=st.integers(0, 100))
    @settings(max_examples=80, deadline=None)
    def test_plan_invariants_property(B, b, P, cap, doorbell, seed):
        rng = np.random.default_rng(seed)
        b = min(b, P)
        topb = _random_topb(rng, B, b, P)
        cache = LRUCacheState(cap)
        plan = plan_batch(topb, cache, doorbell=doorbell)
        # 1. at most one load per partition
        assert all(v == 1 for v in plan.loads_per_partition().values())
        # 2. slots valid and unique within every round
        for rnd in plan.rounds:
            assert len(rnd.fetch_pids) <= cap
            assert all(0 <= s < cap for s in rnd.fetch_slots)
            assert len(set(rnd.fetch_slots.tolist())) == len(rnd.fetch_slots)
            # pairs of a round reference partitions fetched-or-resident
            # with the recorded slots
            for (q, p), s in zip(rnd.serve_pairs, rnd.pair_slots):
                assert 0 <= s < cap
        # 3. every (query, needed-partition) pair served exactly once
        served = [(int(q), int(p)) for rnd in plan.rounds
                  for q, p in rnd.serve_pairs]
        want = sorted({(q, int(p)) for q in range(B) for p in topb[q]})
        assert sorted(served) == want
        # 4. cache never over-full after the batch
        assert len(cache.resident()) <= cap
else:
    def test_plan_invariants_property():
        pytest.importorskip("hypothesis")


def test_lru_eviction_order():
    c = LRUCacheState(2)
    c.admit(1)
    c.admit(2)
    c.touch(1)            # 2 is now LRU
    slot, ev = c.admit(3)
    assert ev == 2
    assert c.resident() == {1, 3}


def test_lru_capacity_one_thrash():
    """cap=1 is pure thrash: every distinct admit evicts the previous
    pid into the same slot, and planning still covers every pair."""
    c = LRUCacheState(1)
    s0, e0 = c.admit(7)
    assert (s0, e0) == (0, -1)
    s1, e1 = c.admit(9)
    assert (s1, e1) == (0, 7)
    s2, e2 = c.admit(9)          # re-admit resident: no eviction
    assert (s2, e2) == (0, -1)
    assert c.resident() == {9}

    rng = np.random.default_rng(9)
    topb = _random_topb(rng, 12, 3, 10)
    plan = plan_batch(topb, LRUCacheState(1), doorbell=2)
    served = {(int(q), int(p)) for r in plan.rounds for q, p in r.serve_pairs}
    assert served == {(q, int(p)) for q in range(12) for p in topb[q]}
    for rnd in plan.rounds:
        assert len(rnd.fetch_pids) <= 1
        assert all(s == 0 for s in rnd.fetch_slots)


def test_lru_drop_then_readmit():
    """drop() (the engine's insert invalidation) frees the slot and the
    next plan refetches the pid into a valid slot."""
    c = LRUCacheState(2)
    c.admit(4)
    c.admit(5)
    c.drop(4)
    assert c.resident() == {5}
    assert 4 not in c._recency
    c.drop(4)                    # idempotent on non-resident pids
    slot, ev = c.admit(4)        # re-admit fills the freed slot
    assert ev == -1 and c.resident() == {4, 5}
    plan = plan_batch(np.array([[4], [5]]), c, doorbell=1)
    assert plan.n_fetches == 0   # both resident again -> pure hits


def test_engine_readmits_after_invalidate_pid(built_engine, sift_small):
    """After an insert invalidates a cached partition, the next search
    must refetch it (no stale serve) and return identical results."""
    q = sift_small.queries[:8]
    d0, g0, _ = built_engine.search(q, k=10)
    _, _, warm = built_engine.search(q, k=10)
    resident = sorted(built_engine.cache.resident())
    assert resident, "warm cache expected"
    built_engine._invalidate_pid(resident[0])
    _, _, st = built_engine.search(q, k=10)
    assert st["n_fetches"] >= 1          # the dropped pid was refetched
    d1, g1, _ = built_engine.search(q, k=10)
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)


# ------------------------------------------------------------ tiered cache

def test_tiered_cache_invalidate_drops_both_tiers():
    t = TieredCacheState(4, 2)
    t.quant.admit(3)
    t.exact.admit(3)
    t.note_rerank_miss(3, 100)
    t.invalidate(3)
    assert 3 not in t.quant.resident()
    assert 3 not in t.exact.resident()
    assert t._miss_rows.get(3) is None


def test_tiered_cache_cost_based_admission():
    t = TieredCacheState(4, 1)
    row_b, span_b = 512, 10 * 512
    t.note_rerank_miss(1, 4)
    assert not t.should_admit(1, row_b, span_b)   # 4 rows < 10-row span
    t.note_rerank_miss(1, 7)
    assert t.should_admit(1, row_b, span_b)       # cumulative 11 >= 10
    t.admit_exact(1)
    assert not t.should_admit(1, row_b, span_b)   # resident: never again
    # evicting 1 decays (not erases) its counter
    t._miss_rows[1] = 6.0            # stale traffic from while resident
    t.note_rerank_miss(2, 20)
    _, ev = t.admit_exact(2)
    assert ev == 1
    assert t._miss_rows[1] == 6.0 * TieredCacheState.DECAY


# ------------------------------------------- merge_ranked vs numpy oracle

def _numpy_fold_merge(run_d, run_g, qi, d, g):
    """The pre-vectorization semantics: fold each pair into its query's
    running top-k through a sequential stable merge."""
    want_d, want_g = run_d.copy(), run_g.copy()
    k = run_d.shape[1]
    for j in range(len(qi)):
        q = int(qi[j])
        md = np.concatenate([want_d[q], d[j]])
        mg = np.concatenate([want_g[q], g[j]])
        order = np.argsort(md, kind="stable")[:k]
        want_d[q], want_g[q] = md[order], mg[order]
    return want_d, want_g


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000), B=st.just(9), k=st.just(8),
           n=st.just(21))
    @settings(max_examples=40, deadline=None)
    def test_merge_ranked_matches_numpy_fold(seed, B, k, n):
        """Property: the fused device scatter-merge == the numpy
        sequential fold, ties included (fixed shapes -> one XLA compile
        across all examples)."""
        import jax.numpy as jnp

        from repro.core.device_store import merge_ranked
        from repro.core.scheduler import _pair_ranks

        rng = np.random.default_rng(seed)
        run_d = np.sort(rng.standard_normal((B, k)).astype(np.float32) ** 2,
                        axis=1)
        run_g = rng.integers(0, 1000, (B, k)).astype(np.int32)
        qi = rng.integers(0, B, n)
        d = np.sort(rng.standard_normal((n, k)).astype(np.float32) ** 2,
                    axis=1)
        if n and rng.random() < 0.5:     # force exact cross-list ties
            d[0] = run_d[int(qi[0])]
        g = rng.integers(1000, 2000, (n, k)).astype(np.int32)

        want_d, want_g = _numpy_fold_merge(run_d, run_g, qi, d, g)
        ranks = _pair_ranks(np.stack([qi, np.zeros(n, np.int64)], axis=1))
        got_d, got_g = merge_ranked(
            jnp.asarray(run_d), jnp.asarray(run_g),
            jnp.asarray(qi, jnp.int32), jnp.asarray(ranks, jnp.int32),
            jnp.asarray(d), jnp.asarray(g),
            n_lanes=int(ranks.max()) + 1 if n else 1)
        assert np.array_equal(np.asarray(got_d), want_d)
        assert np.array_equal(np.asarray(got_g), want_g)
else:
    def test_merge_ranked_matches_numpy_fold():
        pytest.importorskip("hypothesis")
