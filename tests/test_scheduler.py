"""Query-aware batched loading — §3.3 invariants (+hypothesis).

The property tests need ``hypothesis``; when it isn't installed they
skip cleanly (``pytest.importorskip``) and the deterministic invariant
tests still run.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI fast tier / bare containers
    HAVE_HYPOTHESIS = False

from repro.core.scheduler import LRUCacheState, naive_plan, plan_batch


def _random_topb(rng, B, b, P):
    out = np.zeros((B, b), np.int64)
    for q in range(B):
        out[q] = rng.choice(P, size=b, replace=False)
    return out


def test_each_partition_loaded_at_most_once():
    """The paper's headline invariant: one load per partition per batch."""
    rng = np.random.default_rng(0)
    topb = _random_topb(rng, 50, 3, 40)
    plan = plan_batch(topb, LRUCacheState(8), doorbell=4)
    loads = plan.loads_per_partition()
    assert all(v == 1 for v in loads.values()), loads
    assert plan.n_fetches == len(plan.unique_pids)


def test_resident_partitions_not_fetched():
    rng = np.random.default_rng(1)
    cache = LRUCacheState(16)
    topb = _random_topb(rng, 30, 2, 20)
    p1 = plan_batch(topb, cache, doorbell=4)
    # same batch again: everything needed should be cache-hit or refetch
    p2 = plan_batch(topb, cache, doorbell=4)
    assert p2.n_fetches < p1.n_fetches  # warm cache saved transfers
    assert p2.n_cache_hits > 0


def test_every_query_served_for_every_needed_partition():
    rng = np.random.default_rng(2)
    topb = _random_topb(rng, 25, 3, 30)
    plan = plan_batch(topb, LRUCacheState(6), doorbell=4)
    served = set()
    for rnd in plan.rounds:
        for q, p in rnd.serve_pairs:
            served.add((int(q), int(p)))
    want = {(q, int(p)) for q in range(25) for p in topb[q]}
    assert served == want


def test_rounds_respect_cache_capacity():
    rng = np.random.default_rng(3)
    cap = 5
    topb = _random_topb(rng, 40, 4, 60)
    plan = plan_batch(topb, LRUCacheState(cap), doorbell=3)
    for rnd in plan.rounds:
        assert len(rnd.fetch_pids) <= cap
        assert len(set(rnd.fetch_slots.tolist())) == len(rnd.fetch_pids)
        for db in rnd.doorbells:
            assert len(db) <= 3


def test_naive_plan_counts_all_pairs():
    rng = np.random.default_rng(4)
    topb = _random_topb(rng, 10, 3, 50)
    raw = naive_plan(topb)
    assert len(raw) == 30  # no dedup across queries (only within)


def test_serve_ranks_unique_per_query_per_round():
    """The merge lanes the device scatter relies on: within a round, a
    query's pairs occupy ranks 0..m-1 exactly once each."""
    rng = np.random.default_rng(6)
    topb = _random_topb(rng, 30, 4, 25)
    plan = plan_batch(topb, LRUCacheState(6), doorbell=4)
    for rnd in plan.rounds:
        assert len(rnd.pair_ranks) == len(rnd.serve_pairs)
        per_q = {}
        for (q, _), r in zip(rnd.serve_pairs, rnd.pair_ranks):
            per_q.setdefault(int(q), []).append(int(r))
        for ranks in per_q.values():
            assert sorted(ranks) == list(range(len(ranks)))
        assert rnd.n_lanes == max((len(v) for v in per_q.values()),
                                  default=1)
        # padded batch-major view round-trips
        n = len(rnd.serve_pairs)
        qi, pids, slots, ranks, valid = rnd.serve_tensors(n + 3, 30)
        assert valid[:n].all() and not valid[n:].any()
        assert (qi[n:] == 30).all()
        assert np.array_equal(qi[:n], rnd.serve_pairs[:, 0])
        assert np.array_equal(pids[:n], rnd.serve_pairs[:, 1])
        assert np.array_equal(slots[:n], rnd.pair_slots)


if HAVE_HYPOTHESIS:
    @given(B=st.integers(1, 40), b=st.integers(1, 5), P=st.integers(5, 64),
           cap=st.integers(2, 20), doorbell=st.integers(1, 8),
           seed=st.integers(0, 100))
    @settings(max_examples=80, deadline=None)
    def test_plan_invariants_property(B, b, P, cap, doorbell, seed):
        rng = np.random.default_rng(seed)
        b = min(b, P)
        topb = _random_topb(rng, B, b, P)
        cache = LRUCacheState(cap)
        plan = plan_batch(topb, cache, doorbell=doorbell)
        # 1. at most one load per partition
        assert all(v == 1 for v in plan.loads_per_partition().values())
        # 2. slots valid and unique within every round
        for rnd in plan.rounds:
            assert len(rnd.fetch_pids) <= cap
            assert all(0 <= s < cap for s in rnd.fetch_slots)
            assert len(set(rnd.fetch_slots.tolist())) == len(rnd.fetch_slots)
            # pairs of a round reference partitions fetched-or-resident
            # with the recorded slots
            for (q, p), s in zip(rnd.serve_pairs, rnd.pair_slots):
                assert 0 <= s < cap
        # 3. every (query, needed-partition) pair served exactly once
        served = [(int(q), int(p)) for rnd in plan.rounds
                  for q, p in rnd.serve_pairs]
        want = sorted({(q, int(p)) for q in range(B) for p in topb[q]})
        assert sorted(served) == want
        # 4. cache never over-full after the batch
        assert len(cache.resident()) <= cap
else:
    def test_plan_invariants_property():
        pytest.importorskip("hypothesis")


def test_lru_eviction_order():
    c = LRUCacheState(2)
    c.admit(1)
    c.admit(2)
    c.touch(1)            # 2 is now LRU
    slot, ev = c.admit(3)
    assert ev == 2
    assert c.resident() == {1, 3}
