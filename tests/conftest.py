"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device
(the dry-run alone fakes 512); multi-device tests spawn subprocesses."""
from __future__ import annotations

import numpy as np
import pytest


@pytest.fixture(scope="session")
def sift_small():
    from repro.data.synthetic import sift_like
    return sift_like(n=4000, n_queries=64, seed=7)


@pytest.fixture(scope="session")
def gist_small():
    from repro.data.synthetic import gist_like
    return gist_like(n=1500, n_queries=32, seed=7)


@pytest.fixture(scope="session")
def built_engine(sift_small):
    """One shared full-mode engine (graph search) over sift_small."""
    from repro.core import DHNSWEngine, EngineConfig
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="graph",
                                   n_rep=32, b=4, ef=48, cache_frac=0.25,
                                   seed=3))
    return eng.build(sift_small.data)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
