"""RAG serving engine: d-HNSW retrieval tier + LM prefill/decode."""
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.core import DHNSWEngine, EngineConfig
from repro.serve.engine import RagServeEngine, synthetic_doc_store


@pytest.fixture(scope="module")
def rag():
    cfg = smoke_config("phi3-mini-3.8b")
    docs = synthetic_doc_store(300, 32, doc_len=4, vocab=cfg.vocab_size)
    ret = DHNSWEngine(EngineConfig(n_rep=12, b=2, ef=16,
                                   cache_frac=0.4)).build(docs.embeddings)
    return RagServeEngine(cfg, ret, docs, max_new_tokens=4), docs


def test_serve_shapes_and_finiteness(rag):
    eng, docs = rag
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, eng.cfg.vocab_size, (3, 8)).astype(np.int32)
    out, stats = eng.serve(prompts)
    assert out.shape == (3, 4)
    assert (out >= 0).all() and (out < eng.cfg.vocab_size).all()
    assert stats.retrieval["net"]["round_trips"] >= 1


def test_serve_retrieval_is_batched(rag):
    """Two identical prompts must not double-fetch partitions."""
    eng, docs = rag
    rng = np.random.default_rng(1)
    p = rng.integers(0, eng.cfg.vocab_size, (1, 8)).astype(np.int32)
    prompts = np.concatenate([p, p, p, p])
    out, stats = eng.serve(prompts)
    r = stats.retrieval
    # unique fetches <= distinct partitions needed by ONE prompt * b
    assert r["n_fetches"] <= eng.retriever.cfg.b
    assert np.array_equal(out[0], out[1])


def test_deterministic_generation(rag):
    eng, docs = rag
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, eng.cfg.vocab_size, (2, 6)).astype(np.int32)
    out1, _ = eng.serve(prompts)
    out2, _ = eng.serve(prompts)
    assert np.array_equal(out1, out2)
