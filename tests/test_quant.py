"""Quantized resident tier: codec bounds, staged-search acceptance
(recall + bytes), scheme composition, insert coherence, serve routing,
and the quant="none" regression guard."""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G
from repro.quant.codec import dequantize_groups, quantize_groups

CFG = dict(mode="full", search_mode="scan", n_rep=32, b=6, ef=48,
           cache_frac=0.25, doorbell=16, fabric=RDMA_100G, seed=3)


@pytest.fixture(scope="module")
def qds():
    from repro.data.synthetic import sift_like
    return sift_like(n=3000, n_queries=256, seed=7)


@pytest.fixture(scope="module")
def eng_none(qds):
    return DHNSWEngine(EngineConfig(**CFG)).build(qds.data)


@pytest.fixture(scope="module")
def eng_int8(qds):
    return DHNSWEngine(EngineConfig(quant="int8", **CFG)).build(qds.data)


# ------------------------------------------------------------------ codec

def test_codec_roundtrip_error_bound(rng):
    x = rng.standard_normal((100, 128)).astype(np.float32)
    codes, scales = quantize_groups(x, 32)
    xr = dequantize_groups(codes, scales, 32)
    # symmetric int8: error <= scale/2 = absmax/254 per group
    gmax = np.abs(x.reshape(100, 4, 32)).max(-1, keepdims=True)
    bound = np.broadcast_to(gmax / 254 + 1e-7, (100, 4, 32)).reshape(100, 128)
    assert (np.abs(xr - x) <= bound).all()
    assert codes.dtype == np.int8


def test_codec_zero_groups_safe():
    x = np.zeros((4, 64), np.float32)
    codes, scales = quantize_groups(x, 16)
    assert (codes == 0).all()
    assert np.isfinite(scales).all()
    assert (dequantize_groups(codes, scales, 16) == 0).all()


def test_codec_group_must_divide_dim():
    with pytest.raises(AssertionError):
        quantize_groups(np.zeros((2, 100), np.float32), 32)


# ------------------------------------------------- acceptance criteria

def test_int8_recall_and_bytes_vs_none(qds, eng_none, eng_int8):
    """The ISSUE's bar: recall@10 >= 0.85 AND >= 4x fewer fetched bytes
    than quant=none at the same cache byte budget, over a multi-batch
    workload (tier reuse included, cold start included)."""
    batches = [qds.queries[i * 64:(i + 1) * 64] for i in range(4)]
    totals = {}
    recalls = {}
    for name, eng in (("none", eng_none), ("int8", eng_int8)):
        tot, recs = 0.0, []
        for i, qb in enumerate(batches):
            _, g, st = eng.search(qb, k=10)
            tot += st["net"]["bytes"]
            recs.append(recall_at_k(g, qds.gt_ids[i * 64:(i + 1) * 64, :10]))
        totals[name], recalls[name] = tot, float(np.mean(recs))
    assert recalls["int8"] >= 0.85, recalls
    assert totals["none"] >= 4.0 * totals["int8"], totals
    # staged search must not cost recall vs the exact scan at the same b
    assert recalls["int8"] >= recalls["none"] - 0.02, recalls


def test_bytes_saved_counted(qds, eng_int8):
    _, _, st = eng_int8.search(qds.queries[:32], k=10)
    assert st["net"]["bytes_saved"] > 0
    assert st["quant"] == "int8"
    assert st["rerank_m"] >= 10


# --------------------------------------------------- scheme composition

def test_schemes_compose_with_quant(qds):
    """naive / no_doorbell / full with int8 differ ONLY in transfer
    strategy: identical ids, paper-shaped round-trip ordering."""
    common = dict(search_mode="scan", n_rep=12, b=3, ef=48,
                  cache_frac=0.25, seed=3, fabric=RDMA_100G, quant="int8")
    res = {}
    for mode in ("naive", "no_doorbell", "full"):
        eng = DHNSWEngine(EngineConfig(mode=mode, **common)).build(
            qds.data[:1500])
        _, g, st = eng.search(qds.queries[:32], k=10)
        res[mode] = (g, st)
    assert np.array_equal(res["naive"][0], res["no_doorbell"][0])
    assert np.array_equal(res["naive"][0], res["full"][0])
    rt = {m: res[m][1]["net"]["round_trips"] for m in res}
    assert rt["naive"] > rt["no_doorbell"] >= rt["full"]


def test_graph_mode_composes_with_quant(qds):
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="graph",
                                   n_rep=12, b=4, ef=48, cache_frac=0.3,
                                   seed=3, quant="int8")).build(
        qds.data[:1500])
    _, g, st = eng.search(qds.queries[:32], k=10)
    gt_d, gt_i = _brute(qds.data[:1500], qds.queries[:32], 10)
    assert recall_at_k(g, gt_i) >= 0.6   # graph walk at small b
    assert st["net"]["bytes_saved"] > 0


def _brute(data, queries, k):
    from repro.core.hnsw import brute_force_knn
    return brute_force_knn(data, queries, k)


# ------------------------------------------------------ none regression

def test_quant_none_unaffected(qds, eng_none, eng_int8):
    """Regression guard: the default path must be bit-identical whether
    or not quantized engines exist beside it, and must never emit quant
    stats keys."""
    d0, g0, st0 = eng_none.search(qds.queries[:16], k=10)
    eng_int8.search(qds.queries[:16], k=10)   # interleave a staged search
    d1, g1, st1 = eng_none.search(qds.queries[:16], k=10)
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)
    for st in (st0, st1):
        assert "quant" not in st and "rerank_m" not in st
        assert st["net"]["bytes_saved"] == 0.0
    assert eng_none.tiers is None
    assert eng_none.store.qvec_buf is None


def test_exact_tier_admission_after_reuse(qds):
    """Hot re-rank partitions get promoted to the exact tier once their
    cumulative missed rows outweigh one span fetch — and their rows stop
    being charged."""
    eng = DHNSWEngine(EngineConfig(quant="int8", **CFG)).build(qds.data)
    qb = qds.queries[:64]
    threshold = eng.store.spec.partition_bytes() // eng.store.spec.row_bytes()
    admitted = hit_rows = 0
    # same batch over and over -> the hottest re-rank partition crosses
    # the cost threshold (~`threshold` missed rows) and gets promoted
    for _ in range(12):
        _, _, st = eng.search(qb, k=10)
        admitted += st["exact_admitted"]
        hit_rows += st["rerank_hit_rows"]
        if admitted and hit_rows:
            break
    assert admitted >= 1
    assert hit_rows > 0
    assert len(eng.tiers.exact.resident()) >= 1


# ----------------------------------------------------------- insert

def test_insert_searchable_with_quant(qds):
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="scan",
                                   n_rep=16, b=2, ef=32, cache_frac=0.4,
                                   seed=3, quant="int8")).build(
        qds.data[:2000])
    new = qds.data[2000:2010] + 0.001
    gids = eng.insert(new)
    d, g, _ = eng.search(new, k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids)])
    assert found >= 0.9, (found, g[:3], gids[:3])


def test_insert_overflow_repack_with_quant(qds):
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="scan",
                                   n_rep=8, b=2, ef=32, cache_frac=0.5,
                                   seed=3, quant="int8")).build(
        qds.data[:1000])
    ov = eng.store.spec.ov_cap
    base = qds.data[42]
    new = base[None, :] + 0.0005 * np.random.default_rng(0).standard_normal(
        (ov + 3, eng.store.spec.dim)).astype(np.float32)
    gids = eng.insert(new)
    d, g, _ = eng.search(new[:8], k=3)
    found = np.mean([gid in g[i] for i, gid in enumerate(gids[:8])])
    assert found >= 0.8, found
    # the quantized mirror tracked the repack: codes decode near vec_buf
    store = eng.store
    xr = dequantize_groups(store.qvec_buf, store.qscale_buf,
                           store.spec.quant_group)
    assert np.abs(xr - store.vec_buf).max() <= (
        np.abs(store.vec_buf).max() / 200)


# ----------------------------------------------- flat kernel route

def test_flat_kernel_route_dense_resident(qds):
    """With a dense-resident quantized tier (capacity >= n_partitions)
    and scan-mode stage 1, quant_kernel="auto" routes through ONE flat
    ``quant_topk`` scan: recall must not regress vs the per-pair jnp
    path, warm stage-1 must be wire-free, and the Pallas kernel and the
    jnp oracle route must agree exactly."""
    common = dict(mode="full", search_mode="scan", n_rep=16, b=3, ef=32,
                  cache_frac=0.6, seed=3, quant="int8")
    jnp_eng = DHNSWEngine(EngineConfig(**common)).build(qds.data)
    flat = DHNSWEngine(EngineConfig(quant_kernel="auto", **common)).build(
        qds.data)
    assert flat.client._flat_kernel_active()
    q = qds.queries[:64]
    _, gj, _ = jnp_eng.search(q, k=10)
    df, gf, stf = flat.search(q, k=10)
    assert stf["quant_kernel"] == "flat"
    assert stf["flat_rows"] == len(qds.data)
    rj = recall_at_k(gj, qds.gt_ids[:64, :10])
    rf = recall_at_k(gf, qds.gt_ids[:64, :10])
    assert rf >= rj - 1e-9, (rf, rj)   # flat scans every resident row
    # warm: the whole int8 DB is resident -> stage 1 moves zero bytes;
    # only stage-2 row fetches remain on the wire
    _, _, warm = flat.search(q, k=10)
    row_b = flat.store.spec.row_bytes()
    assert warm["net"]["bytes"] <= warm["rerank_rows"] * row_b + 1e-9
    # fallback guard: a sparse tier must keep the per-pair path
    sparse = DHNSWEngine(EngineConfig(mode="full", search_mode="scan",
                                      n_rep=16, b=3, ef=32, cache_frac=0.1,
                                      seed=3, quant="int8",
                                      quant_kernel="auto")).build(qds.data)
    assert not sparse.client._flat_kernel_active()
    _, _, sts = sparse.search(q[:8], k=10)
    assert "quant_kernel" not in sts


def test_auto_kernel_picks_ref_impl_on_cpu(qds):
    """quant_kernel="auto" must select the jnp reference stage-1 on the
    CPU backend (where Pallas would run interpreted, ~8x slower) and the
    Pallas kernel on real accelerators; the choice is reported in
    ``stats["stage1_impl"]``, and an explicit "ref" request always gets
    the ref path."""
    import jax

    from repro.kernels.quant_topk.ops import auto_use_ref
    on_cpu = jax.default_backend() == "cpu"
    assert auto_use_ref() == on_cpu
    common = dict(mode="full", search_mode="scan", n_rep=16, b=3, ef=32,
                  cache_frac=0.6, seed=3, quant="int8")
    auto = DHNSWEngine(EngineConfig(quant_kernel="auto", **common)).build(
        qds.data)
    assert auto.client._flat_kernel_active()
    _, _, st = auto.search(qds.queries[:8], k=10)
    assert st["stage1_impl"] == ("ref" if on_cpu else "pallas")
    ref = DHNSWEngine(EngineConfig(quant_kernel="ref", **common)).build(
        qds.data)
    _, _, st_ref = ref.search(qds.queries[:8], k=10)
    assert st_ref["stage1_impl"] == "ref"


def test_flat_kernel_insert_stays_coherent(qds):
    """Appends keep the dense-resident flat view coherent without a
    resync: the inserted vector is immediately a stage-1 candidate."""
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="scan",
                                   n_rep=16, b=3, ef=32, cache_frac=0.6,
                                   seed=3, quant="int8",
                                   quant_kernel="auto")).build(
        qds.data[:2000])
    eng.search(qds.queries[:8], k=10)         # cold sync
    new = qds.queries[:4] + 0.001
    gids = eng.insert(new)
    d, g, st = eng.search(new, k=3)
    assert st.get("quant_kernel") == "flat"
    found = np.mean([gid in g[i] for i, gid in enumerate(gids)])
    assert found == 1.0, (found, g, gids)


# ------------------------------------------------------------ serving

def test_serve_routes_through_staged_path(qds, eng_int8):
    """Fused batches from the micro-batcher hit the SAME staged path:
    results match per-request searches on a fresh engine, and the server
    surfaces the NetLedger bytes breakdown."""
    from repro.serve.batcher import BatchPolicy
    from repro.serve.server import SearchServer

    queries = qds.queries[:8]
    with SearchServer(eng_int8, BatchPolicy(max_batch=64,
                                            max_wait_s=0.05)) as srv:
        futs = [srv.search_async(queries[i], k=10) for i in range(8)]
        results = [f.result(timeout=120) for f in futs]
        snap = srv.stats()
    fresh = DHNSWEngine(EngineConfig(quant="int8", **CFG)).build(qds.data)
    for i, (d, g, st) in enumerate(results):
        df, gf, _ = fresh.search(queries[i:i + 1], k=10)
        assert np.array_equal(g, gf), i
        assert np.allclose(d, df), i
        assert st["quant"] == "int8"
    assert snap["net"]["bytes_fetched"] >= 0
    assert snap["net"]["bytes_saved"] > 0
