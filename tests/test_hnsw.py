"""Host HNSW: recall vs brute force, bulk L0 build, graph invariants."""
import numpy as np
import pytest

from repro.core.hnsw import (HNSW, HNSWParams, brute_force_knn,
                             bulk_l0_graph, recall_at_k)

# long-running tier: excluded from CI fast job (-m 'not slow')
pytestmark = pytest.mark.slow


def test_brute_force_is_exact(rng):
    data = rng.standard_normal((500, 16)).astype(np.float32)
    q = rng.standard_normal((7, 16)).astype(np.float32)
    d, i = brute_force_knn(data, q, 5)
    # exhaustively check one query
    full = np.sum((data - q[0]) ** 2, axis=1)
    assert set(i[0].tolist()) == set(np.argsort(full)[:5].tolist())
    assert np.all(np.diff(d, axis=1) >= -1e-5)  # sorted ascending


def test_hnsw_recall_beats_random(rng):
    data = rng.standard_normal((2000, 32)).astype(np.float32)
    queries = data[:50] + 0.01 * rng.standard_normal((50, 32)).astype(np.float32)
    _, gt = brute_force_knn(data, queries, 10)
    h = HNSW(32, HNSWParams(M=8, M0=16, ef_construction=64)).build(data)
    pred = np.array([[i for _, i in h.search(q, 10, ef=64)] for q in queries])
    rec = recall_at_k(pred, gt)
    assert rec >= 0.9, rec


def test_hnsw_recall_monotone_in_ef(rng):
    data = rng.standard_normal((1500, 24)).astype(np.float32)
    queries = data[:40] + 0.01 * rng.standard_normal((40, 24)).astype(np.float32)
    _, gt = brute_force_knn(data, queries, 10)
    h = HNSW(24, HNSWParams(M=8, M0=16, ef_construction=48)).build(data)
    recs = []
    for ef in (10, 32, 96):
        pred = np.array([[i for _, i in h.search(q, 10, ef=ef)]
                         for q in queries])
        recs.append(recall_at_k(pred, gt))
    assert recs[-1] >= recs[0] - 0.02, recs  # allow tiny noise
    assert recs[-1] >= 0.85


def test_export_shapes(rng):
    data = rng.standard_normal((300, 8)).astype(np.float32)
    h = HNSW(8, HNSWParams(M=4, M0=8)).build(data)
    g = h.export()
    assert g.vectors.shape == (300, 8)
    assert g.adjacency.shape[1] == 300 and g.adjacency.shape[2] == 8
    assert g.adjacency.min() >= -1 and g.adjacency.max() < 300
    # every live node has at least one neighbor at L0
    deg = (g.adjacency[0] >= 0).sum(1)
    assert (deg[1:] > 0).all()


def test_bulk_l0_graph_properties(rng):
    v = rng.standard_normal((400, 16)).astype(np.float32)
    adj = bulk_l0_graph(v, 8)
    assert adj.shape == (400, 8)
    assert adj.max() < 400
    # no self-edges, padded with -1 only at the tail of each row
    for i in range(0, 400, 37):
        row = adj[i]
        live = row[row >= 0]
        assert i not in live
        assert len(set(live.tolist())) == len(live)


def test_bulk_graph_greedy_search_recall(rng):
    """Beam search over the bulk graph reaches true neighbors."""
    import jax.numpy as jnp
    from repro.core.search import batched_beam_search
    v = rng.standard_normal((800, 16)).astype(np.float32)
    adj = bulk_l0_graph(v, 12)
    queries = v[:30] + 0.01 * rng.standard_normal((30, 16)).astype(np.float32)
    _, gt = brute_force_knn(v, queries, 5)
    d, i = batched_beam_search(jnp.asarray(v), jnp.asarray(adj[None]),
                               jnp.asarray(queries), 0, ef=48)
    rec = recall_at_k(np.asarray(i)[:, :5], gt)
    assert rec >= 0.85, rec
