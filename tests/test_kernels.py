"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.distance_topk.ops import distance_topk
from repro.kernels.distance_topk.ref import distance_topk_ref
from repro.kernels.gather_blocks.ops import gather_blocks


# ------------------------------------------------------------ distance_topk

@pytest.mark.parametrize("B,N,D,k", [
    (1, 100, 16, 1), (7, 333, 128, 10), (37, 1000, 960, 5),
    (128, 256, 64, 16), (130, 513, 32, 3),
])
def test_distance_topk_sweep(rng, B, N, D, k):
    q = rng.standard_normal((B, D)).astype(np.float32)
    x = rng.standard_normal((N, D)).astype(np.float32)
    d, i = distance_topk(jnp.asarray(q), jnp.asarray(x), k)
    dr, ir = distance_topk_ref(jnp.asarray(q), jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               atol=1e-2, rtol=1e-4)


@pytest.mark.parametrize("n_valid", [1, 50, 255, 256])
def test_distance_topk_masking(rng, n_valid):
    q = rng.standard_normal((5, 32)).astype(np.float32)
    x = rng.standard_normal((256, 32)).astype(np.float32)
    d, i = distance_topk(jnp.asarray(q), jnp.asarray(x), 8, n_valid=n_valid)
    dr, ir = distance_topk_ref(jnp.asarray(q), jnp.asarray(x), 8,
                               n_valid=n_valid)
    live = np.asarray(i) >= 0
    assert (np.asarray(i)[live] < n_valid).all()
    np.testing.assert_array_equal(np.asarray(i)[live],
                                  np.asarray(ir)[live])
    if n_valid < 8:  # padding semantics: inf/-1 tail
        assert np.isinf(np.asarray(d)[:, n_valid:]).all()


def test_distance_topk_bf16_inputs(rng):
    q = jnp.asarray(rng.standard_normal((9, 64)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((300, 64)), jnp.bfloat16)
    d, i = distance_topk(q, x, 5)
    dr, ir = distance_topk_ref(q, x, 5)
    # bf16 ties can reorder; compare sets and values loosely
    same = np.mean([len(set(a.tolist()) & set(b.tolist())) / 5
                    for a, b in zip(np.asarray(i), np.asarray(ir))])
    assert same >= 0.95
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), atol=0.1,
                               rtol=0.02)


# ------------------------------------------------------------- quant_topk

@pytest.mark.parametrize("B,N,D,group,k", [
    (1, 100, 16, 16, 1), (7, 333, 128, 32, 10), (37, 500, 960, 64, 5),
    (128, 256, 64, 32, 16), (130, 513, 32, 8, 3),
])
def test_quant_topk_sweep(rng, B, N, D, group, k):
    from repro.kernels.quant_topk.ops import quant_topk
    from repro.kernels.quant_topk.ref import quant_topk_ref
    from repro.quant.codec import quantize_groups

    q = rng.standard_normal((B, D)).astype(np.float32)
    x = rng.standard_normal((N, D)).astype(np.float32)
    codes, scales = quantize_groups(x, group)
    cj, sj = jnp.asarray(codes), jnp.asarray(scales)
    d, i = quant_topk(jnp.asarray(q), cj, sj, k, group)
    dr, ir = quant_topk_ref(jnp.asarray(q), cj, sj, k, group)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               atol=1e-2, rtol=1e-4)


@pytest.mark.parametrize("n_valid", [1, 50, 255, 256])
def test_quant_topk_masking(rng, n_valid):
    from repro.kernels.quant_topk.ops import quant_topk
    from repro.kernels.quant_topk.ref import quant_topk_ref
    from repro.quant.codec import quantize_groups

    q = rng.standard_normal((5, 32)).astype(np.float32)
    x = rng.standard_normal((256, 32)).astype(np.float32)
    codes, scales = quantize_groups(x, 8)
    cj, sj = jnp.asarray(codes), jnp.asarray(scales)
    d, i = quant_topk(jnp.asarray(q), cj, sj, 8, 8, n_valid=n_valid)
    dr, ir = quant_topk_ref(jnp.asarray(q), cj, sj, 8, 8, n_valid=n_valid)
    live = np.asarray(i) >= 0
    assert (np.asarray(i)[live] < n_valid).all()
    np.testing.assert_array_equal(np.asarray(i)[live], np.asarray(ir)[live])
    if n_valid < 8:  # padding semantics: inf/-1 tail
        assert np.isinf(np.asarray(d)[:, n_valid:]).all()


def test_quant_topk_close_to_exact(rng):
    """Dequantized distances track the f32 oracle within codec error."""
    from repro.kernels.distance_topk.ref import distance_topk_ref
    from repro.kernels.quant_topk.ops import quant_topk
    from repro.quant.codec import quantize_groups

    q = rng.standard_normal((16, 128)).astype(np.float32)
    x = rng.standard_normal((400, 128)).astype(np.float32)
    codes, scales = quantize_groups(x, 32)
    d, i = quant_topk(jnp.asarray(q), jnp.asarray(codes),
                      jnp.asarray(scales), 10, 32)
    de, ie = distance_topk_ref(jnp.asarray(q), jnp.asarray(x), 10)
    overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                       for a, b in zip(np.asarray(i), np.asarray(ie))])
    assert overlap >= 0.9, overlap
    np.testing.assert_allclose(np.asarray(d), np.asarray(de),
                               rtol=0.05, atol=0.5)


# ------------------------------------------------------------ gather_blocks

@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("m", [1, 5, 64])
def test_gather_blocks_sweep(rng, dtype, m):
    buf = (rng.standard_normal((40, 192)) * 100).astype(dtype)
    ids = rng.integers(0, 40, m).astype(np.int32)
    out = gather_blocks(jnp.asarray(buf), jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out), buf[ids])


def test_gather_blocks_repeated_ids(rng):
    buf = rng.standard_normal((16, 64)).astype(np.float32)
    ids = np.array([3, 3, 3, 0, 15, 3], np.int32)
    out = gather_blocks(jnp.asarray(buf), jnp.asarray(ids))
    np.testing.assert_array_equal(np.asarray(out), buf[ids])


# --------------------------------------------------------- decode_attention

@pytest.mark.parametrize("B,S,K,G,hd", [
    (1, 256, 1, 1, 64), (3, 512, 4, 2, 64), (2, 1024, 2, 8, 128),
    (5, 300, 6, 1, 32),
])
def test_decode_attention_sweep(rng, B, S, K, G, hd):
    q = rng.standard_normal((B, K * G, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    pos = rng.integers(1, S + 1, B).astype(np.int32)
    o = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(pos))
    orf = decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=2e-5, rtol=1e-4)


def test_decode_attention_bf16(rng):
    B, S, K, G, hd = 2, 256, 2, 2, 64
    q = jnp.asarray(rng.standard_normal((B, K * G, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, K, hd)), jnp.bfloat16)
    pos = jnp.asarray([100, 256], jnp.int32)
    o = decode_attention(q, k, v, pos)
    orf = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(o, dtype=np.float32),
                               np.asarray(orf, dtype=np.float32),
                               atol=0.02, rtol=0.02)


def test_decode_attention_pos_zero_edge(rng):
    """pos=1: only the first cache entry attended."""
    B, S, K, G, hd = 1, 256, 1, 1, 32
    q = rng.standard_normal((B, K * G, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, K, hd)).astype(np.float32)
    pos = np.array([1], np.int32)
    o = decode_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(o)[0, 0], v[0, 0, 0], atol=1e-5)
