"""End-to-end system behaviour: the paper's pipeline on one box.

build -> route -> plan -> doorbell fetch (Pallas gather) -> sub search
-> merge, across all three schemes, plus the Pallas-kernel engine path
and the latency-breakdown accounting the paper's §4 tables report.
"""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G, TPU_ICI

# long-running tier: excluded from CI fast job (-m 'not slow')
pytestmark = pytest.mark.slow


def test_pipeline_with_pallas_gather(sift_small):
    """use_gather_kernel=True routes fetches through the doorbell
    Pallas kernel (interpret on CPU) — results must be identical."""
    common = dict(mode="full", search_mode="scan", n_rep=32, b=4, ef=48,
                  cache_frac=0.25, seed=3)
    a = DHNSWEngine(EngineConfig(use_gather_kernel=False, **common)).build(
        sift_small.data)
    b = DHNSWEngine(EngineConfig(use_gather_kernel=True, **common)).build(
        sift_small.data)
    _, ga, _ = a.search(sift_small.queries[:16], k=10)
    _, gb, _ = b.search(sift_small.queries[:16], k=10)
    assert np.array_equal(ga, gb)


def test_latency_breakdown_accounting(built_engine, sift_small):
    """The three components of the paper's Tables 1-2 are all reported
    and the network term responds to the fabric constants."""
    _, _, st = built_engine.search(sift_small.queries, k=10)
    assert st["meta_s"] >= 0 and st["sub_s"] >= 0
    net = st["net"]
    assert net["latency_s"] > 0
    assert net["bytes"] > 0
    # same plan on the RDMA fabric prices differently
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="graph",
                                   n_rep=32, b=4, ef=48, cache_frac=0.25,
                                   seed=3, fabric=RDMA_100G)).build(
        sift_small.data)
    _, _, st2 = eng.search(sift_small.queries, k=10)
    assert st2["net"]["fabric"] == "rdma-100g"


def test_paper_scheme_ordering_rdma(sift_small):
    """Naive >> no_doorbell > full network latency on the RDMA fabric
    with a large batch — the shape of the paper's Fig. 6 / Table 1."""
    lat = {}
    rt = {}
    for mode in ("naive", "no_doorbell", "full"):
        eng = DHNSWEngine(EngineConfig(
            mode=mode, search_mode="scan", n_rep=64, b=4, ef=48,
            cache_frac=0.10, doorbell=16, seed=3,
            fabric=RDMA_100G)).build(sift_small.data)
        _, g, st = eng.search(sift_small.queries, k=10)
        lat[mode] = st["net"]["latency_s"]
        rt[mode] = st["net"]["round_trips"]
    assert lat["naive"] > lat["no_doorbell"] >= lat["full"]
    assert rt["naive"] / max(rt["full"], 1) > 10   # >=10x fewer trips
    # bytes saved by dedup: naive moved strictly more
    assert lat["naive"] / lat["full"] > 2


def test_recall_efsearch_sweep_shape(sift_small):
    """Monotone-ish latency-recall curve (Fig. 6): recall grows with
    efSearch and saturates below the partition-coverage ceiling."""
    eng = DHNSWEngine(EngineConfig(mode="full", search_mode="graph",
                                   n_rep=32, b=4, ef=48, cache_frac=0.25,
                                   seed=3)).build(sift_small.data)
    scan = DHNSWEngine(EngineConfig(mode="full", search_mode="scan",
                                    n_rep=32, b=4, ef=48, cache_frac=0.25,
                                    seed=3)).build(sift_small.data)
    _, gc, _ = scan.search(sift_small.queries, k=10)
    ceiling = recall_at_k(gc, sift_small.gt_ids[:, :10])
    recs = []
    for ef in (4, 16, 48):
        _, g, _ = eng.search(sift_small.queries, k=10, ef=ef)
        recs.append(recall_at_k(g, sift_small.gt_ids[:, :10]))
    assert recs[0] <= recs[1] <= recs[2] + 0.02
    assert recs[-1] <= ceiling + 1e-9
    assert recs[-1] >= ceiling - 0.05  # ef=48 ~saturates (paper's knee)
