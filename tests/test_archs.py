"""Per-arch smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + finiteness (brief deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape
from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models import model as M
from repro.models.params import init_params
from repro.train import adamw
from repro.train.train_step import make_train_step

# long-running tier: excluded from CI fast job (-m 'not slow')
pytestmark = pytest.mark.slow

SHAPE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")


def _batch(cfg, rng):
    out = {"tokens": rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32),
           "labels": rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)}
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (2, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        out["patches"] = rng.standard_normal(
            (2, cfg.n_patches, cfg.d_model)).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in out.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    rng = np.random.default_rng(0)
    params = init_params(M.param_defs(cfg), jax.random.key(0))
    batch = _batch(cfg, rng)
    logits, aux = M.forward(cfg, params, batch)
    S = 32 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    rng = np.random.default_rng(1)
    step, _, _, _ = make_train_step(cfg, SHAPE, mesh=None)
    params = init_params(M.param_defs(cfg), jax.random.key(1))
    opt = adamw.init(params)
    params, opt, metrics = jax.jit(step)(params, opt, _batch(cfg, rng))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert loss > 0
    # params actually moved
    leaf = jax.tree.leaves(params)[0]
    assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-370m", "zamba2-2.7b",
                                  "whisper-tiny"])
def test_smoke_prefill_decode(arch):
    """Serving path: prefill a short prompt then one decode step."""
    cfg = smoke_config(arch)
    rng = np.random.default_rng(2)
    params = init_params(M.param_defs(cfg), jax.random.key(2))
    B, S, L = 2, 16, 24
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model)).astype(np.float32))
    logits, cache = M.prefill(cfg, params, batch, cache_len=L)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32).reshape(B)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache2 = M.decode_step(cfg, params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


def test_full_configs_match_assignment():
    """Spot-check the full (dry-run) configs against the brief's table."""
    c = get_config("gemma2-27b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (46, 4608, 32, 16, 36864, 256000)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.moe_top_k, c.expert_d_ff) == (128, 8, 768)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1024, 128)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_experts, c.moe_top_k) == (16, 1)
    c = get_config("pixtral-12b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (40, 5120, 131072)
