"""repro.rdma — the verbs layer and the 1/N compacted device staging.

Four layers of coverage:

* **verbs units** — WR-list -> frame mapping invariants (one
  ``post_send`` == one doorbell batch == one frame), MR registration
  geometry, and completion-queue error mapping.
* **bearer conformance** — ``RemotePool`` over {loopback-QP, tcp-QP} x
  {none, int8} must be bit-identical to ``LocalPool`` (results, ledger,
  and ``wire_vs_model`` exact), single-node and sharded over loopback
  HostRegions.
* **1/N staging** — sharded children stage only their owned groups'
  blocks: staged device bytes scale ~1/N across {1, 2, 4} shards, and
  migration / failover healing re-stages only the moved blocks.
* **failure surface** — a server-side error drains as completions and
  raises ``RuntimeError`` without desynchronizing the bearer.
"""
import copy

import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, NetLedger
from repro.core.hnsw import HNSWParams
from repro.core.layout import MT_GROUP, build_store
from repro.core.meta import build_meta
from repro.net import RemotePool, spawn_pool_servers
from repro.pool import LocalPool, ShardedPool
from repro.rdma import verbs as V

CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3, fabric=RDMA_100G)


@pytest.fixture(scope="module")
def servers():
    with spawn_pool_servers(1) as endpoints:
        yield endpoints


@pytest.fixture(scope="module")
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:24]


def _tiny_store(data, ov_cap=0):
    meta = build_meta(data, 8, seed=0, meta_levels=2)
    return build_store(data, meta, ov_cap=ov_cap,
                       sub_params=HNSWParams(M=4, M0=8, ef_construction=40))


def _build(pool, data, **over):
    cfg = {**CFG, **over, "pool": pool}
    return DHNSWEngine(EngineConfig(**cfg)).build(data)


# ------------------------------------------------------------ verbs units

def test_wr_frame_read_list_is_one_doorbell_frame():
    wrs = [V.read_wr(V.RKEY_SPANS, p, 128) for p in (3, 1, 7)]
    op, payload, flags = V.wr_frame(wrs)
    from repro.net import wire as W
    assert op == W.OP_READ_SPANS
    assert np.array_equal(W.dec_pids(payload), [3, 1, 7])
    assert flags == 0
    # row/quant-row rkeys map to their own opcodes
    assert V.wr_frame([V.read_wr(V.RKEY_ROWS, 5, 4)])[0] == W.OP_READ_ROWS
    assert (V.wr_frame([V.read_wr(V.RKEY_QROWS, 5, 4)])[0]
            == W.OP_READ_QUANT_ROWS)


def test_wr_frame_rejects_malformed_lists():
    with pytest.raises(ValueError):
        V.wr_frame([])
    with pytest.raises(ValueError):          # heterogeneous read rkeys
        V.wr_frame([V.read_wr(V.RKEY_SPANS, 0, 8),
                    V.read_wr(V.RKEY_ROWS, 1, 8)])
    with pytest.raises(ValueError):          # write list must close w/ IMM
        V.wr_frame([V.write_wr(V.RKEY_REGION, 0, b"x")])
    with pytest.raises(ValueError):          # SEND is a single-WR batch
        V.wr_frame([V.send_wr(1), V.send_wr(2)])


def test_region_mrs_geometry(pds):
    data, _ = pds
    store = _tiny_store(data)
    spec = store.spec
    mrs = V.region_mrs(spec)
    assert set(mrs) == {V.RKEY_SPANS, V.RKEY_ROWS, V.RKEY_OVERFLOW,
                        V.RKEY_REGION}
    assert mrs[V.RKEY_SPANS].length == spec.n_partitions
    assert mrs[V.RKEY_SPANS].nbytes == spec.partition_bytes()
    assert mrs[V.RKEY_REGION].length == spec.n_blocks
    from repro.core import layout as LA
    LA.attach_quant_mirror(store, 8)
    qmrs = V.region_mrs(store.spec, quant=True)
    assert V.RKEY_QROWS in qmrs
    assert (qmrs[V.RKEY_QROWS].nbytes
            == store.spec.dim + (store.spec.dim // 8) * 4)


def test_completion_queue_maps_remote_errors():
    from repro.net import wire as W

    class ErrBearer:
        frames = False
        closed = False

        def __init__(self):
            self.q = []

        def submit(self, op, payload, flags=0, *, prefix=b"", wrs=None):
            self.q.append((op or 7, W.FLAG_ERROR, b"boom"))
            return 0

        def complete(self):
            return self.q.pop(0)

    qp = V.QueuePair(ErrBearer())
    qp.post_send([V.send_wr(7)])
    comp = qp.cq.poll()[0]
    assert comp.status == V.WC_REMOTE_ERROR
    assert comp.error == "boom"
    with pytest.raises(RuntimeError):
        qp.cq.poll()                          # nothing outstanding


# ------------------------------------------------- bearer conformance

def _assert_search_identical(e0, e1, queries):
    d0, g0, st0 = e0.search(queries, k=10)
    d1, g1, st1 = e1.search(queries, k=10)
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st1["net"][key], key
    return st1


@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("bearer", ["loopback", "tcp"])
def test_bearer_conformance_bit_identical(pds, servers, bearer, quant):
    """The QP path over either bearer: search + insert bit-identical to
    LocalPool, ledger parity, and measured wire bytes == the model for
    every data verb."""
    data, queries = pds
    e0 = _build("local", data, quant=quant)
    e1 = _build("remote", data, quant=quant, bearer=bearer,
                endpoints=servers if bearer == "tcp" else None)
    _assert_search_identical(e0, e1, queries)
    g0 = e0.insert(queries[:2] + 0.001)
    g1 = e1.insert(queries[:2] + 0.001)
    assert np.array_equal(g0, g1)
    _assert_search_identical(e0, e1, queries[:8])
    snap = e1.pool.snapshot()
    assert snap["bearer"] == bearer
    wvm = snap["wire_vs_model"]
    assert wvm, "no wire_vs_model in remote snapshot"
    for verb, row in wvm.items():
        if verb.startswith("read"):
            # span/row reads: payload == model by protocol construction
            assert row["measured"] == row["modeled"], (verb, row)
        elif verb == "append":
            # append frames carry an 8-byte pid routing word the model
            # does not price (it charges vector + gid only)
            assert row["measured"] >= row["modeled"], (verb, row)
            assert row["ratio"] < 1.05, (verb, row)


def test_sharded_over_loopback_regions_bit_identical(pds):
    """Two RemotePool children, each over its own in-process HostRegion:
    the sharded fan-out through the QP path stays bit-identical."""
    data, queries = pds
    e0 = _build("local", data)
    e1 = _build("sharded", data, shard_transport="remote",
                bearer="loopback", n_shards=2)
    _assert_search_identical(e0, e1, queries)
    snap = e1.pool.snapshot()
    assert all(s["bearer"] == "loopback" for s in snap["shards"])
    assert snap["wire_total"]["frames_tx"] > 0


def test_loopback_raw_verbs_match_local_with_doorbell_frames(pds):
    """Raw verb level: one WR-list post per doorbell batch — 5 spans at
    doorbell=2 cost exactly 3 frames == the ledger's round trips — and
    every verb result and charge matches LocalPool."""
    data, _ = pds
    s0, s1 = _tiny_store(data), _tiny_store(data)
    lp = LocalPool(s0)
    rp = RemotePool(s1, None, bearer="loopback")
    led_l, led_r = NetLedger(RDMA_100G), NetLedger(RDMA_100G)

    pids = np.array([0, 2, 3, 5, 6])
    f0 = rp.wire["frames_tx"]
    gl, vl = lp.read_spans(pids, ledger=led_l, doorbell=2)
    gr, vr = rp.read_spans(pids, ledger=led_r, doorbell=2)
    assert np.array_equal(np.asarray(gl), np.asarray(gr))
    assert np.array_equal(np.asarray(vl), np.asarray(vr))
    assert rp.wire["frames_tx"] - f0 == 3 == led_r.round_trips
    assert led_l.as_dict() == led_r.as_dict()

    rows = np.array([[0, 5, 9], [2, -1, 7]], np.int32)
    assert np.array_equal(np.asarray(lp.read_rows(rows)),
                          np.asarray(rp.read_rows(rows)))

    vec = data[0] + 0.5
    assert lp.append(vec, 9999, 1, ledger=led_l) == \
        rp.append(vec, 9999, 1, ledger=led_r) >= 0
    assert np.array_equal(s0.vec_buf, s1.vec_buf)
    assert np.array_equal(s0.meta_table, s1.meta_table)
    assert led_l.as_dict() == led_r.as_dict()
    rp.close()


def test_loopback_server_error_drains_and_surfaces(pds):
    """A bad descriptor raises a clean RuntimeError; the bearer stays
    usable (completions were drained, not abandoned)."""
    data, _ = pds
    rp = RemotePool(_tiny_store(data), None, bearer="loopback")
    with pytest.raises(RuntimeError, match="pool server error"):
        rp.read_spans(np.array([0, 999]), ledger=None)
    g, v = rp.read_spans(np.array([1]), ledger=None)
    assert np.asarray(v).shape[0] == 1
    rp.close()


# ------------------------------------------------------- 1/N staging

@pytest.mark.parametrize("n", [1, 2, 4])
def test_staged_device_bytes_scale_inverse_with_shards(pds, n):
    """Each sharded child stages only its owned groups, block-compacted:
    staged blocks partition the region exactly, and per-shard device
    bytes are the compacted blocks plus the (replicated) meta table."""
    data, _ = pds
    store = _tiny_store(data)
    spec = store.spec
    sp = ShardedPool(store, [lambda s: LocalPool(s)] * n)
    stg = sp.snapshot()["staging"]
    assert sum(stg["blocks_staged_by_shard"]) == spec.n_blocks
    cap = -(-spec.n_groups // n) * spec.group_blocks   # ceil(G/N) groups
    assert max(stg["blocks_staged_by_shard"]) <= cap
    blk_bytes = (spec.gblk + spec.vblk) * 4
    for staged, dev in zip(stg["blocks_staged_by_shard"],
                           stg["device_bytes_by_shard"]):
        assert dev == staged * blk_bytes + store.meta_table.nbytes


def test_compacted_reads_bit_identical_to_full(pds):
    """The indirection is invisible: span/row reads off a compacted
    pool equal the fully staged one, dead lanes and ledger charges
    included.  Two layers: a compacted LocalPool restricted to half the
    groups (like-for-like ledger parity on the owned pids), and a
    2-shard pool whose children are compacted (data parity over all
    pids; the sharded ledger legitimately differs — parallel shards
    charge the max round trip, not the sum)."""
    data, _ = pds
    s0, s1, s2 = _tiny_store(data), _tiny_store(data), _tiny_store(data)
    spec = s0.spec
    lp = LocalPool(s0)
    half = list(range(spec.n_groups // 2))
    cp = LocalPool(s1, owned_groups=half)
    assert cp.staging["compacted"]
    mt = s0.meta_table
    owned_pids = np.array([p for p in range(spec.n_partitions)
                           if int(mt[p, MT_GROUP]) in half])
    led_l, led_c = NetLedger(RDMA_100G), NetLedger(RDMA_100G)
    res_l = lp.read_spans(owned_pids, ledger=led_l, doorbell=4)
    res_c = cp.read_spans(owned_pids, ledger=led_c, doorbell=4)
    for a, b in zip(res_l, res_c):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert led_l.as_dict() == led_c.as_dict()

    sp = ShardedPool(s2, [lambda s: LocalPool(s)] * 2)
    assert all(c.staging["compacted"] for c in sp.children)
    pids = np.arange(spec.n_partitions)
    res_s = sp.read_spans(pids, ledger=None, doorbell=4)
    res_f = lp.read_spans(pids, ledger=None, doorbell=4)
    for a, b in zip(res_f, res_s):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    rows = np.array([[0, 65, 130], [200, -1, 7]], np.int32)
    assert np.array_equal(np.asarray(lp.read_rows(rows)),
                          np.asarray(sp.read_rows(rows)))


def test_migration_restages_only_moved_blocks(pds):
    """Moving one group's serving replica stages exactly that group's
    blocks on the destination — nothing else on any shard."""
    data, _ = pds
    store = _tiny_store(data)
    gb = store.spec.group_blocks
    sp = ShardedPool(store, [lambda s: LocalPool(s)] * 2)
    c0, c1 = sp.children
    assert c0.staging["restaged_blocks"] == 0
    assert c1.staging["restaged_blocks"] == 0
    g = int(np.nonzero(sp._serve == 0)[0][0])
    pre1 = c1.staging["blocks_staged"]
    sp._migrate(g, 0, 1)
    assert sp.owner_of_group(g) == 1
    assert c1.staging["restaged_blocks"] == gb
    assert c1.staging["blocks_staged"] == pre1 + gb
    assert c0.staging["restaged_blocks"] == 0
    lp = LocalPool(_tiny_store(data))
    pids = np.arange(store.spec.n_partitions)
    a = lp.read_spans(pids, ledger=None)
    b = sp.read_spans(pids, ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_failover_restages_only_dead_shards_groups(pds):
    """Healing a death re-stages only the dead shard's groups onto
    survivors (group-granular adoption), never the full region."""
    data, _ = pds
    store = _tiny_store(data)
    spec = store.spec
    sp = ShardedPool(store, [lambda s: LocalPool(s)] * 3, replication=2)
    held0 = sum(1 for row in sp._replicas if (row == 0).any())
    assert held0 > 0
    sp._on_shard_down(0)
    survivors = sp.children[1:]
    restaged = sum(c.staging["restaged_blocks"] for c in survivors)
    assert restaged == sp.failover["rereplicated_groups"] * spec.group_blocks
    assert sp.failover["rereplicated_groups"] <= held0
    for s, c in enumerate(sp.children[1:], start=1):
        assert c.staging["restaged_blocks"] % spec.group_blocks == 0
        held = sum(1 for row in sp._replicas if (row == s).any())
        assert c.staging["blocks_staged"] == held * spec.group_blocks
    lp = LocalPool(_tiny_store(data))
    pids = np.arange(spec.n_partitions)
    a = lp.read_spans(pids, ledger=None)
    b = sp.read_spans(pids, ledger=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
