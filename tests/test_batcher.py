"""Micro-batching serving tier: coalescing correctness, flush policy,
insert/search interleave, admission control, and the vectorized
cross-round merge regression against the old host-loop merge."""
import time

import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.serve.batcher import (AdmissionError, ArrivalRateEWMA,
                                 BatchPolicy, MicroBatcher, TokenBucket)
from repro.serve.server import SearchServer

CFG = dict(mode="full", search_mode="scan", n_rep=16, b=3, ef=32,
           cache_frac=0.3, seed=3)


@pytest.fixture(scope="module")
def small_data(sift_small):
    return sift_small.data[:2000], sift_small.queries[:16]


@pytest.fixture(scope="module")
def engine(small_data):
    data, queries = small_data
    eng = DHNSWEngine(EngineConfig(**CFG)).build(data)
    eng.search(queries[:8], k=10)        # warm the jit caches
    return eng


def test_coalesce_bit_identical_to_serial(engine, small_data):
    """N concurrent requests -> ONE fused engine call, results
    bit-identical to per-request serial search on a fresh engine."""
    data, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_batch=64, max_wait_s=0.1),
                      autostart=False)
    futs = [mb.submit_search(queries[i], k=10) for i in range(8)]
    mb.start()
    results = [f.result(timeout=60) for f in futs]
    mb.stop()
    snap = mb.metrics.snapshot()
    assert snap["n_fused_calls"] == 1
    assert snap["mean_fused_batch"] == 8.0
    assert snap["n_requests"] == 8

    serial = DHNSWEngine(EngineConfig(**CFG)).build(data)
    for i, (d, g, st) in enumerate(results):
        ds, gs, _ = serial.search(queries[i:i + 1], k=10)
        assert np.array_equal(g, gs), i
        assert np.allclose(d, ds), i
        assert st["fused_batch"] == 8
        assert st["queue_s"] >= 0 and st["total_s"] >= st["serve_s"]


def test_mixed_k_requests_prefix_consistent(engine, small_data):
    """One window with different k's: fused at max k, sliced per request."""
    _, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_wait_s=0.1), autostart=False)
    f5 = mb.submit_search(queries[0], k=5)
    f10 = mb.submit_search(queries[0], k=10)
    mb.start()
    d5, g5, _ = f5.result(timeout=60)
    d10, g10, _ = f10.result(timeout=60)
    mb.stop()
    assert g5.shape == (1, 5) and g10.shape == (1, 10)
    assert np.array_equal(g5[0], g10[0, :5])


def test_max_wait_flushes_partial_window(engine, small_data):
    """A lone request must not wait for max_batch to fill."""
    _, queries = small_data
    with MicroBatcher(engine, BatchPolicy(max_batch=4096,
                                          max_wait_s=0.02)) as mb:
        t0 = time.perf_counter()
        d, g, st = mb.search(queries[0], k=10)
        elapsed = time.perf_counter() - t0
    assert st["fused_batch"] == 1
    assert elapsed < 10          # generous: CI boxes stall; policy is 20ms


def test_insert_search_interleave_preserves_order(engine, small_data):
    """search | insert X | search X queued in one window: the trailing
    search must see X (consecutive-run grouping keeps arrival order)."""
    data, _ = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_wait_s=0.05), autostart=False)
    new = data[7] + np.float32(0.0007)
    f_pre = mb.submit_search(data[0], k=5)
    f_ins = mb.submit_insert(new)
    f_post = mb.submit_search(new, k=3)
    mb.start()
    gids = f_ins.result(timeout=60)
    _, g_post, _ = f_post.result(timeout=60)
    f_pre.result(timeout=60)
    mb.stop()
    assert len(gids) == 1
    assert gids[0] in g_post[0]
    assert mb.metrics.snapshot()["n_fused_calls"] == 3  # s | i | s runs


def test_token_bucket_admission():
    tb = TokenBucket(rate=1.0, burst=2)
    assert tb.acquire(2, block=False)
    assert not tb.acquire(1, block=False)   # bucket drained
    time.sleep(1.1)
    assert tb.acquire(1, block=False)       # refilled ~1 token

    eng_stub = None  # admission fires before the engine is touched
    mb = MicroBatcher(eng_stub, BatchPolicy(rate=1.0, burst=1,
                                            admission_block=False),
                      autostart=False)
    mb.submit_search(np.zeros(8, np.float32), k=1)
    with pytest.raises(AdmissionError):
        mb.submit_search(np.zeros(8, np.float32), k=1)
    assert mb.metrics.n_rejected == 1


def test_per_tenant_admission_isolates_tenants():
    """One tenant over its rate gets rejected WITHOUT draining another
    tenant's budget (the global bucket is disabled here), and the stats
    snapshot carries per-tenant admit/reject counts."""
    mb = MicroBatcher(None, BatchPolicy(tenant_rate=1.0, tenant_burst=2,
                                        admission_block=False),
                      autostart=False)
    q = np.zeros(8, np.float32)
    mb.submit_search(q, k=1, tenant="a")
    mb.submit_search(q, k=1, tenant="a")        # drains a's bucket
    with pytest.raises(AdmissionError):
        mb.submit_search(q, k=1, tenant="a")
    # tenant b is untouched by a's exhaustion
    mb.submit_search(q, k=1, tenant="b")
    snap = mb.metrics.snapshot()
    assert snap["tenants"]["a"] == {"admitted": 2, "rejected": 1,
                                    "queued": 2, "served": 0, "share": 0.0}
    assert snap["tenants"]["b"] == {"admitted": 1, "rejected": 0,
                                    "queued": 1, "served": 0, "share": 0.0}
    assert snap["n_rejected"] == 1


def test_tenant_rejection_does_not_drain_global_bucket():
    """A tenant-rejected request must not consume shared global tokens:
    one tenant flooding past ITS rate leaves the global budget (and so
    every other tenant's admission) untouched."""
    mb = MicroBatcher(None, BatchPolicy(rate=1.0, burst=4,
                                        tenant_rate=1.0, tenant_burst=2,
                                        admission_block=False),
                      autostart=False)
    q = np.zeros(8, np.float32)
    mb.submit_search(q, k=1, tenant="flood")
    mb.submit_search(q, k=1, tenant="flood")     # drains flood's bucket
    for _ in range(10):                          # all tenant-rejected
        with pytest.raises(AdmissionError):
            mb.submit_search(q, k=1, tenant="flood")
    # global budget: burst 4, only 2 consumed -> "quiet" still admits
    mb.submit_search(q, k=1, tenant="quiet")
    mb.submit_search(q, k=1, tenant="quiet")
    snap = mb.metrics.snapshot()
    assert snap["tenants"]["quiet"] == {"admitted": 2, "rejected": 0,
                                        "queued": 2, "served": 0,
                                        "share": 0.0}
    assert snap["tenants"]["flood"]["rejected"] == 10


def test_per_tenant_queue_depth_and_dispatch(engine, small_data):
    """Queue depth per tenant: counted while pending, drained to zero
    once dispatched; results are per-request correct."""
    _, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_batch=64, max_wait_s=0.05),
                      autostart=False)
    futs = [mb.submit_search(queries[i], k=10, tenant=t)
            for i, t in enumerate(("a", "a", "b"))]
    depth = mb.metrics.snapshot()["tenants"]
    assert depth["a"]["queued"] == 2 and depth["b"]["queued"] == 1
    assert depth["a"]["admitted"] == 2
    mb.start()
    for f in futs:
        d, g, _ = f.result(timeout=60)
        assert g.shape == (1, 10)
    mb.stop()
    after = mb.metrics.snapshot()["tenants"]
    assert after["a"]["queued"] == 0 and after["b"]["queued"] == 0


def test_default_tenant_untouched_by_policy(engine, small_data):
    """No tenant key + tenant_rate=0: admission behaves exactly as
    before and everything lands under the "-" tenant."""
    _, queries = small_data
    with SearchServer(engine, BatchPolicy(max_wait_s=0.005)) as srv:
        srv.search(queries[0], k=10)
        snap = srv.stats()
    assert snap["tenants"]["-"]["admitted"] == 1
    assert snap["tenants"]["-"]["queued"] == 0


def test_adaptive_wait_shrinks_under_load_grows_idle():
    """The ROADMAP item: the window budget scales with the observed
    arrival rate — tight under load, growing toward the cap when idle
    (synthetic clocks, no threads)."""
    pol = BatchPolicy(max_batch=64, max_wait_s=5e-3, adaptive_wait=True,
                      min_wait_s=1e-4)

    hot = ArrivalRateEWMA(alpha=0.2)
    for i in range(200):                 # 20 us apart: heavy load
        hot.observe(i * 2e-5)
    idle = ArrivalRateEWMA(alpha=0.2)
    for i in range(20):                  # 50 ms apart: sparse
        idle.observe(i * 5e-2)

    w_hot = hot.wait_budget_s(pol)
    w_idle = idle.wait_budget_s(pol)
    assert w_hot < w_idle                # shrinks under load
    assert w_idle == pol.max_wait_s      # grows back to the cap when idle
    assert pol.min_wait_s <= w_hot < pol.max_wait_s
    # extreme load pins the floor
    slam = ArrivalRateEWMA(alpha=0.2)
    for i in range(500):
        slam.observe(i * 1e-8)
    assert slam.wait_budget_s(pol) == pol.min_wait_s
    # non-adaptive policies are untouched
    fixed = BatchPolicy(max_batch=64, max_wait_s=5e-3)
    assert hot.wait_budget_s(fixed) == fixed.max_wait_s
    # no signal yet -> conservative cap
    assert ArrivalRateEWMA().wait_budget_s(pol) == pol.max_wait_s


def test_adaptive_wait_collapses_on_empty_queue():
    """A window whose opener found the queue EMPTY at enqueue time
    collapses straight to the floor — holding it open cannot coalesce
    what isn't there — while a busy-queue opener keeps the rate-derived
    budget, and non-adaptive policies ignore the hint entirely."""
    pol = BatchPolicy(max_batch=64, max_wait_s=5e-3, adaptive_wait=True,
                      min_wait_s=1e-4)
    idle = ArrivalRateEWMA(alpha=0.2)
    for i in range(20):
        idle.observe(i * 5e-2)           # sparse arrivals: budget at cap
    assert idle.wait_budget_s(pol) == pol.max_wait_s
    assert idle.wait_budget_s(pol, queue_empty=True) == pol.min_wait_s
    assert idle.wait_budget_s(pol, queue_empty=False) == pol.max_wait_s
    # non-adaptive: the hint must not shrink the fixed window
    fixed = BatchPolicy(max_batch=64, max_wait_s=5e-3)
    assert idle.wait_budget_s(fixed, queue_empty=True) == fixed.max_wait_s


def test_empty_at_enqueue_flag_set_by_batcher(engine, small_data):
    """The batcher records the queue state the opener saw: a request
    submitted into an empty queue is flagged; one submitted behind a
    backlog is not — and the adaptive loop still answers correctly."""
    _, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_batch=64, max_wait_s=0.05,
                                          adaptive_wait=True,
                                          min_wait_s=1e-4),
                      autostart=False)
    f0 = mb.submit_search(queries[0], k=10)
    f1 = mb.submit_search(queries[1], k=10)
    with mb._cv:
        flags = [r.empty_at_enqueue for r in mb._queue]
    assert flags == [True, False]
    mb.start()
    for f in (f0, f1):
        r = f.result(timeout=60)
        assert r[1].shape == (1, 10)
    mb.stop()


def test_adaptive_wait_live_batcher(engine, small_data):
    """End-to-end: an adaptive batcher still coalesces and answers
    correctly, and its observed EWMA reflects the submissions."""
    _, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_batch=64, max_wait_s=0.05,
                                          adaptive_wait=True),
                      autostart=False)
    futs = [mb.submit_search(queries[i], k=10) for i in range(6)]
    mb.start()
    res = [f.result(timeout=60) for f in futs]
    mb.stop()
    assert len(res) == 6 and all(r[1].shape == (1, 10) for r in res)
    assert mb.arrivals.interarrival_s() is not None


def test_server_stats_snapshot(engine, small_data):
    _, queries = small_data
    with SearchServer(engine, BatchPolicy(max_wait_s=0.005)) as srv:
        for i in range(4):
            srv.search(queries[i], k=10)
        snap = srv.stats()
    assert snap["n_requests"] == 4
    assert snap["p50_ms"] > 0 and snap["p99_ms"] >= snap["p50_ms"]
    for key in ("queue_s", "route_s", "plan_s", "fetch_s", "serve_s"):
        assert snap["breakdown_s"][key] >= 0


def test_wfq_drains_by_weight_not_arrival():
    """Deficit round-robin: with weights 3:1 and tenant B's whole
    backlog queued FIRST, a window still drains ~3 A rows per B row —
    and arrival order is preserved within each tenant."""
    from repro.serve.batcher import _Request

    pol = BatchPolicy(max_batch=16, wfq=True, wfq_quantum=1,
                      tenant_weight={"A": 3.0, "B": 1.0})
    mb = MicroBatcher(None, pol, autostart=False)
    for i in range(40):
        mb._enqueue(_Request("search", np.zeros((1, 4), np.float32), i,
                             time.perf_counter(), "B"))
    for i in range(40):
        mb._enqueue(_Request("search", np.zeros((1, 4), np.float32), i,
                             time.perf_counter(), "A"))
    for _ in range(2):
        win = mb._take_window()
        kinds = [r.tenant for r in win]
        assert kinds.count("A") == 12 and kinds.count("B") == 4
        for t in ("A", "B"):   # per-tenant FIFO (k carries arrival index)
            ks = [r.k for r in win if r.tenant == t]
            assert ks == sorted(ks)
    # FIFO default untouched: no weights, no wfq flag
    assert not BatchPolicy().fair_queue


def test_wfq_deficit_resets_when_backlog_drains():
    """A tenant that goes idle must not bank credit: classic DRR drops
    the deficit once its queue empties (the tenant is pruned from the
    service list entirely, so long-lived servers with many tenant keys
    don't grow the sweep without bound)."""
    from repro.serve.batcher import _Request

    pol = BatchPolicy(max_batch=8, wfq=True, wfq_quantum=1,
                      tenant_weight={"A": 5.0})
    mb = MicroBatcher(None, pol, autostart=False)
    mb._enqueue(_Request("search", np.zeros((1, 4), np.float32), 0,
                         time.perf_counter(), "A"))
    win = mb._take_window()
    assert [r.tenant for r in win] == ["A"]
    assert mb._deficit.get("A", 0.0) == 0.0
    assert "A" not in mb._rr


def test_wfq_rotating_start_prevents_tail_starvation():
    """Regression: a window that fills before the sweep reaches the
    tail tenants must not restart at the same head tenant — the start
    rotates, so every backlogged tenant is served within a bounded
    number of windows."""
    from repro.serve.batcher import _Request

    tenants = [f"t{i}" for i in range(9)]
    pol = BatchPolicy(max_batch=8, wfq=True, wfq_quantum=8)
    mb = MicroBatcher(None, pol, autostart=False)
    for _ in range(4):                       # deep equal backlogs
        for t in tenants:
            mb._enqueue(_Request("search", np.zeros((1, 4), np.float32),
                                 0, time.perf_counter(), t))
    served = []
    for _ in range(9):                       # 9 windows x 8 rows
        served.extend(r.tenant for r in mb._take_window())
    from collections import Counter
    counts = Counter(served)
    assert set(counts) == set(tenants), "no tenant may be starved"
    assert max(counts.values()) - min(counts.values()) <= 8


def test_wfq_zero_weight_tenant_cannot_stall_the_drain():
    """Regression: a zero/near-zero weight must not busy-spin the drain
    loop (which runs while HOLDING the batcher lock) — when no tenant
    can afford its queue head in a full sweep, the head is forced
    through instead of spinning."""
    from repro.serve.batcher import _Request

    pol = BatchPolicy(max_batch=64, wfq=True, wfq_quantum=8,
                      tenant_weight={"bad": 0.0})
    mb = MicroBatcher(None, pol, autostart=False)
    for _ in range(3):
        mb._enqueue(_Request("search", np.zeros((32, 4), np.float32), 0,
                             time.perf_counter(), "bad"))
    t0 = time.perf_counter()
    win = mb._take_window()
    assert time.perf_counter() - t0 < 1.0, "drain must not spin"
    assert sum(r.vecs.shape[0] for r in win) >= 32


def test_wfq_serves_correct_results_and_share(engine, small_data):
    """End-to-end through the dispatcher: fair-queued requests still get
    their own correct answers, and stats()["tenants"] reports the
    served-rows share."""
    _, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_batch=64, max_wait_s=0.05,
                                          wfq=True,
                                          tenant_weight={"a": 2.0}),
                      autostart=False)
    futs = [(i, mb.submit_search(queries[i], k=10,
                                 tenant="a" if i % 4 else "b"))
            for i in range(8)]
    mb.start()
    serial = {i: f.result(timeout=60) for i, f in futs}
    mb.stop()
    for i, (d, g, _) in serial.items():
        assert g.shape == (1, 10)
    snap = mb.metrics.snapshot()
    t = snap["tenants"]
    assert t["a"]["served"] == 6 and t["b"]["served"] == 2
    assert t["a"]["share"] == pytest.approx(0.75)
    assert t["b"]["share"] == pytest.approx(0.25)


def test_wfq_preserves_per_tenant_insert_search_order(engine, small_data):
    """Within one tenant, a search queued after an insert still observes
    the inserted vector under WFQ (cross-tenant reorder is allowed,
    within-tenant order is not)."""
    data, queries = small_data
    mb = MicroBatcher(engine, BatchPolicy(max_wait_s=0.05, wfq=True),
                      autostart=False)
    new = data[11] + np.float32(0.0011)
    noise = [mb.submit_search(queries[i % 8], k=5, tenant="other")
             for i in range(4)]
    f_ins = mb.submit_insert(new, tenant="x")
    f_post = mb.submit_search(new, k=3, tenant="x")
    mb.start()
    gids = f_ins.result(timeout=60)
    _, g_post, _ = f_post.result(timeout=60)
    for f in noise:
        f.result(timeout=60)
    mb.stop()
    assert gids[0] in g_post[0]


def test_vectorized_merge_matches_host_loop_merge():
    """Regression: DS.merge_ranked == the old per-pair host fold (stable
    argsort over [running | pair]) on a fixed seed, ties included."""
    import jax.numpy as jnp

    from repro.core import device_store as DS
    from repro.core.scheduler import _pair_ranks

    rng = np.random.default_rng(42)
    B, k, n = 13, 10, 37
    run_d = np.sort(rng.standard_normal((B, k)).astype(np.float32) ** 2,
                    axis=1)
    run_g = rng.integers(0, 10_000, (B, k)).astype(np.int32)
    qi = rng.integers(0, B, n)
    d = np.sort(rng.standard_normal((n, k)).astype(np.float32) ** 2, axis=1)
    d[5] = run_d[int(qi[5])]                # exact ties across run/new
    g = rng.integers(10_000, 20_000, (n, k)).astype(np.int32)

    # the old engine step-3 host loop, verbatim
    want_d, want_g = run_d.copy(), run_g.astype(np.int64)
    for j in range(n):
        q = int(qi[j])
        md = np.concatenate([want_d[q], d[j]])
        mg = np.concatenate([want_g[q], g[j]])
        order = np.argsort(md, kind="stable")[:k]
        want_d[q], want_g[q] = md[order], mg[order]

    pairs = np.stack([qi, np.zeros(n, np.int64)], axis=1)
    ranks = _pair_ranks(pairs)
    got_d, got_g = DS.merge_ranked(
        jnp.asarray(run_d), jnp.asarray(run_g),
        jnp.asarray(qi, jnp.int32), jnp.asarray(ranks, jnp.int32),
        jnp.asarray(d), jnp.asarray(g), n_lanes=int(ranks.max()) + 1)
    assert np.array_equal(np.asarray(got_d), want_d)
    assert np.array_equal(np.asarray(got_g).astype(np.int64), want_g)
