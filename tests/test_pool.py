"""Transport conformance suite for the MemoryPool boundary.

Every transport must serve the SAME serialized layout with bit-identical
search/insert results and verb accounting that agrees with the
``NetLedger`` the schemes charge — a new transport passes this file or
it isn't a d-HNSW memory pool.  Runs against ``LocalPool`` and
``SimulatedRDMAPool`` (fast: tiny dataset, no slow mark).
"""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, TPU_ICI, Fabric, NetLedger
from repro.core.hnsw import HNSWParams
from repro.core.layout import build_store
from repro.core.meta import build_meta
from repro.pool import LocalPool, ShardedPool, SimulatedRDMAPool
from repro.pool.placement import (FrequencyAwarePlacement,
                                  RoundRobinPlacement,
                                  SizeBalancedPlacement)

POOLS = ("local", "sim_rdma")
SHARD_COUNTS = (1, 2, 4)
CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3, fabric=RDMA_100G)


@pytest.fixture(scope="module")
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:24]


def _build(pool: str, data, **over):
    cfg = {**CFG, **over, "pool": pool}
    return DHNSWEngine(EngineConfig(**cfg)).build(data)


# ----------------------------------------------------------- conformance

@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("mode", ["naive", "full"])
def test_pools_bit_identical_search_insert(pds, mode, quant):
    """Same layout, same results, same counted network — the transport
    may only change HOW bytes move, never WHAT the compute side sees."""
    data, queries = pds
    engines = {p: _build(p, data, mode=mode, quant=quant) for p in POOLS}
    stores = [e.store for e in engines.values()]
    assert np.array_equal(stores[0].graph_buf, stores[1].graph_buf)
    assert np.array_equal(stores[0].vec_buf, stores[1].vec_buf)
    assert np.array_equal(stores[0].meta_table, stores[1].meta_table)

    res = {p: e.search(queries, k=10) for p, e in engines.items()}
    d0, g0, st0 = res["local"]
    d1, g1, st1 = res["sim_rdma"]
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st1["net"][key], key

    # inserts route through the append verb on both transports
    new = queries[:3] + 0.001
    gids = {p: e.insert(new) for p, e in engines.items()}
    assert np.array_equal(gids["local"], gids["sim_rdma"])
    r2 = {p: e.search(queries[:8], k=10) for p, e in engines.items()}
    assert np.array_equal(r2["local"][1], r2["sim_rdma"][1])
    assert np.array_equal(r2["local"][0], r2["sim_rdma"][0])

    # the simulated transport models nonzero wire time; local moves
    # bytes over nothing
    assert st1["pool"]["sim_total_s"] > 0
    assert "sim_total_s" not in st0["pool"]


@pytest.mark.parametrize("pool", POOLS)
def test_verb_counts_match_ledger(pds, pool):
    """Pool-side running totals == the sum of every NetLedger the
    engine charged (searches + inserts): the transport and the scheme
    accounting can never drift apart."""
    data, queries = pds
    eng = _build(pool, data, quant="int8")
    totals = {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}

    def add(net):
        for k in totals:
            totals[k] += net[k]

    for i in range(3):
        _, _, st = eng.search(queries[i * 8:(i + 1) * 8], k=10)
        add(st["net"])
    eng.insert(queries[:2] + 0.001)
    add(eng._last_insert_net)
    snap = eng.pool.snapshot()
    for k in totals:
        assert snap["totals"][k] == pytest.approx(totals[k]), k
    assert snap["verbs"]["read_meta"] >= 3
    assert snap["verbs"]["append"] == 2


# ---------------------------------------------------------- verb level

def _tiny_store(data):
    meta = build_meta(data, 8, seed=0, meta_levels=2)
    store = build_store(data, meta,
                        sub_params=HNSWParams(M=4, M0=8,
                                              ef_construction=40))
    return store, meta


def test_raw_verbs_agree_across_transports(pds):
    """Verb-by-verb: both transports return identical device data for
    identical descriptors, and charge identical ledgers."""
    data, _ = pds
    s0, _ = _tiny_store(data)
    s1, _ = _tiny_store(data)
    lp = LocalPool(s0)
    sp = SimulatedRDMAPool(s1, fabric=RDMA_100G)
    led_l, led_s = NetLedger(RDMA_100G), NetLedger(RDMA_100G)

    pids = np.array([0, 3, 5, 6])
    gl, vl = lp.read_spans(pids, ledger=led_l, doorbell=2)
    gs, vs = sp.read_spans(pids, ledger=led_s, doorbell=2)
    assert np.array_equal(np.asarray(gl), np.asarray(gs))
    assert np.array_equal(np.asarray(vl), np.asarray(vs))
    assert led_l.as_dict() == led_s.as_dict()
    assert lp.totals == sp.totals

    rows = np.array([[0, 5, 9], [2, -1, 7]], np.int32)
    assert np.array_equal(np.asarray(lp.read_rows(rows)),
                          np.asarray(sp.read_rows(rows)))

    vec = data[0] + 0.5
    slot_l = lp.append(vec, 9999, 1, ledger=led_l)
    slot_s = sp.append(vec, 9999, 1, ledger=led_s)
    assert slot_l == slot_s >= 0
    assert np.array_equal(s0.vec_buf, s1.vec_buf)
    assert np.array_equal(s0.graph_buf, s1.graph_buf)
    assert np.array_equal(s0.meta_table, s1.meta_table)
    assert led_l.as_dict() == led_s.as_dict()
    # per-verb sim breakdown covers exactly the charged verbs
    assert set(sp.sim_s) == {"read_spans", "append"}
    assert sp.sim_total_s > 0


# ------------------------------------------------------------ sharded

@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("mode", ["naive", "full"])
def test_sharded_bit_identical_search_insert(pds, mode, quant):
    """ShardedPool is a MemoryPool: search and insert results are
    bit-identical to LocalPool for 1, 2, and 4 shards under every
    scheme x quant config (accounting may differ — per-destination
    doorbell batches and parallel fan-out change trip counts, never
    results)."""
    data, queries = pds
    base = _build("local", data, mode=mode, quant=quant)
    d0, g0, _ = base.search(queries, k=10)
    new = queries[:3] + 0.001
    engines = {ns: _build("sharded", data, mode=mode, quant=quant,
                          n_shards=ns) for ns in SHARD_COUNTS}
    for ns, eng in engines.items():
        d, g, st = eng.search(queries, k=10)
        assert np.array_equal(g0, g), (ns, "gids")
        assert np.array_equal(d0, d), (ns, "dists")
        assert st["pool"]["kind"] == "sharded"
        assert st["pool"]["n_shards"] == ns
        assert sum(st["pool"]["groups_by_shard"]) == base.store.spec.n_groups
    gids0 = base.insert(new)
    d1, g1, _ = base.search(queries[:8], k=10)
    for ns, eng in engines.items():
        gids = eng.insert(new)
        assert np.array_equal(gids0, gids), ns
        d, g, _ = eng.search(queries[:8], k=10)
        assert np.array_equal(g1, g), (ns, "post-insert gids")
        assert np.array_equal(d1, d), (ns, "post-insert dists")


def test_sharded_one_shard_accounting_matches_local(pds):
    """With a single shard the fan-out reduces to the child: counted
    network (trips, descriptors, bytes) matches LocalPool exactly."""
    data, queries = pds
    e0 = _build("local", data)
    e1 = _build("sharded", data, n_shards=1)
    _, _, st0 = e0.search(queries, k=10)
    _, _, st1 = e1.search(queries, k=10)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st1["net"][key], key


@pytest.mark.parametrize("parallel", [True, False])
def test_sharded_verb_parity_summed_ledgers(pds, parallel):
    """Pool-side totals == the sum of every NetLedger the engine
    charged, and the per-shard children sum to the pool on bytes and
    descriptors; trips reduce by max across shards in parallel mode
    (== the per-shard sum only in serial mode)."""
    data, queries = pds
    eng = _build("sharded", data, quant="int8", n_shards=3,
                 shard_parallel=parallel)
    totals = {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}

    def add(net):
        for k in totals:
            totals[k] += net[k]

    for i in range(3):
        _, _, st = eng.search(queries[i * 8:(i + 1) * 8], k=10)
        add(st["net"])
    eng.insert(queries[:2] + 0.001)
    add(eng._last_insert_net)
    snap = eng.pool.snapshot()
    for k in totals:
        assert snap["totals"][k] == pytest.approx(totals[k]), k
    child_sum = {k: sum(s["totals"][k] for s in snap["shards"])
                 for k in totals}
    assert child_sum["bytes"] == pytest.approx(snap["totals"]["bytes"])
    assert child_sum["descriptors"] == pytest.approx(
        snap["totals"]["descriptors"])
    if parallel:
        assert snap["totals"]["round_trips"] <= child_sum["round_trips"]
    else:
        assert snap["totals"]["round_trips"] == pytest.approx(
            child_sum["round_trips"])
    assert snap["verbs"]["append"] == 2


def test_sharded_migration_keeps_results_identical(pds):
    """Frequency-aware placement migrates hot groups under a skewed
    workload; results before/after migration (and after a subsequent
    insert) stay bit-identical to LocalPool."""
    data, queries = pds
    slow = Fabric("slow", rtt_s=100e-6, bw_Bps=0.5e9, per_op_s=5e-6,
                  max_doorbell=32)
    base = _build("local", data, cache_frac=0.1)
    eng = _build("sharded", data, cache_frac=0.1, n_shards=2,
                 shard_transport="sim_rdma",
                 shard_fabrics=(RDMA_100G, slow),
                 placement=FrequencyAwarePlacement(migrate_every=24,
                                                   max_moves=4))
    hot = np.tile(queries[:4], (4, 1))
    for _ in range(8):
        dh, gh, st = eng.search(hot, k=10)
    dh0, gh0, _ = base.search(hot, k=10)
    assert np.array_equal(dh0, dh) and np.array_equal(gh0, gh)
    snap = st["pool"]
    assert snap["migration"]["n"] >= 1, "skewed load should migrate"
    d0, g0, _ = base.search(queries, k=10)
    d1, g1, _ = eng.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    base.insert(queries[:2] + 0.002)
    eng.insert(queries[:2] + 0.002)
    d0, g0, _ = base.search(queries[:8], k=10)
    d1, g1, _ = eng.search(queries[:8], k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)


def test_sharded_hetero_fabric_straggler_dominates(pds):
    """Heterogeneous shards, parallel fan-out: the modeled time of every
    doorbell fan-out is the slowest shard's slice, so the pool clock is
    bounded below by the straggler and well under the serial sum."""
    data, _ = pds
    fast = RDMA_100G
    slow = Fabric("slow", rtt_s=200e-6, bw_Bps=0.125e9, per_op_s=25e-6,
                  max_doorbell=32)

    def run(parallel):
        s, _ = _tiny_store(data)
        pool = ShardedPool(
            s, [lambda st: SimulatedRDMAPool(st, fabric=fast),
                lambda st: SimulatedRDMAPool(st, fabric=slow)],
            placement=RoundRobinPlacement(), parallel=parallel)
        led = NetLedger(RDMA_100G)
        for i in range(3):
            pool.read_spans(np.arange(8), ledger=led, doorbell=4)
        pool.post_row_reads([(p, 2) for p in range(8)], ledger=led,
                            doorbell=4)
        return pool, led

    par, led_p = run(True)
    ser, led_s = run(False)
    fast_t = par.children[0].sim_total_s
    slow_t = par.children[1].sim_total_s
    assert slow_t > 10 * fast_t          # it IS a straggler
    # parallel: critical path == the straggler's slices
    assert slow_t <= par.sim_total_s <= slow_t * 1.05
    # serial: every slice pays — and the charged trips double too
    assert ser.sim_total_s == pytest.approx(fast_t + slow_t)
    assert led_s.round_trips > led_p.round_trips
    # data and bytes never depend on the reduction
    assert led_s.bytes == led_p.bytes
    assert led_s.descriptors == led_p.descriptors


def test_sharded_raw_row_verbs_match_local(pds):
    """Row-granular verbs fan out by owning shard and reassemble into
    exactly what a single pool returns (dead -1 lanes included)."""
    data, _ = pds
    s0, _ = _tiny_store(data)
    s1, _ = _tiny_store(data)
    lp = LocalPool(s0)
    sp = ShardedPool(s1, [lambda st: LocalPool(st) for _ in range(3)])
    rows = np.array([[0, 65, 130], [200, -1, 7]], np.int32)
    a = np.asarray(lp.read_rows(rows))
    b = np.asarray(sp.read_rows(rows))
    live = rows >= 0
    assert np.array_equal(a[live], b[live])


def test_sim_transport_parallel_fanout_hook(pds):
    """The fan-out hook on SimulatedRDMAPool itself: scalar charges are
    bit-identical with or without ``parallel``; per-destination vector
    charges reduce by max (parallel) vs sum (serial)."""
    data, _ = pds
    s0, _ = _tiny_store(data)
    s1, _ = _tiny_store(data)
    ser = SimulatedRDMAPool(s0, fabric=RDMA_100G, parallel=False)
    par = SimulatedRDMAPool(s1, fabric=RDMA_100G, parallel=True)
    led_a, led_b = NetLedger(RDMA_100G), NetLedger(RDMA_100G)
    ser.read_spans(np.arange(6), ledger=led_a, doorbell=3)
    par.read_spans(np.arange(6), ledger=led_b, doorbell=3)
    assert ser.sim_s == par.sim_s          # scalar path: identical
    assert led_a.as_dict() == led_b.as_dict()
    ser._transport("fanout", [1e6, 2e6], [4, 4], [1, 1])
    par._transport("fanout", [1e6, 2e6], [4, 4], [1, 1])
    assert ser.sim_s["fanout"] == pytest.approx(
        ser.model_dt(1e6, 4, 1) + ser.model_dt(2e6, 4, 1))
    assert par.sim_s["fanout"] == pytest.approx(par.model_dt(2e6, 4, 1))


# ------------------------------------------------------------ placement

def test_placement_round_robin_and_size_balanced():
    rr = RoundRobinPlacement().place(10, 3)
    assert rr.tolist() == [0, 1, 2, 0, 1, 2, 0, 1, 2, 0]
    sizes = np.array([100, 1, 1, 1, 50, 49, 1, 1])
    owner = SizeBalancedPlacement().place(8, 2, group_sizes=sizes)
    loads = [sizes[owner == s].sum() for s in (0, 1)]
    assert abs(loads[0] - loads[1]) <= sizes.max() // 2


def test_placement_freq_moves_hot_to_cheap_shard():
    pol = FrequencyAwarePlacement(migrate_every=8, max_moves=2,
                                  min_gain=0.01)
    owner = pol.place(6, 2)               # round-robin start
    due = False
    for _ in range(20):                   # group 0 (shard 0) is blazing hot
        due = pol.note_access(0) or due
    assert due
    # shard 1 is 10x faster: the hot group must move there
    moves = pol.plan_moves(owner, shard_costs=[1.0, 0.1])
    assert (0, 0, 1) in moves


def test_sim_latency_scales_with_fabric(pds):
    """The cost model is live: a slower fabric models more wire time for
    the same verbs (same counts, same results)."""
    data, queries = pds
    slow = Fabric("slow", rtt_s=50e-6, bw_Bps=1e9, per_op_s=1e-6,
                  max_doorbell=32)
    e_fast = _build("sim_rdma", data, fabric=TPU_ICI)
    e_slow = _build("sim_rdma", data, fabric=slow)
    _, gf, stf = e_fast.search(queries, k=10)
    _, gs, sts = e_slow.search(queries, k=10)
    assert np.array_equal(gf, gs)
    assert stf["net"]["round_trips"] == sts["net"]["round_trips"]
    assert sts["pool"]["sim_total_s"] > stf["pool"]["sim_total_s"]
    # and the ledger PRICES the same counts differently too
    assert sts["net"]["latency_s"] > stf["net"]["latency_s"]


# ------------------------------------------------------- capacity layer

def test_apply_budgets_keeps_within_budget_and_spills():
    """A group that would overflow its shard spills to the next-best
    shard with room (cheapest, then least loaded); in-budget groups
    stay exactly where the policy put them."""
    from repro.pool.placement import apply_budgets
    owner = np.array([0, 0, 0, 0, 1, 1], np.int64)
    sizes = np.array([10, 10, 10, 10, 10, 10], np.float64)
    out = apply_budgets(owner, group_sizes=sizes,
                        shard_budgets=[25, 25, 100],
                        shard_costs=[0.0, 0.0, 0.0])
    loads = [sizes[out == s].sum() for s in range(3)]
    assert loads[0] <= 25 and loads[1] <= 25
    # shard 0's overflow landed somewhere with room, not nowhere
    assert sizes.sum() == sum(loads)
    # groups that fit keep their policy assignment
    assert (out[:2] == 0).all()


def test_apply_budgets_never_rejects_data():
    """When every shard is over budget the group still lands on the
    least-loaded shard — budgets shape placement, never drop groups."""
    from repro.pool.placement import apply_budgets
    owner = np.zeros(6, np.int64)
    out = apply_budgets(owner, group_sizes=np.full(6, 10.0),
                        shard_budgets=[5, 5])
    assert set(out.tolist()) <= {0, 1}
    loads = [float((out == s).sum()) for s in (0, 1)]
    assert abs(loads[0] - loads[1]) <= 1


def test_place_replicated_distinct_shards_and_clamp():
    """Replica matrix: column 0 is the primary verbatim, further
    columns are distinct shards per group; R clamps to n_shards."""
    from repro.pool.placement import place_replicated
    owner = np.array([0, 1, 2, 0, 1], np.int64)
    reps = place_replicated(owner, 3, 2)
    assert reps.shape == (5, 2)
    assert np.array_equal(reps[:, 0], owner)
    for row in reps:
        assert row[0] != row[1]
    # R > n_shards clamps: no group can hold two copies on one shard
    reps4 = place_replicated(owner, 3, 4)
    assert reps4.shape == (5, 3)
    for row in reps4:
        assert len(set(row.tolist())) == 3
    # one shard: replication collapses to the primary column
    assert place_replicated(np.zeros(4, np.int64), 1, 3).shape == (4, 1)


def test_place_replicated_respects_budgets():
    """Replica columns prefer shards with room: with ample capacity on
    one spare shard, all secondaries land there before any shard goes
    over budget."""
    from repro.pool.placement import place_replicated
    owner = np.array([0, 1, 0, 1], np.int64)
    reps = place_replicated(owner, 3, 2, group_sizes=np.full(4, 10.0),
                            shard_budgets=[20.0, 20.0, 100.0])
    assert (reps[:, 1] == 2).all()


# ----------------------------------------------------------- replication

class _DeadChild:
    """Stub standing in for a vanished memory node: every verb raises
    ``PoolUnavailableError`` exactly like a RemotePool with a dead
    socket."""

    _VERBS = ("read_spans", "read_rows", "read_quant_rows", "append",
              "repack", "refresh_blocks", "adopt", "_stage_quant",
              "snapshot", "close")

    def __getattr__(self, name):
        from repro.pool.protocol import PoolUnavailableError
        if name in self._VERBS:
            def boom(*a, **k):
                raise PoolUnavailableError("node down (test stub)")
            return boom
        raise AttributeError(name)


def test_replicated_pool_bit_identical_with_parity(pds):
    """replication=2 changes WHERE bytes live, never the results or the
    request-side accounting: search is bit-identical to LocalPool and
    the ledger parity of the conformance gate holds at R=2."""
    data, queries = pds
    base = _build("local", data)
    eng = _build("sharded", data, n_shards=3, replication=2)
    d0, g0, st0 = base.search(queries, k=10)
    d1, g1, st1 = eng.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st1["net"][key], key
    snap = st1["pool"]
    assert snap["replication"] == 2
    assert sum(snap["replicas_by_shard"]) == 2 * base.store.spec.n_groups
    # every group serves from exactly one live replica
    assert sum(snap["groups_by_shard"]) == base.store.spec.n_groups


def test_replica_selection_prefers_cheapest_live_shard(pds):
    """Reads are served by the fastest live replica: with one fast and
    one straggler shard at R=2 every group's serving replica is the
    fast shard, and the straggler receives no span traffic."""
    data, _ = pds
    slow = Fabric("slow", rtt_s=200e-6, bw_Bps=0.125e9, per_op_s=25e-6,
                  max_doorbell=32)
    s, _ = _tiny_store(data)
    pool = ShardedPool(
        s, [lambda st: SimulatedRDMAPool(st, fabric=RDMA_100G),
            lambda st: SimulatedRDMAPool(st, fabric=slow)],
        replication=2)
    assert all(pool.owner_of_group(g) == 0
               for g in range(s.spec.n_groups))
    led = NetLedger(RDMA_100G)
    pool.read_spans(np.arange(6), ledger=led, doorbell=3)
    assert pool.children[1].verbs.get("read_spans", 0) == 0


def test_failover_mid_stream_is_transparent(pds):
    """Kill one shard between searches at replication=2: the next
    search transparently retries on the survivors, results stay
    bit-identical to LocalPool, the dead shard's groups re-replicate,
    and subsequent inserts still fan to the remaining replicas."""
    data, queries = pds
    base = _build("local", data)
    eng = _build("sharded", data, n_shards=3, replication=2)
    base.search(queries, k=10)
    eng.search(queries, k=10)
    pool = eng.pool
    pool.children[0] = _DeadChild()
    d0, g0, st0 = base.search(queries, k=10)
    d1, g1, st1 = eng.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    # ledger parity survives the retry: the dead slice charged nothing,
    # the surviving replica charged exactly once
    for key in ("round_trips", "descriptors", "bytes"):
        assert st0["net"][key] == st1["net"][key], key
    fo = st1["pool"]["failover"]
    assert fo["deaths"] == 1
    assert fo["read_retries"] >= 1
    assert fo["lost_groups"] == 0
    assert fo["rereplicated_groups"] >= 1
    assert st1["pool"]["alive"] == [False, True, True]
    # writes after the death: inserted vectors remain searchable and
    # identical to the single-pool engine
    new = queries[:2] + 0.001
    assert np.array_equal(base.insert(new), eng.insert(new))
    d2, g2, _ = base.search(queries[:8], k=10)
    d3, g3, _ = eng.search(queries[:8], k=10)
    assert np.array_equal(d2, d3) and np.array_equal(g2, g3)
    assert pool.replication_io["fanout_writes"] >= 1


def test_single_replica_death_still_surfaces(pds):
    """replication=1 has nothing to fail over to: a dead shard's groups
    raise PoolUnavailableError, exactly the pre-replication contract."""
    from repro.pool.protocol import PoolUnavailableError
    data, queries = pds
    eng = _build("sharded", data, n_shards=2, replication=1)
    eng.search(queries[:4], k=10)
    eng.pool.children[0] = _DeadChild()
    with pytest.raises(PoolUnavailableError):
        eng.search(queries[:4], k=10)


def test_elastic_add_remove_shard(pds):
    """Live fleet changes: add_shard migrates only the groups the
    policy newly maps there; remove_shard drains through re-replication.
    Results stay bit-identical throughout."""
    data, queries = pds
    base = _build("local", data)
    eng = _build("sharded", data, n_shards=2, replication=2)
    d0, g0, _ = base.search(queries, k=10)
    pool = eng.pool
    new = pool.add_shard(lambda st: LocalPool(st))
    assert new == 2 and pool.n_shards == 3
    d1, g1, _ = eng.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    assert pool.elastic["added"] == 1
    assert pool.elastic["moved_groups"] >= 1
    pool.remove_shard(0)
    d2, g2, _ = eng.search(queries, k=10)
    assert np.array_equal(d0, d2) and np.array_equal(g0, g2)
    snap = pool.snapshot()
    assert snap["alive"] == [False, True, True]
    assert snap["failover"]["deaths"] == 0      # planned, not a death
    assert snap["elastic"]["removed"] == 1
    assert snap["failover"]["lost_groups"] == 0


def test_shard_budgets_cap_primary_load(pds):
    """Per-shard byte budgets bound how many groups a shard owns: with
    one group's footprint as shard 0's budget, at most one primary can
    live there and the rest spill — results unchanged."""
    data, queries = pds
    base = _build("local", data)
    eng_free = _build("sharded", data, n_shards=2)
    fp = eng_free.pool._group_footprint_bytes()
    eng = _build("sharded", data, n_shards=2,
                 shard_budgets=(fp, fp * 64))
    d0, g0, _ = base.search(queries, k=10)
    d1, g1, st = eng.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    assert st["pool"]["groups_by_shard"][0] <= 1
