"""Transport conformance suite for the MemoryPool boundary.

Every transport must serve the SAME serialized layout with bit-identical
search/insert results and verb accounting that agrees with the
``NetLedger`` the schemes charge — a new transport passes this file or
it isn't a d-HNSW memory pool.  Runs against ``LocalPool`` and
``SimulatedRDMAPool`` (fast: tiny dataset, no slow mark).
"""
import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, TPU_ICI, Fabric, NetLedger
from repro.core.hnsw import HNSWParams
from repro.core.layout import build_store
from repro.core.meta import build_meta
from repro.pool import LocalPool, SimulatedRDMAPool

POOLS = ("local", "sim_rdma")
CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3, fabric=RDMA_100G)


@pytest.fixture(scope="module")
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:24]


def _build(pool: str, data, **over):
    cfg = {**CFG, **over, "pool": pool}
    return DHNSWEngine(EngineConfig(**cfg)).build(data)


# ----------------------------------------------------------- conformance

@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("mode", ["naive", "full"])
def test_pools_bit_identical_search_insert(pds, mode, quant):
    """Same layout, same results, same counted network — the transport
    may only change HOW bytes move, never WHAT the compute side sees."""
    data, queries = pds
    engines = {p: _build(p, data, mode=mode, quant=quant) for p in POOLS}
    stores = [e.store for e in engines.values()]
    assert np.array_equal(stores[0].graph_buf, stores[1].graph_buf)
    assert np.array_equal(stores[0].vec_buf, stores[1].vec_buf)
    assert np.array_equal(stores[0].meta_table, stores[1].meta_table)

    res = {p: e.search(queries, k=10) for p, e in engines.items()}
    d0, g0, st0 = res["local"]
    d1, g1, st1 = res["sim_rdma"]
    assert np.array_equal(g0, g1)
    assert np.array_equal(d0, d1)
    for key in ("round_trips", "descriptors", "bytes", "bytes_saved"):
        assert st0["net"][key] == st1["net"][key], key

    # inserts route through the append verb on both transports
    new = queries[:3] + 0.001
    gids = {p: e.insert(new) for p, e in engines.items()}
    assert np.array_equal(gids["local"], gids["sim_rdma"])
    r2 = {p: e.search(queries[:8], k=10) for p, e in engines.items()}
    assert np.array_equal(r2["local"][1], r2["sim_rdma"][1])
    assert np.array_equal(r2["local"][0], r2["sim_rdma"][0])

    # the simulated transport models nonzero wire time; local moves
    # bytes over nothing
    assert st1["pool"]["sim_total_s"] > 0
    assert "sim_total_s" not in st0["pool"]


@pytest.mark.parametrize("pool", POOLS)
def test_verb_counts_match_ledger(pds, pool):
    """Pool-side running totals == the sum of every NetLedger the
    engine charged (searches + inserts): the transport and the scheme
    accounting can never drift apart."""
    data, queries = pds
    eng = _build(pool, data, quant="int8")
    totals = {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}

    def add(net):
        for k in totals:
            totals[k] += net[k]

    for i in range(3):
        _, _, st = eng.search(queries[i * 8:(i + 1) * 8], k=10)
        add(st["net"])
    eng.insert(queries[:2] + 0.001)
    add(eng._last_insert_net)
    snap = eng.pool.snapshot()
    for k in totals:
        assert snap["totals"][k] == pytest.approx(totals[k]), k
    assert snap["verbs"]["read_meta"] >= 3
    assert snap["verbs"]["append"] == 2


# ---------------------------------------------------------- verb level

def _tiny_store(data):
    meta = build_meta(data, 8, seed=0, meta_levels=2)
    store = build_store(data, meta,
                        sub_params=HNSWParams(M=4, M0=8,
                                              ef_construction=40))
    return store, meta


def test_raw_verbs_agree_across_transports(pds):
    """Verb-by-verb: both transports return identical device data for
    identical descriptors, and charge identical ledgers."""
    data, _ = pds
    s0, _ = _tiny_store(data)
    s1, _ = _tiny_store(data)
    lp = LocalPool(s0)
    sp = SimulatedRDMAPool(s1, fabric=RDMA_100G)
    led_l, led_s = NetLedger(RDMA_100G), NetLedger(RDMA_100G)

    pids = np.array([0, 3, 5, 6])
    gl, vl = lp.read_spans(pids, ledger=led_l, doorbell=2)
    gs, vs = sp.read_spans(pids, ledger=led_s, doorbell=2)
    assert np.array_equal(np.asarray(gl), np.asarray(gs))
    assert np.array_equal(np.asarray(vl), np.asarray(vs))
    assert led_l.as_dict() == led_s.as_dict()
    assert lp.totals == sp.totals

    rows = np.array([[0, 5, 9], [2, -1, 7]], np.int32)
    assert np.array_equal(np.asarray(lp.read_rows(rows)),
                          np.asarray(sp.read_rows(rows)))

    vec = data[0] + 0.5
    slot_l = lp.append(vec, 9999, 1, ledger=led_l)
    slot_s = sp.append(vec, 9999, 1, ledger=led_s)
    assert slot_l == slot_s >= 0
    assert np.array_equal(s0.vec_buf, s1.vec_buf)
    assert np.array_equal(s0.graph_buf, s1.graph_buf)
    assert np.array_equal(s0.meta_table, s1.meta_table)
    assert led_l.as_dict() == led_s.as_dict()
    # per-verb sim breakdown covers exactly the charged verbs
    assert set(sp.sim_s) == {"read_spans", "append"}
    assert sp.sim_total_s > 0


def test_sim_latency_scales_with_fabric(pds):
    """The cost model is live: a slower fabric models more wire time for
    the same verbs (same counts, same results)."""
    data, queries = pds
    slow = Fabric("slow", rtt_s=50e-6, bw_Bps=1e9, per_op_s=1e-6,
                  max_doorbell=32)
    e_fast = _build("sim_rdma", data, fabric=TPU_ICI)
    e_slow = _build("sim_rdma", data, fabric=slow)
    _, gf, stf = e_fast.search(queries, k=10)
    _, gs, sts = e_slow.search(queries, k=10)
    assert np.array_equal(gf, gs)
    assert stf["net"]["round_trips"] == sts["net"]["round_trips"]
    assert sts["pool"]["sim_total_s"] > stf["pool"]["sim_total_s"]
    # and the ledger PRICES the same counts differently too
    assert sts["net"]["latency_s"] > stf["net"]["latency_s"]
