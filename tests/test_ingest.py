"""repro.ingest — WAL, checkpoints, crash recovery, bulk load, compaction.

Coverage tiers:

* **WAL framing** — encode/decode round trips, torn-tail semantics
  (short header, short body, oversized length, CRC breakage all stop
  replay cleanly), plus a hypothesis property test when available.
* **checkpoints** — atomic save/load round trip, corruption surfaced as
  IOError, the ``Durability`` cadence + WAL rotation invariants.
* **crash recovery** — kill -9 a durable ``PoolServer`` mid-ingest and
  restart it from its ``--data-dir``: the recovered region must be
  bit-identical (verified through the ``attach="auto"`` fingerprint
  handshake and span reads), recovery must come from WAL replay, and at
  engine scale (replication=2) a recovered shard rejoins with zero lost
  groups and bit-identical search results.
* **bulk load** — the out-of-core ``BulkLoader`` reproduces the
  in-memory build bit for bit with O(chunk) peak builder memory; the
  parse/validate/retry error queue; group-by-group shipping accounting.
* **compaction** — the mutation-hook-driven ``Compactor`` repacks dirty
  over-threshold groups under its rate budget.
"""
import os
import signal

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:         # CI fast tier / bare containers
    HAVE_HYPOTHESIS = False

from repro.core import DHNSWEngine, EngineConfig, build_meta, build_store
from repro.core.hnsw import HNSWParams
from repro.core.layout import MT_OV_A, MT_OV_B
from repro.ingest import (BulkLoader, CompactionPolicy, Compactor,
                          Durability, chunked_source, encode_record,
                          iter_records, load_checkpoint, read_wal,
                          save_checkpoint)
from repro.ingest.wal import _HDR, MAX_BODY
from repro.net import RemotePool, spawn_pool_servers
from repro.net import wire as W
from repro.pool import LocalPool

CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3)


def _tiny_store(data, ov_cap=0):
    meta = build_meta(data, 8, seed=0, meta_levels=2)
    return build_store(data, meta, ov_cap=ov_cap,
                       sub_params=HNSWParams(M=4, M0=8, ef_construction=40))


# ------------------------------------------------------------ WAL framing

def test_wal_record_roundtrip_and_validation():
    rec = encode_record(7, 0x1234, b"payload bytes")
    [out] = list(iter_records(rec))
    assert (out.op, out.flags, out.payload) == (7, 0x1234, b"payload bytes")
    # empty payload is legal (e.g. a zero-arg verb)
    [out] = list(iter_records(encode_record(1, 0, b"")))
    assert out.payload == b""
    with pytest.raises(ValueError):
        encode_record(256, 0, b"")
    with pytest.raises(ValueError):
        encode_record(-1, 0, b"")
    with pytest.raises(ValueError):
        encode_record(0, 0x1_0000, b"")


def test_wal_torn_tail_variants_stop_cleanly():
    """Every way a crash can tear the tail reads as a clean end-of-log:
    the committed prefix replays, nothing raises."""
    good = encode_record(2, 0, b"aaaa") + encode_record(3, 1, b"bb")
    torn = [
        good + b"\x05",                              # short header
        good + _HDR.pack(100, 0),                    # short body
        good + _HDR.pack(MAX_BODY + 1, 0) + b"x" * 64,   # absurd length
        good + encode_record(4, 0, b"cc")[:-1],      # truncated record
    ]
    # CRC breakage: flip a byte inside the last record's body
    bad = bytearray(good + encode_record(4, 0, b"cc"))
    bad[-1] ^= 0xFF
    torn.append(bytes(bad))
    for buf in torn:
        recs = list(iter_records(buf))
        assert [(r.op, r.payload) for r in recs] == [(2, b"aaaa"),
                                                     (3, b"bb")]


def test_read_wal_reports_torn_bytes(tmp_path):
    p = str(tmp_path / "w.log")
    full = encode_record(9, 0, b"x" * 10)
    with open(p, "wb") as f:
        f.write(full + full[: len(full) // 2])
    recs, torn = read_wal(p)
    assert len(recs) == 1 and torn == len(full) // 2
    # a missing file is an empty log, not an error (fresh server)
    assert read_wal(str(tmp_path / "absent.log")) == ([], 0)


if HAVE_HYPOTHESIS:
    @given(ops=st.lists(st.tuples(st.integers(0, 255),
                                  st.integers(0, 0xFFFF),
                                  st.binary(max_size=200)),
                        max_size=20),
           cut=st.integers(0, 50))
    @settings(max_examples=60, deadline=None)
    def test_wal_roundtrip_property(ops, cut):
        """Any record sequence round-trips; truncating the serialized
        log anywhere yields a committed prefix, never garbage."""
        buf = b"".join(encode_record(o, f, p) for o, f, p in ops)
        back = [(r.op, r.flags, r.payload) for r in iter_records(buf)]
        assert back == ops
        # arbitrary truncation: a (possibly shorter) committed prefix
        cropped = [(r.op, r.flags, r.payload)
                   for r in iter_records(buf[:max(0, len(buf) - cut)])]
        assert cropped == ops[:len(cropped)]


# ----------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip_and_corruption(tmp_path, sift_small):
    data = sift_small.data[:600]
    store = _tiny_store(data, ov_cap=4)
    d = str(tmp_path)
    n = save_checkpoint(d, store, applied=17)
    assert n > 0 and not os.path.exists(os.path.join(d, "checkpoint.bin.tmp"))
    back, applied = load_checkpoint(d)
    assert applied == 17
    assert np.array_equal(back.graph_buf, store.graph_buf)
    assert np.array_equal(back.vec_buf, store.vec_buf)
    assert np.array_equal(back.meta_table, store.meta_table)
    assert np.array_equal(back.n_base, store.n_base)
    assert back.spec == store.spec
    # corruption must surface, not silently serve
    p = os.path.join(d, "checkpoint.bin")
    blob = bytearray(open(p, "rb").read())
    blob[-1] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    with pytest.raises(IOError):
        load_checkpoint(d)
    # absent checkpoint -> None (fresh data dir)
    assert load_checkpoint(str(tmp_path / "fresh")) is None


def test_durability_cadence_rotation_and_recovery(tmp_path, sift_small):
    """The orchestrator invariants: log -> cadence checkpoint -> WAL
    rotation (new log named by applied count, old log removed) ->
    recover replays exactly the un-checkpointed tail once."""
    data = sift_small.data[:600]
    store = _tiny_store(data)
    d = str(tmp_path / "srv")
    dur = Durability(d, checkpoint_every=4)
    assert dur.recover() == (None, [])

    for i in range(6):
        dur.log(W.OP_APPEND, 0, b"m%d" % i)
        fired = dur.maybe_checkpoint(store)
        assert fired == (i == 3)      # cadence: exactly at the 4th record
    st = dur.stats()
    assert st["applied"] == 6 and st["checkpoints"] == 1
    assert st["wal_records"] == 2     # rotated: only the post-ckpt tail
    assert os.path.exists(os.path.join(d, "wal.000000000004.log"))
    assert not os.path.exists(os.path.join(d, "wal.000000000000.log"))
    dur.close()

    dur2 = Durability(d, checkpoint_every=4)
    store2, tail = dur2.recover()
    assert store2 is not None and np.array_equal(store2.vec_buf,
                                                 store.vec_buf)
    assert [(r.op, r.payload) for r in tail] == [(W.OP_APPEND, b"m4"),
                                                 (W.OP_APPEND, b"m5")]
    assert dur2.applied == 6 and dur2.stats()["recovered"]
    # replay must never re-log (that would double records on next crash)
    with dur2.replay_guard():
        dur2.log(W.OP_APPEND, 0, b"replayed")
    assert dur2.stats()["wal_records"] == 0
    # checkpoints with cadence disabled never fire
    dur2.checkpoint_every = 0
    assert not dur2.maybe_checkpoint(store)
    dur2.close()


# -------------------------------------------------------- crash recovery

def test_poolserver_kill9_recovers_from_wal(tmp_path, sift_small):
    """The acceptance gate at pool scale: kill -9 a durable server
    mid-ingest, restart from the same data-dir, and the recovered
    region is bit-identical — proven by the ``attach="auto"``
    fingerprint handshake (no re-upload), WAL-replay counters, and span
    reads matching an uninterrupted ``LocalPool`` twin.  A garbage tail
    appended to the WAL (the torn write) must not poison replay."""
    data = sift_small.data[:600]
    ddir = str(tmp_path / "node0")
    s_ctl = _tiny_store(data, ov_cap=8)
    ctl = LocalPool(s_ctl)
    vecs = [data[0] + 0.01 * (i + 1) for i in range(6)]

    with spawn_pool_servers(1, data_dirs=[ddir], with_procs=True) as (
            eps, procs):
        rp = RemotePool(_tiny_store(data, ov_cap=8), eps[0])
        for i, v in enumerate(vecs):
            assert ctl.append(v, 50_000 + i, 1, ledger=None) \
                == rp.append(v, 50_000 + i, 1, ledger=None) >= 0
        os.kill(procs[0].pid, signal.SIGKILL)     # no goodbye
        procs[0].wait(timeout=10)

    # torn write: a half-record of garbage at the WAL tail
    [wal] = [f for f in os.listdir(ddir) if f.startswith("wal.")]
    with open(os.path.join(ddir, wal), "ab") as f:
        f.write(b"\xde\xad\xbe\xef\x00")

    with spawn_pool_servers(1, data_dirs=[ddir]) as eps2:
        # the mirror of an uninterrupted run (base region + appends)
        pool = RemotePool(s_ctl, eps2[0], attach="auto")
        assert pool.attached_via == "recovered", \
            "recovery must come from the data-dir, not a re-upload"
        ing = pool.server_stats()["ingest"]
        assert ing["recovered"] and ing["replayed_records"] >= 1 + len(vecs)
        assert ing["torn_bytes"] == 5
        a = ctl.read_spans(np.arange(4), ledger=None)
        b = pool.read_spans(np.arange(4), ledger=None)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))
        server_meta, n_base = pool.server_meta()
        assert np.array_equal(server_meta, s_ctl.meta_table)
        assert np.array_equal(n_base, s_ctl.n_base)


def test_poolserver_checkpoint_plus_tail_recovery(tmp_path, sift_small):
    """With an aggressive checkpoint cadence the restart recovers
    snapshot + short tail instead of replaying the whole history."""
    data = sift_small.data[:600]
    ddir = str(tmp_path / "node0")
    s_ctl = _tiny_store(data, ov_cap=8)
    ctl = LocalPool(s_ctl)

    # cadence 4 over 6 mutations (attach + 5 appends): one checkpoint
    # fires at record 4, leaving a genuine 2-record WAL tail
    with spawn_pool_servers(1, data_dirs=[ddir], checkpoint_every=4,
                            with_procs=True) as (eps, procs):
        rp = RemotePool(_tiny_store(data, ov_cap=8), eps[0])
        for i in range(5):
            v = data[1] + 0.01 * (i + 1)
            assert ctl.append(v, 60_000 + i, 3, ledger=None) \
                == rp.append(v, 60_000 + i, 3, ledger=None) >= 0
        os.kill(procs[0].pid, signal.SIGKILL)
        procs[0].wait(timeout=10)

    assert os.path.exists(os.path.join(ddir, "checkpoint.bin"))
    with spawn_pool_servers(1, data_dirs=[ddir]) as eps2:
        pool = RemotePool(s_ctl, eps2[0], attach="auto")
        assert pool.attached_via == "recovered"
        ing = pool.server_stats()["ingest"]
        assert ing["recovered"]
        # tail replay is SHORT: the checkpoint folded most mutations in
        assert 0 < ing["replayed_records"] < 1 + 5
        b = pool.read_spans(np.arange(4), ledger=None)
        a = ctl.read_spans(np.arange(4), ledger=None)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_engine_kill9_mid_ingest_recovered_shard_rejoins(tmp_path,
                                                         sift_small):
    """The ISSUE acceptance test end to end: an engine over two durable
    replicated servers; kill -9 one mid-ingest; searches stay bit-
    identical with zero lost groups (replication holds the fort); the
    restarted server recovers from its WAL and ``recover_shard`` rejoins
    it through the fingerprint handshake — after which inserts and
    searches on the healed pool still match the local twin bit for bit."""
    data = sift_small.data[:1200]
    queries = sift_small.queries[:16]
    d0, d1 = str(tmp_path / "n0"), str(tmp_path / "n1")
    base = DHNSWEngine(EngineConfig(**CFG)).build(data)

    with spawn_pool_servers(2, data_dirs=[d0, d1], with_procs=True) as (
            eps, procs):
        eng = DHNSWEngine(EngineConfig(pool="remote", endpoints=tuple(eps),
                                       replication=2, **CFG)).build(data)
        new1 = queries[:3] + 0.001
        assert np.array_equal(base.insert(new1), eng.insert(new1))
        da, ga, _ = base.search(queries, k=10)
        db, gb, _ = eng.search(queries, k=10)
        assert np.array_equal(da, db) and np.array_equal(ga, gb)

        os.kill(procs[0].pid, signal.SIGKILL)   # mid-ingest: WAL has the
        procs[0].wait(timeout=10)               # appends, nothing else does
        db, gb, st = eng.search(queries, k=10)
        assert np.array_equal(da, db) and np.array_equal(ga, gb)
        fo = st["pool"]["failover"]
        assert fo["deaths"] == 1 and fo["lost_groups"] == 0

        # restart node 0 from its data dir and rejoin it in place
        with spawn_pool_servers(1, data_dirs=[d0]) as eps2:
            eng.pool.recover_shard(
                0, lambda store: RemotePool(store, eps2[0], attach="auto"))
            child = eng.pool.children[0]
            assert child.attached_via == "recovered", \
                "rejoin must ride the WAL recovery, not a region re-upload"
            ing = child.server_stats()["ingest"]
            assert ing["recovered"] and ing["replayed_records"] > 0
            snap = eng.pool.snapshot()
            fo = snap["failover"]
            assert fo["recovered_shards"] == 1
            assert fo["recovered_groups"] > 0
            assert fo["lost_groups"] == 0
            assert snap["alive"] == [True, True]

            new2 = queries[3:6] + 0.002
            assert np.array_equal(base.insert(new2), eng.insert(new2))
            da2, ga2, _ = base.search(queries[:8], k=10)
            db2, gb2, _ = eng.search(queries[:8], k=10)
            assert np.array_equal(da2, db2) and np.array_equal(ga2, gb2)


# --------------------------------------------------------- bulk loading

def test_bulk_loader_bit_identical_bounded_memory(sift_small):
    """The loader acceptance gate: streaming with a chunk budget of 1/8
    of the dataset reproduces the in-memory meta + region bit for bit,
    while peak builder memory stays O(chunk), not O(dataset)."""
    data = sift_small.data[:1600]
    n, dim = data.shape
    chunk_rows = n // 8
    p = HNSWParams(M=4, M0=8, ef_construction=40)

    meta0 = build_meta(data, 12, seed=3, meta_levels=3)
    store0 = build_store(data, meta0, sub_params=p)

    ld = BulkLoader(n_rep=12, chunk_rows=chunk_rows, seed=3, meta_levels=3,
                    sub_params=p)
    ld.add_chunks(chunked_source(data, chunk_rows))
    meta, store, rep = ld.finalize()
    ld.close()

    assert np.array_equal(meta.graph.vectors, meta0.graph.vectors)
    assert np.array_equal(meta.graph.adjacency, meta0.graph.adjacency)
    assert meta.graph.entry == meta0.graph.entry
    assert np.array_equal(meta.assignments, meta0.assignments)
    assert np.array_equal(store.graph_buf, store0.graph_buf)
    assert np.array_equal(store.vec_buf, store0.vec_buf)
    assert np.array_equal(store.meta_table, store0.meta_table)
    assert np.array_equal(store.n_base, store0.n_base)
    assert store.spec == store0.spec

    assert rep.rows == n and rep.chunks_ok == 8 and rep.chunks_failed == 0
    assert rep.dataset_bytes == n * dim * 4
    # bounded memory: the builder never held anything near the dataset
    assert rep.peak_builder_bytes < rep.dataset_bytes / 2
    assert rep.peak_builder_bytes <= 4 * rep.chunk_bytes + 12 * dim * 4


def test_bulk_loader_error_queue_and_retry():
    """Bad chunks land in the retryable error queue instead of aborting;
    ``retry_failed`` with a fix recovers them and the final region covers
    every row."""
    rng = np.random.default_rng(0)
    good = rng.standard_normal((300, 16)).astype(np.float32)
    nan_chunk = good[:50].copy()
    nan_chunk[3, 2] = np.nan
    ld = BulkLoader(n_rep=6, chunk_rows=100, seed=0, meta_levels=2,
                    sub_params=HNSWParams(M=4, M0=8, ef_construction=40))
    ld.add_chunks([good[:100], nan_chunk, "not an array", good[100:200],
                   good[:10, None, :]])          # 3-D: wrong rank
    assert ld.report.chunks_total == 5
    assert ld.report.chunks_ok == 2 and ld.report.chunks_failed == 3
    assert len(ld.error_queue) == 3
    assert all(r in {fc.index for fc in ld.error_queue} for r in (1, 2, 4))

    def fix(chunk):
        arr = np.asarray(chunk, np.float32) if not isinstance(chunk, str) \
            else good[200:250]
        arr = arr.reshape(-1, 16) if arr.ndim == 3 else arr
        return np.nan_to_num(arr)

    assert ld.retry_failed(fix=fix) == 3
    assert not ld.error_queue and ld.report.chunks_retried == 3
    meta, store, rep = ld.finalize()
    ld.close()
    assert rep.rows == 100 + 50 + 50 + 100 + 10
    assert store.n_base.sum() == rep.rows
    # unfixable chunks stay queued with their latest reason
    ld2 = BulkLoader(n_rep=4, chunk_rows=50, seed=0, meta_levels=2)
    ld2.add_chunks([good[:50], "junk"])
    assert ld2.retry_failed() == 0
    assert ld2.error_queue[0].retries == 1 and ld2.error_queue[0].reason
    ld2.close()


def test_bulk_loader_ships_groups_through_pool_verb():
    """``finalize(into_pool=...)``: every finished group goes out
    immediately through ``refresh_blocks`` — one verb per group, ids
    covering exactly that group's block span."""
    rng = np.random.default_rng(1)
    data = rng.standard_normal((500, 16)).astype(np.float32)

    class _ShipLog:
        def __init__(self):
            self.calls = []

        def refresh_blocks(self, ids):
            self.calls.append(np.asarray(ids))

    ship = _ShipLog()
    ld = BulkLoader(n_rep=8, chunk_rows=100, seed=0, meta_levels=2,
                    sub_params=HNSWParams(M=4, M0=8, ef_construction=40))
    ld.add_chunks(chunked_source(data, 100))
    meta, store, rep = ld.finalize(into_pool=ship)
    ld.close()
    n_groups = store.spec.n_groups
    assert rep.verbs_issued == rep.groups_shipped == n_groups
    assert len(ship.calls) == n_groups
    gb = store.spec.group_blocks
    shipped = np.concatenate(ship.calls)
    assert np.array_equal(np.sort(shipped), np.arange(n_groups * gb))


def test_chunked_source_covers_everything():
    data = np.arange(23 * 3, dtype=np.float32).reshape(23, 3)
    chunks = list(chunked_source(data, 10))
    assert [len(c) for c in chunks] == [10, 10, 3]
    assert np.array_equal(np.concatenate(chunks), data)


def test_engine_build_streaming_bit_identical(sift_small):
    """`DHNSWEngine.build_streaming` — the wired-up loader — searches
    bit-identically to `build`, reports bounded builder memory, and
    (satellite: kernel routing) both engines pick the jnp ref stage-1
    on the CPU backend under ``quant_kernel="auto"``."""
    data = sift_small.data[:1500]
    queries = sift_small.queries[:16]
    common = dict(mode="full", search_mode="scan", n_rep=16, b=3, ef=32,
                  cache_frac=4.0, seed=3, quant="int8",
                  quant_kernel="auto")
    mem = DHNSWEngine(EngineConfig(**common)).build(data)
    stream = DHNSWEngine(EngineConfig(**common)).build_streaming(
        chunked_source(data, 200), chunk_rows=200)
    d0, g0, st0 = mem.search(queries, k=10)
    d1, g1, st1 = stream.search(queries, k=10)
    assert np.array_equal(d0, d1) and np.array_equal(g0, g1)
    rep = stream.last_load_report
    assert rep.peak_builder_bytes < rep.dataset_bytes / 2
    import jax
    if jax.default_backend() == "cpu":
        assert st0["stage1_impl"] == st1["stage1_impl"] == "ref"
    # inserts read vectors back through the disk-backed view
    new = queries[:2] + 0.001
    assert np.array_equal(mem.insert(new), stream.insert(new))
    da, ga, _ = mem.search(queries[:8], k=10)
    db, gb, _ = stream.search(queries[:8], k=10)
    assert np.array_equal(da, db) and np.array_equal(ga, gb)


# ----------------------------------------------------------- compaction

def _overflow_pool(data, ov_cap=8):
    store = _tiny_store(data, ov_cap=ov_cap)
    return LocalPool(store), store


def test_mutation_hooks_fire_on_append_and_repack(sift_small):
    data = sift_small.data[:600]
    pool, store = _overflow_pool(data)
    events = []
    pool.register_mutation_hook(lambda verb, **kw: events.append((verb, kw)))
    assert pool.append(data[0] + 0.5, 90_000, 1, ledger=None) >= 0
    assert events and events[-1][0] == "append"
    assert events[-1][1]["group"] == 0 and events[-1][1]["pid"] == 1
    pool.repack(0, lambda gids: np.stack(
        [data[g] if g < len(data) else data[0] + 0.5 for g in gids]))
    assert events[-1][0] == "repack" and events[-1][1]["group"] == 0


def test_compactor_repacks_dirty_groups_under_budget(sift_small):
    """Appends past the threshold mark groups dirty via the mutation
    hook; a tick repacks worst-first under the rate budget and the
    overflow ratio drops back to zero."""
    data = sift_small.data[:600]
    pool, store = _overflow_pool(data, ov_cap=8)
    extra = {}

    def lookup(gids):
        return np.stack([data[g] if g < len(data) else extra[g]
                         for g in (int(x) for x in gids)])

    comp = Compactor(pool, lookup,
                     CompactionPolicy(threshold=0.25,
                                      max_repacks_per_tick=1))
    assert comp.tick() == 0          # clean region: nothing to do

    # dirty two groups past the threshold (pids 1 and 3 -> groups 0, 1)
    gid = 90_000
    for pid in (1, 1, 1, 3, 3, 3):
        vec = data[pid] + 0.01 * (gid - 90_000 + 1)
        extra[gid] = vec
        assert pool.append(vec, gid, pid, ledger=None) >= 0
        gid += 1
    ratios = comp.overflow_ratios()
    assert ratios[0] > 0.25 and ratios[1] > 0.25
    assert comp.dirty == {0, 1}

    done = comp.tick()               # budget 1: one repack, one deferred
    assert done == 1 and comp.skipped_budget >= 1
    done2 = comp.tick()
    assert done2 == 1
    after = comp.overflow_ratios()
    assert after[0] == 0.0 and after[1] == 0.0
    assert comp.dirty == set()
    assert pool.verbs["repack"] >= 2
    st = comp.stats()
    assert st["groups_compacted"] == 2 and st["ticks"] == 3
    # repacked region still holds every appended vector in its base rows
    mt = np.asarray(pool.read_meta())
    assert mt[1][MT_OV_A] == 0 and mt[1][MT_OV_B] == 0
    assert int(store.n_base[1]) > 0


def test_compactor_thread_start_stop(sift_small):
    data = sift_small.data[:600]
    pool, _ = _overflow_pool(data)
    comp = Compactor(pool, lambda gids: data[np.asarray(gids, np.int64)],
                     CompactionPolicy(interval_s=0.01))
    comp.start()
    assert comp.start() is comp      # idempotent
    import time
    time.sleep(0.05)
    comp.stop()
    comp.stop()                      # idempotent
    assert comp.ticks >= 1


# -------------------------------------------------------- observability

def test_ingest_metrics_render(sift_small):
    """The Prometheus exporters cover the new counters: the pool-server
    ingest block and the bulk-load/compactor render."""
    import dataclasses

    from repro.obs.metrics import render_ingest, render_pool_server
    ld = BulkLoader(n_rep=6, chunk_rows=100, seed=0, meta_levels=2)
    ld.add_chunks(chunked_source(sift_small.data[:300], 100))
    _, _, rep = ld.finalize()
    ld.close()
    txt = render_ingest(dataclasses.asdict(rep),
                        compactor={"ticks": 3, "groups_compacted": 1})
    assert 'repro_ingest_load{what="rows"} 300' in txt
    assert 'repro_ingest_load{what="peak_builder_bytes"}' in txt
    assert 'repro_ingest_compactor_total{what="ticks"} 3' in txt

    txt = render_pool_server({"verbs": {"append": 2}, "service_s": {},
                              "ingest": {"applied": 5, "wal_records": 5}})
    assert 'repro_poolserver_ingest_total{what="applied"} 5' in txt
    assert 'repro_poolserver_ingest_total{what="wal_records"} 5' in txt
