"""repro.obs — end-to-end tracing and metrics.

Four contracts:

* **tracer mechanics** — nesting via the per-thread parent stack, ring
  capacity + drop accounting, and the disabled tracer being a true
  no-op (shared null span, nothing allocated or recorded).
* **span tree shape** — a search through ``SearchServer`` produces the
  documented taxonomy: pool verb events nest under ``compute.fetch``
  which nests under ``compute.round`` / ``compute.search`` under the
  serve window spans.
* **wire propagation** — against a loopback ``PoolServer`` the client
  negotiates FLAG_TRACE at PING, stamps verb frames with trace context,
  and harvests server-side service-time spans whose durations are
  covered by the matching client-side ``net.*`` span; a server that
  never acks the flag (old server) is simply never sent trace bytes.
* **observability is free** — with tracing off OR on, results and the
  NetLedger are bit-identical across every transport x quant combo;
  only the tracer's own buffer grows.

Plus exporter round-trips (Chrome trace JSON, Prometheus text, the
report CLI) and the serving benchmark's counted-pass determinism that
``benchmarks/perf_gate.py`` relies on.
"""
from __future__ import annotations

import json
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.net.server import PoolServer
from repro.obs import report
from repro.obs.metrics import render_pool_server, render_prometheus
from repro.obs.trace import TRACER, Tracer, chrome_trace, load_trace
from repro.serve.batcher import BatchPolicy
from repro.serve.server import SearchServer

CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3)


@pytest.fixture(autouse=True)
def _tracer_guard():
    """Every test leaves the process-global tracer disabled."""
    yield
    TRACER.disable()


@pytest.fixture()
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:16]


def _by_id(spans):
    return {s["id"]: s for s in spans}


def _ancestors(span, idx):
    out = []
    while span["parent"]:
        span = idx[span["parent"]]
        out.append(span["name"])
    return out


# ------------------------------------------------------------ mechanics


def test_disabled_tracer_is_noop():
    tr = Tracer()
    s1 = tr.span("a")
    s2 = tr.span("b", tier="x", big=1)
    assert s1 is s2                      # shared null object, no allocs
    with s1 as s:
        assert s.span_id == 0
    tr.event("e")
    tr.add("t", "x", 0.0, 1.0)
    assert tr.add_span("u", "x", 0.0, 1.0) == 0
    assert tr.snapshot() == []


def test_nesting_and_threads():
    tr = Tracer()
    tr.configure(trace_id=9)
    with tr.span("outer", tier="t") as outer:
        with tr.span("inner", tier="t"):
            tr.event("leaf", tier="t")
        assert tr._current_id() == outer.span_id

        def other():
            with tr.span("sibling", tier="t"):
                pass

        th = threading.Thread(target=other)
        th.start()
        th.join()
    spans = {s["name"]: s for s in tr.snapshot()}
    assert spans["leaf"]["parent"] == spans["inner"]["id"]
    assert spans["inner"]["parent"] == spans["outer"]["id"]
    assert spans["outer"]["parent"] == 0
    # a thread with no open span must not inherit another thread's stack
    assert spans["sibling"]["parent"] == 0
    assert spans["sibling"]["tid"] != spans["outer"]["tid"]
    assert all(s["trace"] == 9 for s in spans.values())


def test_capacity_and_drop_counter():
    tr = Tracer(capacity=4)
    tr.configure(trace_id=1)
    for i in range(7):
        tr.event(f"e{i}")
    assert len(tr.snapshot()) == 4
    assert tr.dropped == 3
    assert [s["name"] for s in tr.snapshot()] == ["e3", "e4", "e5", "e6"]


def test_phase_tagging():
    tr = Tracer()
    tr.configure(trace_id=1)
    tr.set_phase("warm")
    tr.event("a")
    tr.set_phase(None)
    tr.event("b")
    a, b = tr.snapshot()
    assert a["attrs"]["phase"] == "warm" and "phase" not in b["attrs"]


# ------------------------------------------------------------ tree shape


def test_span_tree_through_search_server(pds):
    data, queries = pds
    TRACER.configure(trace_id=5)
    eng = DHNSWEngine(EngineConfig(**CFG)).build(data)
    with SearchServer(eng, BatchPolicy(max_batch=8, max_wait_s=1e-3)) as srv:
        srv.search(queries[:2], k=5)
    spans = TRACER.snapshot()
    idx = _by_id(spans)
    verbs = [s for s in spans if s["tier"] == "pool"
             and s["name"] == "pool.read_spans"]
    assert verbs, [s["name"] for s in spans]
    chain = _ancestors(verbs[-1], idx)
    # pool verb -> fetch -> round -> client search -> engine facade ->
    # serve dispatch -> serve window
    for name in ("compute.fetch", "compute.round", "compute.search",
                 "serve.dispatch", "serve.window"):
        assert name in chain, (name, chain)
    queue = [s for s in spans if s["name"] == "serve.queue"]
    assert queue and all(s["tier"] == "serve" for s in queue)


# ------------------------------------------------------------ wire


def test_trace_flag_roundtrip_loopback(pds):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        TRACER.configure(trace_id=21)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        eng.search(queries[:4], k=5)
        pool = eng.pool
        assert pool._server_trace is True     # PING capability ack
        n = pool.harvest_trace()
        assert n > 0
        spans = TRACER.snapshot()
        idx = _by_id(spans)
        server_spans = [s for s in spans if s["tier"] == "server"]
        assert len(server_spans) == n
        for s in server_spans:
            parent = idx[s["parent"]]
            assert parent["tier"] == "net"
            assert parent["name"] == "net." + s["name"][len("server."):]
            # client-side verb span covers the server service time
            assert parent["dur"] >= s["dur"] - 1e-9
            # re-based inside the parent on the client clock
            assert parent["t0"] - 1e-9 <= s["t0"]
            assert s["t0"] + s["dur"] <= parent["t0"] + parent["dur"] + 1e-9
            assert s["attrs"]["clock"] == "server"
        # drained: a second harvest only sees the previous harvest's own
        # traced STATS drain request, never a verb span twice
        n_before = len([s for s in TRACER.snapshot()
                        if s["tier"] == "server"])
        pool.harvest_trace()
        fresh = [s for s in TRACER.snapshot()
                 if s["tier"] == "server"][n_before:]
        assert all(s["name"] == "server.stats" for s in fresh)
        pool.close()
    finally:
        TRACER.disable()
        srv.stop()


def test_old_server_never_sent_trace_bytes(pds):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        d0, g0, s0 = eng.search(queries[:4], k=5)
        eng.pool.close()

        TRACER.configure(trace_id=33)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        # simulate an old server: the PING ack never arrived, so the
        # client must not prefix trace context onto any frame
        eng.pool._server_trace = False
        d1, g1, s1 = eng.search(queries[:4], k=5)
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
        assert np.array_equal(np.asarray(g0), np.asarray(g1))
        assert s0["net"]["bytes"] == s1["net"]["bytes"]
        assert eng.pool.harvest_trace() == 0
        assert not any(s["tier"] == "server" for s in TRACER.snapshot())
        eng.pool.close()
    finally:
        TRACER.disable()
        srv.stop()


# ------------------------------------------------------------ free-ness


def _run_combo(data, queries, pool_kind, quant, endpoints=None):
    kw = dict(CFG, pool=pool_kind, quant=quant)
    if pool_kind == "sharded":
        kw["n_shards"] = 2
    if pool_kind == "remote":
        kw["endpoints"] = endpoints
    eng = DHNSWEngine(EngineConfig(**kw)).build(data)
    d, g, st = eng.search(queries, k=5)
    out = (np.asarray(d).copy(), np.asarray(g).copy(), dict(st["net"]))
    if pool_kind == "remote":
        eng.pool.close()
    return out


@pytest.mark.parametrize("pool_kind", ["local", "sim_rdma", "sharded",
                                       "remote"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_tracing_off_vs_on_bit_identical(pds, pool_kind, quant):
    data, queries = pds
    srv = None
    endpoints = None
    if pool_kind == "remote":
        srv = PoolServer()
        srv.start()
        endpoints = (srv.endpoint,)
    try:
        TRACER.disable()
        d0, g0, net0 = _run_combo(data, queries[:6], pool_kind, quant,
                                  endpoints)
        TRACER.configure(trace_id=11)
        d1, g1, net1 = _run_combo(data, queries[:6], pool_kind, quant,
                                  endpoints)
        assert len(TRACER.snapshot()) > 0
        assert np.array_equal(d0, d1)
        assert np.array_equal(g0, g1)
        assert net0 == net1      # ledger parity: tracing charges nothing
    finally:
        TRACER.disable()
        if srv is not None:
            srv.stop()


# ------------------------------------------------------------ exporters


def test_chrome_trace_round_trip(tmp_path):
    tr = Tracer()
    tr.configure(trace_id=3)
    with tr.span("a", tier="serve", rows=2):
        tr.event("b", tier="pool", bytes=4096.0)
    path = tmp_path / "t.json"
    assert tr.save(path) == 2
    spans = load_trace(path)
    orig = tr.snapshot()
    assert [s["name"] for s in spans] == [s["name"] for s in orig]
    assert spans[1]["attrs"]["rows"] == 2
    assert spans[0]["parent"] == spans[1]["id"]
    for a, b in zip(spans, orig):
        assert a["trace"] == b["trace"] == 3
        assert abs(a["dur"] - b["dur"]) < 1e-6
    blob = chrome_trace(orig)
    assert all(ev["ph"] == "X" for ev in blob["traceEvents"])


def test_report_names_dominant_stage(tmp_path, capsys):
    tr = Tracer()
    tr.configure(trace_id=7)
    for phase, slow in (("serial", 0.010), ("batched", 0.002)):
        tr.set_phase(phase)
        with tr.span(report.REQUEST_SPAN, tier="bench"):
            tr.add("stage.slow", "compute", 0.0, slow)
            tr.add("stage.fast", "compute", 0.0, 0.001)
    path = tmp_path / "t.json"
    tr.save(path)
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "dominant stage" in out
    # the gap table must name the stage whose per-request self time
    # moved, not merely the biggest absolute stage
    assert "batched-vs-serial gap" in out
    assert "stage.slow" in out


def test_prometheus_renderers(pds):
    data, queries = pds
    TRACER.configure(trace_id=13)
    eng = DHNSWEngine(EngineConfig(**CFG)).build(data)
    with SearchServer(eng, BatchPolicy(max_batch=8, max_wait_s=1e-3)) as srv:
        srv.search(queries[:2], k=5)
        txt = srv.metrics_text()
    assert "# TYPE repro_serve_requests_total counter" in txt
    assert "repro_serve_requests_total 1" in txt
    assert "repro_span_seconds_bucket" in txt
    assert 'repro_pool_verbs_total{verb="read_spans"}' in txt
    assert "repro_cache_hit_ratio" in txt
    # every exposition line parses: "name{...} value" with float value
    for line in txt.strip().splitlines():
        if line.startswith("#"):
            continue
        float(line.rsplit(" ", 1)[1])
    pool_txt = render_pool_server({"verbs": {"read_rows": 3},
                                   "service_s": {"read_rows": 0.5},
                                   "payload_rx": 10, "payload_tx": 20,
                                   "uptime_s": 1.5})
    assert 'repro_poolserver_verbs_total{verb="read_rows"} 3' in pool_txt
    assert 'repro_poolserver_payload_bytes_total{dir="rx"} 10' in pool_txt
    # renderers work with tracing off too (no histogram section)
    TRACER.disable()
    off = render_prometheus({"n_requests": 0})
    assert "repro_span_seconds" not in off


def test_dump_trace_harvests_remote(pds, tmp_path):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        TRACER.configure(trace_id=17)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        with SearchServer(eng, BatchPolicy(max_batch=8,
                                           max_wait_s=1e-3)) as ss:
            ss.search(queries[:2], k=5)
            path = tmp_path / "trace.json"
            n = ss.dump_trace(path)
        spans = load_trace(path)
        assert len(spans) == n
        assert any(s["tier"] == "server" for s in spans)
        eng.pool.close()
    finally:
        TRACER.disable()
        srv.stop()


# ------------------------------------------------------------ determinism


def test_counted_pass_deterministic(sift_small):
    """Back-to-back counted passes must emit identical gated metrics —
    the contract benchmarks/perf_gate.py's serving gate stands on."""
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    try:
        import serving
    finally:
        sys.path.pop(0)
    data, queries = sift_small.data[:1200], sift_small.queries[:16]
    a = serving.counted_pass("full", data, queries, n_rep=12, C=3, k=5,
                             waves=2, seed=0)
    b = serving.counted_pass("full", data, queries, n_rep=12, C=3, k=5,
                             waves=2, seed=0)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    fused = {r["impl"]: r["mean_fused_batch"] for r in a}
    assert fused == {"serial": 1.0, "batched": 3.0}
